"""The backend surface has ONE op count — ``backend.N_OPS`` — and every
consumer derives from it: the op registries, both backend classes, the
mechanism coverage maps, and the counts quoted in README.md / DESIGN.md
(which used to hard-code "fifteen" and drifted the moment op sixteen
landed).  This module pins all of them together."""
import re

from repro.core import backend as kb
from repro.core import types as t


def test_n_ops_is_the_surface():
    assert kb.N_OPS == len(kb.SURFACE_OPS) == 16
    assert len(set(kb.SURFACE_OPS)) == kb.N_OPS
    assert "iterate_validate" in kb.SURFACE_OPS


def test_backends_implement_every_surface_op():
    for cls in (kb.JnpBackend, kb.PallasBackend):
        missing = [op for op in kb.SURFACE_OPS if not callable(
            getattr(cls, op, None))]
        assert not missing, (cls.__name__, missing)


def test_registries_subset_surface():
    surface = set(kb.SURFACE_OPS)
    for cc, ops in kb.CC_OPS.items():
        assert set(ops) <= surface, t.CC_NAMES.get(cc, cc)
    for ops in (kb.DIST_OPS, kb.DIST_MV_OPS, kb.DIST_MVOCC_OPS):
        assert set(ops) <= surface


def test_iterate_validate_coverage_policy():
    """Every mechanism validates scans EXCEPT mvcc (snapshot isolation
    admits phantoms — the negative control), locally and distributed."""
    for cc, ops in kb.CC_OPS.items():
        name = t.CC_NAMES.get(cc, cc)
        if name == "mvcc":
            assert "iterate_validate" not in ops
        else:
            assert "iterate_validate" in ops, name
    assert "iterate_validate" in kb.DIST_OPS
    assert "iterate_validate" in kb.DIST_MVOCC_OPS
    assert "iterate_validate" not in kb.DIST_MV_OPS


def test_docs_quote_the_real_op_count():
    """README.md and DESIGN.md cite the op count as ``N_OPS (= <n>)``;
    every citation must match kb.N_OPS so docs can't silently drift when
    op seventeen lands."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    for doc in ("README.md", "DESIGN.md"):
        text = (root / doc).read_text()
        counts = re.findall(r"N_OPS.{0,3}\(=\s*(\d+)\)", text)
        assert counts, f"{doc} no longer cites backend.N_OPS"
        assert all(int(c) == kb.N_OPS for c in counts), (doc, counts)


def test_dashboard_cause_order_tracks_taxonomy():
    """perf_dashboard renders abort causes in taxonomy order; adding a
    cause (as CAUSE_PHANTOM did) must extend the dashboard too."""
    from benchmarks.perf_dashboard import _CAUSE_ORDER
    assert tuple(_CAUSE_ORDER) == tuple(
        t.CAUSE_NAMES[i] for i in range(t.N_ABORT_CAUSES))
