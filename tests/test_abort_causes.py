"""Abort-cause taxonomy (core/types.py ABORT_CAUSE): the conservation
invariant — per-cause counts sum EXACTLY to total aborts — for every
mechanism x granularity x backend, locally and through the distributed
stats vector at pipeline depths 1 and 2, plus the per-mechanism cause
semantics and the open-loop incarnation-cap reclassification identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import distributed as D
from repro.core import types as t
from repro.core.engine import hot_records, run, sweep
from repro.core.types import EngineConfig
from repro.workloads import PoissonArrivals, YCSBWorkload

# Small but contended so every mechanism actually aborts.
WL = YCSBWorkload.make(n_keys=64, theta=0.9)
ALL_CCS = sorted(t.CC_NAMES)


def _cfg(cc, gran=1, backend="jnp", lanes=16, mv_depth=3, **kw):
    # mv_depth stays set even for single-version ccs: sweep() derives the
    # MV mechanisms' configs from the base one.
    return EngineConfig(
        cc=cc, lanes=lanes, slots=WL.slots, n_records=WL.n_records,
        n_groups=WL.n_groups, n_cols=WL.n_cols, n_txn_types=WL.n_txn_types,
        granularity=gran, n_rings=WL.n_rings, backend=backend,
        mv_depth=mv_depth, **kw)


# ------------------------------------------------------- local engine
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
def test_local_conservation_every_mechanism(gran, backend):
    """Acceptance criterion: sum(per-cause) == aborts for every mechanism
    at both granularities on both backends, via the vmapped sweep."""
    pts = sweep(_cfg(t.CC_OCC, gran, backend), WL, 12, ccs=ALL_CCS,
                grans=(gran,), lane_counts=(16,))
    assert len(pts) == len(ALL_CCS)
    for p in pts:
        assert p.abort_causes is not None
        assert all(c >= 0 for c in p.abort_causes)
        assert sum(p.abort_causes) == p.aborts, \
            (t.CC_NAMES[p.cc], p.abort_causes, p.aborts)
        assert p.aborts > 0, t.CC_NAMES[p.cc]   # contended: causes real


def test_per_wave_causes_sum_to_totals():
    """per_wave=True decomposes the same totals wave by wave: each wave's
    cause row sums to that wave's aborts, and the rows sum to the point's
    abort_causes."""
    (p,) = sweep(_cfg(t.CC_OCC), WL, 10, ccs=[t.CC_OCC], grans=(1,),
                 lane_counts=(16,), per_wave=True)
    pw_causes = np.asarray(p.per_wave_causes)
    pw_aborts = np.asarray(p.per_wave_aborts)
    np.testing.assert_array_equal(pw_causes.sum(axis=1), pw_aborts)
    np.testing.assert_array_equal(pw_causes.sum(axis=0),
                                  np.asarray(p.abort_causes))


def test_cause_semantics_per_mechanism():
    """Which causes each mechanism can emit, closed-loop: occ/tictoc
    aborts are read validation; 2pl aborts are wound locks; the
    multi-version pair aborts on stale snapshots and write-write
    first-committer-wins, never read validation (mvcc)."""
    pts = {t.CC_NAMES[p.cc]: p
           for p in sweep(_cfg(t.CC_OCC), WL, 12, ccs=ALL_CCS, grans=(1,),
                          lane_counts=(16,))}
    for name in ("occ", "tictoc"):
        c = pts[name].abort_causes
        assert c[t.CAUSE_READ_VAL] == pts[name].aborts, (name, c)
    c = pts["2pl"].abort_causes
    assert c[t.CAUSE_LOCK_WOUND] == pts["2pl"].aborts, c
    c = pts["mvcc"].abort_causes
    assert (c[t.CAUSE_STALE_SNAPSHOT] + c[t.CAUSE_WW]
            == pts["mvcc"].aborts), c
    assert c[t.CAUSE_READ_VAL] == 0, c


def test_run_carries_causes_and_hot_records():
    """run() returns the same invariant plus the top-k conflict histogram
    (track_conflicts): hot records sorted by descending conflict count,
    every entry a real record id with a positive count."""
    res = run(_cfg(t.CC_OCC, track_conflicts=True), WL, 12, seed=2)
    assert sum(res.abort_causes) == res.aborts > 0
    assert res.per_wave_causes is not None
    assert res.hot_records, "contended run must surface hot records"
    counts = [hits for _, _, hits, _ in res.hot_records]
    assert counts == sorted(counts, reverse=True)
    assert all(hits >= peak > 0
               for _, _, hits, peak in res.hot_records)
    assert all(0 <= rec < WL.n_records and 0 <= grp < WL.n_groups
               for rec, grp, _, _ in res.hot_records)


def test_open_loop_inc_cap_identity_local():
    """Open loop, depth-1 semantics: a terminal abort (incarnation cap)
    reclassifies to CAUSE_INC_CAP, so causes[INC_CAP] == inc_drops
    exactly, and the conservation sum still holds over ALL aborts."""
    res = run(_cfg(t.CC_OCC, arrival_rate=16.0, queue_cap=64,
                   max_incarnations=2, lat_bins=16), WL, 25, seed=3)
    assert res.inc_drops > 0
    assert res.abort_causes[t.CAUSE_INC_CAP] == res.inc_drops
    assert sum(res.abort_causes) == res.aborts


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16), max_inc=st.integers(0, 3))
def test_property_causes_partition_aborts(seed, max_inc):
    """Property (any seed / incarnation cap): each cause count is bounded
    by total aborts and the counts partition them exactly."""
    res = run(_cfg(t.CC_OCC, arrival_rate=12.0, queue_cap=48,
                   max_incarnations=max_inc, lat_bins=8), WL, 15,
              seed=seed)
    assert all(0 <= c <= res.aborts for c in res.abort_causes)
    assert sum(res.abort_causes) == res.aborts
    assert res.abort_causes[t.CAUSE_INC_CAP] == res.inc_drops


# ------------------------------------------------- distributed engine
def _dist_inputs(rng, waves, T, K, N):
    keys = jnp.asarray(rng.integers(0, N, (waves, T, K), dtype=np.int32))
    groups = jnp.asarray(rng.integers(0, 2, (waves, T, K),
                                      dtype=np.int32))
    kinds = jnp.asarray(rng.choice([t.READ, t.WRITE],
                                   (waves, T, K)).astype(np.int32))
    prio = jnp.asarray(np.stack(
        [np.random.default_rng(w).permutation(T)
         for w in range(waves)]).astype(np.uint32))
    return keys, groups, kinds, prio


def _dist_stats(cc, backend, depth, waves=8):
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ns = len(jax.devices())
    N, T, K = 128, 8, 4
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T,
                       slots=K, cc=cc, backend=backend,
                       mv_depth=3 if cc != "occ" else 0,
                       pipeline_depth=depth, route_cap=2 * K)
    keys, groups, kinds, prio = _dist_inputs(
        np.random.default_rng(0), waves, ns * T, K, N)
    run_fn = jax.jit(D.make_run_fn(cfg, mesh, waves))
    _, _, stats = run_fn(keys, groups, kinds, prio,
                         D.init_tables(cfg, mesh), jnp.uint32(0))
    return np.asarray(stats).reshape(waves, ns, D.STATS_LEN).astype(
        np.int64)


@pytest.mark.parametrize("cc", ["occ", "mvcc", "mvocc"])
def test_distributed_conservation_and_depth_parity(cc):
    """Acceptance criterion: the stats vector's per-cause slots sum to
    its abort slot PER WAVE PER SHARD, and the software-pipelined wave
    (depth 2) reports bit-identical per-cause totals to the synchronous
    one (depth 1)."""
    s1 = _dist_stats(cc, "jnp", 1)
    np.testing.assert_array_equal(
        s1[:, :, D.STAT_CAUSES].sum(axis=2), s1[:, :, D.STAT_ABORTS])
    assert s1[:, :, D.STAT_ABORTS].sum() > 0, "contended: causes real"
    # capacity drops are forced by the small route_cap and classified
    assert s1[:, :, D.STAT_CAUSE0 + t.CAUSE_CAPACITY].sum() > 0
    s2 = _dist_stats(cc, "jnp", 2)
    np.testing.assert_array_equal(
        s2[:, :, D.STAT_CAUSES].sum(axis=2), s2[:, :, D.STAT_ABORTS])
    np.testing.assert_array_equal(
        s1[:, :, D.STAT_CAUSES].sum(axis=(0, 1)),
        s2[:, :, D.STAT_CAUSES].sum(axis=(0, 1)))


def test_distributed_backend_parity_on_causes():
    """jnp and pallas(interpret) report identical per-cause counts."""
    a = _dist_stats("occ", "jnp", 1, waves=4)
    b = _dist_stats("occ", "pallas", 1, waves=4)
    np.testing.assert_array_equal(a[:, :, D.STAT_CAUSES],
                                  b[:, :, D.STAT_CAUSES])


def _dist_gen(n_total, K, N, seed_base=900):
    # Mixed reads+writes on a tiny keyspace: OCC aborts are READ
    # validation (blind writes never abort), and the contention is high
    # enough that retries hit the incarnation cap even on a single-device
    # mesh.
    def gen(w):
        rng = np.random.default_rng(seed_base + w)
        return (jnp.asarray(rng.integers(0, N, (n_total, K),
                                         dtype=np.int32)),
                jnp.asarray(rng.integers(0, 2, (n_total, K),
                                         dtype=np.int32)),
                jnp.asarray(rng.choice([t.READ, t.WRITE],
                                       (n_total, K)).astype(np.int32)),
                jnp.asarray(rng.permutation(n_total).astype(np.uint32)))
    return gen


@pytest.mark.parametrize("depth", [1, 2])
def test_distributed_open_loop_causes(depth):
    """Open loop through the sharded admission rings: conservation stays
    exact at both depths; causes[INC_CAP] == inc_drops exactly at depth 1,
    and bounded above by it when retries pipeline (a ring-overflow-
    rejected retry keeps its validation cause, core/distributed.py)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ns = len(jax.devices())
    N, T, K = 16, 8, 4
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T,
                       slots=K, cc="occ", pipeline_depth=depth,
                       queue_cap=24, max_incarnations=1, lat_bins=8)
    arr = PoissonArrivals(rate=0.9 * ns * T, seed=5).shard_counts(
        18, ns, T)
    s = D.run_open_loop(cfg, mesh, arr, _dist_gen(ns * T, K, N), 18)
    assert sum(s["abort_causes"]) == s["aborts"]
    assert s["inc_drops"] > 0
    if cfg.depth(ns) == 1:
        assert s["abort_causes"][t.CAUSE_INC_CAP] == s["inc_drops"]
    else:
        assert s["abort_causes"][t.CAUSE_INC_CAP] <= s["inc_drops"]


# ------------------------------------------------------------ the enum
def test_cause_enum_is_closed():
    """CAUSE_NAMES covers exactly the N_ABORT_CAUSES codes, CAUSE_NONE
    sits one past the end (the scatter-drop index cause_counts relies
    on), and cause_counts drops it exactly."""
    assert sorted(t.CAUSE_NAMES) == list(range(t.N_ABORT_CAUSES))
    assert t.CAUSE_NONE == t.N_ABORT_CAUSES
    lane_cause = jnp.asarray([t.CAUSE_WW, t.CAUSE_NONE, t.CAUSE_WW,
                              t.CAUSE_READ_VAL], jnp.int32)
    aborted = jnp.asarray([True, False, True, True])
    got = np.asarray(t.cause_counts(lane_cause, aborted))
    want = np.zeros(t.N_ABORT_CAUSES, np.int32)
    want[t.CAUSE_WW], want[t.CAUSE_READ_VAL] = 2, 1
    np.testing.assert_array_equal(got, want)
