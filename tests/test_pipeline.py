"""Software-pipelined distributed waves (DESIGN.md section 10): depth-1
vs depth->=2 bit-identity across mechanisms / granularities / backends,
the bit-packed verdict wire (verdict_pack/verdict_unpack), the ONE-fused-
exchange guarantee (AST + HLO guards), the 2-D axiswise exchange
factoring, open-loop conservation at every depth, and the pipeline knobs'
validation.

In-process tests build their mesh over every available host device (8
under the CI XLA_FLAGS, else 1 — where ``pipeline_depth`` auto-falls back
to the synchronous wave, so the identity checks stay meaningful but
trivial); the subprocess tests force 8 devices regardless.
"""
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import distributed as D
from repro.core import types as t
from repro.kernels import ops, ref


def _full_mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _stacked_batch(rng, n_waves, T, K, N):
    keys = jnp.asarray(rng.integers(0, N, (n_waves, T, K), dtype=np.int32))
    groups = jnp.asarray(rng.integers(0, 2, (n_waves, T, K),
                                      dtype=np.int32))
    kinds = jnp.asarray(rng.choice([t.READ, t.WRITE, t.ADD, t.NOP],
                                   (n_waves, T, K)).astype(np.int32))
    prio = jnp.asarray(np.stack([rng.permutation(T)
                                 for _ in range(n_waves)]).astype(np.uint32))
    return keys, groups, kinds, prio


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------ verdict_pack / unpack
def test_verdict_pack_oracle_roundtrip_and_4x_reduction():
    """The wire layout: op j's 2 bits land at bits 2*(j%16)(+1) of word
    j//16; unpack inverts exactly; for 16-aligned rows the int32 words
    carry exactly 1/4 the bytes of the old 1-int8-per-op scheme."""
    rng = np.random.default_rng(0)
    for D_, M in ((1, 8), (4, 16), (8, 40), (3, 48), (2, 256)):
        v = jnp.asarray(rng.integers(0, 4, (D_, M)).astype(np.int8))
        words = ref.verdict_pack(v)
        assert words.shape == (D_, -(-M // 16)) and words.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(ref.verdict_unpack(words, M)),
                                      np.asarray(v))
        # spot-check the interleaved layout itself, not just the roundtrip
        w = np.asarray(words)
        vv = np.asarray(v).astype(np.int32)
        for j in (0, M // 2, M - 1):
            np.testing.assert_array_equal(
                (w[:, j // 16] >> (2 * (j % 16))) & 3, vv[:, j] & 3)
        if M % 16 == 0:
            # int32 words carry 4 bytes each; int8 verdicts carried 1
            assert words.size * 4 * 4 == v.size  # exactly 4x fewer bytes
    assert D.verdict_words(16) == 1 and D.verdict_words(17) == 2


def test_verdict_pack_pallas_parity():
    """kernels/verdict_pack.py == the jnp oracle, bit for bit, over shape
    sweeps (the same discipline as the other thirteen backend ops)."""
    rng = np.random.default_rng(1)
    for D_, M in ((1, 8), (4, 16), (8, 40), (3, 48)):
        v = jnp.asarray(rng.integers(0, 4, (D_, M)).astype(np.int8))
        np.testing.assert_array_equal(
            np.asarray(ops.verdict_pack(v, use_pallas=True)),
            np.asarray(ref.verdict_pack(v)))
        words = ref.verdict_pack(v)
        np.testing.assert_array_equal(
            np.asarray(ops.verdict_unpack(words, M, use_pallas=True)),
            np.asarray(ref.verdict_unpack(words, M)))


def test_backend_surface_has_verdict_ops():
    """Both backend surfaces expose the op pair and list it in the
    distributed coverage maps."""
    from repro.core import backend as kb
    assert "verdict_pack" in kb.DIST_OPS
    assert "verdict_unpack" in kb.DIST_MV_OPS
    v = jnp.asarray(np.array([[1, 2, 3, 0, 1, 0, 2, 3]], np.int8))
    for b in ("jnp", "pallas"):
        be = kb.resolve(D.DistConfig(n_records=64, backend=b))
        w = be.verdict_pack(v)
        np.testing.assert_array_equal(np.asarray(be.verdict_unpack(w, 8)),
                                      np.asarray(v))


# --------------------------------------------- pipelined scan bit-identity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", ["occ", "mvcc", "mvocc"])
def test_pipeline_depth_bit_identity(cc, gran, backend):
    """ISSUE acceptance criterion: the software-pipelined scan (depth 2)
    returns bit-identical commit masks, ALL tables, and the full stats
    vector vs depth 1 — per cc × granularity × backend, over every host
    device (8 in CI)."""
    mesh = _full_mesh()
    NW, Tl, K, N = 5, 8, 6, 512
    ns = D.n_shards(mesh)
    rng = np.random.default_rng(7)
    keys, groups, kinds, prio = _stacked_batch(rng, NW, ns * Tl, K, N)
    outs = {}
    for depth in (1, 2):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=gran, backend=backend,
                           cc=cc, mv_depth=4 if cc != "occ" else 0,
                           pipeline_depth=depth)
        run = jax.jit(D.make_run_fn(cfg, mesh, NW))
        outs[depth] = run(keys, groups, kinds, prio,
                          D.init_tables(cfg, mesh), jnp.uint32(0))
    _assert_trees_equal(outs[1], outs[2])
    commit = np.asarray(outs[1][0])
    assert commit.shape == (NW, ns * Tl)
    assert commit.sum() > 0


def test_depth1_scan_matches_wave_fn_loop():
    """The depth-1 scanned runner is the synchronous make_wave_fn loop,
    wave for wave (commit, stats) and in the final tables."""
    mesh = _full_mesh()
    NW, Tl, K, N = 4, 8, 6, 256
    ns = D.n_shards(mesh)
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                       slots=K, cc="mvcc", mv_depth=4)
    rng = np.random.default_rng(9)
    keys, groups, kinds, prio = _stacked_batch(rng, NW, ns * Tl, K, N)
    run = jax.jit(D.make_run_fn(cfg, mesh, NW))
    c_run, t_run, s_run = run(keys, groups, kinds, prio,
                              D.init_tables(cfg, mesh), jnp.uint32(0))
    wave = jax.jit(D.make_wave_fn(cfg, mesh))
    tables = D.init_tables(cfg, mesh)
    cs, ss = [], []
    for w in range(NW):
        c, tables, s = wave(keys[w], groups[w], kinds[w], prio[w], tables,
                            jnp.uint32(w))
        cs.append(np.asarray(c))
        ss.append(np.asarray(s))
    np.testing.assert_array_equal(np.stack(cs), np.asarray(c_run))
    np.testing.assert_array_equal(np.stack(ss), np.asarray(s_run))
    _assert_trees_equal(tables, t_run)


def test_pipeline_8dev_subprocess_and_hlo_exchange_count():
    """8 forced host devices: depth 2 == depth 1 (commits, tables, stats)
    for occ and mvocc on both backends and both topologies, AND the
    compiled steady-state wave body issues exactly ONE all-to-all at
    depth 2 (vs three at depth 1) — counted in the scan-loop HLO."""
    prog = textwrap.dedent("""
        import os, re
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed as D
        from repro.core import types as t

        NW, Tl, K, N = 5, 8, 6, 512
        rng = np.random.default_rng(3)

        def hlo_a2a_count(run, args):
            # count op DEFS ("... = (...) all-to-all(operands)"), not the
            # get-tuple-element lines that reference %all-to-all.N
            txt = jax.jit(run).lower(*args).compile().as_text()
            return len(re.findall(r"\\ball-to-all\\(", txt))

        for shape, axes, topo in ((( 8,), ("data",), "flat"),
                                  ((4, 2), ("pod", "data"), "axiswise")):
            mesh = jax.make_mesh(shape, axes)
            ns = D.n_shards(mesh)
            T = ns * Tl
            keys = jnp.asarray(
                rng.integers(0, N, (NW, T, K), dtype=np.int32))
            groups = jnp.asarray(
                rng.integers(0, 2, (NW, T, K), dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE], (NW, T, K)).astype(np.int32))
            prio = jnp.asarray(np.stack(
                [rng.permutation(T) for _ in range(NW)]).astype(np.uint32))
            for cc in ("occ", "mvocc"):
                for backend in ("jnp", "pallas"):
                    outs, counts = {}, {}
                    for depth in (1, 2):
                        cfg = D.DistConfig(
                            n_records=N, n_groups=2, lanes_per_shard=Tl,
                            slots=K, backend=backend, cc=cc,
                            mv_depth=4 if cc != "occ" else 0,
                            pipeline_depth=depth, topology=topo)
                        run = D.make_run_fn(cfg, mesh, NW)
                        args = (keys, groups, kinds, prio,
                                D.init_tables(cfg, mesh), jnp.uint32(0))
                        outs[depth] = jax.jit(run)(*args)
                        if backend == "jnp":
                            counts[depth] = hlo_a2a_count(run, args)
                    for a, b in zip(jax.tree.leaves(outs[1]),
                                    jax.tree.leaves(outs[2])):
                        np.testing.assert_array_equal(np.asarray(a),
                                                      np.asarray(b))
                    assert int(np.asarray(outs[1][0]).sum()) > 0
                    if counts:
                        # The whole program holds the scan loop's wave
                        # body once: one fused exchange per steady-state
                        # wave at depth 2 (per mesh axis when axiswise),
                        # three at depth 1.
                        hops = 2 if topo == "axiswise" else 1
                        assert counts[2] == 1 * hops, counts
                        assert counts[1] == 3 * hops, counts
                    print(shape, topo, cc, backend, "ok", counts)
        print("PIPELINE_8DEV_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "PIPELINE_8DEV_OK" in r.stdout, r.stdout + r.stderr


def test_single_exchange_ast_guard():
    """Enforced on the source (the pattern of the no-argsort guard): the
    ``all_to_all`` collective appears in exactly one place —
    ``_make_exchange`` — and each software-pipelined step body calls the
    ``exchange`` closure exactly once (the fused wire); the synchronous
    body keeps its documented three calls."""
    import ast
    import pathlib

    import repro.core.distributed as dist
    src = pathlib.Path(dist.__file__).read_text()
    tree = ast.parse(src)
    # Docstrings name the collectives while DOCUMENTING this very guard —
    # strip them at every level so only executable code is counted.
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.FunctionDef, ast.ClassDef)):
            b = node.body
            if (b and isinstance(b[0], ast.Expr)
                    and isinstance(b[0].value, ast.Constant)
                    and isinstance(b[0].value.value, str)):
                node.body = b[1:] or [ast.Pass()]
    code = ast.unparse(tree)
    assert code.count("all_to_all") == 1, \
        "all_to_all must stay confined to _make_exchange"

    funcs = {n.name: ast.unparse(n) for n in tree.body
             if isinstance(n, ast.FunctionDef)}
    assert "all_to_all" in funcs["_make_exchange"]
    call = re.compile(r"(?<![\w.])exchange\(")
    assert len(call.findall(funcs["_make_pipeline_step"])) == 1
    assert len(call.findall(funcs["_make_open_pipeline_step"])) == 1
    assert len(call.findall(funcs["_make_shard_body"])) == 3


# ------------------------------------------------- open loop, pipelined
def _dist_gen(n_total, K, N, seed_base=900):
    def gen(w):
        rng = np.random.default_rng(seed_base + w)
        keys = jnp.asarray(rng.integers(0, N, (n_total, K), dtype=np.int32))
        groups = jnp.asarray(rng.integers(0, 2, (n_total, K),
                                          dtype=np.int32))
        kinds = jnp.asarray(rng.choice([t.READ, t.WRITE],
                                       (n_total, K)).astype(np.int32))
        prio = jnp.asarray(rng.permutation(n_total).astype(np.uint32))
        return keys, groups, kinds, prio
    return gen


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_open_loop_conservation_at_every_depth(seed):
    """Hypothesis property (ISSUE satellite): whatever the arrival draw,
    pipeline depth NEVER changes the conservation identities — admitted ==
    commits + queued_final + inc_drops and offered == admitted +
    arrival_drops hold exactly at depth 1 AND depth 2 (where a retry
    re-enqueues two waves later and may itself overflow into inc_drops)."""
    mesh = _full_mesh()
    ns = D.n_shards(mesh)
    NW, Tl, K, N = 12, 8, 6, 128
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, Tl + 3, (NW, ns))
    sums = {}
    for depth in (1, 2):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, queue_cap=4 * Tl, max_incarnations=2,
                           pipeline_depth=depth)
        s = D.run_open_loop(cfg, mesh, arr,
                            _dist_gen(ns * Tl, K, N, seed_base=seed % 999),
                            NW)
        assert s["admitted"] == (s["commits"] + s["queued_final"]
                                 + s["inc_drops"]), (depth, s)
        assert s["offered"] == s["admitted"] + s["arrival_drops"], (depth, s)
        sums[depth] = s
    # Same traffic at both depths: the front-end admits identically.
    assert sums[1]["offered"] == sums[2]["offered"]


def test_open_loop_depth2_identical_without_retries():
    """With max_incarnations=0 no lane ever re-enters the ring, so the
    pipelined open loop's only semantic difference (retries landing two
    waves later) vanishes — every summary counter matches depth 1."""
    mesh = _full_mesh()
    ns = D.n_shards(mesh)
    NW, Tl, K, N = 10, 8, 6, 128
    rng = np.random.default_rng(5)
    arr = rng.integers(0, Tl, (NW, ns))
    sums = {}
    for depth in (1, 2):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, queue_cap=4 * Tl, max_incarnations=0,
                           pipeline_depth=depth)
        sums[depth] = D.run_open_loop(cfg, mesh, arr,
                                      _dist_gen(ns * Tl, K, N), NW)
    for k in ("commits", "aborts", "ro_commits", "ro_aborts", "offered",
              "admitted", "arrival_drops", "inc_drops", "queued_final"):
        assert sums[1][k] == sums[2][k], k
    np.testing.assert_array_equal(sums[1]["lat_hist"], sums[2]["lat_hist"])
    assert sums[1]["commits"] > 0


# ------------------------------------------------- wire-byte model
def test_wire_bytes_model_4x_verdict_reduction():
    """The modeled verdict wire beats the retired 1-byte-per-op scheme by
    exactly 4x for 16-aligned caps (>= 4x otherwise), on flat and
    axiswise topologies alike (hops scale both sides)."""
    mesh = jax.make_mesh((1,), ("data",))
    cfg = D.DistConfig(n_records=4096, lanes_per_shard=64, slots=16,
                       route_cap=32)
    w = D.wire_bytes_per_wave(cfg, mesh)
    assert w["verdict_bytes_per_wave_legacy"] \
        == 4 * w["verdict_bytes_per_wave"]
    assert w["wire_bytes_per_wave"] == (w["route_bytes_per_wave"]
                                        + w["verdict_bytes_per_wave"]
                                        + w["commit_bytes_per_wave"])
    # a non-16-aligned cap still wins >= 4x is false in general (ceil),
    # but never does worse than the fair ceil(cap/16) words
    cfg8 = D.DistConfig(n_records=64, lanes_per_shard=1, slots=8,
                        route_cap=8)
    w8 = D.wire_bytes_per_wave(cfg8, mesh)
    assert w8["verdict_bytes_per_wave"] == D.verdict_words(8) * 4


# ------------------------------------------------- knob validation
def test_pipeline_depth_validation():
    with pytest.raises(ValueError, match="pipeline_depth"):
        D.DistConfig(n_records=64, pipeline_depth=0)
    with pytest.raises(ValueError, match="pipeline_depth"):
        D.DistConfig(n_records=64, pipeline_depth=-2)


def test_pipeline_rejects_aged_snapshots():
    """Aged MV snapshots depend on the one install the pipelined gather
    has not seen yet (reclamation visibility) — depth >= 2 with
    snapshot_age > 0 must be rejected, age 0 accepted."""
    with pytest.raises(ValueError, match="snapshot_age"):
        D.DistConfig(n_records=64, cc="mvcc", mv_depth=4, snapshot_age=1,
                     pipeline_depth=2)
    D.DistConfig(n_records=64, cc="mvcc", mv_depth=4, snapshot_age=0,
                 pipeline_depth=2)            # fine
    D.DistConfig(n_records=64, cc="mvcc", mv_depth=4, snapshot_age=3,
                 pipeline_depth=1)            # fine: synchronous wave


def test_topology_validation_and_flat_fallback():
    with pytest.raises(ValueError, match="topology"):
        D.DistConfig(n_records=64, topology="ring")
    cfg = D.DistConfig(n_records=64, topology="axiswise")
    # 1-axis meshes fall back to the flat exchange (same bytes)
    mesh = jax.make_mesh((1,), ("data",))
    assert (D.wire_bytes_per_wave(cfg, mesh)
            == D.wire_bytes_per_wave(
                D.DistConfig(n_records=64, topology="flat"), mesh))


def test_one_shard_depth_auto_fallback():
    """pipeline_depth auto-falls back to 1 on a 1-shard mesh (nothing to
    overlap) — the synchronous drivers still work there, and the scanned
    runner picks the depth-1 schedule."""
    cfg = D.DistConfig(n_records=64, lanes_per_shard=4, slots=8,
                       pipeline_depth=4)
    assert cfg.depth(1) == 1 and cfg.depth(2) == 4
    mesh = jax.make_mesh((1,), ("data",))
    D.make_wave_fn(cfg, mesh)                 # no raise: effective depth 1
    rng = np.random.default_rng(2)
    keys, groups, kinds, prio = _stacked_batch(rng, 3, 4, 8, 64)
    run = jax.jit(D.make_run_fn(cfg, mesh, 3))
    c, tb, s = run(keys, groups, kinds, prio, D.init_tables(cfg, mesh),
                   jnp.uint32(0))
    assert np.asarray(c).shape == (3, 4)


def test_wave_fn_rejects_pipelined_config_on_multi_shard_mesh():
    """The one-wave-per-call drivers cannot overlap waves: a multi-shard
    mesh with effective depth >= 2 must be pointed at the scanned
    runners."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 host devices (CI runs with 8)")
    mesh = _full_mesh()
    cfg = D.DistConfig(n_records=64, lanes_per_shard=4, slots=8,
                       pipeline_depth=2)
    with pytest.raises(ValueError, match="make_run_fn"):
        D.make_wave_fn(cfg, mesh)
    ocfg = D.DistConfig(n_records=64, lanes_per_shard=4, slots=8,
                        pipeline_depth=2, queue_cap=16)
    with pytest.raises(ValueError, match="run_open_loop"):
        D.make_open_wave_fn(ocfg, mesh)


def test_open_run_fn_requires_pipelined_config():
    mesh = jax.make_mesh((1,), ("data",))
    cfg = D.DistConfig(n_records=64, lanes_per_shard=4, slots=8,
                       queue_cap=16, pipeline_depth=2)
    with pytest.raises(ValueError, match="make_open_wave_fn"):
        D.make_open_run_fn(cfg, mesh, 4)      # 1 shard: effective depth 1
    with pytest.raises(ValueError, match="queue_cap"):
        D.make_open_run_fn(
            D.DistConfig(n_records=64, lanes_per_shard=4, slots=8), mesh, 4)
