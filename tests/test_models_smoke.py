"""Per-assigned-architecture smoke tests: reduced config, one forward and
one train step on CPU, asserting shapes and finiteness; plus prefill/decode
== full-forward consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.data import make_batch
from repro.models import model as M
from repro.models import steps
from repro.models.attention import ModelCtx
from repro.optim import AdamW

ARCHS = list(configs.SMOKES)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nans(name):
    cfg = configs.get_smoke(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ctx = ModelCtx(tp=1, n_groups=1, mode="train")
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.n_patches:
        kw["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                 jnp.float32) * 0.01
    if cfg.n_frames:
        kw["frames"] = jnp.ones((B, cfg.n_frames, cfg.d_model),
                                jnp.float32) * 0.01
    fwd = jax.jit(lambda p, t, kw: M.forward(p, cfg, ctx, t, **kw)[0])
    logits = fwd(params, tokens, kw)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_reduces_loss(name, mesh):
    cfg = configs.get_smoke(name)
    S = 32 + (cfg.n_patches or 0)
    shape = ShapeSpec("t", "train", S, 4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW.from_config(cfg, peak_lr=1e-3, total_steps=8,
                            warmup_steps=1)
    opt_state = opt.init(params)
    ts = jax.jit(steps.build_train_step(cfg, mesh, opt))
    first = None
    for s in range(4):
        params, opt_state, m = ts(params, opt_state,
                                  make_batch(cfg, shape, s), jnp.int32(s))
        if first is None:
            first = float(m["loss"])
        assert np.isfinite(float(m["loss"])), name
    assert float(m["loss"]) < first, f"{name}: loss did not decrease"


@pytest.mark.parametrize("name", [
    "qwen3-32b",                    # dense + qk-norm
    "mixtral-8x22b",                # MoE + SWA rolling cache
    "rwkv6-3b",                     # attention-free state
    "recurrentgemma-9b",            # hybrid rec/attn
    "whisper-medium",               # enc-dec cross attention
    "llava-next-34b",               # patch-prefix VLM
])
def test_prefill_decode_matches_full_forward(name, mesh):
    cfg = configs.get_smoke(name)
    B, S = 2, 16
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab)
    batch = {"tokens": tokens[:, :S]}
    kw = {}
    if cfg.n_patches:
        pp = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.n_patches, cfg.d_model)) * 0.02
        batch["patches"] = kw["patches"] = pp
    if cfg.n_frames:
        ff = jax.random.normal(jax.random.PRNGKey(3),
                               (B, cfg.n_frames, cfg.d_model)) * 0.02
        batch["frames"] = kw["frames"] = ff

    S_total = S + (cfg.n_patches or 0)
    pre = jax.jit(steps.build_prefill_step(cfg, mesh, S_total + 8))
    dec = jax.jit(steps.build_decode_step(cfg, mesh))
    cache, logits_last = pre(params, batch)
    logits_dec, _ = dec(params, cache, tokens[:, S:S + 1],
                        jnp.int32(S_total))

    ctx = ModelCtx(tp=1, n_groups=1, mode="train")
    logits_full, _, _, npre = M.forward(params, cfg, ctx, tokens, **kw)
    np.testing.assert_allclose(np.asarray(logits_last),
                               np.asarray(logits_full[:, npre + S - 1]),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, npre + S]),
                               atol=2e-3, rtol=1e-3)


def test_rolling_window_cache_decode():
    """Mixtral-style SWA: decoding past the window must match a full
    forward (the rolling cache keeps exactly the last `window` keys)."""
    cfg = configs.get_smoke("mixtral-8x22b")
    assert cfg.window == 32
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    B, S, extra = 1, 40, 6          # prompt exceeds the 32-token window
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                                cfg.vocab)
    pre = jax.jit(steps.build_prefill_step(cfg, mesh, S + extra))
    dec = jax.jit(steps.build_decode_step(cfg, mesh))
    cache, logits = pre(params, {"tokens": tokens[:, :S]})
    outs = [logits]
    for i in range(extra):
        logits, cache = dec(params, cache, tokens[:, S + i:S + i + 1],
                            jnp.int32(S + i))
        outs.append(logits)
    ctx = ModelCtx(tp=1, n_groups=1, mode="train")
    full, _, _, _ = M.forward(params, cfg, ctx, tokens)
    for i, got in enumerate(outs[:-1]):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(full[:, S - 1 + i]),
                                   atol=3e-3, rtol=1e-3)


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.moe import moe_ffn
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("mixtral-8x22b"),
                              moe_cap_factor=0.5)   # force drops
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    p = params["stages"][0]["0"]["ffn"]
    p0 = jax.tree.map(lambda x: x[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = jax.jit(lambda p, x: moe_ffn(p, x, cfg, 1))(p0, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_param_counts_match_closed_form():
    """The roofline's closed-form parameter count must track the real
    (abstract) parameter tree of the FULL configs (norm scales/biases and
    lerp vectors are the only untracked terms — sub-1% at scale)."""
    for name in ARCHS:
        cfg = configs.get(name)
        abstract = M.abstract_params(cfg)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract))
        want = cfg.param_count(padded=True)
        assert abs(n - want) / max(want, 1) < 0.01, \
            f"{name}: {n} vs closed-form {want}"
