"""The sharded multi-version wave (ISSUE 5 tentpole): mvcc/mvocc routed
through core/distributed.py with the version ring sharded alongside the
claim tables.

Acceptance criteria covered here:
- 1-shard distributed mvcc/mvocc is bit-identical to the local engine
  (commit masks AND every table: claim_w, claim_r, mv_begin, mv_head), at
  both granularities, on both backends, across multiple waves;
- multi-shard jnp vs pallas is bit-identical, with and without capacity
  overflow, at both granularities;
- snapshot_age > 0 runs demonstrate nonzero reclamation aborts with zero
  garbage reads (reader verdicts match the ref.mv_gather oracle exactly).

Like tests/test_distributed.py, the in-process tests mesh over every host
device (8 under the CI XLA_FLAGS); the subprocess test forces 8.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import mvstore
from repro.core import types as t
from repro.core.cc import mvcc, mvocc
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init
from repro.kernels import ref

EXACT = CostModel(opt_overlap=1.0, phase_overlap=1.0)
MV_MODS = {"mvcc": (mvcc, t.CC_MVCC), "mvocc": (mvocc, t.CC_MVOCC)}


def _batch(rng, T, K, N, with_nops=False):
    """Mixed op kinds including ADD, so the plain-write claim channel
    (claim_r — the ADD-commutes rule) is exercised."""
    keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
    kinds = [t.READ, t.WRITE, t.ADD] + ([t.NOP] if with_nops else [])
    kinds = jnp.asarray(rng.choice(kinds, (T, K)).astype(np.int32))
    return keys, groups, kinds


def _txn_batch(keys, groups, kinds):
    T, K = keys.shape
    return TxnBatch(op_key=keys, op_group=groups,
                    op_col=jnp.zeros_like(keys), op_kind=kinds,
                    op_val=jnp.zeros(keys.shape, jnp.float32),
                    txn_type=jnp.zeros((T,), jnp.int32),
                    n_ops=jnp.full((T,), K, jnp.int32))


def _full_mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


# --------------------------------------------------- local-engine parity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", ["mvcc", "mvocc"])
def test_single_shard_parity_with_local_mv(cc, gran, backend):
    """Acceptance criterion: across several waves, the 1-shard routed MV
    wave commits exactly the local mechanism's lanes AND leaves bit-
    identical state — both claim channels and the whole version ring."""
    mod, ccid = MV_MODS[cc]
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K, depth = 96, 12, 6, 3
    dcfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                        granularity=gran, backend=backend, cc=cc,
                        mv_depth=depth)
    ecfg = EngineConfig(cc=ccid, lanes=T, slots=K, n_records=N, n_groups=2,
                        n_cols=0, n_txn_types=1, granularity=gran,
                        mv_depth=depth, backend=backend, cost=EXACT)
    wave_fn = jax.jit(D.make_wave_fn(dcfg, mesh))
    local_fn = jax.jit(mod.wave_validate, static_argnums=(4,))
    tables = D.init_tables(dcfg, mesh)
    store = store_init(N, 2, 0, mv_depth=depth)
    rng = np.random.default_rng(5)
    for w in range(4):
        keys, groups, kinds = _batch(rng, T, K, N)
        prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
        commit, tables, stats = wave_fn(keys, groups, kinds, prio, tables,
                                        jnp.uint32(w))
        store, res = local_fn(store, _txn_batch(keys, groups, kinds), prio,
                              jnp.uint32(w), ecfg)
        np.testing.assert_array_equal(np.asarray(commit),
                                      np.asarray(res.commit))
        claim_w, claim_r, mv_begin, mv_head = tables
        np.testing.assert_array_equal(np.asarray(claim_w),
                                      np.asarray(store.claim_w))
        np.testing.assert_array_equal(np.asarray(claim_r),
                                      np.asarray(store.claim_r))
        np.testing.assert_array_equal(np.asarray(mv_begin),
                                      np.asarray(store.mv_begin))
        np.testing.assert_array_equal(np.asarray(mv_head),
                                      np.asarray(store.mv_head))
        s = np.asarray(stats)
        assert s[D.STAT_COMMITS] == np.asarray(res.commit).sum()


def test_mvocc_readonly_lanes_exempt_from_read_validation():
    """The read-validation bit only bites update lanes: the same conflicted
    read aborts a lane that also writes but not a pure reader — the local
    mvocc rule, reproduced over the wire (the sender applies the has-write
    mask; it never travels)."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 16, 3, 2
    # lane 0: pure reader of record 0; lane 1: reader of record 0 that also
    # writes record 5; lane 2: strongest-prio writer of record 0.
    keys = jnp.asarray([[0, -1], [0, 5], [0, -1]], jnp.int32)
    groups = jnp.zeros((T, K), jnp.int32)
    kinds = jnp.asarray([[t.READ, t.NOP], [t.READ, t.WRITE],
                         [t.WRITE, t.NOP]], jnp.int32)
    prio = jnp.asarray([2, 1, 0], jnp.uint32)
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       cc="mvocc", mv_depth=3)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    commit, _, stats = wave_fn(keys, groups, kinds, prio,
                               D.init_tables(cfg, mesh), jnp.uint32(0))
    assert list(np.asarray(commit)) == [True, False, True]
    # and mvcc (snapshot isolation) commits all three
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       cc="mvcc", mv_depth=3)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    commit, _, _ = wave_fn(keys, groups, kinds, prio,
                           D.init_tables(cfg, mesh), jnp.uint32(0))
    assert list(np.asarray(commit)) == [True, True, True]


# --------------------------------------------------- backend bit-identity
@pytest.mark.parametrize("route_cap", [0, 8])
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", ["mvcc", "mvocc"])
def test_backend_bit_identity_mv(cc, gran, route_cap):
    """Acceptance criterion: the routed MV wave is bit-identical across
    jnp/pallas — commit mask, both claim channels, ring begins + heads, and
    the stats vector — over every host device, with and without capacity
    overflow."""
    mesh = _full_mesh()
    ns = D.n_shards(mesh)
    N, Tl, K = 256, 8, 6
    rng = np.random.default_rng(9)
    keys, groups, kinds = _batch(rng, ns * Tl, K, N)
    prio = jnp.asarray(rng.permutation(ns * Tl).astype(np.uint32))
    outs = {}
    for backend in ("jnp", "pallas"):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=gran, route_cap=route_cap,
                           backend=backend, cc=cc, mv_depth=3)
        wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
        tables = D.init_tables(cfg, mesh)
        # two waves so the second probes tables the first populated
        for w in range(2):
            commit, tables, stats = wave_fn(keys, groups, kinds, prio,
                                            tables, jnp.uint32(w))
        outs[backend] = (commit, tables, stats)
    for a, b in zip(jax.tree.leaves(outs["jnp"]),
                    jax.tree.leaves(outs["pallas"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    commit, _, stats = outs["jnp"]
    assert int(commit.sum()) > 0
    if route_cap:
        s = np.asarray(stats).reshape(ns, D.STATS_LEN)
        assert int(s[:, D.STAT_DROPPED_OPS].sum()) > 0


def test_multi_shard_mv_runs_in_subprocess():
    """8 host devices: the sharded MV wave must commit on 1-D and 2-D
    meshes and stay bit-identical across backends, for both mechanisms."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys
        sys.path.insert(0, "src")
        from repro.core import distributed as D
        from repro.core import types as t

        N, Tl, K = 256, 8, 6
        rng = np.random.default_rng(4)

        for shape, axes in (((8,), ("data",)), ((2, 4), ("pod", "data"))):
            mesh = jax.make_mesh(shape, axes)
            ns = D.n_shards(mesh)
            T = ns * Tl
            keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE, t.ADD], (T, K)).astype(np.int32))
            prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
            for cc in ("mvcc", "mvocc"):
                outs = {}
                for backend in ("jnp", "pallas"):
                    cfg = D.DistConfig(n_records=N, n_groups=2,
                                       lanes_per_shard=Tl, slots=K,
                                       backend=backend, cc=cc, mv_depth=3)
                    tables = D.init_tables(cfg, mesh)
                    fn = jax.jit(D.make_wave_fn(cfg, mesh))
                    outs[backend] = fn(keys, groups, kinds, prio, tables,
                                       jnp.uint32(0))
                for a, b in zip(jax.tree.leaves(outs["jnp"]),
                                jax.tree.leaves(outs["pallas"])):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
                commit, _, stats = outs["jnp"]
                print(shape, cc, "commits:", int(commit.sum()))
                assert int(commit.sum()) > 0
        print("MULTI_SHARD_MV_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "MULTI_SHARD_MV_OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------- aged reader snapshots
def test_snapshot_age_reclamation_fires_with_zero_garbage_reads():
    """Acceptance criterion: with snapshot_age > 0 and writers outrunning a
    shallow ring, read-only lanes abort on reclamation (nonzero ro aborts
    in the stats vector) and every reader verdict matches the ref.mv_gather
    oracle on the pre-wave ring — a reader commits iff its aged snapshot is
    still retained, so no committed read ever touched a recycled slot."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K, depth, age = 16, 4, 8, 2, 4
    # lane 0: read-only scans of records 0/1; lanes 1-3: writers hammering
    # the same records every wave (ring depth 2 recycles fast).
    keys = jnp.asarray(np.tile(np.arange(K) % 2, (T, 1)).astype(np.int32))
    groups = jnp.zeros((T, K), jnp.int32)
    kinds = jnp.asarray([[t.READ] * K] + [[t.WRITE] * K] * (T - 1),
                        jnp.int32)
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       cc="mvcc", mv_depth=depth, snapshot_age=age)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    tables = D.init_tables(cfg, mesh)
    ro_commits = ro_aborts = 0
    for w in range(10):
        prio = jnp.asarray(np.roll(np.arange(T, dtype=np.uint32), w))
        begin_prev = tables[2]
        commit, tables, stats = wave_fn(keys, groups, kinds, prio, tables,
                                        jnp.uint32(w))
        s = np.asarray(stats)
        ro_commits += int(s[D.STAT_RO_COMMITS])
        ro_aborts += int(s[D.STAT_RO_ABORTS])
        # zero-garbage oracle: the read-only lane commits iff EVERY read's
        # aged snapshot still has a retained version in the pre-wave ring
        _, ok = ref.mv_gather(begin_prev, keys[:1], groups[:1],
                              mvstore.snapshot_ts(jnp.uint32(w), age), True)
        assert bool(np.asarray(commit)[0]) == bool(np.asarray(ok).all()), w
    assert ro_commits > 0     # early waves: snapshot 0 is still slot 0
    assert ro_aborts > 0      # later waves: the ring outran the aged reader


def test_snapshot_age_zero_readers_never_abort():
    """The control: same hammering workload with wave-fresh snapshots never
    aborts the read-only lane (the classic MV headline)."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K, depth = 16, 4, 8, 2
    keys = jnp.asarray(np.tile(np.arange(K) % 2, (T, 1)).astype(np.int32))
    groups = jnp.zeros((T, K), jnp.int32)
    kinds = jnp.asarray([[t.READ] * K] + [[t.WRITE] * K] * (T - 1),
                        jnp.int32)
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       cc="mvcc", mv_depth=depth)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    tables = D.init_tables(cfg, mesh)
    for w in range(8):
        prio = jnp.asarray(np.roll(np.arange(T, dtype=np.uint32), w))
        commit, tables, stats = wave_fn(keys, groups, kinds, prio, tables,
                                        jnp.uint32(w))
        assert bool(np.asarray(commit)[0]), w
        assert int(np.asarray(stats)[D.STAT_RO_ABORTS]) == 0, w
