"""analysis/txn_cost.py: the per-op roofline cost model — WAVE_OPS pinned
against the backend attribution tables (and the tictoc source), the
granularity switch visible as a byte difference, and the memory-bound
verdict on every chip in the shared peaks table."""
import re

import repro.analysis.peaks as peaks
import repro.analysis.roofline as roofline
from repro.analysis.txn_cost import (DIST_WAVE_OPS, WAVE_OPS, WaveShape,
                                     op_costs, txn_cost, wave_cost)
from repro.core import backend as kb
from repro.core import types as t

SHAPE = WaveShape(lanes=64, slots=16, n_groups=2, granularity=1, mv_depth=4)


# ---------------------------------------------------- op-count pinning
def test_wave_ops_pin_backend_attribution():
    """WAVE_OPS mirrors each mechanism's backend call set.  CC_OPS
    (core/backend.py) is the attribution table benchmark rows record, and
    it additionally lists segment_count for every mechanism (the ENGINE's
    per-wave install-contention counter, not a mechanism op) — so the op
    SETS must agree modulo that one op.  A new backend call added to a
    cc/*.py wave lands in CC_OPS and fails here until the cost model
    learns its traffic."""
    assert set(WAVE_OPS) == set(t.CC_IDS), "one entry per mechanism"
    for name, ops in WAVE_OPS.items():
        want = set(kb.CC_OPS[t.CC_IDS[name]])
        assert set(ops) | {"segment_count"} == want | {"segment_count"}, \
            (name, sorted(ops), sorted(want))
        assert all(k >= 1 for k in ops.values()), name


def test_dist_wave_ops_pin_backend_attribution():
    assert set(DIST_WAVE_OPS["occ"]) == set(kb.DIST_OPS)
    assert set(DIST_WAVE_OPS["mvcc"]) == set(kb.DIST_MV_OPS)
    assert set(DIST_WAVE_OPS["mvocc"]) == set(kb.DIST_MVOCC_OPS)


def test_tictoc_counts_pin_source():
    """The docstring's example claim — tictoc's 2 ts_gather + 2
    segment_count + 3 ts_install_max — counted in cc/tictoc.py itself
    (those calls are all local to the module)."""
    src = open("src/repro/core/cc/tictoc.py").read()
    for op in ("ts_gather", "segment_count", "ts_install_max"):
        calls = len(re.findall(rf"be\.{op}\(", src))
        assert calls == WAVE_OPS["tictoc"][op], (op, calls)


def test_every_counted_op_has_a_descriptor():
    costs = op_costs(SHAPE)
    for table in (WAVE_OPS, DIST_WAVE_OPS):
        for name, ops in table.items():
            for op in ops:
                assert op in costs, (name, op)


# ---------------------------------------------------- cost-model shape
def test_granularity_is_a_byte_difference():
    """The paper's switch, in traffic terms: fine timestamps probe ONE
    group word where coarse probes the whole row — strictly fewer bytes
    per txn for every mechanism once n_groups > 1."""
    for cc in WAVE_OPS:
        fine = txn_cost(cc, SHAPE)
        coarse = txn_cost(cc, WaveShape(lanes=64, slots=16, n_groups=2,
                                        granularity=0, mv_depth=4))
        assert fine["bytes_per_txn"] < coarse["bytes_per_txn"], cc


def test_memory_bound_at_small_waves_on_every_chip():
    """Gather/scatter over uint32 words with a few compares per cell: at
    SMALL waves (where the all-pairs wave term is noise) intensity sits
    far below every ridge in the shared peaks table.  Large waves are the
    quad-dominance test below — the probe family's O(n^2) in-wave-min
    term changes the regime there."""
    small = WaveShape(lanes=8, slots=4, n_groups=2, granularity=1,
                      mv_depth=4)
    for chip in peaks.HW_PEAKS:
        for cc in WAVE_OPS:
            c = txn_cost(cc, small, chip=chip)
            assert c["bound"] == "memory", (chip, cc)
            assert 0.0 < c["roofline_frac"] < 0.05, (chip, cc, c)
        for cc in DIST_WAVE_OPS:
            c = txn_cost(cc, WaveShape(lanes=16, slots=8, n_shards=8,
                                       route_cap=64, mv_depth=4),
                         distributed=True, chip=chip)
            assert c["bound"] == "memory", (chip, cc)


def test_quadratic_wave_term_pinned():
    """ISSUE 9 satellite: the in-wave min of segment_count / claim_probe /
    wave_commit is an all-pairs same-cell compare — 2*n^2 flops on top of
    the linear per-cell work, pinned termwise here."""
    n, c = SHAPE.ops, SHAPE.cells
    costs = op_costs(SHAPE)
    assert costs["segment_count"].flops_per_call == 2.0 * n + 2.0 * n * n
    assert costs["claim_probe"].flops_per_call == 3.0 * n * c + 2.0 * n * n
    assert costs["wave_commit"].flops_per_call == 4.0 * n * c + 2.0 * n * n
    # The quadratic term is per-CALL, not per-cell: granularity must not
    # change it (only the linear table-word traffic narrows at fine).
    coarse = op_costs(WaveShape(lanes=64, slots=16, n_groups=2,
                                granularity=0))
    assert coarse["wave_commit"].flops_per_call == \
        4.0 * n * 2 + 2.0 * n * n


def test_quad_term_dominates_at_large_waves():
    """When it dominates (DESIGN.md section 5): large waves.  At n = T*K
    = 1024 the 2*n^2 all-pairs compares are >90% of the probe family's
    flops and intensity is a sizable fraction of the ridge — orders of
    magnitude above the small-wave regime, though the bytes still win on
    the chips in the peaks table."""
    n = SHAPE.ops
    wc = op_costs(SHAPE)["wave_commit"]
    assert 2.0 * n * n / wc.flops_per_call > 0.9
    big = txn_cost("occ", SHAPE)
    small = txn_cost("occ", WaveShape(lanes=8, slots=4, n_groups=2,
                                      granularity=1))
    assert big["roofline_frac"] > 0.25
    assert big["intensity"] > 20 * small["intensity"]


def test_probe_chain_launch_and_row_accounting():
    """ISSUE 9 acceptance: fused probe chain = ONE launch and ONE row
    visit per wave; the unfused chain's modeled DMA-row traffic is >= 2x
    for every probe-family mechanism."""
    from repro.analysis.txn_cost import PROBE_CHAIN_LAUNCHES, probe_chain
    for cc, launches in PROBE_CHAIN_LAUNCHES.items():
        fused = probe_chain(cc, SHAPE, fused=True)
        unfused = probe_chain(cc, SHAPE, fused=False)
        assert fused["launches_per_wave"] == 1, cc
        assert unfused["launches_per_wave"] == launches, cc
        assert fused["dma_rows_per_wave"] == SHAPE.ops, cc
        assert unfused["dma_rows_per_wave"] >= 2 * fused["dma_rows_per_wave"], cc
    try:
        probe_chain("mvcc", SHAPE)
    except KeyError as e:
        assert "mvcc" in str(e)
    else:
        raise AssertionError("mvcc is not probe-family")


def test_bytes_per_txn_lane_invariant():
    """All ops are per-(lane x slot) linear except the distributed route
    buffers, so LOCAL bytes-per-txn is lane-count invariant."""
    a = txn_cost("occ", WaveShape(lanes=8, slots=16))
    b = txn_cost("occ", WaveShape(lanes=256, slots=16))
    assert a["bytes_per_txn"] == b["bytes_per_txn"]


def test_mv_depth_raises_mv_gather_cost():
    shallow = wave_cost("mvcc", WaveShape(lanes=64, slots=16, mv_depth=1))
    deep = wave_cost("mvcc", WaveShape(lanes=64, slots=16, mv_depth=8))
    assert deep["bytes_per_wave"] > shallow["bytes_per_wave"]


def test_distributed_adds_route_and_verdict_traffic():
    s = WaveShape(lanes=64, slots=16, n_shards=8, route_cap=128)
    local = wave_cost("occ", s)
    dist = wave_cost("occ", s, distributed=True)
    assert dist["bytes_per_wave"] > local["bytes_per_wave"]
    assert "route_pack" in dist["ops"] and "verdict_pack" in dist["ops"]


def test_unknown_mechanism_raises():
    try:
        wave_cost("nope", SHAPE)
    except KeyError as e:
        assert "nope" in str(e)
    else:
        raise AssertionError("expected KeyError")


# ---------------------------------------------------- shared peaks table
def test_roofline_reexports_shared_peaks():
    """ISSUE 8 satellite: the hardware peaks moved to analysis/peaks.py;
    analysis/roofline.py must consume the SAME constants (single source of
    truth for both the collective model and the txn cost model)."""
    assert roofline.PEAK_FLOPS is peaks.PEAK_FLOPS
    assert roofline.HBM_BW is peaks.HBM_BW
    assert roofline.LINK_BW is peaks.LINK_BW
    d = peaks.HW_PEAKS[peaks.DEFAULT_CHIP]
    assert peaks.PEAK_FLOPS == d["peak_flops"]
    assert peaks.ridge(peaks.DEFAULT_CHIP) == (d["peak_flops"]
                                               / d["hbm_bw"])
