"""Distributed txn engine, routed through the kernel-backend surface:
parity with the local engine on a 1-shard mesh, jnp vs pallas bit-identity,
sort-free capacity-drop semantics, and multi-shard execution.

The in-process tests build their mesh over every available host device, so
running this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(as CI does, in both jobs) exercises real multi-shard routing; without the
flag they degrade to the 1-shard mesh.  The subprocess tests force 8
devices regardless.  The multi-version (mvcc/mvocc) routed wave has its own
suite in tests/test_distributed_mv.py.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import distributed as D
from repro.core import types as t
from repro.core.cc import occ_validate
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init

EXACT = CostModel(opt_overlap=1.0, phase_overlap=1.0)


def _batch(rng, T, K, N, with_nops=False):
    keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
    kinds = [t.READ, t.WRITE] + ([t.NOP] if with_nops else [])
    kinds = jnp.asarray(rng.choice(kinds, (T, K)).astype(np.int32))
    return keys, groups, kinds


def _full_mesh():
    """One shard per available host device (8 under the CI XLA_FLAGS)."""
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _run_wave(cfg, mesh, keys, groups, kinds, prio, wave=0):
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    tables = D.init_tables(cfg, mesh)
    return wave_fn(keys, groups, kinds, prio, tables, jnp.uint32(wave))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
def test_single_shard_parity_with_local_occ(gran, backend):
    """Acceptance criterion: the routed wave commits exactly the local
    OCC engine's lanes on a 1-shard mesh, on either backend."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 256, 16, 8
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       granularity=gran, backend=backend)
    rng = np.random.default_rng(0)
    keys, groups, kinds = _batch(rng, T, K, N)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    commit, (wts2, _), stats = _run_wave(cfg, mesh, keys, groups, kinds,
                                         prio)

    ecfg = EngineConfig(cc=t.CC_OCC, lanes=T, slots=K, n_records=N,
                        n_groups=2, n_cols=0, n_txn_types=1,
                        granularity=gran, cost=EXACT)
    store = store_init(N, 2, 0)
    batch = TxnBatch(op_key=keys, op_group=groups,
                     op_col=jnp.zeros_like(keys), op_kind=kinds,
                     op_val=jnp.zeros(keys.shape, jnp.float32),
                     txn_type=jnp.zeros((T,), jnp.int32),
                     n_ops=jnp.full((T,), K, jnp.int32))
    _, res = occ_validate(store, batch, prio, jnp.uint32(0), ecfg)
    np.testing.assert_array_equal(np.asarray(commit),
                                  np.asarray(res.commit))


@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("route_cap", [0, 8])
def test_backend_bit_identity(gran, route_cap):
    """Acceptance criterion: the distributed wave is bit-identical across
    jnp/pallas backends — commit mask, installed versions, claim words, and
    drop stats — over every host device, with and without capacity
    overflow (route_cap=8 forces drops)."""
    mesh = _full_mesh()
    ns = D.n_shards(mesh)
    N, Tl, K = 512, 8, 6
    rng = np.random.default_rng(3)
    keys, groups, kinds = _batch(rng, ns * Tl, K, N)
    prio = jnp.asarray(rng.permutation(ns * Tl).astype(np.uint32))
    outs = {}
    for backend in ("jnp", "pallas"):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=gran, route_cap=route_cap,
                           backend=backend)
        outs[backend] = _run_wave(cfg, mesh, keys, groups, kinds, prio)
    for a, b in zip(jax.tree.leaves(outs["jnp"]),
                    jax.tree.leaves(outs["pallas"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    commit, _, stats = outs["jnp"]
    assert int(commit.sum()) > 0
    if route_cap:  # 1 shard x 48 ops (or more) vs cap 8: must drop
        s = np.asarray(stats).reshape(ns, D.STATS_LEN)
        assert int(s[:, D.STAT_DROPPED_OPS].sum()) > 0


def test_stats_vector_carries_readonly_split():
    """The distributed stats vector is int32[STATS_LEN] (closed-loop waves
    zero the open-loop slots) and its read-only commit/abort split counts
    exactly the lanes with no live write ops (the split SimResult/dashboard
    rows expect — ISSUE 5 satellite)."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 128, 8, 4
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K)
    rng = np.random.default_rng(11)
    keys, groups, kinds = _batch(rng, T, K, N, with_nops=True)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    commit, _, stats = _run_wave(cfg, mesh, keys, groups, kinds, prio)
    s = np.asarray(stats)
    assert s.shape == (D.STATS_LEN,)
    c = np.asarray(commit)
    ro = ~((np.asarray(kinds) != t.READ) & (np.asarray(kinds) != t.NOP)
           & (np.asarray(keys) >= 0)).any(axis=1)
    assert s[D.STAT_COMMITS] == c.sum()
    assert s[D.STAT_ABORTS] == (~c).sum()
    assert s[D.STAT_RO_COMMITS] == (c & ro).sum()
    assert s[D.STAT_RO_ABORTS] == (~c & ro).sum()
    assert ro.any()     # the split is exercised, not vacuous


def test_no_argsort_and_no_direct_table_writes():
    """Acceptance criterion, enforced on the source: the routed wave holds
    no argsort and no direct claim/version table writes — every shard-local
    table touch goes through backend.resolve(cfg)."""
    import ast
    import pathlib

    import repro.core.distributed as dist
    tree = ast.parse(pathlib.Path(dist.__file__).read_text())
    # Code only — docstrings/comments may (and do) *mention* the old sort.
    code = ast.unparse(ast.fix_missing_locations(
        ast.Module(body=[n for n in tree.body
                         if not isinstance(n, ast.Expr)], type_ignores=[])))
    assert "argsort" not in code
    assert "import claims" not in code   # no core/claims.py helpers either
    assert ".at[" not in code            # no hand-rolled scatters
    assert "kb.resolve" in code


def test_multi_shard_runs_in_subprocess():
    """8 host devices: the sharded wave must commit on 1-D and 2-D meshes
    and stay bit-identical across backends on identical inputs."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys
        sys.path.insert(0, "src")
        from repro.core import distributed as D
        from repro.core import types as t

        N, Tl, K = 512, 8, 6
        rng = np.random.default_rng(1)

        for shape, axes in (((8,), ("data",)), ((2, 4), ("pod", "data"))):
            mesh = jax.make_mesh(shape, axes)
            ns = D.n_shards(mesh)
            T = ns * Tl
            keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
            kinds = jnp.asarray(
                rng.choice([t.READ, t.WRITE], (T, K)).astype(np.int32))
            prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
            outs = {}
            for backend in ("jnp", "pallas"):
                cfg = D.DistConfig(n_records=N, n_groups=2,
                                   lanes_per_shard=Tl, slots=K,
                                   backend=backend)
                tables = D.init_tables(cfg, mesh)
                fn = jax.jit(D.make_wave_fn(cfg, mesh))
                outs[backend] = fn(keys, groups, kinds, prio, tables,
                                   jnp.uint32(0))
            for a, b in zip(jax.tree.leaves(outs["jnp"]),
                            jax.tree.leaves(outs["pallas"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            commit, _, stats = outs["jnp"]
            s = np.asarray(stats).reshape(ns, D.STATS_LEN)
            print(shape, "commits:", int(commit.sum()),
                  "drops:", int(s[:, D.STAT_DROPPED_LANES].sum()))
            assert int(commit.sum()) > 0
        print("MULTI_SHARD_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "MULTI_SHARD_OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------- capacity-drop semantics
def _numpy_drop_oracle(keys, kinds, cap):
    """Per-lane capacity-drop ground truth for a 1-shard mesh: ops land in
    flat-op order; a live op whose in-destination rank reaches cap drops."""
    live = (np.asarray(kinds) != t.NOP).reshape(-1) & (
        np.asarray(keys).reshape(-1) >= 0)
    rank = np.cumsum(live) - live            # rank among live ops
    dropped_op = live & (rank >= cap)
    return dropped_op, dropped_op.reshape(keys.shape).any(axis=1)


def test_capacity_drops_abort_lanes():
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 64, 8, 8
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       route_cap=8)    # only 8 ops land; 8*8=64 sent
    rng = np.random.default_rng(2)
    keys, groups, kinds = _batch(rng, T, K, N)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    commit, _, stats = _run_wave(cfg, mesh, keys, groups, kinds, prio)
    dropped_op, dropped_lane = _numpy_drop_oracle(keys, kinds, 8)
    stats = np.asarray(stats)
    assert stats[D.STAT_DROPPED_LANES] == dropped_lane.sum() > 0
    assert stats[D.STAT_DROPPED_OPS] == dropped_op.sum() > 0
    assert not np.asarray(commit)[dropped_lane].any()   # dropped => abort


@pytest.fixture(scope="module")
def drop_wave_fn():
    """One jitted 1-shard wave shared by the property test's examples."""
    mesh = jax.make_mesh((1,), ("data",))
    cfg = D.DistConfig(n_records=64, n_groups=2, lanes_per_shard=8, slots=8,
                       route_cap=8)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    tables0 = D.init_tables(cfg, mesh)
    return lambda ks, gs, kd, pr: wave_fn(ks, gs, kd, pr, tables0,
                                          jnp.uint32(0))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_capacity_dropped_lanes_always_abort_and_are_counted(
        drop_wave_fn, seed):
    """Property: whatever the op mix (including NOP holes), every
    capacity-dropped lane aborts, and the wave stats count exactly the
    dropped lanes and ops of the flat-order routing oracle."""
    T, K, N, cap = 8, 8, 64, 8
    rng = np.random.default_rng(seed)
    keys, groups, kinds = _batch(rng, T, K, N, with_nops=True)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    commit, _, stats = drop_wave_fn(keys, groups, kinds, prio)
    dropped_op, dropped_lane = _numpy_drop_oracle(keys, kinds, cap)
    stats = np.asarray(stats)
    assert stats[D.STAT_DROPPED_LANES] == dropped_lane.sum()
    assert stats[D.STAT_DROPPED_OPS] == dropped_op.sum()
    assert not np.asarray(commit)[dropped_lane].any()


# -------------------------------------------------- DistConfig validation
def test_route_cap_below_slots_rejected():
    with pytest.raises(ValueError, match="route_cap"):
        D.DistConfig(n_records=64, lanes_per_shard=8, slots=8, route_cap=4)


def test_route_cap_negative_rejected():
    with pytest.raises(ValueError, match="negative"):
        D.DistConfig(n_records=64, slots=8, route_cap=-8)


def test_route_cap_ragged_rejected():
    """Explicit caps must honor the 8-alignment the auto path guarantees —
    exchange buffers are the Pallas kernels' lane dimension."""
    with pytest.raises(ValueError, match="multiple of 8"):
        D.DistConfig(n_records=64, slots=8, route_cap=12)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        D.DistConfig(n_records=64, backend="tpu")


def test_unknown_cc_rejected():
    with pytest.raises(ValueError, match="distributed cc"):
        D.DistConfig(n_records=64, cc="tictoc")


def test_mv_cc_requires_depth():
    with pytest.raises(ValueError, match="mv_depth"):
        D.DistConfig(n_records=64, cc="mvcc")


def test_occ_with_ring_rejected():
    with pytest.raises(ValueError, match="no version ring"):
        D.DistConfig(n_records=64, mv_depth=4)


def test_snapshot_age_requires_mv_cc():
    with pytest.raises(ValueError, match="snapshot_age"):
        D.DistConfig(n_records=64, snapshot_age=2)
    with pytest.raises(ValueError, match="snapshot_age"):
        D.DistConfig(n_records=64, cc="mvcc", mv_depth=4, snapshot_age=-1)


def test_wide_group_wire_format_rejected():
    with pytest.raises(ValueError, match="n_groups"):
        D.DistConfig(n_records=64, n_groups=3)


def test_auto_cap_is_8_aligned_and_fits_one_lane():
    """The auto capacity rounds up to a multiple of 8 (Pallas lane tiling
    never sees ragged exchange buffers) and never drops below slots — one
    lane routing its whole transaction to a single shard always fits, the
    same invariant the explicit-cap validation enforces.  Explicit >= slots
    caps pass through."""
    for T, K, ns in ((8, 6, 8), (64, 16, 3), (5, 3, 7), (1, 1, 1),
                     (1, 16, 8)):       # 4x fair share = 8 < slots = 16
        cfg = D.DistConfig(n_records=64, lanes_per_shard=T, slots=K)
        cap = cfg.cap(ns)
        assert cap % 8 == 0 and cap >= 8
        assert cap >= K
        assert cap >= 4 * T * K // ns     # the 4x-fair-share floor itself
    assert D.DistConfig(n_records=64, slots=8, route_cap=8).cap(4) == 8


def test_moe_ep_shardmap_matches_reference_multidevice():
    """The token-routed EP MoE (shard_map + all_to_all, Perf iteration A2)
    must compute the same function as the pjit reference dispatch, on a
    real (data=2, model=2) device mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import model as M
        from repro.models.moe import moe_ffn, moe_ffn_ep

        cfg = configs.get_smoke("llama4-maverick-400b-a17b")  # E=8 top-1
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda x: x[0], params["stages"][0]["0"]["ffn"])
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                              jnp.float32) * 0.3

        ref, aux_ref = jax.jit(lambda p, x: moe_ffn(p, x, cfg, 1))(p, x)

        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        ps = {
            "router": jax.device_put(p["router"], NamedSharding(mesh, P())),
            "w_gate": jax.device_put(p["w_gate"],
                                     NamedSharding(mesh,
                                                   P("data", None, "model"))),
            "w_in": jax.device_put(p["w_in"],
                                   NamedSharding(mesh,
                                                 P("data", None, "model"))),
            "w_out": jax.device_put(p["w_out"],
                                    NamedSharding(mesh,
                                                  P("data", "model", None))),
        }
        ep, aux_ep = jax.jit(
            lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(ps, xs)
        err = float(jnp.abs(ep - ref).max())
        # capacity accounting differs (per-device C vs global C): with the
        # drop-free smoke cap factor both paths route every token
        assert err < 2e-4, f"EP vs reference mismatch: {err}"
        print("EP_PARITY_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "EP_PARITY_OK" in r.stdout, r.stdout + r.stderr
