"""Distributed txn engine: parity with the local engine, multi-shard
execution in a subprocess with 8 host devices."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import types as t
from repro.core.cc import occ_validate
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init

EXACT = CostModel(opt_overlap=1.0, phase_overlap=1.0)


def _batch(rng, T, K, N):
    keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
    kinds = jnp.asarray(rng.choice([t.READ, t.WRITE], (T, K)).astype(
        np.int32))
    return keys, groups, kinds


@pytest.mark.parametrize("gran", [0, 1])
def test_single_shard_parity_with_local_occ(gran):
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 256, 16, 8
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       granularity=gran)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    rng = np.random.default_rng(0)
    keys, groups, kinds = _batch(rng, T, K, N)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    wts, claim_w = D.init_tables(cfg, mesh)
    commit, wts2, _, stats = wave_fn(keys, groups, kinds, prio, wts,
                                     claim_w, jnp.uint32(0))

    ecfg = EngineConfig(cc=t.CC_OCC, lanes=T, slots=K, n_records=N,
                        n_groups=2, n_cols=0, n_txn_types=1,
                        granularity=gran, cost=EXACT)
    store = store_init(N, 2, 0)
    batch = TxnBatch(op_key=keys, op_group=groups,
                     op_col=jnp.zeros_like(keys), op_kind=kinds,
                     op_val=jnp.zeros(keys.shape, jnp.float32),
                     txn_type=jnp.zeros((T,), jnp.int32),
                     n_ops=jnp.full((T,), K, jnp.int32))
    _, res = occ_validate(store, batch, prio, jnp.uint32(0), ecfg)
    store2 = res  # silence lint
    np.testing.assert_array_equal(np.asarray(commit),
                                  np.asarray(res.commit))


def test_multi_shard_runs_in_subprocess():
    """8 host devices: the sharded wave must agree with the 1-shard run on
    identical inputs (same global keys/prio => same commit set)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys
        sys.path.insert(0, "src")
        from repro.core import distributed as D
        from repro.core import types as t

        N, Tl, K = 512, 8, 6
        rng = np.random.default_rng(1)

        results = []
        for shape, axes in (((8,), ("data",)), ((2, 4), ("pod", "data"))):
            mesh = jax.make_mesh(shape, axes)
            ns = D.n_shards(mesh)
            cfg = D.DistConfig(n_records=N, n_groups=2,
                               lanes_per_shard=Tl, slots=K)
            T = ns * Tl
            keys = jnp.asarray(rng.integers(0, N, (T, K), dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32))
            kinds = jnp.asarray(
                rng.choice([t.READ, t.WRITE], (T, K)).astype(np.int32))
            prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
            wts, cw = D.init_tables(cfg, mesh)
            fn = jax.jit(D.make_wave_fn(cfg, mesh))
            commit, wts2, _, stats = fn(keys, groups, kinds, prio, wts, cw,
                                        jnp.uint32(0))
            print(shape, "commits:", int(commit.sum()),
                  "drops:", int(np.asarray(stats)[-1]))
            assert int(commit.sum()) > 0
        print("MULTI_SHARD_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "MULTI_SHARD_OK" in r.stdout, r.stdout + r.stderr


def test_capacity_drops_abort_lanes():
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K = 64, 8, 8
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T, slots=K,
                       route_cap=4)    # only 4 ops land; 8*8=64 sent
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    rng = np.random.default_rng(2)
    keys, groups, kinds = _batch(rng, T, K, N)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    wts, cw = D.init_tables(cfg, mesh)
    commit, _, _, stats = wave_fn(keys, groups, kinds, prio, wts, cw,
                                  jnp.uint32(0))
    assert int(np.asarray(stats)[2]) > 0          # drops counted
    assert int(commit.sum()) < T                  # dropped lanes aborted


def test_moe_ep_shardmap_matches_reference_multidevice():
    """The token-routed EP MoE (shard_map + all_to_all, Perf iteration A2)
    must compute the same function as the pjit reference dispatch, on a
    real (data=2, model=2) device mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import model as M
        from repro.models.moe import moe_ffn, moe_ffn_ep

        cfg = configs.get_smoke("llama4-maverick-400b-a17b")  # E=8 top-1
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda x: x[0], params["stages"][0]["0"]["ffn"])
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                              jnp.float32) * 0.3

        ref, aux_ref = jax.jit(lambda p, x: moe_ffn(p, x, cfg, 1))(p, x)

        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        ps = {
            "router": jax.device_put(p["router"], NamedSharding(mesh, P())),
            "w_gate": jax.device_put(p["w_gate"],
                                     NamedSharding(mesh,
                                                   P("data", None, "model"))),
            "w_in": jax.device_put(p["w_in"],
                                   NamedSharding(mesh,
                                                 P("data", None, "model"))),
            "w_out": jax.device_put(p["w_out"],
                                    NamedSharding(mesh,
                                                  P("data", "model", None))),
        }
        ep, aux_ep = jax.jit(
            lambda p, x: moe_ffn_ep(p, x, cfg, mesh))(ps, xs)
        err = float(jnp.abs(ep - ref).max())
        # capacity accounting differs (per-device C vs global C): with the
        # drop-free smoke cap factor both paths route every token
        assert err < 2e-4, f"EP vs reference mismatch: {err}"
        print("EP_PARITY_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "EP_PARITY_OK" in r.stdout, r.stdout + r.stderr
