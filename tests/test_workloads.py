"""Workload generators: distributions and the paper's schema splits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import types as t
from repro.workloads import TPCCWorkload, YCSBWorkload
from repro.workloads.tpcc import G_HOT, G_RARE, NEW_ORDER, ORDER_STATUS, \
    PAYMENT
from repro.workloads.zipf import ZipfSampler, nurand, scramble


def test_zipf_is_skewed_and_scrambled():
    z = ZipfSampler.make(10_000, 0.9)
    ranks = np.asarray(z.ranks(jax.random.PRNGKey(0), (20_000,)))
    # rank 0 hottest; top-10 ranks carry a large share
    share = (ranks < 10).mean()
    assert 0.10 < share < 0.45
    keys = np.asarray(z.sample(jax.random.PRNGKey(0), (20_000,)))
    # scrambling disperses the hot prefix (not the identity map) while
    # preserving hotness (some key still carries rank-0's mass)
    assert (keys < 10).mean() < (ranks < 10).mean() / 2
    counts = np.bincount(keys, minlength=10_000)
    assert counts.max() / len(keys) > 0.015
    assert keys.min() >= 0 and keys.max() < 10_000


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 10))
def test_nurand_in_range(seed):
    v = np.asarray(nurand(jax.random.PRNGKey(seed), 1023, 0, 2999, 259,
                          (512,)))
    assert v.min() >= 0 and v.max() <= 2999


def test_ycsb_parity_groups():
    wl = YCSBWorkload.make(n_keys=100)
    b, _ = wl.gen(jax.random.PRNGKey(0), jnp.uint32(0), 8,
                  jnp.zeros((1,), jnp.int32))
    cols = np.asarray(b.op_col)
    groups = np.asarray(b.op_group)
    np.testing.assert_array_equal(groups, cols % 2)   # the paper's split


def test_tpcc_group_split_matches_paper():
    """Payment writes the hot group; New-order's W/D/C reads the rare group
    (section 3.4: tax & identity vs YTD & balance)."""
    wl = TPCCWorkload.make(n_warehouses=2, scale=0.1)
    b, _ = wl.gen(jax.random.PRNGKey(1), jnp.uint32(0), 256,
                  jnp.zeros((wl.n_rings,), jnp.int32))
    tt = np.asarray(b.txn_type)
    kinds = np.asarray(b.op_kind)
    groups = np.asarray(b.op_group)
    keys = np.asarray(b.op_key)

    pay = tt == PAYMENT
    # Payment ops 0/1 are W_YTD / D_YTD ADDs in the hot group
    assert (kinds[pay][:, 0] == t.ADD).all()
    assert (groups[pay][:, 0] == G_HOT).all()
    assert (groups[pay][:, 1] == G_HOT).all()
    # Payment op 2 reads customer identity: rare group
    assert (groups[pay][:, 2] == G_RARE).all()
    no = tt == NEW_ORDER
    # New-order ops 0/1 read W_TAX / D_TAX: rare group, READ
    assert (kinds[no][:, 0] == t.READ).all()
    assert (groups[no][:, 0] == G_RARE).all()
    assert (groups[no][:, 1] == G_RARE).all()
    # all keys in range
    live = keys >= 0
    assert keys[live].max() < wl.n_records


def test_tpcc_mix_proportions():
    wl = TPCCWorkload.make(n_warehouses=2, scale=0.1)
    b, _ = wl.gen(jax.random.PRNGKey(2), jnp.uint32(0), 4096,
                  jnp.zeros((wl.n_rings,), jnp.int32))
    tt = np.asarray(b.txn_type)
    assert abs((tt == NEW_ORDER).mean() - 45 / 92) < 0.05
    assert abs((tt == PAYMENT).mean() - 43 / 92) < 0.05
    assert abs((tt == ORDER_STATUS).mean() - 4 / 92) < 0.03


def test_tpcc_ring_slots_unique_per_wave():
    """Concurrent New-orders in one wave get distinct order slots."""
    wl = TPCCWorkload.make(n_warehouses=1, scale=0.1)
    b, tails = wl.gen(jax.random.PRNGKey(3), jnp.uint32(0), 64,
                      jnp.zeros((wl.n_rings,), jnp.int32))
    tt = np.asarray(b.txn_type)
    okeys = np.asarray(b.op_key)[:, 48]     # the O-row write slot
    no_keys = okeys[tt == NEW_ORDER]
    assert len(no_keys) == len(set(no_keys.tolist()))
    assert int(np.asarray(tails).sum()) == (tt == NEW_ORDER).sum()
