"""Checkpoint store: atomicity, async, retention, fingerprint, elastic
resharding, and bit-exact restart continuation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs.base import ShapeSpec
from repro.data import make_batch
from repro.models import model as M
from repro.models import steps
from repro.optim import AdamW


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save(d, 3, tree(), fingerprint="fp")
    got, manifest = restore(d, 3, tree(), fingerprint="fp")
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree()["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16
    assert manifest["step"] == 3


def test_fingerprint_mismatch_refuses(tmp_path):
    d = str(tmp_path)
    save(d, 1, tree(), fingerprint="qwen3-32b")
    with pytest.raises(ValueError, match="fingerprint"):
        restore(d, 1, tree(), fingerprint="rwkv6-3b")


def test_async_save_and_retention(tmp_path):
    d = str(tmp_path)
    handles = [save(d, s, tree(), blocking=False, keep=2)
               for s in (1, 2, 3)]
    for h in handles:
        h.join()
    steps_on_disk = sorted(os.listdir(d))
    assert len([s for s in steps_on_disk if s.startswith("step_")]) <= 2
    assert latest_step(d) == 3


def test_elastic_reshard_on_restore(tmp_path):
    """Arrays restore onto a *different* sharding than they were saved
    with (device counts may change between runs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    save(d, 1, t)
    sh = {"w": NamedSharding(mesh, P("data"))}
    got, _ = restore(d, 1, t, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_restart_continuation_is_exact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical
    parameters (stateless data pipeline + durable state = exact resume)."""
    cfg = configs.get_smoke("qwen2-7b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("t", "train", 16, 2)
    opt = AdamW.from_config(cfg, total_steps=6, warmup_steps=1)
    ts = jax.jit(steps.build_train_step(cfg, mesh, opt))

    def go(params, opt_state, lo, hi):
        for s in range(lo, hi):
            params, opt_state, _ = ts(params, opt_state,
                                      make_batch(cfg, shape, s),
                                      jnp.int32(s))
        return params, opt_state

    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    o0 = opt.init(p0)
    p_straight, _ = go(p0, o0, 0, 6)

    p3, o3 = go(p0, o0, 0, 3)
    d = str(tmp_path)
    save(d, 3, {"params": p3, "opt": o3})
    restored, manifest = restore(d, 3, {"params": p3, "opt": o3})
    p_resumed, _ = go(restored["params"], restored["opt"],
                      manifest["step"], 6)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_survives_injected_failures(tmp_path):
    """End-to-end fault tolerance: inject 2 failures, reach the target step,
    and match the no-failure run exactly."""
    from repro.checkpoint import CheckpointManager
    from repro.ft import FailureInjector
    from repro.launch.train import TrainRun, run_supervised

    cfg = configs.get_smoke("starcoder2-3b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("t", "train", 16, 2)
    opt = AdamW.from_config(cfg, total_steps=8, warmup_steps=1)

    def build_run(ckdir, inject):
        return TrainRun(
            cfg=cfg, mesh=mesh, optimizer=opt, shape=shape,
            ckpt=CheckpointManager(ckdir, interval=2, fingerprint="t"),
            injector=FailureInjector(at_steps=inject), log_every=100)

    p_fail, _, _, restarts = run_supervised(
        build_run(str(tmp_path / "a"), (3, 5)), 8)
    assert restarts == 2
    p_ok, _, _, r0 = run_supervised(build_run(str(tmp_path / "b"), ()), 8)
    assert r0 == 0
    for a, b in zip(jax.tree.leaves(p_fail), jax.tree.leaves(p_ok)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
