"""Backend parity: the Pallas kernels (interpret mode on CPU) and the jnp
gather/scatter path must be bit-identical — same commit masks, same installed
versions — because both decode the one claim-word layout in
core/claimword.py (DESIGN.md section 5)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import claims
from repro.core import types as t
from repro.core.cc import autogran, occ
from repro.core.engine import run
from repro.core.types import EngineConfig, TxnBatch, store_init
from repro.kernels import ref
from repro.workloads import TPCCWorkload, YCSBWorkload

RNG = np.random.default_rng(42)


def _random_batch(T, K, N, G):
    ks = RNG.integers(-1, N, (T, K)).astype(np.int32)
    gs = RNG.integers(0, G, (T, K)).astype(np.int32)
    kd = RNG.choice([t.NOP, t.READ, t.WRITE, t.ADD], (T, K)).astype(np.int32)
    return TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                    op_col=jnp.zeros((T, K), jnp.int32),
                    op_kind=jnp.asarray(kd),
                    op_val=jnp.zeros((T, K), jnp.float32),
                    txn_type=jnp.zeros((T,), jnp.int32),
                    n_ops=jnp.full((T,), K, jnp.int32))


def _cfg(cc, T, K, N, gran, backend):
    return EngineConfig(cc=cc, lanes=T, slots=K, n_records=N, n_groups=2,
                        n_cols=0, n_txn_types=1, granularity=gran,
                        backend=backend)


# -------------------------------------------------- single-wave validation
@pytest.mark.parametrize("cc_mod,cc_id", [(occ, t.CC_OCC),
                                          (autogran, t.CC_AUTOGRAN)])
@pytest.mark.parametrize("gran", [0, 1])
def test_wave_validate_backend_parity(cc_mod, cc_id, gran):
    T, K, N = 6, 4, 32
    for trial in range(3):
        batch = _random_batch(T, K, N, 2)
        prio = jnp.asarray(RNG.permutation(T).astype(np.uint32))
        wave = jnp.uint32(trial)
        store_a = store_init(N, 2, 0)
        store_b = store_init(N, 2, 0)
        sa, ra = cc_mod.wave_validate(store_a, batch, prio, wave,
                                      _cfg(cc_id, T, K, N, gran, "jnp"))
        sb, rb = cc_mod.wave_validate(store_b, batch, prio, wave,
                                      _cfg(cc_id, T, K, N, gran, "pallas"))
        np.testing.assert_array_equal(np.asarray(ra.commit),
                                      np.asarray(rb.commit))
        np.testing.assert_array_equal(np.asarray(ra.conflict_op),
                                      np.asarray(rb.conflict_op))
        np.testing.assert_array_equal(np.asarray(sa.wts), np.asarray(sb.wts))


# ------------------------------------------------------- whole-run parity
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("wlname", ["ycsb", "tpcc"])
def test_run_backend_parity(wlname, gran):
    """EngineConfig(backend='pallas') must yield bit-identical commit masks
    and versions to backend='jnp' on both paper workloads (ISSUE acceptance
    criterion)."""
    if wlname == "ycsb":
        wl = YCSBWorkload.make(n_keys=512)
    else:
        wl = TPCCWorkload.make(n_warehouses=1, scale=0.05)
    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       granularity=gran, n_rings=wl.n_rings)
    a = run(cfg, wl, n_waves=6, seed=0, keep_state=True)
    b = run(dataclasses.replace(cfg, backend="pallas"), wl, n_waves=6,
            seed=0, keep_state=True)
    np.testing.assert_array_equal(np.asarray(a.per_wave_commits),
                                  np.asarray(b.per_wave_commits))
    assert (a.commits, a.aborts) == (b.commits, b.aborts)
    np.testing.assert_array_equal(np.asarray(a.final_state.store.wts),
                                  np.asarray(b.final_state.store.wts))
    np.testing.assert_array_equal(
        np.asarray(a.final_state.pending_live),
        np.asarray(b.final_state.pending_live))


# ------------------------------------- shared layout: claims vs kernel oracle
@pytest.mark.parametrize("fine", [True, False])
def test_claims_probe_matches_kernel_oracle(fine):
    """The engine's jnp probe and the kernel oracle decode identical claim
    words — the core/claimword.py contract both backends build on."""
    T, K, N, G = 5, 6, 64, 2
    table = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    myp = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    check = jnp.asarray(RNG.random((T, K)) < 0.8) & (keys >= 0)
    wave = jnp.uint32(3)

    wprio = (claims.probe(table, keys, groups, wave) if fine
             else claims.probe_any_group(table, keys, wave))
    via_claims = check & (wprio < myp)
    via_oracle = ref.occ_validate(table, keys, groups, myp, check,
                                  claims.inv_wave(wave), fine)
    np.testing.assert_array_equal(np.asarray(via_claims),
                                  np.asarray(via_oracle))
