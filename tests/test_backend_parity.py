"""Backend parity: the Pallas kernels (interpret mode on CPU) and the jnp
gather/scatter path must be bit-identical — same commit masks, same installed
versions/timestamps — because both decode the one claim-word layout in
core/claimword.py through the one backend op surface in core/backend.py
(DESIGN.md section 5)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import claims
from repro.core import types as t
from repro.core.cc import autogran, mvcc, mvocc, occ, tictoc
from repro.core.engine import run, sweep
from repro.core.types import EngineConfig, TxnBatch, store_init
from repro.kernels import ref
from repro.workloads import TPCCWorkload, YCSBWorkload

RNG = np.random.default_rng(42)

WORKLOADS = {
    "ycsb": YCSBWorkload.make(n_keys=512),
    "tpcc": TPCCWorkload.make(n_warehouses=1, scale=0.05),
}


def _random_batch(T, K, N, G):
    ks = RNG.integers(-1, N, (T, K)).astype(np.int32)
    gs = RNG.integers(0, G, (T, K)).astype(np.int32)
    kd = RNG.choice([t.NOP, t.READ, t.WRITE, t.ADD], (T, K)).astype(np.int32)
    return TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                    op_col=jnp.zeros((T, K), jnp.int32),
                    op_kind=jnp.asarray(kd),
                    op_val=jnp.zeros((T, K), jnp.float32),
                    txn_type=jnp.zeros((T,), jnp.int32),
                    n_ops=jnp.full((T,), K, jnp.int32))


def _cfg(cc, T, K, N, gran, backend):
    return EngineConfig(cc=cc, lanes=T, slots=K, n_records=N, n_groups=2,
                        n_cols=0, n_txn_types=1, granularity=gran,
                        backend=backend,
                        mv_depth=3 if cc in t.MV_CCS else 0)


# -------------------------------------------------- single-wave validation
@pytest.mark.parametrize("cc_mod,cc_id", [(occ, t.CC_OCC),
                                          (tictoc, t.CC_TICTOC),
                                          (autogran, t.CC_AUTOGRAN),
                                          (mvcc, t.CC_MVCC),
                                          (mvocc, t.CC_MVOCC)])
@pytest.mark.parametrize("gran", [0, 1])
def test_wave_validate_backend_parity(cc_mod, cc_id, gran):
    T, K, N = 6, 4, 32
    mvd = 3 if cc_id in t.MV_CCS else 0
    for trial in range(3):
        batch = _random_batch(T, K, N, 2)
        prio = jnp.asarray(RNG.permutation(T).astype(np.uint32))
        wave = jnp.uint32(trial)
        store_a = store_init(N, 2, 0, mv_depth=mvd)
        store_b = store_init(N, 2, 0, mv_depth=mvd)
        sa, ra = cc_mod.wave_validate(store_a, batch, prio, wave,
                                      _cfg(cc_id, T, K, N, gran, "jnp"))
        sb, rb = cc_mod.wave_validate(store_b, batch, prio, wave,
                                      _cfg(cc_id, T, K, N, gran, "pallas"))
        np.testing.assert_array_equal(np.asarray(ra.commit),
                                      np.asarray(rb.commit))
        np.testing.assert_array_equal(np.asarray(ra.conflict_op),
                                      np.asarray(rb.conflict_op))
        np.testing.assert_array_equal(np.asarray(sa.wts), np.asarray(sb.wts))
        np.testing.assert_array_equal(np.asarray(sa.rts), np.asarray(sb.rts))
        np.testing.assert_array_equal(np.asarray(sa.claim_w),
                                      np.asarray(sb.claim_w))
        np.testing.assert_array_equal(np.asarray(sa.mv_begin),
                                      np.asarray(sb.mv_begin))
        np.testing.assert_array_equal(np.asarray(sa.mv_head),
                                      np.asarray(sb.mv_head))


# ------------------------------------------------------- whole-run parity
@pytest.mark.parametrize("cc", [t.CC_OCC, t.CC_TICTOC, t.CC_AUTOGRAN,
                                t.CC_MVCC, t.CC_MVOCC])
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("wlname", ["ycsb", "tpcc"])
def test_run_backend_parity(wlname, gran, cc):
    """EngineConfig(backend='pallas') must yield bit-identical commit masks,
    versions, timestamps, and MV rings to backend='jnp' on both paper
    workloads for OCC, TicToc, AutoGran, MVCC, and MV-OCC (ISSUE acceptance
    criterion)."""
    wl = WORKLOADS[wlname]
    cfg = EngineConfig(cc=cc, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       granularity=gran, n_rings=wl.n_rings,
                       mv_depth=4 if cc in t.MV_CCS else 0)
    a = run(cfg, wl, n_waves=6, seed=0, keep_state=True)
    b = run(dataclasses.replace(cfg, backend="pallas"), wl, n_waves=6,
            seed=0, keep_state=True)
    np.testing.assert_array_equal(np.asarray(a.per_wave_commits),
                                  np.asarray(b.per_wave_commits))
    assert (a.commits, a.aborts) == (b.commits, b.aborts)
    np.testing.assert_array_equal(np.asarray(a.final_state.store.wts),
                                  np.asarray(b.final_state.store.wts))
    np.testing.assert_array_equal(np.asarray(a.final_state.store.rts),
                                  np.asarray(b.final_state.store.rts))
    np.testing.assert_array_equal(np.asarray(a.final_state.store.mv_begin),
                                  np.asarray(b.final_state.store.mv_begin))
    np.testing.assert_array_equal(np.asarray(a.final_state.store.mv_head),
                                  np.asarray(b.final_state.store.mv_head))
    np.testing.assert_array_equal(
        np.asarray(a.final_state.pending_live),
        np.asarray(b.final_state.pending_live))


@pytest.mark.parametrize("cc", [t.CC_2PL, t.CC_SWISS, t.CC_ADAPTIVE])
@pytest.mark.parametrize("gran", [0, 1])
def test_run_backend_parity_lock_mechanisms(cc, gran):
    """The lock-based mechanisms compose the surface differently (claim_r
    scatters, dual claim_w/claim_r probes, Adaptive's pess-masked visible
    reads) — the README backend matrix promises them the same no-fallback
    bit-identity, so prove it end-to-end too."""
    wl = WORKLOADS["ycsb"]
    cfg = EngineConfig(cc=cc, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       granularity=gran, n_rings=wl.n_rings)
    a = run(cfg, wl, n_waves=6, seed=0, keep_state=True)
    b = run(dataclasses.replace(cfg, backend="pallas"), wl, n_waves=6,
            seed=0, keep_state=True)
    np.testing.assert_array_equal(np.asarray(a.per_wave_commits),
                                  np.asarray(b.per_wave_commits))
    assert (a.commits, a.aborts) == (b.commits, b.aborts)
    np.testing.assert_array_equal(np.asarray(a.final_state.store.wts),
                                  np.asarray(b.final_state.store.wts))
    np.testing.assert_array_equal(np.asarray(a.final_state.store.claim_r),
                                  np.asarray(b.final_state.store.claim_r))


# --------------------------------------------------- sweep-grid parity
def test_sweep_backend_parity_all_mechanisms():
    """Bit-identical SweepPoints jnp vs pallas for OCC, TicToc, AutoGran,
    MVCC, and MV-OCC at both granularities (ISSUE acceptance criterion)."""
    wl = WORKLOADS["ycsb"]
    ccs = [t.CC_OCC, t.CC_TICTOC, t.CC_AUTOGRAN, t.CC_MVCC, t.CC_MVOCC]
    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       n_rings=wl.n_rings, mv_depth=3)
    a = sweep(cfg, wl, 4, ccs=ccs, grans=(0, 1), lane_counts=(8,),
              seeds=(0,))
    b = sweep(dataclasses.replace(cfg, backend="pallas"), wl, 4, ccs=ccs,
              grans=(0, 1), lane_counts=(8,), seeds=(0,))
    assert a == b  # SweepPoint dataclass equality: every field, every point


# --------------------------------------------------- open-loop parity
@pytest.mark.parametrize("cc", [t.CC_OCC, t.CC_TICTOC, t.CC_MVCC])
def test_open_loop_run_backend_parity(cc):
    """The open-loop front-end rides the same backend surface: queue state
    (ring buffers AND cursors), latency histograms, and every conservation
    counter must be bit-identical jnp vs pallas (ISSUE 6 satellite)."""
    wl = YCSBWorkload.make(n_keys=256, theta=0.8)
    cfg = EngineConfig(cc=cc, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       n_rings=wl.n_rings,
                       mv_depth=3 if cc in t.MV_CCS else 0,
                       arrival_rate=6.0, queue_cap=32, max_incarnations=3,
                       lat_bins=16)
    a = run(cfg, wl, n_waves=12, seed=4, keep_state=True)
    b = run(dataclasses.replace(cfg, backend="pallas"), wl, n_waves=12,
            seed=4, keep_state=True)
    np.testing.assert_array_equal(np.asarray(a.per_wave_commits),
                                  np.asarray(b.per_wave_commits))
    assert (a.commits, a.aborts, a.offered, a.admitted, a.arrival_drops,
            a.inc_drops, a.queued_final) == \
           (b.commits, b.aborts, b.offered, b.admitted, b.arrival_drops,
            b.inc_drops, b.queued_final)
    assert a.p50_ttc == b.p50_ttc and a.p99_ttc == b.p99_ttc
    np.testing.assert_array_equal(np.asarray(a.lat_hist),
                                  np.asarray(b.lat_hist))
    qa, qb = a.final_state.ol.queue, b.final_state.ol.queue
    for f in ("op_key", "op_kind", "admit_wave", "incarnation", "txn_id",
              "head", "size"):
        np.testing.assert_array_equal(np.asarray(getattr(qa, f)),
                                      np.asarray(getattr(qb, f)), err_msg=f)
    assert a.commits > 0 and a.aborts > 0  # parity over real traffic


def test_open_loop_sweep_backend_parity():
    """Open-loop SweepPoints (goodput, queue counters, ttc percentiles)
    bit-identical jnp vs pallas across occ/mvcc x both granularities."""
    wl = YCSBWorkload.make(n_keys=256, theta=0.8)
    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       n_rings=wl.n_rings, mv_depth=3,
                       arrival_rate=6.0, queue_cap=32, max_incarnations=3,
                       lat_bins=16)
    ccs = [t.CC_OCC, t.CC_MVCC]
    a = sweep(cfg, wl, 8, ccs=ccs, grans=(0, 1), lane_counts=(8,),
              seeds=(4,))
    b = sweep(dataclasses.replace(cfg, backend="pallas"), wl, 8, ccs=ccs,
              grans=(0, 1), lane_counts=(8,), seeds=(4,))
    for pa, pb in zip(a, b):
        # goodput/throughput divide by identical sim time; compare the
        # whole dataclass minus nothing — they must match exactly.
        assert pa == pb, (pa.cc, pa.granularity)
        assert pa.open_loop


# ------------------------------------- shared layout: claims vs kernel oracle
@pytest.mark.parametrize("fine", [True, False])
def test_claims_probe_matches_kernel_oracle(fine):
    """The engine's jnp probe and the kernel oracle decode identical claim
    words — the core/claimword.py contract both backends build on."""
    T, K, N, G = 5, 6, 64, 2
    table = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    myp = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    check = jnp.asarray(RNG.random((T, K)) < 0.8) & (keys >= 0)
    wave = jnp.uint32(3)

    wprio = (claims.probe(table, keys, groups, wave) if fine
             else claims.probe_any_group(table, keys, wave))
    via_claims = check & (wprio < myp)
    via_oracle = ref.occ_validate(table, keys, groups, myp, check,
                                  claims.inv_wave(wave), fine)
    np.testing.assert_array_equal(np.asarray(via_claims),
                                  np.asarray(via_oracle))


def test_no_backend_branches_left_in_cc():
    """The refactor's contract: zero per-mechanism ``cfg.backend`` branches
    in cc/*.py — all routing goes through core/backend.py (ISSUE acceptance
    criterion)."""
    import pathlib

    import repro.core.cc as cc_pkg
    pkg_dir = pathlib.Path(cc_pkg.__file__).parent
    for path in pkg_dir.glob("*.py"):
        assert "cfg.backend" not in path.read_text(), path.name
