"""Multi-version store + mechanism semantics: snapshot reads, FCW
write-write rules, the read-only no-abort guarantee, ring reclamation,
aged reader snapshots (snapshot_age), and the value-oracle serializability
check (thinning disabled where rules must be deterministic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import mvstore
from repro.core import types as t
from repro.core.cc import mvcc, mvocc
from repro.core.engine import run, sweep
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init
from repro.kernels import ref
from repro.workloads import YCSBWorkload

EXACT = CostModel(opt_overlap=1.0, phase_overlap=1.0)


def make_cfg(cc, lanes, slots, gran=1, n_rec=8, depth=3, **kw):
    return EngineConfig(cc=cc, lanes=lanes, slots=slots, n_records=n_rec,
                        n_groups=2, n_cols=0, n_txn_types=1,
                        granularity=gran, mv_depth=depth, cost=EXACT, **kw)


def batch_of(ops, lanes, slots):
    """ops: list per lane of (key, group, kind) tuples."""
    ks = np.full((lanes, slots), -1, np.int32)
    gs = np.zeros((lanes, slots), np.int32)
    kd = np.zeros((lanes, slots), np.int32)
    for i, lane in enumerate(ops):
        for j, (k, g, kind) in enumerate(lane):
            ks[i, j], gs[i, j], kd[i, j] = k, g, kind
    return TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                    op_col=jnp.zeros((lanes, slots), jnp.int32),
                    op_kind=jnp.asarray(kd),
                    op_val=jnp.zeros((lanes, slots), jnp.float32),
                    txn_type=jnp.zeros((lanes,), jnp.int32),
                    n_ops=jnp.asarray([len(l) for l in ops], jnp.int32))


# ----------------------------------------------------------- protocol rules
def test_mvcc_reader_survives_concurrent_writer():
    """The MV headline vs the paper's Figure 1: a reader of a cell a
    stronger lane writes this wave commits anyway — it reads its snapshot
    version instead of aborting (single-version OCC aborts it)."""
    ops = [[(0, 0, t.READ)],          # Txn 1 (later prio)
           [(0, 0, t.WRITE)]]         # Txn 2 (earlier prio, commits first)
    batch = batch_of(ops, 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)
    for mod, cc in ((mvcc, t.CC_MVCC), (mvocc, t.CC_MVOCC)):
        store = store_init(8, 2, 0, mv_depth=3)
        _, res = mod.wave_validate(store, batch, prio, jnp.uint32(0),
                                   make_cfg(cc, 2, 2))
        # mvocc exempts the reader too: it is read-only (no write set).
        assert list(np.asarray(res.commit)) == [True, True], t.CC_NAMES[cc]


def test_mvocc_update_reader_aborts_readonly_does_not():
    """MV-OCC read validation only applies to update transactions: the same
    conflicted read aborts a lane that also writes, but not a pure reader
    (it serializes at its snapshot)."""
    update_reader = [[(0, 0, t.READ), (5, 0, t.WRITE)],
                     [(0, 0, t.WRITE)]]
    batch = batch_of(update_reader, 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)
    store = store_init(8, 2, 0, mv_depth=3)
    _, res = mvocc.wave_validate(store, batch, prio, jnp.uint32(0),
                                 make_cfg(t.CC_MVOCC, 2, 2))
    assert list(np.asarray(res.commit)) == [False, True]
    # same shape under mvcc (snapshot isolation): both commit
    store = store_init(8, 2, 0, mv_depth=3)
    _, res = mvcc.wave_validate(store, batch, prio, jnp.uint32(0),
                                make_cfg(t.CC_MVCC, 2, 2))
    assert list(np.asarray(res.commit)) == [True, True]


@pytest.mark.parametrize("mod,cc", [(mvcc, t.CC_MVCC), (mvocc, t.CC_MVOCC)])
def test_first_committer_wins_granularity(mod, cc):
    """Write-write conflicts honor the granularity switch: different-group
    writers of one record both commit under fine timestamps, the weaker
    aborts under coarse (the paper's false conflicts, at the version ring).
    Same-group writers conflict at both granularities."""
    diff_group = batch_of([[(3, 0, t.WRITE)], [(3, 1, t.WRITE)]], 2, 2)
    same_group = batch_of([[(3, 1, t.WRITE)], [(3, 1, t.WRITE)]], 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)
    for gran, batch, want in ((0, diff_group, [False, True]),
                              (1, diff_group, [True, True]),
                              (0, same_group, [False, True]),
                              (1, same_group, [False, True])):
        store = store_init(8, 2, 0, mv_depth=3)
        _, res = mod.wave_validate(store, batch, prio, jnp.uint32(0),
                                   make_cfg(cc, 2, 2, gran=gran))
        assert list(np.asarray(res.commit)) == want, (gran, want)


@pytest.mark.parametrize("mod,cc", [(mvcc, t.CC_MVCC), (mvocc, t.CC_MVOCC)])
def test_add_add_commutes_write_add_conflicts(mod, cc):
    """Blind commutative ADDs keep their STO semantics on the MV path:
    ADD-ADD pairs both commit, WRITE-vs-ADD aborts the weaker lane."""
    prio = jnp.asarray([1, 0], jnp.uint32)
    adds = batch_of([[(2, 1, t.ADD)], [(2, 1, t.ADD)]], 2, 2)
    store = store_init(8, 2, 0, mv_depth=3)
    _, res = mod.wave_validate(store, adds, prio, jnp.uint32(0),
                               make_cfg(cc, 2, 2))
    assert list(np.asarray(res.commit)) == [True, True]
    mixed = batch_of([[(2, 1, t.ADD)], [(2, 1, t.WRITE)]], 2, 2)
    store = store_init(8, 2, 0, mv_depth=3)
    _, res = mod.wave_validate(store, mixed, prio, jnp.uint32(0),
                               make_cfg(cc, 2, 2))
    assert list(np.asarray(res.commit)) == [False, True]


# ------------------------------------------------------- ring + reclamation
def test_duplicate_in_txn_writes_claim_one_slot():
    """Two writes of the same record inside ONE transaction merge into a
    single new ring version (head advances once), and the value path
    resolves them in program order (the second write wins)."""
    ops = [[(1, 0, t.WRITE), (1, 0, t.WRITE)]]
    batch = batch_of(ops, 1, 2)
    batch = dataclasses.replace(
        batch, op_val=jnp.asarray([[4.0, 9.0]], jnp.float32))
    prio = jnp.asarray([0], jnp.uint32)
    store = store_init(8, 2, 1, mv_depth=3)
    cfg = make_cfg(t.CC_MVCC, 1, 2, n_rec=8, track_values=True)
    cfg = dataclasses.replace(cfg, n_cols=1)
    store2, res = mvcc.wave_validate(store, batch, prio, jnp.uint32(0), cfg)
    assert list(np.asarray(res.commit)) == [True]
    head = np.asarray(store2.mv_head)
    assert head[1] == 1 and (head[np.arange(8) != 1] == 0).all()
    begin = np.asarray(store2.mv_begin)
    assert begin[1, 1, 0] == 1          # published install ts (wave 0 + 1)
    assert begin[1, 1, 1] == 0          # carried from the initial version
    assert np.asarray(store2.mv_vals)[1, 1, 0] == 9.0   # program order wins
    # snapshot read helpers: the next wave sees 9.0, the install wave's own
    # snapshot still sees the initial 0.0
    keys = jnp.asarray([[1]], jnp.int32)
    zero = jnp.zeros((1, 1), jnp.int32)
    v1, ok1 = mvstore.snapshot_values(store2.mv_vals, store2.mv_begin, keys,
                                      zero, zero, jnp.uint32(1), True)
    v0, ok0 = mvstore.snapshot_values(store2.mv_vals, store2.mv_begin, keys,
                                      zero, zero, jnp.uint32(0), True)
    assert bool(np.asarray(ok1)[0, 0]) and np.asarray(v1)[0, 0] == 9.0
    assert bool(np.asarray(ok0)[0, 0]) and np.asarray(v0)[0, 0] == 0.0


def test_ring_overflow_reclaims_oldest_and_aborts_stale_readers():
    """Fill a depth-2 ring past capacity: the oldest version is recycled,
    a snapshot that still fits commits, and a snapshot older than every
    retained slot reports reclaimed (ok False) — never a garbage read."""
    D = 2
    begin, head, _ = mvstore.mv_init(4, D, 2)
    keys = jnp.asarray([[0]], jnp.int32)
    grps = jnp.zeros((1, 1), jnp.int32)
    do = jnp.asarray([[True]])
    for wave in range(3):   # install at ts 1, 2, 3 -> initial v0 reclaimed
        begin, head = ref.mv_install(begin, head, keys, grps, do,
                                     jnp.uint32(wave + 1))
    # retained: versions with begin 2 and 3; begin-0 and begin-1 reclaimed
    _, ok_new = ref.mv_gather(begin, keys, grps, jnp.uint32(3), True)
    _, ok_mid = ref.mv_gather(begin, keys, grps, jnp.uint32(2), True)
    _, ok_old = ref.mv_gather(begin, keys, grps, jnp.uint32(1), True)
    _, ok_zero = ref.mv_gather(begin, keys, grps, jnp.uint32(0), True)
    assert bool(np.asarray(ok_new)[0, 0]) and bool(np.asarray(ok_mid)[0, 0])
    assert not np.asarray(ok_old)[0, 0]
    assert not np.asarray(ok_zero)[0, 0]
    # mechanism level: a reader whose snapshot predates the ring aborts
    # cleanly (conflict, not garbage).  Build a store whose record-0 ring
    # only retains future versions relative to wave 0's snapshot.
    store = store_init(4, 2, 0, mv_depth=D)
    store = dataclasses.replace(store, mv_begin=begin, mv_head=head)
    rd = batch_of([[(0, 0, t.READ)]], 1, 2)
    _, res = mvcc.wave_validate(store, rd, jnp.asarray([0], jnp.uint32),
                                jnp.uint32(0), make_cfg(t.CC_MVCC, 1, 2,
                                                        n_rec=4, depth=D))
    assert list(np.asarray(res.commit)) == [False]
    # an untouched record is still readable at the same snapshot
    rd2 = batch_of([[(1, 0, t.READ)]], 1, 2)
    _, res2 = mvcc.wave_validate(store, rd2, jnp.asarray([0], jnp.uint32),
                                 jnp.uint32(0), make_cfg(t.CC_MVCC, 1, 2,
                                                         n_rec=4, depth=D))
    assert list(np.asarray(res2.commit)) == [True]


def test_snapshot_reads_time_travel_per_group():
    """Fine-granularity visibility is per column group: a group-1-only
    update leaves group-0 snapshots on the older version's timestamp, while
    coarse visibility treats the record as one unit."""
    begin, head, _ = mvstore.mv_init(4, 3, 2)
    keys = jnp.asarray([[2]], jnp.int32)
    g1 = jnp.ones((1, 1), jnp.int32)
    do = jnp.asarray([[True]])
    begin, head = ref.mv_install(begin, head, keys, g1, do, jnp.uint32(5))
    g0 = jnp.zeros((1, 1), jnp.int32)
    # snapshot ts=3 predates the group-1 update
    s_f0, ok_f0 = ref.mv_gather(begin, keys, g0, jnp.uint32(3), True)
    s_f1, ok_f1 = ref.mv_gather(begin, keys, g1, jnp.uint32(3), True)
    s_c, ok_c = ref.mv_gather(begin, keys, g0, jnp.uint32(3), False)
    assert bool(np.asarray(ok_f0)[0, 0]) and bool(np.asarray(ok_f1)[0, 0])
    # group 0 reads the NEW slot (carried begin 0 <= 3, newest value equal);
    # group 1 must fall back to the pre-update slot
    assert np.asarray(s_f1)[0, 0] == 0
    # coarse: the new slot's record-level ts is 5 > 3 -> old slot
    assert bool(np.asarray(ok_c)[0, 0]) and np.asarray(s_c)[0, 0] == 0


# ----------------------------------------------------- end-to-end + metrics
@pytest.mark.parametrize("cc", [t.CC_MVCC, t.CC_MVOCC])
def test_engine_attempts_accounting(cc):
    wl = YCSBWorkload.make(n_keys=500)
    cfg = EngineConfig(cc=cc, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       granularity=1, n_rings=wl.n_rings, mv_depth=4)
    r = run(cfg, wl, n_waves=15, seed=1)
    assert r.commits + r.aborts == 8 * 15
    assert r.commits > 0


@pytest.mark.parametrize("cc", [t.CC_MVCC, t.CC_MVOCC])
@pytest.mark.parametrize("gran", [0, 1])
def test_mv_values_match_sequential_replay(cc, gran):
    """Value oracle (ISSUE acceptance criterion): the newest ring version of
    every record must equal the engine's serially-replayed store values —
    committed MV transactions are explainable by the wave serialization
    order, at both granularities."""
    wl = YCSBWorkload.make(n_keys=48, theta=0.6, ops_per_txn=4,
                           write_frac=0.6)
    cfg = EngineConfig(cc=cc, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       granularity=gran, n_rings=wl.n_rings, mv_depth=4,
                       track_values=True, cost=EXACT)
    r = run(cfg, wl, n_waves=12, seed=3, keep_state=True)
    assert r.commits > 0
    store = r.final_state.store
    N, C = store.values.shape
    # newest version per record = slot at mv_head
    heads = np.asarray(store.mv_head)
    ring_newest = np.asarray(store.mv_vals)[np.arange(N), heads, :]
    np.testing.assert_allclose(ring_newest, np.asarray(store.values),
                               rtol=1e-6, atol=1e-6)


def test_mv_add_sum_conservation():
    """Committed ADD deltas land exactly once in the ring's newest versions
    (the track_values conservation law, on the MV path)."""
    wl = YCSBWorkload.make(n_keys=32, theta=0.5, ops_per_txn=4,
                           write_frac=1.0)

    class AddWorkload:
        n_records = wl.n_records
        n_groups = wl.n_groups
        n_cols = wl.n_cols
        n_rings = wl.n_rings
        n_txn_types = 1
        slots = wl.slots

        def init_store(self, track_values=False, mv_depth=0):
            return wl.init_store(track_values, mv_depth=mv_depth)

        def gen(self, rng, wave, lanes, tails):
            b, tails = wl.gen(rng, wave, lanes, tails)
            b = dataclasses.replace(
                b, op_kind=jnp.where(b.op_kind == t.WRITE, t.ADD, b.op_kind),
                op_val=jnp.ones_like(b.op_val))
            return b, tails

    cfg = EngineConfig(cc=t.CC_MVCC, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=1, granularity=1,
                       mv_depth=4, track_values=True, cost=EXACT)
    r = run(cfg, AddWorkload(), n_waves=10, seed=3, keep_state=True)
    store = r.final_state.store
    heads = np.asarray(store.mv_head)
    newest = np.asarray(store.mv_vals)[np.arange(wl.n_records), heads, :]
    assert newest.sum() == pytest.approx(r.commits * wl.slots)


def test_readonly_abort_rate_zero_mvcc_nonzero_occ():
    """The acceptance headline, in-suite: under a write-heavy
    high-contention YCSB mix with read-only clients, the MV mechanisms'
    read-only abort rate is exactly 0 in the same sweep where coarse
    single-version OCC's is nonzero."""
    wl = YCSBWorkload.make(n_keys=96, theta=0.9, write_frac=0.8,
                           ro_frac=0.25)
    cfg = EngineConfig(cc=t.CC_OCC, lanes=16, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                       n_rings=wl.n_rings, mv_depth=4)
    pts = sweep(cfg, wl, 25, ccs=[t.CC_OCC, t.CC_MVCC, t.CC_MVOCC],
                grans=(0, 1), lane_counts=(16,), seeds=(0,))
    by = {(p.cc, p.granularity): p for p in pts}
    occ_c = by[(t.CC_OCC, 0)]
    assert occ_c.ro_aborts > 0 and occ_c.ro_abort_rate > 0
    for cc in (t.CC_MVCC, t.CC_MVOCC):
        for g in (0, 1):
            p = by[(cc, g)]
            assert p.ro_commits > 0
            assert p.ro_aborts == 0, (t.CC_NAMES[cc], g)
            assert p.ro_abort_rate == 0.0


def test_mv_requires_depth():
    with pytest.raises(ValueError, match="mv_depth"):
        EngineConfig(cc=t.CC_MVCC, lanes=4, slots=4, n_records=16,
                     n_groups=2, n_cols=0, n_txn_types=1)


# ------------------------------------------------- aged reader snapshots
def test_snapshot_age_config_validation():
    with pytest.raises(ValueError, match="snapshot_age"):
        EngineConfig(cc=t.CC_OCC, lanes=4, slots=4, n_records=16,
                     n_groups=2, n_cols=0, n_txn_types=1, snapshot_age=2)
    with pytest.raises(ValueError, match="snapshot_age"):
        EngineConfig(cc=t.CC_MVCC, lanes=4, slots=4, n_records=16,
                     n_groups=2, n_cols=0, n_txn_types=1, mv_depth=2,
                     snapshot_age=-1)


def test_snapshot_ts_ages_and_saturates():
    """snapshot_ts(w, age) = w - age, saturating at 0 so the earliest waves
    still see the initial versions."""
    assert int(mvstore.snapshot_ts(jnp.uint32(9), 3)) == 6
    assert int(mvstore.snapshot_ts(jnp.uint32(2), 5)) == 0
    assert int(mvstore.snapshot_ts(jnp.uint32(7))) == 7


def test_aged_reader_aborts_once_ring_outruns_it():
    """Mechanism level: a reader whose snapshot is pinned ``age`` waves back
    commits while the ring still retains its version and aborts cleanly
    (reclamation, ok=False) once writers have recycled it — deterministic,
    never thinned."""
    D_, age = 2, 4
    begin, head, _ = mvstore.mv_init(4, D_, 2)
    keys = jnp.asarray([[0]], jnp.int32)
    grps = jnp.zeros((1, 1), jnp.int32)
    do = jnp.asarray([[True]])
    rd = batch_of([[(0, 0, t.READ)]], 1, 2)
    prio = jnp.asarray([0], jnp.uint32)
    cfg = make_cfg(t.CC_MVCC, 1, 2, n_rec=4, depth=D_, snapshot_age=age)
    for wave in range(8):
        store = store_init(4, 2, 0, mv_depth=D_)
        store = dataclasses.replace(store, mv_begin=begin, mv_head=head)
        _, res = mvcc.wave_validate(store, rd, prio, jnp.uint32(wave), cfg)
        # retained begins after w installs: {w-1, w} (plus initial 0 early);
        # aged snapshot max(wave-age, 0) falls off once wave-age < wave-1.
        snap = max(wave - age, 0)
        retained = {max(wave - 1, 0), wave}
        want = any(b <= snap for b in retained)
        assert bool(np.asarray(res.commit)[0]) == want, wave
        # writers push one new version per wave
        begin, head = ref.mv_install(begin, head, keys, grps, do,
                                     jnp.uint32(wave + 1))
    assert not bool(np.asarray(res.commit)[0])   # it did eventually abort


def test_engine_snapshot_age_reclamation_aborts_end_to_end():
    """Engine level: under a write-heavy contended YCSB mix with read-only
    clients and a shallow ring, snapshot_age > 0 produces nonzero
    reclamation (read-only) aborts where the age-0 control has none."""
    wl = YCSBWorkload.make(n_keys=32, theta=0.95, write_frac=0.9,
                           ro_frac=0.3, ops_per_txn=4)
    base = dict(lanes=16, slots=wl.slots, n_records=wl.n_records,
                n_groups=wl.n_groups, n_cols=wl.n_cols,
                n_txn_types=wl.n_txn_types, n_rings=wl.n_rings,
                granularity=1, mv_depth=2)
    aged = run(EngineConfig(cc=t.CC_MVCC, snapshot_age=6, **base), wl,
               n_waves=30, seed=0)
    fresh = run(EngineConfig(cc=t.CC_MVCC, **base), wl, n_waves=30, seed=0)
    assert fresh.ro_aborts == 0
    assert aged.ro_aborts > 0
    assert aged.ro_commits > 0           # early waves still commit
    assert aged.commits + aged.aborts == fresh.commits + fresh.aborts


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_stale_snapshots_abort_and_never_read_reclaimed(seed):
    """Property (ISSUE 5 satellite): under snapshot_age > 0 and ring
    overflow, every stale snapshot gets ok=False, and whenever ok is True
    the value oracle returns exactly the version a serial history would —
    never a reclaimed slot's bytes."""
    rng = np.random.default_rng(seed)
    N, D_, G = 4, int(rng.integers(2, 4)), 1
    age = int(rng.integers(1, 6))
    begin, head, vals = mvstore.mv_init(N, D_, G, n_cols=1)
    # serial history per record: [(begin_ts, value)], initial version 0.0
    hist = {r: [(0, 0.0)] for r in range(N)}
    for wave in range(8):
        ts = wave + 1
        for r in range(N):
            if rng.random() < 0.5:
                continue
            k = jnp.asarray([[r]], jnp.int32)
            g = jnp.zeros((1, 1), jnp.int32)
            do = jnp.asarray([[True]])
            h_old = int(head[r])
            begin, head = ref.mv_install(begin, head, k, g, do,
                                         jnp.uint32(ts))
            h_new = int(head[r])
            v = float(ts * 10 + r)
            vals = vals.at[r, h_new, :].set(vals[r, h_old, :])
            vals = vals.at[r, h_new, 0].set(v)
            hist[r].append((ts, v))
        # aged snapshot of a wave-`wave` reader
        snap = max(wave - age, 0)
        keys = jnp.asarray([[r for r in range(N)]], jnp.int32)
        zz = jnp.zeros((1, N), jnp.int32)
        got_v, got_ok = mvstore.snapshot_values(
            vals, begin, keys, zz, zz, jnp.uint32(snap), True)
        for r in range(N):
            retained = hist[r][-D_:]
            visible = [(b, v) for b, v in retained if b <= snap]
            ok = bool(np.asarray(got_ok)[0, r])
            assert ok == bool(visible), (wave, r)
            if ok:
                # newest visible retained version — the serial answer; a
                # reclaimed slot's bytes would differ (every value unique)
                assert np.asarray(got_v)[0, r] == max(visible)[1], (wave, r)
