"""Interval read-sets end to end (ISSUE 10): the ``iterate_validate``
oracle and kernel, extent-1 bit-identity with the pre-interval engine,
phantom-cause conservation, the numpy sequential-replay phantom oracle
(hypothesis), and the distributed scan wave — fragment splitting,
backend parity, pipeline-depth identity.

Runs in the plain tier-1 suite (1-shard degenerate meshes) and in both
8-host-device CI suite lists, where the distributed tests exercise real
multi-shard interval splitting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import claimword as cw
from repro.core import distributed as D
from repro.core import types as t
from repro.core.cc import base, occ_validate
from repro.core.engine import run
from repro.kernels import ref
from repro.kernels.iterate_validate import iterate_validate_pallas
from repro.workloads import TPCCWorkload, YCSBWorkload

EXACT = t.CostModel(opt_overlap=1.0, phase_overlap=1.0)

_CC_MODULES = {"2pl": "two_pl"}
WAVE_VALIDATE = {}
for _name in t.CC_IDS:
    _mod = __import__(f"repro.core.cc.{_CC_MODULES.get(_name, _name)}",
                      fromlist=["wave_validate"])
    WAVE_VALIDATE[_name] = _mod.wave_validate


def _full_mesh():
    """One shard per available host device (8 under the CI XLA_FLAGS)."""
    return jax.make_mesh((len(jax.devices()),), ("data",))


def scan_batch(rng, T, K, N, ext_cap, p_scan=0.3):
    """Random mixed batch: point READ/WRITE ops plus interval READs of
    extent 2..ext_cap, clamped to stay inside the table."""
    keys = rng.integers(0, N, (T, K), dtype=np.int32)
    groups = rng.integers(0, 2, (T, K), dtype=np.int32)
    kinds = rng.choice([t.READ, t.WRITE], (T, K)).astype(np.int32)
    ext = np.ones((T, K), np.int32)
    sc = (rng.random((T, K)) < p_scan) & (kinds == t.READ)
    if sc.any() and ext_cap > 1:
        ext[sc] = rng.integers(2, ext_cap + 1, sc.sum())
    keys = np.minimum(keys, N - ext)
    return keys, groups, kinds, ext


def txn_batch(keys, groups, kinds, ext=None):
    T, K = keys.shape
    kw = {} if ext is None else {"op_extent": jnp.asarray(ext)}
    return t.TxnBatch(op_key=jnp.asarray(keys), op_group=jnp.asarray(groups),
                      op_col=jnp.zeros((T, K), jnp.int32),
                      op_kind=jnp.asarray(kinds),
                      op_val=jnp.zeros((T, K), jnp.float32),
                      txn_type=jnp.zeros((T,), jnp.int32),
                      n_ops=jnp.full((T,), K, jnp.int32), **kw)


def engine_cfg(cc, T, K, N, gran, *, ext=1, backend="jnp", **kw):
    return t.EngineConfig(cc=cc, lanes=T, slots=K, n_records=N, n_groups=2,
                          n_cols=0, n_txn_types=1, granularity=gran,
                          cost=EXACT, max_extent=ext, backend=backend,
                          mv_depth=4 if cc in t.MV_CCS else 0, **kw)


def ycsb_cfg(cc, wl, lanes=32, gran=1, backend="jnp", **kw):
    kw.setdefault("mv_depth", 4 if cc in t.MV_CCS else 0)
    return t.EngineConfig(cc=cc, lanes=lanes, slots=wl.slots,
                          n_records=wl.n_records, n_groups=wl.n_groups,
                          n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                          granularity=gran, n_rings=wl.n_rings,
                          backend=backend, max_extent=wl.max_extent, **kw)


# ------------------------------------------------ oracle semantics (jnp)
def test_iterate_validate_oracle_semantics():
    """Handwritten cases pinning the interval-conflict rule: fine probes
    the op's group over [key, key+ext), coarse probes the row-min over
    the bucket expansion; only STRICTLY stronger live same-wave claims
    conflict; stale claims, masked ops, and OOB tails never do."""
    N, G = 32, 2
    ivw = jnp.uint32(0xFFFF - 5)
    word = (ivw.astype(jnp.uint32) << 16) | jnp.uint32(3)
    tbl = jnp.full((N, G), cw.EMPTY_WORD, jnp.uint32).at[10, 1].set(word)

    keys = jnp.array([[8, 8, 0]], jnp.int32)
    ext = jnp.array([[4, 4, 1]], jnp.int32)
    grp = jnp.array([[1, 0, 1]], jnp.int32)
    pri = jnp.array([[7, 7, 7]], jnp.uint32)
    chk = jnp.array([[True, True, True]])

    # fine: op0 scans [8,12) group1 -> row10/g1 claim (3 < 7) conflicts;
    # op1 scans group0 -> clean; op2 points elsewhere -> clean.
    c = ref.iterate_validate(tbl, keys, ext, grp, pri, chk, ivw, True, 8, 4)
    assert c.tolist() == [[True, False, False]]
    # coarse B=8: [8,12) expands to [8,16) any-group -> op1 conflicts too;
    # op2's bucket [0,8) holds no claim.
    c = ref.iterate_validate(tbl, keys, ext, grp, pri, chk, ivw, False, 8, 4)
    assert c.tolist() == [[True, True, False]]
    # coarse edge: key=15 ext=1 expands to [8,16) -> catches row 10.
    c = ref.iterate_validate(tbl, jnp.array([[15]], jnp.int32),
                             jnp.array([[1]], jnp.int32),
                             jnp.array([[0]], jnp.int32),
                             jnp.array([[7]], jnp.uint32),
                             jnp.array([[True]]), ivw, False, 8, 4)
    assert c.tolist() == [[True]]
    # strictly-stronger rule: prio 2 beats the claim, equal prio (3) is
    # the scanner's OWN claim — neither conflicts.
    for p in (2, 3):
        c = ref.iterate_validate(tbl, jnp.array([[8]], jnp.int32),
                                 jnp.array([[4]], jnp.int32),
                                 jnp.array([[1]], jnp.int32),
                                 jnp.array([[p]], jnp.uint32),
                                 jnp.array([[True]]), ivw, True, 8, 4)
        assert not bool(c[0, 0]), p
    # stale (previous-wave) claim is invisible.
    old = (jnp.uint32(0xFFFF - 4) << 16) | jnp.uint32(1)
    tbl2 = jnp.full((N, G), cw.EMPTY_WORD, jnp.uint32).at[10, 1].set(old)
    c = ref.iterate_validate(tbl2, keys, ext, grp, pri, chk, ivw, True, 8, 4)
    assert not c.any()
    # OOB tail clean; masked ops clean; ext_cap=1 degenerates to a point.
    c = ref.iterate_validate(tbl, jnp.array([[30]], jnp.int32),
                             jnp.array([[4]], jnp.int32),
                             jnp.array([[1]], jnp.int32),
                             jnp.array([[7]], jnp.uint32),
                             jnp.array([[True]]), ivw, True, 8, 4)
    assert not c.any()
    c = ref.iterate_validate(tbl, keys, ext, grp, pri,
                             jnp.zeros_like(chk), ivw, True, 8, 4)
    assert not c.any()
    c = ref.iterate_validate(tbl, jnp.array([[10]], jnp.int32),
                             jnp.array([[1]], jnp.int32),
                             jnp.array([[1]], jnp.int32),
                             jnp.array([[7]], jnp.uint32),
                             jnp.array([[True]]), ivw, True, 8, 1)
    assert c.tolist() == [[True]]


def test_iterate_validate_kernel_matches_oracle():
    """Fuzz the Pallas kernel (interpret mode) against the jnp oracle
    over random tables (empty/live/stale words), OOB keys, both
    granularities, bucket sizes, and the ext_cap=1 degenerate case —
    including the lane_block=1 tiling override."""
    rng = np.random.default_rng(0)
    for trial in range(6):
        N, G, T, K, wave = 64, 2, 8, 3, 9
        ivw = jnp.uint32(0xFFFF - wave)
        tbl = np.full((N, G), cw.EMPTY_WORD, np.uint32)
        for _ in range(30):
            r, g = rng.integers(N), rng.integers(G)
            w = rng.choice([wave, wave, wave - 1])
            tbl[r, g] = ((0xFFFF - w) << 16) | rng.integers(0, 16)
        tbl = jnp.asarray(tbl)
        keys = jnp.asarray(rng.integers(-2, N + 4, (T, K)), jnp.int32)
        ext = jnp.asarray(rng.integers(1, 7, (T, K)), jnp.int32)
        grp = jnp.asarray(rng.integers(0, G, (T, K)), jnp.int32)
        pri = jnp.asarray(rng.integers(0, 16, (T, K)), jnp.uint32)
        chk = jnp.asarray(rng.random((T, K)) < 0.8)
        for fine in (True, False):
            for B in (4, 8):
                for cap in (1, 6):
                    want = ref.iterate_validate(tbl, keys, ext, grp, pri,
                                                chk, ivw, fine, B, cap)
                    got = iterate_validate_pallas(tbl, keys, ext, grp, pri,
                                                  chk, ivw, fine, B, cap,
                                                  interpret=True)
                    assert (want == got).all(), (trial, fine, B, cap)
                    got1 = iterate_validate_pallas(tbl, keys, ext, grp,
                                                   pri, chk, ivw, fine, B,
                                                   cap, lane_block=1,
                                                   interpret=True)
                    assert (want == got1).all(), (trial, fine, B, cap)


def test_scan_span_law_shared():
    """analysis/txn_cost.py charges by the SAME span law the kernels tile
    by — pinned here so the closed-form model can't drift from ref."""
    from repro.analysis.txn_cost import WaveShape
    for ext in (1, 2, 7, 8, 9, 16):
        for B in (4, 8):
            for gran in (0, 1):
                s = WaveShape(lanes=8, slots=4, granularity=gran,
                              max_extent=ext, bucket_size=B)
                assert s.scan_span == ref.scan_span(ext, gran == 1, B), \
                    (ext, B, gran)


# -------------------------------------------- extent-1 bit-identity guard
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", sorted(WAVE_VALIDATE))
def test_extent1_bit_identical_per_mechanism(cc, gran):
    """The fast-path guard: an all-point batch validated under a
    scan-enabled config (max_extent > 1, every extent 1) is bit-identical
    to the pre-interval point path (max_extent = 1) — verdicts, causes,
    and every store table."""
    rng = np.random.default_rng(3)
    N, T, K = 128, 16, 4
    keys, groups, kinds, _ = scan_batch(rng, T, K, N, ext_cap=1, p_scan=0)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    ccid = t.CC_IDS[cc]
    outs = {}
    for ext in (1, 4):
        cfg = engine_cfg(ccid, T, K, N, gran, ext=ext)
        store = t.store_init(N, 2, 0, mv_depth=cfg.mv_depth)
        batch = txn_batch(keys, groups, kinds)
        store2, res = WAVE_VALIDATE[cc](store, batch, prio, jnp.uint32(2),
                                        cfg)
        outs[ext] = (store2, res.commit, res.conflict_op, res.cause_op)
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[4])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------- engine runs: parity + causes
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", ["occ", "tictoc", "mvocc"])
def test_scan_engine_jnp_pallas_bit_identical(cc, gran):
    """Acceptance: scan workloads produce bit-identical engine stats on
    both backends (interpret mode on CPU)."""
    wl = YCSBWorkload.make(n_keys=4096, scan_frac=0.3, scan_len=8)
    res = {}
    for backend in ("jnp", "pallas"):
        cfg = ycsb_cfg(t.CC_IDS[cc], wl, lanes=16, gran=gran,
                       backend=backend)
        res[backend] = run(cfg, wl, n_waves=15, seed=4)
    a, b = res["jnp"], res["pallas"]
    assert a.commits == b.commits and a.aborts == b.aborts
    assert list(a.abort_causes) == list(b.abort_causes)
    assert a.commits_by_type == b.commits_by_type


def test_scan_sweep_jnp_pallas_bit_identical():
    """Same guarantee through the compiled-grid sweep path (the CLI's
    substrate): every grid point's counters match across backends."""
    from repro.core.engine import sweep
    wl = YCSBWorkload.make(n_keys=4096, scan_frac=0.3, scan_len=8)
    pts = {}
    for backend in ("jnp", "pallas"):
        cfg = ycsb_cfg(t.CC_OCC, wl, lanes=16, backend=backend,
                       mv_depth=4)
        pts[backend] = sweep(cfg, wl, 10, ccs=[t.CC_OCC, t.CC_MVOCC],
                             grans=(0, 1), lane_counts=(8, 16), seeds=(2,))
    for a, b in zip(pts["jnp"], pts["pallas"]):
        assert (a.cc, a.granularity, a.lanes) == (b.cc, b.granularity,
                                                  b.lanes)
        assert a.commits == b.commits and a.aborts == b.aborts
        assert list(a.abort_causes) == list(b.abort_causes)


@pytest.mark.parametrize("gran", [0, 1])
def test_phantom_cause_conservation_all_mechanisms(gran):
    """CAUSE_PHANTOM joins the taxonomy without breaking conservation:
    per-cause counts sum exactly to the abort count for every mechanism
    on a scan-heavy mix; mvcc reports ZERO phantoms (SI admits them);
    occ reports some."""
    wl = YCSBWorkload.make(n_keys=2048, scan_frac=0.4, scan_len=16)
    for cc in sorted(t.CC_IDS):
        cfg = ycsb_cfg(t.CC_IDS[cc], wl, lanes=32, gran=gran)
        r = run(cfg, wl, n_waves=20, seed=6)
        assert sum(r.abort_causes) == r.aborts, cc
        ph = r.abort_causes[t.CAUSE_PHANTOM]
        if cc == "mvcc":
            assert ph == 0, "snapshot scans admit phantoms by design"
        if cc == "occ":
            assert ph > 0, "expected phantoms in a scan-heavy occ mix"


def test_coarse_phantoms_dominate_fine():
    """The paper's granularity gap on the scan axis: bucket-interval
    claims over-approximate, so coarse phantom aborts >= fine on the
    same workload."""
    wl = YCSBWorkload.make(n_keys=2048, scan_frac=0.4, scan_len=16)
    ph = {}
    for gran in (0, 1):
        cfg = ycsb_cfg(t.CC_OCC, wl, lanes=32, gran=gran)
        ph[gran] = run(cfg, wl, n_waves=20, seed=6).abort_causes[
            t.CAUSE_PHANTOM]
    assert ph[0] >= ph[1] > 0


def test_tpcc_scan_classes_run():
    """TPC-C with scan_len > 0 gains Order-status/Stock-level; all txn
    types commit and the interval class produces phantoms under
    contention, with conservation intact."""
    wl = TPCCWorkload.make(n_warehouses=1, scale=0.05, scan_len=16)
    cfg = t.EngineConfig(cc=t.CC_OCC, lanes=32, slots=wl.slots,
                         n_records=wl.n_records, n_groups=wl.n_groups,
                         n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                         n_rings=wl.n_rings, max_extent=wl.max_extent)
    r = run(cfg, wl, n_waves=40, seed=0)
    assert sum(r.abort_causes) == r.aborts
    assert all(n > 0 for n in r.commits_by_type)
    assert r.abort_causes[t.CAUSE_PHANTOM] > 0


def test_open_loop_scan_conservation():
    """The admission queue carries op_extent: an open-loop scan run keeps
    cause conservation (including INC_CAP drops) and still sees
    phantoms on retried incarnations."""
    wl = YCSBWorkload.make(n_keys=2048, scan_frac=0.4, scan_len=8)
    cfg = ycsb_cfg(t.CC_OCC, wl, lanes=16, gran=0, arrival_rate=12.0,
                   queue_cap=64, max_incarnations=3)
    r = run(cfg, wl, n_waves=30, seed=1)
    assert r.open_loop
    assert sum(r.abort_causes) == r.aborts
    assert r.abort_causes[t.CAUSE_PHANTOM] > 0


# ------------------------------- numpy sequential-replay phantom oracle
def np_phantom_oracle(keys, groups, kinds, ext, prio, fine, B, N):
    """Sequential replay in numpy: install every live write op's claim
    (strongest priority per (record, group) cell), then walk each scan
    op's interval — fine probes its own group over [key, key+ext),
    coarse probes both groups over the bucket expansion.  A scan
    conflicts iff some covered cell holds a STRICTLY stronger claim."""
    T, K = keys.shape
    BIG = 1 << 30
    claim = np.full((N, 2), BIG, np.int64)
    for lane in range(T):
        for k in range(K):
            if kinds[lane, k] in (t.WRITE, t.ADD) and keys[lane, k] >= 0:
                r, g = keys[lane, k], groups[lane, k]
                claim[r, g] = min(claim[r, g], int(prio[lane]))
    out = np.zeros((T, K), bool)
    for lane in range(T):
        for k in range(K):
            if ext[lane, k] <= 1 or kinds[lane, k] == t.NOP:
                continue
            lo, hi = int(keys[lane, k]), int(keys[lane, k] + ext[lane, k])
            if not fine:
                lo, hi = (lo // B) * B, -(-hi // B) * B
            lo, hi = max(lo, 0), min(hi, N)
            for r in range(lo, hi):
                cells = ([claim[r, groups[lane, k]]] if fine
                         else [claim[r, 0], claim[r, 1]])
                if any(c < int(prio[lane]) for c in cells):
                    out[lane, k] = True
    return out


ORACLE_CCS = ["occ", "tictoc", "2pl", "swisstm", "adaptive", "mvcc",
              "mvocc"]


def check_phantom_replay(cc, backend, seed, gran):
    """Each mechanism's scan-op verdicts equal the numpy sequential-replay
    oracle — per mechanism x granularity x backend.  mvcc never flags a
    scan (snapshot cut); mvocc only re-validates lanes that wrote;
    everyone else takes the oracle verbatim, carrying CAUSE_PHANTOM on
    exactly the conflicting scan ops."""
    rng = np.random.default_rng(seed)
    N, T, K, EXT = 64, 8, 3, 6
    keys, groups, kinds, ext = scan_batch(rng, T, K, N, EXT, p_scan=0.5)
    prio = rng.permutation(T).astype(np.uint32)
    gran = int(gran)

    cfg = engine_cfg(t.CC_IDS[cc], T, K, N, gran, ext=EXT,
                     backend=backend)
    store = t.store_init(N, 2, 0, mv_depth=cfg.mv_depth)
    batch = txn_batch(keys, groups, kinds, ext)
    _, res = WAVE_VALIDATE[cc](store, batch, jnp.asarray(prio),
                               jnp.uint32(1), cfg)
    got = np.asarray(res.conflict_op)
    causes = np.asarray(res.cause_op)
    is_scan = ext > 1

    # AutoGran always scans at the coarse layout (an interval spans
    # records of mixed promotion state), so it is pinned separately in
    # the extent-1 guard, not here.
    fine = bool(gran)
    want = np_phantom_oracle(keys, groups, kinds, ext, prio, fine,
                             cfg.bucket_size, N)
    if cc == "mvcc":
        want = np.zeros_like(want)
    elif cc == "mvocc":
        has_write = ((kinds != t.READ) & (kinds != t.NOP)).any(axis=1)
        want = want & has_write[:, None]
    np.testing.assert_array_equal(got[is_scan], want[is_scan])
    assert (causes[want] == t.CAUSE_PHANTOM).all()
    assert (causes[is_scan & ~want] == t.CAUSE_NONE).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("cc", ORACLE_CCS)
def test_phantom_matches_replay_oracle_fixed(cc, backend):
    """Fixed-seed slice of the replay-oracle property — always runs,
    including where hypothesis is not installed."""
    for seed in (0, 1, 2):
        for gran in (0, 1):
            check_phantom_replay(cc, backend, seed, gran)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("cc", ORACLE_CCS)
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), gran=st.booleans())
def test_phantom_matches_replay_oracle(cc, backend, seed, gran):
    check_phantom_replay(cc, backend, seed, int(gran))


# -------------------------------------------------- distributed scans
def _pack(kinds, ext):
    """Caller-side extent transport: extents ride the kind channel's high
    bits, so every wave signature (and the admission ring) is unchanged."""
    return np.where(ext > 1, kinds | (ext << 2), kinds).astype(np.int32)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
def test_distributed_scan_local_parity(gran, backend):
    """The routed scan wave — interval fragments split at range-shard
    boundaries, owner-side iterate_validate, sender-side AND-reduce —
    commits exactly the local engine's lanes on the full mesh, with
    phantom causes conserved."""
    mesh = _full_mesh()
    ns = len(jax.devices())
    N, K, EXT = 512, 6, 8
    Tl = max(16 // ns, 2)
    T = ns * Tl
    rng = np.random.default_rng(7)
    keys, groups, kinds, ext = scan_batch(rng, T, K, N, EXT)
    prio = rng.permutation(T).astype(np.uint32)

    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                       slots=K, granularity=gran, backend=backend,
                       max_extent=EXT, bucket_size=8)
    wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
    commit, _, stats = wave_fn(jnp.asarray(keys), jnp.asarray(groups),
                               jnp.asarray(_pack(kinds, ext)),
                               jnp.asarray(prio),
                               D.init_tables(cfg, mesh), jnp.uint32(0))
    s = np.asarray(stats).reshape(ns, D.STATS_LEN)
    assert s[:, D.STAT_CAUSES].sum() == s[:, D.STAT_ABORTS].sum()
    assert s[:, D.STAT_CAUSE0 + t.CAUSE_PHANTOM].sum() > 0

    ecfg = engine_cfg(t.CC_OCC, T, K, N, gran, ext=EXT)
    store = t.store_init(N, 2, 0)
    _, res = occ_validate(store, txn_batch(keys, groups, kinds, ext),
                          jnp.asarray(prio), jnp.uint32(0), ecfg)
    np.testing.assert_array_equal(np.asarray(commit), np.asarray(res.commit))


def test_distributed_mv_scans():
    """Sharded MV waves with scans in flight: mvcc admits every phantom
    (zero CAUSE_PHANTOM — snapshot cut), mvocc re-validates through the
    owner-side iterate_validate; both backends bit-identical, causes
    conserved."""
    mesh = _full_mesh()
    ns = len(jax.devices())
    N, K, EXT = 512, 6, 8
    Tl = max(16 // ns, 2)
    T = ns * Tl
    rng = np.random.default_rng(5)
    keys, groups, kinds, ext = scan_batch(rng, T, K, N, EXT)
    prio = jnp.asarray(rng.permutation(T).astype(np.uint32))
    args = (jnp.asarray(keys), jnp.asarray(groups),
            jnp.asarray(_pack(kinds, ext)), prio)
    for cc in ("mvcc", "mvocc"):
        outs = {}
        for backend in ("jnp", "pallas"):
            cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                               slots=K, granularity=0, backend=backend,
                               cc=cc, mv_depth=4, max_extent=EXT)
            wf = jax.jit(D.make_wave_fn(cfg, mesh))
            outs[backend] = wf(*args, D.init_tables(cfg, mesh),
                               jnp.uint32(0))
        for a, b in zip(jax.tree.leaves(outs["jnp"]),
                        jax.tree.leaves(outs["pallas"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        s = np.asarray(outs["jnp"][2]).reshape(ns, D.STATS_LEN)
        assert s[:, D.STAT_CAUSES].sum() == s[:, D.STAT_ABORTS].sum(), cc
        if cc == "mvcc":
            assert s[:, D.STAT_CAUSE0 + t.CAUSE_PHANTOM].sum() == 0


def test_distributed_pipeline_depth_identity_with_scans():
    """The software-pipelined runner must stay bit-identical to the
    synchronous wave with interval fragments in flight (depth 1 == 2)."""
    mesh = _full_mesh()
    ns = len(jax.devices())
    N, K, EXT, n_waves = 512, 6, 8, 6
    Tl = max(16 // ns, 2)
    T = ns * Tl
    rng = np.random.default_rng(9)
    per_wave = [scan_batch(rng, T, K, N, EXT) for _ in range(n_waves)]
    keys = jnp.asarray(np.stack([p[0] for p in per_wave]))
    groups = jnp.asarray(np.stack([p[1] for p in per_wave]))
    kinds = jnp.asarray(np.stack([_pack(p[2], p[3]) for p in per_wave]))
    prio = jnp.asarray(np.stack([rng.permutation(T) for _ in
                                 range(n_waves)]).astype(np.uint32))
    outs = {}
    for depth in (1, 2):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=0, pipeline_depth=depth,
                           max_extent=EXT)
        run_fn = jax.jit(D.make_run_fn(cfg, mesh, n_waves))
        outs[depth] = run_fn(keys, groups, kinds, prio,
                             D.init_tables(cfg, mesh), jnp.uint32(0))
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_point_wave_unchanged_by_scan_config():
    """Wire-compat guard: an all-point batch under a scan-enabled
    DistConfig commits identically to the pre-interval config (the meta
    word's scan bits are zero for point ops)."""
    mesh = _full_mesh()
    ns = len(jax.devices())
    N, K = 256, 4
    Tl = max(8 // ns, 2)
    T = ns * Tl
    rng = np.random.default_rng(11)
    keys, groups, kinds, _ = scan_batch(rng, T, K, N, ext_cap=1, p_scan=0)
    args = (jnp.asarray(keys), jnp.asarray(groups), jnp.asarray(kinds),
            jnp.asarray(rng.permutation(T).astype(np.uint32)))
    outs = {}
    for ext in (1, 8):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=1, max_extent=ext)
        wf = jax.jit(D.make_wave_fn(cfg, mesh))
        outs[ext] = wf(*args, D.init_tables(cfg, mesh), jnp.uint32(0))
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[8])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_scan_config_rejections():
    """Unsupportable scan configs fail loudly at config/trace time:
    aged snapshots with intervals in flight, extents wider than a range
    shard, and coarse buckets that don't divide the shard width."""
    with pytest.raises(ValueError, match="snapshot_age"):
        D.DistConfig(n_records=256, n_groups=2, lanes_per_shard=4,
                     slots=4, cc="mvcc", mv_depth=4, max_extent=8,
                     snapshot_age=2)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    rec_per = 256 // len(jax.devices())
    with pytest.raises(ValueError, match="max_extent"):
        cfg = D.DistConfig(n_records=256, n_groups=2, lanes_per_shard=4,
                           slots=4, max_extent=rec_per + 1)
        D.make_wave_fn(cfg, mesh)
    with pytest.raises(ValueError, match="bucket"):
        cfg = D.DistConfig(n_records=256, n_groups=2, lanes_per_shard=4,
                           slots=4, granularity=0, max_extent=4,
                           bucket_size=3)
        D.make_wave_fn(cfg, mesh)
