"""Optional-hypothesis shim: property tests skip when hypothesis is absent.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  Test modules
import ``given``/``settings``/``st`` from here instead of from hypothesis
directly; without hypothesis installed the decorators mark the property tests
skipped and everything else in the module still collects and runs.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute is a callable that
        returns None (the skipped tests never execute their strategies)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
