"""The vmapped sweep runner: one jitted XLA program per benchmark grid
(core/engine.py sweep), padded-lane masking, and the txn_bench row schema."""
import dataclasses

import numpy as np
import pytest

from repro.core import types as t
from repro.core.engine import run, sweep
from repro.workloads import YCSBWorkload

WL = YCSBWorkload.make(n_keys=512)


def base_cfg(backend="jnp"):
    return t.EngineConfig(cc=t.CC_OCC, lanes=8, slots=WL.slots,
                          n_records=WL.n_records, n_groups=WL.n_groups,
                          n_cols=WL.n_cols, n_txn_types=WL.n_txn_types,
                          n_rings=WL.n_rings, backend=backend)


def test_sweep_full_grid_shape_and_attempts():
    """granularity x {occ, tictoc} x 3 lane counts in a single jitted call
    (ISSUE acceptance criterion)."""
    lanes = (4, 8, 16)
    pts = sweep(base_cfg(), WL, 5, ccs=[t.CC_OCC, t.CC_TICTOC],
                grans=(0, 1), lane_counts=lanes, seeds=(0,))
    assert len(pts) == 2 * 2 * 3
    for p in pts:
        # Inactive padding lanes are masked out of all accounting.
        assert p.commits + p.aborts == p.lanes * 5
    coords = {(p.cc, p.granularity, p.lanes) for p in pts}
    assert len(coords) == 12


def test_sweep_matches_run_at_max_lanes():
    """A grid point at T == max(lane_counts) is bit-identical to run()."""
    T = 16
    pts = sweep(base_cfg(), WL, 8, ccs=[t.CC_OCC, t.CC_TICTOC],
                grans=(0, 1), lane_counts=(4, T), seeds=(3,))
    for p in pts:
        if p.lanes != T:
            continue
        cfg = dataclasses.replace(base_cfg(), cc=p.cc,
                                  granularity=p.granularity, lanes=T)
        r = run(cfg, WL, n_waves=8, seed=3)
        assert (r.commits, r.aborts) == (p.commits, p.aborts), \
            (p.cc, p.granularity)
        assert r.throughput == pytest.approx(p.throughput)
        assert r.ext_events == p.ext_events


def test_sweep_seeds_axis():
    pts = sweep(base_cfg(), WL, 5, ccs=[t.CC_OCC], grans=(1,),
                lane_counts=(8,), seeds=(0, 1, 2))
    assert len(pts) == 3
    assert {p.seed for p in pts} == {0, 1, 2}
    # different seeds draw different workloads
    assert len({p.commits for p in pts}) > 1 or len(
        {p.throughput for p in pts}) > 1


def test_sweep_pallas_backend_parity():
    a = sweep(base_cfg("jnp"), WL, 5, ccs=[t.CC_OCC], grans=(0, 1),
              lane_counts=(8,), seeds=(0,))
    b = sweep(base_cfg("pallas"), WL, 5, ccs=[t.CC_OCC], grans=(0, 1),
              lane_counts=(8,), seeds=(0,))
    for pa, pb in zip(a, b):
        assert (pa.commits, pa.aborts) == (pb.commits, pb.aborts)


def test_txn_bench_grid_schema():
    """txn_bench --json schema: the seed keys plus the new backend field."""
    from repro.launch.txn_bench import run_grid
    rows = run_grid("ycsb", ["occ", "tictoc"], (0, 1), [4, 8], 4,
                    n_keys=512, backend="jnp")
    assert len(rows) == 2 * 2 * 2
    want = {"workload", "cc", "granularity", "lanes", "waves", "commits",
            "aborts", "abort_rate", "throughput", "ext_events", "wall_s",
            "backend"}
    for r in rows:
        assert set(r) == want
        assert r["backend"] == "jnp"
        assert r["commits"] + r["aborts"] == r["lanes"] * r["waves"]
