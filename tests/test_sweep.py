"""The vmapped sweep runner: one jitted XLA program per benchmark grid
(core/engine.py sweep), padded-lane masking, and the txn_bench row schema."""
import dataclasses

import numpy as np
import pytest

from repro.core import types as t
from repro.core.engine import lane_buckets, run, sweep
from repro.workloads import YCSBWorkload

WL = YCSBWorkload.make(n_keys=512)


def base_cfg(backend="jnp"):
    return t.EngineConfig(cc=t.CC_OCC, lanes=8, slots=WL.slots,
                          n_records=WL.n_records, n_groups=WL.n_groups,
                          n_cols=WL.n_cols, n_txn_types=WL.n_txn_types,
                          n_rings=WL.n_rings, backend=backend)


def test_sweep_full_grid_shape_and_attempts():
    """granularity x {occ, tictoc} x 3 lane counts in a single jitted call
    (ISSUE acceptance criterion)."""
    lanes = (4, 8, 16)
    pts = sweep(base_cfg(), WL, 5, ccs=[t.CC_OCC, t.CC_TICTOC],
                grans=(0, 1), lane_counts=lanes, seeds=(0,))
    assert len(pts) == 2 * 2 * 3
    for p in pts:
        # Inactive padding lanes are masked out of all accounting.
        assert p.commits + p.aborts == p.lanes * 5
    coords = {(p.cc, p.granularity, p.lanes) for p in pts}
    assert len(coords) == 12


def test_sweep_matches_run_at_max_lanes():
    """A grid point at T == max(lane_counts) is bit-identical to run()."""
    T = 16
    pts = sweep(base_cfg(), WL, 8, ccs=[t.CC_OCC, t.CC_TICTOC],
                grans=(0, 1), lane_counts=(4, T), seeds=(3,))
    for p in pts:
        if p.lanes != T:
            continue
        cfg = dataclasses.replace(base_cfg(), cc=p.cc,
                                  granularity=p.granularity, lanes=T)
        r = run(cfg, WL, n_waves=8, seed=3)
        assert (r.commits, r.aborts) == (p.commits, p.aborts), \
            (p.cc, p.granularity)
        assert r.throughput == pytest.approx(p.throughput)
        assert r.ext_events == p.ext_events


def test_lane_buckets():
    """Greedy grouping bounds padding waste to the ratio; None = one bucket
    (legacy pad-to-global-max)."""
    assert lane_buckets((16, 64, 128), 2.0) == [[16], [64, 128]]
    assert lane_buckets((8, 16, 32, 64, 96, 128), 2.0) == \
        [[8, 16], [32, 64], [96, 128]]
    assert lane_buckets((16, 128), 8.0) == [[16, 128]]
    assert lane_buckets((16, 64, 128), None) == [[16, 64, 128]]
    assert lane_buckets((128, 16, 64), 2.0) == [[16], [64, 128]]  # sorted


def test_sweep_matches_run_at_every_bucket_max():
    """Bucketed padding strengthens the bit-identity guarantee: EVERY point
    sitting at its bucket's max lane count equals a standalone run()."""
    lanes = (4, 16)   # ratio 2 puts these in separate buckets
    assert lane_buckets(lanes, 2.0) == [[4], [16]]
    pts = sweep(base_cfg(), WL, 6, ccs=[t.CC_OCC], grans=(1,),
                lane_counts=lanes, seeds=(2,))
    for p in pts:
        cfg = dataclasses.replace(base_cfg(), cc=p.cc,
                                  granularity=p.granularity, lanes=p.lanes)
        r = run(cfg, WL, n_waves=6, seed=2)
        assert (r.commits, r.aborts) == (p.commits, p.aborts), p.lanes


def test_sweep_bucketing_preserves_grid_order():
    """Bucketed execution must not permute the returned point grid."""
    pts = sweep(base_cfg(), WL, 3, ccs=[t.CC_OCC, t.CC_TICTOC], grans=(0, 1),
                lane_counts=(4, 8, 16), seeds=(0, 1))
    coords = [(p.cc, p.granularity, p.lanes, p.seed) for p in pts]
    want = [(cc, g, T, sd)
            for g in (0, 1) for cc in (t.CC_OCC, t.CC_TICTOC)
            for T in (4, 8, 16) for sd in (0, 1)]
    assert coords == want


def test_sweep_seeds_axis():
    pts = sweep(base_cfg(), WL, 5, ccs=[t.CC_OCC], grans=(1,),
                lane_counts=(8,), seeds=(0, 1, 2))
    assert len(pts) == 3
    assert {p.seed for p in pts} == {0, 1, 2}
    # different seeds draw different workloads
    assert len({p.commits for p in pts}) > 1 or len(
        {p.throughput for p in pts}) > 1


def test_sweep_pallas_backend_parity():
    a = sweep(base_cfg("jnp"), WL, 5, ccs=[t.CC_OCC], grans=(0, 1),
              lane_counts=(8,), seeds=(0,))
    b = sweep(base_cfg("pallas"), WL, 5, ccs=[t.CC_OCC], grans=(0, 1),
              lane_counts=(8,), seeds=(0,))
    for pa, pb in zip(a, b):
        assert (pa.commits, pa.aborts) == (pb.commits, pb.aborts)


def test_txn_bench_grid_schema():
    """txn_bench --json schema: the seed keys plus backend attribution and
    the observability fields (per-cause aborts + analytic cost model)."""
    from repro.launch.txn_bench import run_grid
    rows = run_grid("ycsb", ["occ", "tictoc"], (0, 1), [4, 8], 4,
                    n_keys=512, backend="jnp")
    assert len(rows) == 2 * 2 * 2
    want = {"workload", "cc", "granularity", "lanes", "waves", "commits",
            "aborts", "abort_rate", "ro_commits", "ro_aborts",
            "ro_abort_rate", "throughput", "ext_events", "wall_s",
            "backend", "kernel_ops", "abort_causes", "bytes_per_txn",
            "flops_per_txn", "roofline_frac", "roofline_bound",
            "roofline_chip", "launches_per_wave", "dma_rows_per_wave",
            "dma_rows_per_wave_unfused", "max_extent"}
    for r in rows:
        assert set(r) == want
        assert r["backend"] == "jnp"
        assert r["commits"] + r["aborts"] == r["lanes"] * r["waves"]
        assert sum(r["abort_causes"].values()) == r["aborts"]
        assert all(v == "xla" for v in r["kernel_ops"].values())


def test_txn_bench_kernel_ops_attribution():
    """Pallas rows must name the ops that actually ran as kernels, per
    mechanism: the probe family (OCC, TicToc, 2PL, SwissTM, Adaptive) runs
    the FUSED wave_commit megakernel — claim install, probe, verdicts, and
    version bumps in one launch (ISSUE 9) — while AutoGran keeps
    validate_dual and the multi-version pair keeps its claim channels +
    mv ring ops."""
    from repro.core.backend import dist_kernel_coverage, kernel_coverage
    occ_ops = kernel_coverage("pallas", t.CC_OCC)
    tic_ops = kernel_coverage("pallas", t.CC_TICTOC)
    ag_ops = kernel_coverage("pallas", t.CC_AUTOGRAN)
    mv_ops = kernel_coverage("pallas", t.CC_MVCC)
    # every mechanism's wave also counts same-row contention through
    # segment_count (the engine cost model) — no XLA sort on the pallas
    # path; every scan-validating mechanism (all but mvcc) also runs the
    # iterate_validate interval pass (ISSUE 10)
    assert occ_ops == {"wave_commit": "pallas",
                       "iterate_validate": "pallas",
                       "commit_install": "pallas",
                       "segment_count": "pallas"}
    assert tic_ops == {"wave_commit": "pallas",
                       "iterate_validate": "pallas",
                       "ts_gather": "pallas",
                       "ts_install_max": "pallas", "segment_count": "pallas"}
    assert ag_ops == {"validate_dual": "pallas",
                      "iterate_validate": "pallas",
                      "claim_scatter": "pallas",
                      "commit_install": "pallas", "segment_count": "pallas"}
    assert mv_ops == {"validate": "pallas", "claim_scatter": "pallas",
                      "mv_gather": "pallas", "mv_install": "pallas",
                      "segment_count": "pallas"}
    assert kernel_coverage("pallas", t.CC_MVOCC) == dict(
        mv_ops, iterate_validate="pallas")
    for cc in (t.CC_2PL, t.CC_SWISS, t.CC_ADAPTIVE):
        assert kernel_coverage("pallas", cc) == occ_ops
    # the distributed wave's shard-local coverage (benchmarks/txn_scaling):
    # occ bumps versions on the return trip, the MV pair gathers snapshots
    # and publishes into the sharded ring instead; both ship verdicts and
    # commit bits bit-packed through the verdict_pack/verdict_unpack pair
    assert dist_kernel_coverage("pallas") == {
        "route_pack": "pallas", "verdict_pack": "pallas",
        "verdict_unpack": "pallas", "wave_commit": "pallas",
        "iterate_validate": "pallas", "commit_install": "pallas"}
    dist_mv = {"route_pack": "pallas", "verdict_pack": "pallas",
               "verdict_unpack": "pallas", "claim_probe": "pallas",
               "mv_gather": "pallas", "mv_install": "pallas"}
    # mvcc never validates intervals (snapshot cut); mvocc adds the
    # owner-side interval pass
    assert dist_kernel_coverage("pallas", "mvcc") == dist_mv
    assert dist_kernel_coverage("pallas", "mvocc") == dict(
        dist_mv, iterate_validate="pallas")
    assert set(dist_kernel_coverage("jnp").values()) == {"xla"}
    assert set(dist_kernel_coverage("jnp", "mvcc").values()) == {"xla"}
