"""CC-mechanism semantics: the paper's scenarios + property tests against a
pure-python oracle (thinning disabled so rules are deterministic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import claims
from repro.core import types as t
from repro.core.cc import occ, tictoc, two_pl
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init

EXACT = CostModel(opt_overlap=1.0, phase_overlap=1.0)


def make_cfg(cc, lanes, slots, gran=1, n_rec=8):
    return EngineConfig(cc=cc, lanes=lanes, slots=slots, n_records=n_rec,
                        n_groups=2, n_cols=0, n_txn_types=1,
                        granularity=gran, cost=EXACT)


def batch_of(ops, lanes, slots):
    """ops: list per lane of (key, group, kind) tuples."""
    ks = np.full((lanes, slots), -1, np.int32)
    gs = np.zeros((lanes, slots), np.int32)
    kd = np.zeros((lanes, slots), np.int32)
    for i, lane in enumerate(ops):
        for j, (k, g, kind) in enumerate(lane):
            ks[i, j], gs[i, j], kd[i, j] = k, g, kind
    return TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                    op_col=jnp.zeros((lanes, slots), jnp.int32),
                    op_kind=jnp.asarray(kd),
                    op_val=jnp.zeros((lanes, slots), jnp.float32),
                    txn_type=jnp.zeros((lanes,), jnp.int32),
                    n_ops=jnp.asarray([len(l) for l in ops], jnp.int32))


# ------------------------------------------------- the paper's two scenarios
def test_figure1_tictoc_commits_both_where_occ_aborts():
    """Paper Figure 1: Txn1 reads row A; Txn2 updates row A and commits
    first.  TicToc reschedules Txn1 before Txn2; OCC aborts Txn1."""
    ops = [[(0, 0, t.READ)],          # Txn 1 (later prio)
           [(0, 0, t.WRITE)]]         # Txn 2 (earlier prio = commits first)
    batch = batch_of(ops, 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)
    wave = jnp.uint32(0)

    cfg = make_cfg(t.CC_OCC, 2, 2)
    store = store_init(8, 2, 0)
    _, res = occ.wave_validate(store, batch, prio, wave, cfg)
    assert list(np.asarray(res.commit)) == [False, True]

    cfg = make_cfg(t.CC_TICTOC, 2, 2)
    store = store_init(8, 2, 0)
    _, res = tictoc.wave_validate(store, batch, prio, wave, cfg)
    assert list(np.asarray(res.commit)) == [True, True]


def test_district_false_conflict_fine_vs_coarse():
    """Paper section 3.4: New-order reads the district tax (group 0) while
    Payment updates the district YTD (group 1).  Coarse timestamps abort the
    reader (false conflict); fine timestamps commit both."""
    ops = [[(3, 0, t.READ)],          # New-order: D_TAX, rare group
           [(3, 1, t.ADD)]]           # Payment:  D_YTD, hot group
    batch = batch_of(ops, 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)  # Payment first
    wave = jnp.uint32(0)

    for gran, want in ((0, [False, True]), (1, [True, True])):
        cfg = make_cfg(t.CC_OCC, 2, 2, gran=gran)
        store = store_init(8, 2, 0)
        _, res = occ.wave_validate(store, batch, prio, wave, cfg)
        assert list(np.asarray(res.commit)) == want, f"gran={gran}"


def test_fine_granularity_still_detects_true_conflicts():
    ops = [[(3, 1, t.READ)],          # reads the SAME group Payment writes
           [(3, 1, t.ADD)]]
    batch = batch_of(ops, 2, 2)
    prio = jnp.asarray([1, 0], jnp.uint32)
    cfg = make_cfg(t.CC_OCC, 2, 2, gran=1)
    store = store_init(8, 2, 0)
    _, res = occ.wave_validate(store, batch, prio, jnp.uint32(0), cfg)
    assert list(np.asarray(res.commit)) == [False, True]


# ------------------------------------------------------------ oracle checks
def occ_oracle(ks, gs, kd, prio, gran):
    """Commit set per OCC rule: a lane aborts iff one of its reads' cells is
    write-claimed by a strictly-earlier-priority lane."""
    T, K = ks.shape
    commit = []
    for i in range(T):
        ok = True
        for j in range(K):
            if kd[i, j] == t.READ and ks[i, j] >= 0:
                for i2 in range(T):
                    if prio[i2] >= prio[i]:
                        continue
                    for j2 in range(K):
                        if kd[i2, j2] in (t.WRITE, t.ADD) \
                           and ks[i2, j2] == ks[i, j] \
                           and (gran == 0 or gs[i2, j2] == gs[i, j]):
                            ok = False
        commit.append(ok)
    return commit


def twopl_oracle(ks, gs, kd, prio, gran):
    T, K = ks.shape
    commit = []
    for i in range(T):
        ok = True
        for j in range(K):
            if ks[i, j] < 0 or kd[i, j] == t.NOP:
                continue
            mine_w = kd[i, j] in (t.WRITE, t.ADD)
            for i2 in range(T):
                if prio[i2] >= prio[i]:
                    continue
                for j2 in range(K):
                    if ks[i2, j2] != ks[i, j] or kd[i2, j2] == t.NOP \
                       or ks[i2, j2] < 0:
                        continue
                    if gran == 1 and gs[i2, j2] != gs[i, j]:
                        continue
                    theirs_w = kd[i2, j2] in (t.WRITE, t.ADD)
                    if theirs_w or mine_w:       # RR compatible only
                        ok = False
        commit.append(ok)
    return commit


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gran=st.integers(0, 1))
def test_occ_matches_oracle(seed, gran):
    rng = np.random.default_rng(seed)
    T, K, N = 5, 4, 6
    ks = rng.integers(-1, N, (T, K)).astype(np.int32)
    gs = rng.integers(0, 2, (T, K)).astype(np.int32)
    kd = rng.choice([t.NOP, t.READ, t.WRITE, t.ADD], (T, K)).astype(np.int32)
    prio = rng.permutation(T).astype(np.uint32)
    batch = TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                     op_col=jnp.zeros((T, K), jnp.int32),
                     op_kind=jnp.asarray(kd),
                     op_val=jnp.zeros((T, K), jnp.float32),
                     txn_type=jnp.zeros((T,), jnp.int32),
                     n_ops=jnp.full((T,), K, jnp.int32))
    cfg = make_cfg(t.CC_OCC, T, K, gran=gran, n_rec=N)
    store = store_init(N, 2, 0)
    _, res = occ.wave_validate(store, batch, jnp.asarray(prio),
                               jnp.uint32(0), cfg)
    assert list(np.asarray(res.commit)) == occ_oracle(ks, gs, kd, prio, gran)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gran=st.integers(0, 1))
def test_twopl_matches_oracle(seed, gran):
    rng = np.random.default_rng(seed)
    T, K, N = 5, 4, 6
    ks = rng.integers(-1, N, (T, K)).astype(np.int32)
    gs = rng.integers(0, 2, (T, K)).astype(np.int32)
    kd = rng.choice([t.NOP, t.READ, t.WRITE], (T, K)).astype(np.int32)
    prio = rng.permutation(T).astype(np.uint32)
    batch = TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                     op_col=jnp.zeros((T, K), jnp.int32),
                     op_kind=jnp.asarray(kd),
                     op_val=jnp.zeros((T, K), jnp.float32),
                     txn_type=jnp.zeros((T,), jnp.int32),
                     n_ops=jnp.full((T,), K, jnp.int32))
    cfg = make_cfg(t.CC_2PL, T, K, gran=gran, n_rec=N)
    store = store_init(N, 2, 0)
    _, res = two_pl.wave_validate(store, batch, jnp.asarray(prio),
                                  jnp.uint32(0), cfg)
    assert list(np.asarray(res.commit)) == twopl_oracle(ks, gs, kd, prio,
                                                        gran)


def test_tictoc_never_commits_fewer_than_occ():
    """TicToc commits a superset of OCC's schedules (fresh store) — at the
    pure-protocol level, i.e. with the stochastic lock-contention effects
    (extension failures) disabled; with them enabled TicToc may abort
    transactions OCC commits, which is exactly the paper's Fig 2a point."""
    pure = CostModel(opt_overlap=1.0, phase_overlap=0.0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        T, K, N = 6, 4, 5
        ks = rng.integers(0, N, (T, K)).astype(np.int32)
        gs = rng.integers(0, 2, (T, K)).astype(np.int32)
        kd = rng.choice([t.READ, t.WRITE], (T, K)).astype(np.int32)
        prio = rng.permutation(T).astype(np.uint32)
        batch = TxnBatch(op_key=jnp.asarray(ks), op_group=jnp.asarray(gs),
                         op_col=jnp.zeros((T, K), jnp.int32),
                         op_kind=jnp.asarray(kd),
                         op_val=jnp.zeros((T, K), jnp.float32),
                         txn_type=jnp.zeros((T,), jnp.int32),
                         n_ops=jnp.full((T,), K, jnp.int32))
        store = store_init(N, 2, 0)
        cfg_o = dataclasses.replace(make_cfg(t.CC_OCC, T, K, n_rec=N),
                                    cost=pure)
        cfg_t = dataclasses.replace(make_cfg(t.CC_TICTOC, T, K, n_rec=N),
                                    cost=pure)
        _, r_occ = occ.wave_validate(store, batch, jnp.asarray(prio),
                                     jnp.uint32(0), cfg_o)
        _, r_tic = tictoc.wave_validate(store, batch, jnp.asarray(prio),
                                        jnp.uint32(0), cfg_t)
        assert int(r_tic.commit.sum()) >= int(r_occ.commit.sum())


def test_add_sum_conservation_end_to_end():
    """Committed ADD deltas must equal the final stored sums exactly
    (track_values path applies committed writes serially by priority)."""
    from repro.core.engine import run
    from repro.workloads import YCSBWorkload

    wl = YCSBWorkload.make(n_keys=64, theta=0.5, ops_per_txn=4,
                           write_frac=1.0)

    # make every write an ADD of 1.0 by patching gen output
    class AddWorkload:
        n_records = wl.n_records
        n_groups = wl.n_groups
        n_cols = wl.n_cols
        n_rings = wl.n_rings
        n_txn_types = 1
        slots = wl.slots

        def init_store(self, track_values=False):
            return wl.init_store(track_values)

        def gen(self, rng, wave, lanes, tails):
            b, tails = wl.gen(rng, wave, lanes, tails)
            b = dataclasses.replace(
                b, op_kind=jnp.where(b.op_kind == t.WRITE, t.ADD, b.op_kind),
                op_val=jnp.ones_like(b.op_val))
            return b, tails

    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=wl.slots,
                       n_records=wl.n_records, n_groups=wl.n_groups,
                       n_cols=wl.n_cols, n_txn_types=1, granularity=1,
                       track_values=True, cost=EXACT)
    res = run(cfg, AddWorkload(), n_waves=10, seed=3, keep_state=True)
    total = float(res.final_state.store.values.sum())
    assert total == pytest.approx(res.commits * wl.slots)
