"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in kernels/ref.py, across shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- OCC kernels
@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (8, 16, 512, 2),
                                     (3, 5, 33, 1)])
@pytest.mark.parametrize("fine", [True, False])
def test_occ_validate(T, K, N, G, fine):
    claim = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    prio = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    check = jnp.asarray(RNG.random((T, K)) < 0.7) & (keys >= 0)
    ivw = jnp.uint32(0xFF00)
    a = ops.occ_validate(claim, keys, groups, prio, check, ivw, fine,
                         use_pallas=True)
    b = ref.occ_validate(claim, keys, groups, prio, check, ivw, fine)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1)])
def test_occ_commit_with_duplicates(T, K, N, G):
    wts = jnp.asarray(RNG.integers(0, 9, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N // 2, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    do = jnp.asarray(RNG.random((T, K)) < 0.6)
    a = ops.occ_commit(wts, keys, groups, do, use_pallas=True)
    b = ref.occ_commit(wts, keys, groups, do)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------- backend-surface kernels (new)
@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (3, 5, 33, 1)])
def test_occ_validate_dual(T, K, N, G):
    """One row DMA, two verdicts: the dual kernel must equal BOTH
    single-granularity oracles."""
    claim = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    prio = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    check = jnp.asarray(RNG.random((T, K)) < 0.7) & (keys >= 0)
    ivw = jnp.uint32(0xFF00)
    af, ac = ops.occ_validate_dual(claim, keys, groups, prio, check, ivw,
                                   use_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(af),
        np.asarray(ref.occ_validate(claim, keys, groups, prio, check, ivw,
                                    fine=True)))
    np.testing.assert_array_equal(
        np.asarray(ac),
        np.asarray(ref.occ_validate(claim, keys, groups, prio, check, ivw,
                                    fine=False)))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (3, 5, 17, 1)])
@pytest.mark.parametrize("fine", [True, False])
def test_claim_probe(T, K, N, G, fine):
    table = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    ivw = jnp.uint32(0xFFF0)
    a = ops.claim_probe(table, keys, groups, ivw, fine, use_pallas=True)
    b = ref.claim_probe(table, keys, groups, ivw, fine)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1)])
@pytest.mark.parametrize("fine", [True, False])
def test_ts_gather(T, K, N, G, fine):
    """TicToc (wts, rts) observation: fine = own cell, coarse = row max."""
    table = jnp.asarray(RNG.integers(0, 1000, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    a = ops.ts_gather(table, keys, groups, fine, use_pallas=True)
    b = ref.ts_gather(table, keys, groups, fine)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1)])
@pytest.mark.parametrize("whole_row", [False, True])
def test_ts_install_max_with_duplicates(T, K, N, G, whole_row):
    """Scatter-max install; keys drawn from N//2 records force duplicate
    (record, group) cells within the wave."""
    table = jnp.asarray(RNG.integers(0, 500, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N // 2, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    vals = jnp.asarray(RNG.integers(0, 1000, (T, K), dtype=np.uint32))
    do = jnp.asarray(RNG.random((T, K)) < 0.6)
    a = ops.ts_install_max(table, keys, groups, vals, do, whole_row,
                           use_pallas=True)
    b = ref.ts_install_max(table, keys, groups, vals, do, whole_row)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1)])
def test_claim_scatter_with_duplicates(T, K, N, G):
    """Fused pack+scatter-min; duplicate cells must resolve to the strongest
    claimant exactly like the XLA scatter-min."""
    table = jnp.asarray(RNG.integers(0, 2 ** 32, (N, G), dtype=np.uint32))
    keys = jnp.asarray(RNG.integers(-1, N // 2, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    prio = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    do = jnp.asarray(RNG.random((T, K)) < 0.6)
    wave = jnp.uint32(5)
    a = ops.claim_scatter(table, keys, groups, prio, do, wave,
                          use_pallas=True)
    b = ref.claim_scatter(table, keys, groups, prio, do, wave)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1),
                                     (8, 16, 16, 2)])
@pytest.mark.parametrize("fine", [True, False])
def test_claim_probe_fused_with_duplicates(T, K, N, G, fine):
    """Fused install + probe vs the two-phase oracle: the returned table
    must equal claim_scatter's and the returned probe must equal a probe of
    that POST-install table — duplicate cells (keys drawn from N//2), reads
    probing cells written this wave, and masked ops included.  Table words
    respect the monotone-wave-tag precondition (ref.claim_probe_fused)."""
    from repro.core.claimword import EMPTY_WORD, inv_wave
    wave = jnp.uint32(5)
    ivw = int(inv_wave(wave))
    # plausible table: claims from waves <= current (tag >= ivw) + empties
    tag = RNG.integers(ivw, 0x10000, (N, G))
    words = (tag << 16 | RNG.integers(0, 2 ** 16, (N, G))).astype(np.uint32)
    words[RNG.random((N, G)) < 0.3] = EMPTY_WORD
    table = jnp.asarray(words)
    keys = jnp.asarray(RNG.integers(-1, max(N // 2, 1), (T, K),
                                    dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    prio = jnp.asarray(RNG.integers(0, 2 ** 16, (T, K), dtype=np.uint32))
    do = jnp.asarray(RNG.random((T, K)) < 0.6)
    a_t, a_p = ops.claim_probe_fused(table, keys, groups, prio, do, wave,
                                     fine, use_pallas=True)
    b_t, b_p = ref.claim_probe_fused(table, keys, groups, prio, do, wave,
                                     fine)
    np.testing.assert_array_equal(np.asarray(a_t), np.asarray(b_t))
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(b_p))
    # the fused op IS the claim_scatter + post-install probe pair
    np.testing.assert_array_equal(
        np.asarray(b_t),
        np.asarray(ref.claim_scatter(table, keys, groups, prio, do, wave)))
    np.testing.assert_array_equal(
        np.asarray(b_p),
        np.asarray(ref.claim_probe(b_t, keys, groups, inv_wave(wave),
                                   fine)))


@pytest.mark.parametrize("M,ns,cap", [(48, 4, 8), (64, 8, 8), (33, 3, 16),
                                      (16, 1, 8)])
def test_route_pack(M, ns, cap):
    """Sort-free pack vs the counting oracle: duplicate destinations force
    in-destination ranking, M > ns*cap forces capacity drops, owner == ns
    exercises masked ops.  Placement must equal a stable argsort by owner."""
    owner = jnp.asarray(RNG.integers(0, ns + 1, M).astype(np.int32))
    vals = jnp.asarray(RNG.integers(-4, 1000, (3, M)).astype(np.int32))
    fills = (0x7FFFFFFF, 0x7FF8, -1)
    a_buf, a_pos, a_took = ops.route_pack(owner, vals, ns, cap, fills,
                                          use_pallas=True)
    b_buf, b_pos, b_took = ref.route_pack(owner, vals, ns, cap, fills)
    np.testing.assert_array_equal(np.asarray(a_buf), np.asarray(b_buf))
    np.testing.assert_array_equal(np.asarray(a_pos), np.asarray(b_pos))
    np.testing.assert_array_equal(np.asarray(a_took), np.asarray(b_took))
    # independent oracle: stable argsort placement
    own = np.asarray(owner)
    vs = np.asarray(vals)
    want = np.stack([np.full((ns, cap), f, np.int32) for f in fills])
    for i in np.argsort(own, kind="stable"):
        d = own[i]
        if d >= ns:
            assert not np.asarray(b_took)[i]
            continue
        p = int(np.asarray(b_pos)[i])
        assert p == (own[:i] == d).sum()
        if p < cap:
            assert np.asarray(b_took)[i]
            want[:, d, p] = vs[:, i]
        else:
            assert not np.asarray(b_took)[i]
    np.testing.assert_array_equal(np.asarray(b_buf), want)


@pytest.mark.parametrize("T,K,N,G", [(4, 8, 64, 2), (6, 3, 17, 1),
                                     (8, 16, 16, 2)])
def test_segment_count_with_duplicates(T, K, N, G):
    """All-pairs same-cell counts vs the sort-based oracle; keys drawn from
    N//2 force duplicate cells, sparse masks force sentinel handling."""
    keys = jnp.asarray(RNG.integers(-1, max(N // 2, 1), (T, K),
                                    dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    mask = jnp.asarray(RNG.random((T, K)) < 0.5)
    a = ops.segment_count(keys, groups, G, mask, use_pallas=True)
    b = ref.segment_count(keys, groups, G, mask)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # spot-check semantics: each masked op counts its cell's wave population
    cells = np.where(np.asarray(mask), np.asarray(keys) * G
                     + np.asarray(groups), -123)
    for t_ in range(T):
        for k_ in range(K):
            want = (cells == cells[t_, k_]).sum() if cells[t_, k_] != -123 \
                else 0
            assert np.asarray(b)[t_, k_] == want


# ------------------------------------------------------- multi-version ring
def _mv_begin_table(N, D, G, lo=0, hi=50):
    """A plausible ring: slot 0 always live, later slots a mix of installed
    and MV_EMPTY begins."""
    from repro.core.mvstore import MV_EMPTY
    b = RNG.integers(lo, hi, (N, D, G)).astype(np.uint32)
    empty = RNG.random((N, D)) < 0.3
    empty[:, 0] = False
    b[empty] = MV_EMPTY
    return jnp.asarray(b)


@pytest.mark.parametrize("T,K,N,D,G", [(4, 8, 64, 3, 2), (6, 3, 17, 2, 1),
                                       (3, 5, 9, 4, 2)])
@pytest.mark.parametrize("fine", [True, False])
def test_mv_gather(T, K, N, D, G, fine):
    """Snapshot version select: newest visible slot per op, reclaimed flag
    when every retained begin postdates the snapshot."""
    begin = _mv_begin_table(N, D, G)
    keys = jnp.asarray(RNG.integers(-1, N, (T, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
    for ts in (0, 7, 49):
        a_s, a_ok = ops.mv_gather(begin, keys, groups, jnp.uint32(ts), fine,
                                  use_pallas=True)
        b_s, b_ok = ref.mv_gather(begin, keys, groups, jnp.uint32(ts), fine)
        np.testing.assert_array_equal(np.asarray(a_s), np.asarray(b_s))
        np.testing.assert_array_equal(np.asarray(a_ok), np.asarray(b_ok))
    # masked ops never report a visible version
    assert not np.asarray(b_ok)[np.asarray(keys) < 0].any()


@pytest.mark.parametrize("T,K,N,D,G", [(4, 8, 64, 3, 2), (6, 3, 17, 2, 1),
                                       (5, 4, 8, 4, 2)])
def test_mv_install_with_duplicates(T, K, N, D, G):
    """Ring-slot claim + publish; keys drawn from N//2 force several
    committed ops onto one record in a wave (they must merge into ONE new
    slot).  Begin values respect the < ts monotonicity precondition."""
    from repro.core import mvstore
    begin, head, _ = mvstore.mv_init(N, D, G)
    # age the ring a little with real installs so heads differ
    for wave in range(3):
        ks = jnp.asarray(RNG.integers(-1, max(N // 2, 2), (T, K),
                                      dtype=np.int32))
        gs = jnp.asarray(RNG.integers(0, G, (T, K), dtype=np.int32))
        do = jnp.asarray(RNG.random((T, K)) < 0.4)
        ts = jnp.uint32(wave + 1)
        a_b, a_h = ops.mv_install(begin, head, ks, gs, do, ts,
                                  use_pallas=True)
        b_b, b_h = ref.mv_install(begin, head, ks, gs, do, ts)
        np.testing.assert_array_equal(np.asarray(a_b), np.asarray(b_b))
        np.testing.assert_array_equal(np.asarray(a_h), np.asarray(b_h))
        begin, head = b_b, b_h
    # every touched record claimed exactly one slot per wave: heads stay
    # within [0, D) and begins never exceed the last install ts
    from repro.core.mvstore import MV_EMPTY
    b = np.asarray(begin)
    assert ((b <= 3) | (b == MV_EMPTY)).all()
    assert (np.asarray(head) >= 0).all() and (np.asarray(head) < D).all()


# ------------------------------------------- precondition validation (new)
def _future_tagged_table():
    """A claim table holding a wave-7 claim — newer than the wave-3 calls
    below, violating the monotone-wave-tag precondition."""
    from repro.core.claimword import claim_word
    from repro.core.types import NO_CLAIM
    table = jnp.full((8, 2), NO_CLAIM, jnp.uint32)
    return table.at[2, 0].set(claim_word(jnp.uint32(7), jnp.uint32(5)))


def test_claim_probe_fused_rejects_future_wave_tags():
    """The documented monotone-wave-tag precondition of claim_probe is now
    CHECKED on eager calls (both backends): a table cell claimed by a wave
    newer than the current one raises instead of silently answering wrong
    (ISSUE 5 satellite).  Untouched violating cells don't fire — the check
    is per touched row, so it stays cheap."""
    table = _future_tagged_table()
    keys = jnp.asarray([[2]], jnp.int32)
    groups = jnp.zeros((1, 1), jnp.int32)
    prio = jnp.asarray([[1]], jnp.uint32)
    do = jnp.asarray([[True]])
    wave = jnp.uint32(3)
    with pytest.raises(ValueError, match="precondition"):
        ref.claim_probe_fused(table, keys, groups, prio, do, wave, True)
    with pytest.raises(ValueError, match="precondition"):
        ops.claim_probe_fused(table, keys, groups, prio, do, wave, True,
                              use_pallas=True)
    # the same wave's own tag is NOT a violation (claims land per wave)...
    ref.claim_probe_fused(table, keys, groups, prio, do, jnp.uint32(7),
                          True)
    # ...and ops that don't touch the poisoned row never see it
    ref.claim_probe_fused(table, jnp.asarray([[4]], jnp.int32), groups,
                          prio, do, wave, True)


def test_mv_install_rejects_non_monotone_begin():
    """Same for mv_install: an installed-into ring row already holding a
    begin >= the install ts (a wave driven backwards / a reused ts) raises
    on eager calls instead of silently merging distinct waves."""
    from repro.core import mvstore
    begin, head, _ = mvstore.mv_init(8, 3, 2)
    begin = begin.at[2, 0, 0].set(jnp.uint32(9))
    keys = jnp.asarray([[2]], jnp.int32)
    groups = jnp.zeros((1, 1), jnp.int32)
    do = jnp.asarray([[True]])
    with pytest.raises(ValueError, match="precondition"):
        ref.mv_install(begin, head, keys, groups, do, jnp.uint32(5))
    with pytest.raises(ValueError, match="precondition"):
        ops.mv_install(begin, head, keys, groups, do, jnp.uint32(5),
                       use_pallas=True)
    # strictly newer ts passes; so does a masked (do=False) touch of the row
    ref.mv_install(begin, head, keys, groups, do, jnp.uint32(10))
    ref.mv_install(begin, head, keys, groups, jnp.asarray([[False]]),
                   jnp.uint32(5))


def test_precondition_checks_jit_free_and_env_gated(monkeypatch):
    """Under jit the inputs are tracers and the check compiles to nothing;
    REPRO_PRECONDITION_CHECKS=0 disables it eagerly too."""
    table = _future_tagged_table()
    keys = jnp.asarray([[2]], jnp.int32)
    groups = jnp.zeros((1, 1), jnp.int32)
    prio = jnp.asarray([[1]], jnp.uint32)
    do = jnp.asarray([[True]])
    jax.jit(lambda t_: ref.claim_probe_fused(t_, keys, groups, prio, do,
                                             jnp.uint32(3), True))(table)
    monkeypatch.setenv("REPRO_PRECONDITION_CHECKS", "0")
    ref.claim_probe_fused(table, keys, groups, prio, do, jnp.uint32(3),
                          True)


def test_repro_kernels_env_resolved_per_call(monkeypatch):
    """REPRO_KERNELS must be read per call, not frozen at import time."""
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert ops._use_pallas(None) is True
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    assert ops._use_pallas(None) is False
    monkeypatch.delenv("REPRO_KERNELS")
    import jax
    assert ops._use_pallas(None) == (jax.default_backend() == "tpu")


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D", [
    (2, 4, 2, 64, 64, 32),       # GQA
    (1, 2, 2, 128, 128, 16),     # MHA
    (1, 4, 1, 32, 32, 8),        # MQA
])
@pytest.mark.parametrize("window", [None, 16])
def test_flash_attention(B, Hq, Hkv, Sq, Sk, D, window):
    q = jnp.asarray(RNG.standard_normal((B, Hq, Sq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Sk, D)), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=32, block_k=32, use_pallas=True)
    b = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)), jnp.bfloat16)
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                            use_pallas=True)
    b = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2)


# ------------------------------------------------------------ jnp-flash
@pytest.mark.parametrize("S,window", [(1024, None), (2048, None),
                                      (2048, 256)])
def test_jnp_flash_matches_dense(S, window):
    """models/attention.py blocked path vs its own dense fallback."""
    from repro.models.attention import _dense, _flash
    B, G, R, D = 1, 2, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, G, R, S, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, G, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, G, S, D)), jnp.float32)
    blocked = _flash(q, k, v, causal=True, window=window,
                     block_q=512, block_k=512)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    dense = _dense(q * D ** -0.5, k, v, mask)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ RG-LRU
@pytest.mark.parametrize("B,S,D", [(2, 32, 128), (1, 64, 256)])
def test_rglru(B, S, D):
    la = -jnp.abs(jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32))
    x = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((B, D)), jnp.float32)
    a, al = ops.rglru(la, x, h0=h0, use_pallas=True)
    b, bl = ref.rglru(la, x, h0=h0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(al), np.asarray(bl), atol=1e-5)


def test_rglru_chunked_carries_state():
    B, S, D = 1, 96, 128
    la = -jnp.abs(jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32))
    x = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
    a, al = ops.rglru(la, x, chunk=32, use_pallas=True)
    b, bl = ref.rglru(la, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(al), np.asarray(bl), atol=1e-5)


# ------------------------------------------------------------------ RWKV-6
@pytest.mark.parametrize("B,H,S,Dk,Dv", [(2, 2, 16, 8, 8), (1, 4, 32, 16, 16)])
def test_rwkv6(B, H, S, Dk, Dv):
    r = jnp.asarray(RNG.standard_normal((B, H, S, Dk)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, Dv)), jnp.float32)
    w = jnp.asarray(RNG.random((B, H, S, Dk)) * 0.9 + 0.05, jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, Dk)), jnp.float32)
    a, asl = ops.rwkv6(r, k, v, w, u, use_pallas=True)
    b, bsl = ref.rwkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(asl), np.asarray(bsl), atol=2e-5,
                               rtol=2e-5)


def test_rwkv6_chunked_carries_state():
    B, H, S, D = 1, 2, 48, 8
    r = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, D)), jnp.float32)
    w = jnp.asarray(RNG.random((B, H, S, D)) * 0.9 + 0.05, jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, D)), jnp.float32)
    a, asl = ops.rwkv6(r, k, v, w, u, chunk=16, use_pallas=True)
    b, bsl = ref.rwkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(asl), np.asarray(bsl), atol=2e-5,
                               rtol=2e-5)
