"""Data pipeline: determinism, elasticity, host slicing."""
import jax
import numpy as np

from repro import configs
from repro.configs.base import SHAPES, ShapeSpec
from repro.data import make_batch


def test_deterministic_in_step():
    cfg = configs.get_smoke("qwen3-32b")
    shape = ShapeSpec("t", "train", 16, 4)
    a = make_batch(cfg, shape, 7)
    b = make_batch(cfg, shape, 7)
    c = make_batch(cfg, shape, 8)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_host_slices_partition_the_global_batch():
    cfg = configs.get_smoke("qwen3-32b")
    shape = ShapeSpec("t", "train", 16, 8)
    s0 = make_batch(cfg, shape, 3, host_slice=(0, 2))
    s1 = make_batch(cfg, shape, 3, host_slice=(1, 2))
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s1["tokens"]))


def test_tokens_in_vocab_and_skewed():
    cfg = configs.get_smoke("rwkv6-3b")
    shape = ShapeSpec("t", "train", 256, 8)
    b = make_batch(cfg, shape, 0)
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < cfg.vocab
    # skewed unigram: low ids more frequent
    assert (toks < cfg.vocab // 10).mean() > 0.3


def test_modality_stubs_present():
    cfg = configs.get_smoke("whisper-medium")
    b = make_batch(cfg, ShapeSpec("t", "train", 16, 2), 0)
    assert b["frames"].shape == (2, cfg.n_frames, cfg.d_model)
    cfg = configs.get_smoke("llava-next-34b")
    b = make_batch(cfg, ShapeSpec("t", "train", 16 + cfg.n_patches, 2), 0)
    assert b["patches"].shape == (2, cfg.n_patches, cfg.d_model)
    assert b["tokens"].shape == (2, 17)
