"""Op fifteen — ``wave_commit``, the lane-block megakernel (ISSUE 9).

Covers: Pallas-vs-oracle bit-identity (duplicate cells, masked ops, both
granularities, dual tables, version bumps, explicit lane blocks), the
monotone-wave-tag precondition (eager check, ``REPRO_PRECONDITION_CHECKS=0``
opt-out), fuse_wave on/off bit-identity for every probe-family mechanism at
run() and sweep() level on both backends, the distributed fused owner step,
lane-block selection, and the single-launch jaxpr guard (the fused
probe-family wave emits exactly ONE transaction ``pallas_call`` per wave
on the pallas backend).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import types as t
from repro.core.claimword import EMPTY_WORD, NO_PRIO
from repro.core.engine import run, sweep
from repro.core.types import EngineConfig, TxnBatch, store_init
from repro.kernels import ops, ref
from repro.kernels.wave_commit import pick_lane_block
from repro.workloads import YCSBWorkload

RNG = np.random.default_rng(7)

PROBE_CCS = {"occ": t.CC_OCC, "tictoc": t.CC_TICTOC, "2pl": t.CC_2PL,
             "swisstm": t.CC_SWISS, "adaptive": t.CC_ADAPTIVE}

WL = YCSBWorkload.make(n_keys=256)


# ------------------------------------------------------- oracle parity
def _op_inputs(T, K, N, G, wave, dup=True, masked=True):
    """Random op tensors with duplicate cells and masked (key < 0) ops
    baked in, plus claim tables seeded with BOTH dead older-wave claims
    and live same-wave claims — the fetched-row probe term and the
    all-pairs wave term must both fire."""
    keys = RNG.integers(0, max(2, N // 8) if dup else N, (T, K),
                        dtype=np.int32)
    if masked:
        keys[RNG.random((T, K)) < 0.3] = -1
    groups = RNG.integers(0, G, (T, K), dtype=np.int32)
    prio = RNG.integers(0, 0xFFFF, (T, K), dtype=np.uint32)

    def table():
        tbl = np.full((N, G), EMPTY_WORD, np.uint32)
        dead = RNG.random((N, G)) < 0.4
        old_ivw = (0xFFFF - max(wave - 1, 0)) & 0xFFFF
        tbl[dead] = ((np.uint32(old_ivw) << 16)
                     | RNG.integers(0, 0xFFFF, dead.sum(), dtype=np.uint32))
        live = RNG.random((N, G)) < 0.3
        cur_ivw = (0xFFFF - wave) & 0xFFFF
        tbl[live] = ((np.uint32(cur_ivw) << 16)
                     | RNG.integers(0, 0xFFFF, live.sum(), dtype=np.uint32))
        return jnp.asarray(tbl)

    wts = jnp.asarray(RNG.integers(0, 50, (N, G), dtype=np.uint32))
    masks = tuple(jnp.asarray(RNG.random((T, K)) < p)
                  for p in (0.5, 0.5, 0.5, 0.3, 0.4, 0.1))
    return (jnp.asarray(keys), jnp.asarray(groups), jnp.asarray(prio),
            table(), table(), wts, masks)


@pytest.mark.parametrize("lane_block", [0, 1, 2])
@pytest.mark.parametrize("fine", [False, True])
@pytest.mark.parametrize("dual,bump", [(False, False), (False, True),
                                       (True, True)])
def test_wave_commit_pallas_matches_oracle(fine, dual, bump, lane_block):
    """The megakernel is bit-identical to ref.wave_commit on all five
    outputs — claim tables, version table, conflict mask, commit mask —
    with duplicate cells, masked ops, live and dead table claims, and
    every lane-block width (0 = auto)."""
    T, K, N, G, wave = 8, 4, 64, 3, 5
    keys, groups, prio, cw, cr, wts, masks = _op_inputs(T, K, N, G, wave)
    do_w, do_r, check_w, check_w2, check_r, extra = masks
    args = (cw, cr if dual else None, wts if bump else None, keys, groups,
            prio, do_w, do_r if dual else None, check_w, check_w2,
            check_r if dual else None, extra, jnp.uint32(wave), fine,
            dual, bump)
    a = ref.wave_commit(*args)
    b = ops.wave_commit(*args, lane_block=lane_block, use_pallas=True)
    for name, x, y in zip(("claim_w", "claim_r", "wts", "conflict",
                           "commit"), a, b):
        if x is None:
            assert y is None, name
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), name)


def test_wave_commit_oracle_semantics():
    """Hand-checked case: two lanes contending one cell — the weaker
    (larger prio16) lane conflicts via the all-pairs wave term, the
    stronger commits, and exactly its write bumps the version."""
    N, G = 16, 2
    cw = jnp.full((N, G), EMPTY_WORD, jnp.uint32)
    wts = jnp.zeros((N, G), jnp.uint32)
    keys = jnp.asarray([[5], [5]], jnp.int32)
    groups = jnp.zeros((2, 1), jnp.int32)
    prio = jnp.asarray([[1], [2]], jnp.uint32)
    on = jnp.ones((2, 1), bool)
    cw2, _, wts2, conflict, commit = ref.wave_commit(
        cw, None, wts, keys, groups, prio, on, None, on, None, None, None,
        jnp.uint32(3), True, False, True)
    assert conflict.tolist() == [[False], [True]]
    assert commit.tolist() == [True, False]
    assert int(wts2[5, 0]) == 1 and int(wts2.sum()) == 1
    # the winning claim word is installed: inv-wave tag | strongest prio16
    assert int(cw2[5, 0]) == (((0xFFFF - 3) << 16) | 1)
    # a masked op (key < 0) neither probes, installs, nor bumps
    _, _, wts3, conflict3, _ = ref.wave_commit(
        cw, None, wts, -jnp.ones_like(keys), groups, prio, on, None, on,
        None, None, None, jnp.uint32(3), True, False, True)
    assert not bool(conflict3.any()) and int(wts3.sum()) == 0


def test_wave_commit_monotone_tag_precondition(monkeypatch):
    """A claim table already tagged with a FUTURE wave (inv_wave below the
    current wave's) means the wave counter ran backwards — the eager
    pallas path must raise on either table, and
    REPRO_PRECONDITION_CHECKS=0 must bypass the check."""
    T, K, N, G, wave = 2, 2, 16, 2, 5
    keys = jnp.zeros((T, K), jnp.int32).at[0, 0].set(3)
    groups = jnp.zeros((T, K), jnp.int32)
    prio = jnp.ones((T, K), jnp.uint32)
    on = jnp.ones((T, K), bool)
    good = jnp.full((N, G), EMPTY_WORD, jnp.uint32)
    # inv_wave(9) < inv_wave(5): row 3 claims to be from a future wave
    bad = good.at[3, 0].set(jnp.uint32(((0xFFFF - 9) << 16) | 1))
    wts = jnp.zeros((N, G), jnp.uint32)

    def call(cw, cr):
        return ops.wave_commit(cw, cr, wts, keys, groups, prio, on, on,
                               on, None, on, None, jnp.uint32(wave), True,
                               True, True, use_pallas=True)

    with pytest.raises(ValueError, match="precondition"):
        call(bad, good)
    with pytest.raises(ValueError, match="precondition"):
        call(good, bad)
    monkeypatch.setenv("REPRO_PRECONDITION_CHECKS", "0")
    call(bad, good)


def test_pick_lane_block():
    """Lane-block selection: overrides snap DOWN to a divisor of T (so the
    grid tiles exactly), auto widths shrink as the table row widens, and
    the result always divides T."""
    assert pick_lane_block(8, 4, 2, override=3) == 2     # snap 3 -> 2
    assert pick_lane_block(8, 4, 2, override=64) == 8    # cap at T
    assert pick_lane_block(8, 4, 512) == 1               # wide row -> 1 lane
    for T in (6, 8, 64, 96):
        for g in (1, 2, 64, 512):
            assert T % pick_lane_block(T, 16, g) == 0
    with pytest.raises(ValueError):
        EngineConfig(cc=t.CC_OCC, lanes=8, slots=4, n_records=64,
                     n_groups=2, n_cols=0, n_txn_types=1, lane_block=-1)
    with pytest.raises(ValueError):
        D.DistConfig(n_records=64, n_groups=2, lanes_per_shard=8, slots=4,
                     lane_block=-1)


# --------------------------------------- fused vs unfused engine identity
def _engine_cfg(cc_name, gran, backend, fuse):
    return EngineConfig(
        cc=PROBE_CCS[cc_name], lanes=8, slots=WL.slots,
        n_records=WL.n_records, n_groups=WL.n_groups, n_cols=WL.n_cols,
        n_txn_types=WL.n_txn_types, granularity=gran, n_rings=WL.n_rings,
        backend=backend, fuse_wave=fuse)


def _assert_runs_identical(a, b):
    assert (a.commits, a.aborts) == (b.commits, b.aborts)
    assert (a.ro_commits, a.ro_aborts) == (b.ro_commits, b.ro_aborts)
    np.testing.assert_array_equal(np.asarray(a.abort_causes),
                                  np.asarray(b.abort_causes))
    for name in ("wts", "rts", "claim_w", "claim_r"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.final_state.store, name)),
            np.asarray(getattr(b.final_state.store, name)), name)


@pytest.mark.parametrize("cc", sorted(PROBE_CCS))
@pytest.mark.parametrize("gran", [0, 1])
def test_fuse_wave_run_bit_identity_jnp(cc, gran):
    """ISSUE 9 acceptance: fuse_wave=True is bit-identical to the unfused
    probe chain — commits, aborts, per-cause breakdown, and ALL final
    store tables — for every probe-family mechanism x granularity."""
    a = run(_engine_cfg(cc, gran, "jnp", True), WL, n_waves=4, seed=0,
            keep_state=True)
    b = run(_engine_cfg(cc, gran, "jnp", False), WL, n_waves=4, seed=0,
            keep_state=True)
    _assert_runs_identical(a, b)


@pytest.mark.parametrize("cc,gran", [("2pl", 1), ("adaptive", 0),
                                     ("tictoc", 1)])
def test_fuse_wave_run_bit_identity_pallas(cc, gran):
    """The same identity with both paths on the interpret-mode kernels
    (dual-table, coarse, and no-bump representatives; the full matrix
    runs on jnp above and via the sweep test below)."""
    a = run(_engine_cfg(cc, gran, "pallas", True), WL, n_waves=3, seed=0,
            keep_state=True)
    b = run(_engine_cfg(cc, gran, "pallas", False), WL, n_waves=3, seed=0,
            keep_state=True)
    _assert_runs_identical(a, b)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fuse_wave_sweep_bit_identity(backend):
    """sweep()-level identity: the whole probe family x both granularities
    in ONE compiled grid per fuse setting, on each backend."""
    cfg = _engine_cfg("occ", 1, backend, True)
    pts_f = sweep(cfg, WL, 3, ccs=sorted(PROBE_CCS.values()), grans=(0, 1),
                  lane_counts=(8,), seeds=(0,))
    pts_u = sweep(dataclasses.replace(cfg, fuse_wave=False), WL, 3,
                  ccs=sorted(PROBE_CCS.values()), grans=(0, 1),
                  lane_counts=(8,), seeds=(0,))
    assert len(pts_f) == len(pts_u) == 10
    for pa, pb in zip(pts_f, pts_u):
        assert (pa.cc, pa.granularity) == (pb.cc, pb.granularity)
        assert (pa.commits, pa.aborts) == (pb.commits, pb.aborts)
        assert (pa.ro_commits, pa.ro_aborts) == (pb.ro_commits, pb.ro_aborts)
        assert pa.abort_causes == pb.abort_causes


# --------------------------------------------------- distributed owner step
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
def test_distributed_fuse_wave_bit_identity(gran, backend):
    """The routed occ wave's owner step through the fused op vs the
    claim_probe chain: identical commit mask, tables, and stats over
    every available host device (8 under the CI XLA_FLAGS)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ns = len(jax.devices())
    N, Tl, K = 256, 8, 4
    keys = jnp.asarray(RNG.integers(0, N, (ns * Tl, K), dtype=np.int32))
    groups = jnp.asarray(RNG.integers(0, 2, (ns * Tl, K), dtype=np.int32))
    kinds = jnp.asarray(RNG.choice([t.READ, t.WRITE],
                                   (ns * Tl, K)).astype(np.int32))
    prio = jnp.asarray(RNG.permutation(ns * Tl).astype(np.uint32))
    outs = {}
    for fuse in (True, False):
        cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=Tl,
                           slots=K, granularity=gran, backend=backend,
                           fuse_wave=fuse)
        wave_fn = jax.jit(D.make_wave_fn(cfg, mesh))
        tables = D.init_tables(cfg, mesh)
        outs[fuse] = wave_fn(keys, groups, kinds, prio, tables,
                             jnp.uint32(0))
    for a, b in zip(jax.tree.leaves(outs[True]),
                    jax.tree.leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    commit = outs[True][0]
    assert int(commit.sum()) > 0


# ------------------------------------------------------ single-launch guard
def _pallas_launches(fn, *args):
    """Names of every pallas_call in fn's jaxpr, sub-jaxprs included."""
    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jx, out):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                out.append(str(eqn.params.get("name_and_src_info")))
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr, out)
        return out
    return walk(jaxpr.jaxpr, [])


@pytest.mark.parametrize("cc", sorted(PROBE_CCS))
def test_fused_wave_single_launch_guard(cc):
    """ISSUE 9 guard: on the pallas backend the fused probe-family wave
    emits exactly ONE transaction pallas_call — the wave_commit
    megakernel — and none of the unfused chain's claim_probe / occ_commit
    launches.  Unfused occ, for contrast, launches the chain."""
    from repro.core.cc import adaptive, occ, swisstm, tictoc, two_pl
    mod = {"occ": occ, "tictoc": tictoc, "2pl": two_pl,
           "swisstm": swisstm, "adaptive": adaptive}[cc]
    T, K = 4, 3
    cfg = _engine_cfg(cc, 1, "pallas", True)
    cfg = dataclasses.replace(cfg, lanes=T)
    store = store_init(cfg.n_records, cfg.n_groups, 0)
    batch = TxnBatch(op_key=jnp.zeros((T, K), jnp.int32),
                     op_group=jnp.zeros((T, K), jnp.int32),
                     op_col=jnp.zeros((T, K), jnp.int32),
                     op_kind=jnp.full((T, K), t.WRITE, jnp.int32),
                     op_val=jnp.zeros((T, K), jnp.float32),
                     txn_type=jnp.zeros((T,), jnp.int32),
                     n_ops=jnp.full((T,), K, jnp.int32))
    prio = jnp.arange(T, dtype=jnp.uint32)

    def fused(s, b, p):
        return mod.wave_validate(s, b, p, jnp.uint32(1), cfg)

    names = _pallas_launches(fused, store, batch, prio)
    wc = [n for n in names if "_wave_commit_kernel" in n]
    assert len(wc) == 1, names
    assert not any("claim_probe" in n or "occ_commit" in n
                   for n in names), names

    ucfg = dataclasses.replace(cfg, fuse_wave=False)

    def unfused(s, b, p):
        return mod.wave_validate(s, b, p, jnp.uint32(1), ucfg)

    unames = _pallas_launches(unfused, store, batch, prio)
    assert not any("_wave_commit_kernel" in n for n in unames), unames
    assert any("claim_probe" in n for n in unames), unames


def test_wave_commit_in_backend_surface():
    """The op is part of the ``backend.N_OPS``-op surface: both backends expose it,
    CC_OPS attributes it to every probe-family mechanism, and the
    distributed occ op list routes through it."""
    from repro.core import backend as kb
    assert hasattr(kb.JnpBackend, "wave_commit")
    assert hasattr(kb.PallasBackend, "wave_commit")
    for cc in PROBE_CCS.values():
        assert "wave_commit" in kb.CC_OPS[cc], cc
        assert "claim_probe" not in kb.CC_OPS[cc], cc
    assert "wave_commit" in kb.DIST_OPS
    # the MV routed wave keeps the two-channel claim_probe (no fused path)
    assert "claim_probe" in kb.DIST_MV_OPS
