"""benchmarks/perf_dashboard.py: JSON-row aggregation into the markdown
perf dashboard (peak-point selection, kernel-op attribution cells, the
distributed txn_scaling section, and malformed-row resilience)."""
import json

from benchmarks.perf_dashboard import (_causes_cell, _ops_cell, load_rows,
                                       main, render_markdown)

MECH_ROWS = [
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 16,
     "throughput": 10.0, "abort_rate": 0.10, "backend": "pallas",
     "kernel_ops": {"claim_probe": "pallas", "commit_install": "pallas",
                    "segment_count": "pallas"}},
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 64,
     "throughput": 25.5, "abort_rate": 0.20, "backend": "pallas",
     "kernel_ops": {"claim_probe": "pallas", "commit_install": "pallas",
                    "segment_count": "pallas"}},
    {"workload": "ycsb", "cc": "tictoc", "granularity": 0, "lanes": 64,
     "throughput": 18.0, "abort_rate": 0.30, "backend": "jnp",
     "kernel_ops": {"claim_probe": "xla", "ts_gather": "xla",
                    "ts_install_max": "xla", "segment_count": "xla"}},
]
DIST_ROWS = [
    {"shards": 0, "commits": 900, "waves_per_s": 50.0,
     "coll_bytes_per_wave": 0, "backend": "jnp", "kernel_ops": {}},
    {"shards": 8, "cc": "mvcc", "commits": 850, "waves_per_s": 12.5,
     "ro_commits": 120, "ro_aborts": 3,
     "coll_bytes_per_wave": 65536, "backend": "pallas",
     "kernel_ops": {"route_pack": "pallas", "claim_probe": "pallas",
                    "mv_gather": "pallas", "mv_install": "pallas"}},
]


def test_ops_cell_attribution():
    assert _ops_cell({}) == "—"
    assert _ops_cell({"a": "pallas", "b": "pallas"}) == "2/2 pallas"
    assert _ops_cell({"a": "xla", "b": "xla"}) == "xla"
    # a mixed map means a partial fallback — rendered loudly, per op
    assert _ops_cell({"a": "pallas", "b": "xla"}) == "a:pallas, b:xla"


def test_render_picks_peak_point_per_group():
    rows = [dict(r, _src="BENCH_a.json") for r in MECH_ROWS]
    md = render_markdown(rows, [])
    # rows predating the cost model / cause taxonomy / megakernel / scan
    # era render '—' in the abort-causes, scan, B/txn, flop/txn,
    # roofline, launches/wave, and DMA-rows/wave columns
    assert "| ycsb | occ | fine | pallas | 25.500 | 64 | 20.00% " \
           "| — | — | — | — | — | — | — | 3/3 pallas | BENCH_a.json |" in md
    assert "10.000" not in md                     # dominated point dropped
    assert "| ycsb | tictoc | coarse | jnp | 18.000 | 64 | 30.00% " \
           "| — | — | — | — | — | — | — | xla | BENCH_a.json |" in md


def test_render_distributed_section():
    rows = [dict(r, _src="txn_scaling.json") for r in DIST_ROWS]
    md = render_markdown([], rows)
    # rows without the cc / read-only / pipeline-wire fields (pre-MV,
    # pre-pipeline txn_scaling files) default to occ and render unknown
    # splits as '?' and unknown depth/wire columns as '—'
    assert "| 0 | occ | — | 50.0 | 900 | ? | ? | 0.0 | — | — | — | jnp " \
           "| — | txn_scaling.json |" in md
    assert "| 8 | mvcc | — | 12.5 | 850 | 120 | 3 | 64.0 | — | — | — " \
           "| pallas | 4/4 pallas | txn_scaling.json |" in md


def test_render_distributed_depth_and_wire_columns():
    """Pipelined txn_scaling rows carry pipeline_depth + the modeled wire
    split; the dashboard renders depth, wire KiB/wave, and the packed vs
    legacy verdict bytes side by side, and orders depth-1 before depth-2
    within one (source, cc, shards) group."""
    base = {"shards": 8, "cc": "occ", "commits": 800, "waves_per_s": 100.0,
            "ro_commits": 0, "ro_aborts": 0, "coll_bytes_per_wave": 16384,
            "backend": "jnp", "kernel_ops": {}, "_src": "txn_scaling.json",
            "wire_bytes_per_wave": 18432, "route_bytes_per_wave": 16384,
            "verdict_bytes_per_wave": 1024, "commit_bytes_per_wave": 1024,
            "verdict_bytes_per_wave_legacy": 4096}
    rows = [dict(base, pipeline_depth=2, waves_per_s=150.0),
            dict(base, pipeline_depth=1)]
    md = render_markdown([], rows)
    assert "| 8 | occ | 1 | 100.0 | 800 | 0 | 0 | 16.0 | 18.0 " \
           "| 1024 / 4096 | — | jnp | — | txn_scaling.json |" in md
    assert "| 8 | occ | 2 | 150.0 | 800 | 0 | 0 | 16.0 | 18.0 " \
           "| 1024 / 4096 | — | jnp | — | txn_scaling.json |" in md
    assert md.index("| 8 | occ | 1 |") < md.index("| 8 | occ | 2 |")
    # the legend explains the columns
    assert "verdict B/wave" in md and "depth" in md


def test_causes_cell_shapes():
    assert _causes_cell(None) == "—"
    assert _causes_cell("bogus") == "—"
    assert _causes_cell({"read_val": 56, "ww": 0}) == "read_val:56"
    # txn_scaling rows store the code-ordered 6-list
    assert _causes_cell([0, 3, 0, 0, 9, 2]) == "capacity:3 ww:9 read_val:2"
    assert _causes_cell({"read_val": 0}) == "none"
    assert _causes_cell({"read_val": "junk"}) == "—"


def test_render_mech_cost_and_cause_columns():
    """Rows carrying the ISSUE 8 observability fields render the per-cause
    breakdown, the analytic B/txn + flop/txn, and the roofline fraction."""
    r = dict(MECH_ROWS[1], _src="BENCH_a.json",
             abort_causes={"inc_cap": 0, "capacity": 0, "stale_snapshot": 0,
                           "lock_wound": 0, "ww": 0, "read_val": 56},
             bytes_per_txn=512.0, flops_per_txn=128.0,
             roofline_frac=0.00104, roofline_bound="memory",
             roofline_chip="tpu_v5e")
    md = render_markdown([r], [])
    assert "| ycsb | occ | fine | pallas | 25.500 | 64 | 20.00% " \
           "| read_val:56 | — | 512 | 128 | 0.10% (memory) | — | — " \
           "| 3/3 pallas | BENCH_a.json |" in md


def test_render_mech_fusion_columns():
    """Probe-family rows carrying the ISSUE 9 megakernel fields render
    launches/wave and DMA rows/wave with the modeled cut vs unfused."""
    r = dict(MECH_ROWS[1], _src="BENCH_a.json",
             launches_per_wave=1, dma_rows_per_wave=1024,
             dma_rows_per_wave_unfused=3072)
    md = render_markdown([r], [])
    assert "| 20.00% | — | — | — | — | — | 1 | 1024 (/3 vs unfused) " \
           "| 3/3 pallas | BENCH_a.json |" in md
    assert "launches/wave" in md and "DMA rows/wave" in md


def test_render_distributed_dedupes_repeat_runs():
    """Regression (ISSUE 8 satellite): txn_scaling appends a row per run,
    so three runs of one config stacked three near-identical rows in the
    report.  The dashboard keys by (cc, shards, depth, backend) and keeps
    only the latest (last-in-file) row; distinct depths/backends all
    survive."""
    base = {"shards": 1, "cc": "mvcc", "pipeline_depth": 1, "commits": 800,
            "ro_commits": 0, "ro_aborts": 0, "coll_bytes_per_wave": 0,
            "backend": "jnp", "kernel_ops": {}, "_src": "txn_scaling.json"}
    rows = [dict(base, waves_per_s=10.0), dict(base, waves_per_s=20.0),
            dict(base, waves_per_s=30.0),             # latest run wins
            dict(base, pipeline_depth=2, waves_per_s=44.0),
            dict(base, backend="pallas", waves_per_s=55.0)]
    md = render_markdown([], rows)
    dup = [ln for ln in md.splitlines()
           if ln.startswith("| 1 | mvcc | 1 |") and "| jnp |" in ln]
    assert len(dup) == 1, md
    assert "| 30.0 |" in dup[0]
    assert "| 10.0 |" not in md and "| 20.0 |" not in md
    assert "| 44.0 |" in md and "| 55.0 |" in md     # other configs kept
    assert "latest run wins" in md                   # legend explains it


def test_render_distributed_open_loop_rows_disambiguated():
    """The open-loop row family shares (cc, shards, depth) with the
    closed-loop rows; mode + granularity join the dedupe key and the cc
    cell so the three rows of one config no longer render as an
    identical-looking stack."""
    base = {"shards": 1, "cc": "mvcc", "pipeline_depth": 1, "commits": 800,
            "waves_per_s": 73.8, "ro_commits": 0, "ro_aborts": 0,
            "coll_bytes_per_wave": 0, "backend": "jnp", "kernel_ops": {},
            "_src": "txn_scaling.json"}
    rows = [base,
            dict(base, mode="open_loop", granularity=0, waves_per_s=1.4),
            dict(base, mode="open_loop", granularity=1, waves_per_s=1.6)]
    md = render_markdown([], rows)
    assert "| 1 | mvcc | 1 | 73.8 |" in md
    assert "| 1 | mvcc open/coarse | 1 | 1.4 |" in md
    assert "| 1 | mvcc open/fine | 1 | 1.6 |" in md


def test_render_distributed_causes_column():
    r = dict(DIST_ROWS[1], _src="txn_scaling.json",
             abort_causes=[0, 60, 0, 0, 159, 0])
    md = render_markdown([], [r])
    assert "| capacity:60 ww:159 | pallas |" in md


def test_string_throughput_compares_numerically():
    """Regression (ISSUE 6 satellite): CSV-converted/hand-edited bench
    files store throughput as STRINGS — "0.9" vs "12.3" must compare
    numerically (12.3 wins), not lexically ("0.9" > "12.3")."""
    rows = [
        {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 8,
         "throughput": "0.9", "abort_rate": 0.1, "backend": "jnp",
         "kernel_ops": {}, "_src": "BENCH_csv.json"},
        {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 64,
         "throughput": "12.3", "abort_rate": 0.2, "backend": "jnp",
         "kernel_ops": {}, "_src": "BENCH_csv.json"},
    ]
    md = render_markdown(rows, [])
    assert "| 12.300 | 64 |" in md          # the numeric peak
    assert "| 0.900 | 8 |" not in md        # lexical "winner" dropped
    assert "## Skipped rows" not in md      # numeric strings aren't skipped


def test_string_throughput_mixed_with_numeric():
    """A numeric 5.0 row and a string "12.3" row rank on one scale."""
    rows = [dict(MECH_ROWS[0], throughput=5.0, _src="a.json"),
            dict(MECH_ROWS[0], lanes=32, throughput="12.3", _src="a.json")]
    md = render_markdown(rows, [])
    assert "| 12.300 | 32 |" in md
    assert "| 5.000 |" not in md


OPEN_ROWS = [
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 64,
     "throughput": 9.0, "abort_rate": 0.2, "backend": "jnp",
     "kernel_ops": {}, "open_loop": True, "goodput": 7.25,
     "p50_ttc_waves": [1.0], "p99_ttc_waves": [4.0, 6.0],
     "inc_drops": 12, "arrival_drops": 3, "arrival_rate": 48.0},
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 8,
     "throughput": 2.0, "abort_rate": 0.1, "backend": "jnp",
     "kernel_ops": {}, "open_loop": True, "goodput": "1.5",
     "p50_ttc_waves": [1.0], "p99_ttc_waves": [2.0],
     "inc_drops": 0, "arrival_drops": 0, "arrival_rate": 6.0},
]


def test_render_open_loop_latency_section():
    """Open-loop rows get their own latency section: peak-GOODPUT point
    per group (string goodputs coerced too), per-class ttc cells."""
    rows = [dict(r, _src="open_loop.json") for r in OPEN_ROWS]
    md = render_markdown(rows, [])
    assert "## Open-loop latency" in md
    assert "| ycsb | occ | fine | jnp | 7.250 | 1 | 4/6 | 12 | 3 " \
           "| open_loop.json |" in md
    assert "1.500" not in md               # dominated (and string) goodput
    # closed-loop section still renders these rows by throughput
    assert "| 9.000 | 64 |" in md


def test_no_open_loop_rows_no_section():
    md = render_markdown([dict(r, _src="a.json") for r in MECH_ROWS], [])
    assert "## Open-loop latency" not in md


# ------------------------------------------------ malformed-row resilience
def test_truncated_mech_row_is_skipped_with_warning():
    """Regression (ISSUE 5 satellite): a partial row — e.g. the tail of a
    killed bench run — must not abort the whole dashboard; it is skipped
    and called out in the report."""
    rows = [dict(r, _src="BENCH_a.json") for r in MECH_ROWS]
    rows.append({"workload": "ycsb", "cc": "occ", "_src": "BENCH_cut.json"})
    rows.append({"cc": "occ", "throughput": "fast?",
                 "_src": "BENCH_bad.json"})
    md = render_markdown(rows, [])
    assert "25.500" in md                          # good rows still render
    assert "## Skipped rows (2)" in md
    assert "`BENCH_cut.json`: mechanism row: missing/non-numeric " \
           "'throughput'" in md
    assert "`BENCH_bad.json`" in md


def test_truncated_dist_row_is_skipped_with_warning():
    rows = [dict(r, _src="txn_scaling.json") for r in DIST_ROWS]
    rows.append({"shards": None, "commits": 7, "_src": "txn_cut.json"})
    md = render_markdown([], rows)
    assert "| 8 | mvcc |" in md                    # good rows still render
    assert "## Skipped rows (1)" in md
    assert "`txn_cut.json`: distributed row: missing/non-numeric " \
           "'shards'" in md


def test_only_bad_rows_still_renders_warnings():
    md = render_markdown([{"cc": "x", "_src": "a.json"}], [])
    assert "## Skipped rows (1)" in md
    assert "No benchmark rows found" not in md


def test_main_end_to_end(tmp_path):
    """Glob -> split -> render -> write: the CLI path, on a synthetic
    BENCH file mixing both row shapes plus an unreadable file and a
    truncated row."""
    bench = tmp_path / "BENCH_mix.json"
    bench.write_text(json.dumps(
        MECH_ROWS + DIST_ROWS
        + [{"cc": "occ", "workload": "ycsb"}]))       # truncated row
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    out = tmp_path / "reports" / "perf_dashboard.md"
    assert main([str(tmp_path / "BENCH_*.json"), "--out", str(out)]) == 0
    md = out.read_text()
    assert "## Mechanisms" in md and "## Distributed engine" in md
    assert "25.500" in md and "route_pack" not in md  # ops compressed
    assert "## Skipped rows (1)" in md
    mech, dist = load_rows((str(tmp_path / "BENCH_*.json"),))
    assert len(mech) == 4 and len(dist) == 2          # truncated row loads…
    md2 = render_markdown(mech, dist)                 # …and only warns
    assert "## Skipped rows (1)" in md2


def test_main_no_rows(tmp_path):
    out = tmp_path / "dash.md"
    assert main([str(tmp_path / "nothing_*.json"), "--out", str(out)]) == 0
    assert "No benchmark rows found" in out.read_text()


def test_pre_scan_rows_render_unchanged():
    """Regression (ISSUE 10 satellite): JSON rows written before the
    interval era — no max_extent / scan_frac / scan_len, a 6-cause
    abort_causes dict without 'phantom' — must render with a '—' scan
    cell and NO skipped-row warning."""
    r = dict(MECH_ROWS[1], _src="BENCH_pr9.json",
             abort_causes={"inc_cap": 0, "capacity": 0,
                           "stale_snapshot": 0, "lock_wound": 0,
                           "ww": 2, "read_val": 56})
    md = render_markdown([r], [])
    assert "## Skipped rows" not in md
    assert "| ww:2 read_val:56 | — |" in md
    # the code-ordered 6-list (pre-phantom txn_scaling files) also parses
    assert _causes_cell([0, 0, 0, 0, 2, 56]) == "ww:2 read_val:56"


def test_scan_rows_render_and_keep_own_peak_group():
    """A scan-mix row shares (workload, cc, gran, backend) with a faster
    point row; max_extent joins the peak-group key so BOTH render — the
    scan row with its 'ext=L (frac x len)' cell."""
    point = dict(MECH_ROWS[1], _src="BENCH_a.json", throughput=25.5)
    scan = dict(MECH_ROWS[1], _src="scan_mix.json", throughput=9.25,
                max_extent=16, scan_frac=0.5, scan_len=16,
                abort_causes={"read_val": 3, "phantom": 41})
    md = render_markdown([point, scan], [])
    assert "| 25.500 | 64 | " in md                 # point peak survives
    assert "| 9.250 | 64 | " in md                  # scan row not dominated
    assert "| ext=16 (0.5×16) |" in md
    assert "phantom:41" in md
