"""benchmarks/perf_dashboard.py: JSON-row aggregation into the markdown
perf dashboard (peak-point selection, kernel-op attribution cells, the
distributed txn_scaling section)."""
import json

from benchmarks.perf_dashboard import (_ops_cell, load_rows, main,
                                       render_markdown)

MECH_ROWS = [
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 16,
     "throughput": 10.0, "abort_rate": 0.10, "backend": "pallas",
     "kernel_ops": {"claim_probe": "pallas", "commit_install": "pallas",
                    "segment_count": "pallas"}},
    {"workload": "ycsb", "cc": "occ", "granularity": 1, "lanes": 64,
     "throughput": 25.5, "abort_rate": 0.20, "backend": "pallas",
     "kernel_ops": {"claim_probe": "pallas", "commit_install": "pallas",
                    "segment_count": "pallas"}},
    {"workload": "ycsb", "cc": "tictoc", "granularity": 0, "lanes": 64,
     "throughput": 18.0, "abort_rate": 0.30, "backend": "jnp",
     "kernel_ops": {"claim_probe": "xla", "ts_gather": "xla",
                    "ts_install_max": "xla", "segment_count": "xla"}},
]
DIST_ROWS = [
    {"shards": 0, "commits": 900, "waves_per_s": 50.0,
     "coll_bytes_per_wave": 0, "backend": "jnp", "kernel_ops": {}},
    {"shards": 8, "commits": 850, "waves_per_s": 12.5,
     "coll_bytes_per_wave": 65536, "backend": "pallas",
     "kernel_ops": {"route_pack": "pallas", "claim_probe": "pallas",
                    "commit_install": "pallas"}},
]


def test_ops_cell_attribution():
    assert _ops_cell({}) == "—"
    assert _ops_cell({"a": "pallas", "b": "pallas"}) == "2/2 pallas"
    assert _ops_cell({"a": "xla", "b": "xla"}) == "xla"
    # a mixed map means a partial fallback — rendered loudly, per op
    assert _ops_cell({"a": "pallas", "b": "xla"}) == "a:pallas, b:xla"


def test_render_picks_peak_point_per_group():
    rows = [dict(r, _src="BENCH_a.json") for r in MECH_ROWS]
    md = render_markdown(rows, [])
    assert "| ycsb | occ | fine | pallas | 25.500 | 64 | 20.00% " \
           "| 3/3 pallas | BENCH_a.json |" in md
    assert "10.000" not in md                     # dominated point dropped
    assert "| ycsb | tictoc | coarse | jnp | 18.000 | 64 | 30.00% " \
           "| xla | BENCH_a.json |" in md


def test_render_distributed_section():
    rows = [dict(r, _src="txn_scaling.json") for r in DIST_ROWS]
    md = render_markdown([], rows)
    assert "| 0 | 50.0 | 900 | 0.0 | jnp | — | txn_scaling.json |" in md
    assert "| 8 | 12.5 | 850 | 64.0 | pallas | 3/3 pallas " \
           "| txn_scaling.json |" in md


def test_main_end_to_end(tmp_path):
    """Glob -> split -> render -> write: the CLI path, on a synthetic
    BENCH file mixing both row shapes plus an unreadable file."""
    bench = tmp_path / "BENCH_mix.json"
    bench.write_text(json.dumps(MECH_ROWS + DIST_ROWS))
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    out = tmp_path / "reports" / "perf_dashboard.md"
    assert main([str(tmp_path / "BENCH_*.json"), "--out", str(out)]) == 0
    md = out.read_text()
    assert "## Mechanisms" in md and "## Distributed engine" in md
    assert "25.500" in md and "route_pack" not in md  # ops compressed
    mech, dist = load_rows((str(tmp_path / "BENCH_*.json"),))
    assert len(mech) == 3 and len(dist) == 2


def test_main_no_rows(tmp_path):
    out = tmp_path / "dash.md"
    assert main([str(tmp_path / "nothing_*.json"), "--out", str(out)]) == 0
    assert "No benchmark rows found" in out.read_text()
