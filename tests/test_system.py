"""End-to-end behaviour tests for the paper's system: reduced-size versions
of the figure benchmarks asserting the paper's ORDERING claims."""
import jax
import pytest

from repro.core import types as t
from repro.core.engine import run
from repro.workloads import TPCCWorkload, YCSBWorkload


def mk(cc, wl, lanes, gran):
    return t.EngineConfig(cc=cc, lanes=lanes, slots=wl.slots,
                          n_records=wl.n_records, n_groups=wl.n_groups,
                          n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                          granularity=gran, n_rings=wl.n_rings)


@pytest.fixture(scope="module")
def tpcc():
    return TPCCWorkload.make(n_warehouses=8, scale=0.5)


def test_tpcc_fine_occ_beats_everything_at_high_lanes(tpcc):
    """Paper section 4.3 / Fig 3b: with fine-grained timestamps OCC is the
    fastest mechanism at high core counts."""
    T, W = 96, 120
    occ = run(mk(t.CC_OCC, tpcc, T, 1), tpcc, W, seed=5).throughput
    for cc in (t.CC_TICTOC, t.CC_2PL, t.CC_SWISS):
        other = run(mk(cc, tpcc, T, 1), tpcc, W, seed=5).throughput
        assert occ > other, t.CC_NAMES[cc]


def test_tpcc_coarse_tictoc_beats_occ_midrange(tpcc):
    """Fig 3a: TicToc above OCC at mid-high core counts with coarse TS."""
    T, W = 64, 120
    occ = run(mk(t.CC_OCC, tpcc, T, 0), tpcc, W, seed=5)
    tic = run(mk(t.CC_TICTOC, tpcc, T, 0), tpcc, W, seed=5)
    assert tic.throughput > occ.throughput
    assert tic.abort_rate < occ.abort_rate


def test_tpcc_fine_granularity_large_abort_drop(tpcc):
    """Section 4.3: OCC's abort rate collapses when timestamps go fine
    (paper: 30.91% -> 1.75% at 128 threads)."""
    T, W = 128, 120
    coarse = run(mk(t.CC_OCC, tpcc, T, 0), tpcc, W, seed=5).abort_rate
    fine = run(mk(t.CC_OCC, tpcc, T, 1), tpcc, W, seed=5).abort_rate
    assert coarse > 5 * fine
    assert fine < 0.05


def test_occ_fine_beats_tictoc_coarse(tpcc):
    """The headline: OCC + fine-grained timestamps outperforms TicToc with
    coarse timestamps (paper: 1.37x @96)."""
    T, W = 96, 120
    occ_f = run(mk(t.CC_OCC, tpcc, T, 1), tpcc, W, seed=5).throughput
    tic_c = run(mk(t.CC_TICTOC, tpcc, T, 0), tpcc, W, seed=5).throughput
    assert occ_f > 1.15 * tic_c


def test_ycsb_tictoc_collapses_at_high_lanes():
    """Fig 2a: TicToc ends up much worse than OCC as parallelism increases
    (rts-extension CAS failures under contention)."""
    wl = YCSBWorkload.make(n_keys=200_000)
    W = 100
    occ = run(mk(t.CC_OCC, wl, 128, 0), wl, W, seed=6)
    tic = run(mk(t.CC_TICTOC, wl, 128, 0), wl, W, seed=6)
    assert tic.throughput < 0.7 * occ.throughput
    assert tic.abort_rate > occ.abort_rate


def test_ycsb_fine_lifts_all_mechanisms():
    """Fig 2b: every mechanism improves with the parity split."""
    wl = YCSBWorkload.make(n_keys=200_000)
    W = 80
    for cc in (t.CC_OCC, t.CC_TICTOC, t.CC_2PL, t.CC_SWISS, t.CC_ADAPTIVE):
        c = run(mk(cc, wl, 96, 0), wl, W, seed=7).throughput
        f = run(mk(cc, wl, 96, 1), wl, W, seed=7).throughput
        assert f > c, t.CC_NAMES[cc]
