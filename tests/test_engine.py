"""Wave-engine behaviour: determinism, retry accounting, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import types as t
from repro.core.engine import run
from repro.workloads import TPCCWorkload, YCSBWorkload


def mk(cc, wl, lanes=16, gran=1, **kw):
    return t.EngineConfig(cc=cc, lanes=lanes, slots=wl.slots,
                          n_records=wl.n_records, n_groups=wl.n_groups,
                          n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                          granularity=gran, n_rings=wl.n_rings, **kw)


def test_determinism_same_seed():
    wl = YCSBWorkload.make(n_keys=1000)
    cfg = mk(t.CC_OCC, wl)
    a = run(cfg, wl, n_waves=20, seed=7)
    b = run(cfg, wl, n_waves=20, seed=7)
    assert a.commits == b.commits and a.aborts == b.aborts
    assert a.throughput == pytest.approx(b.throughput)


def test_attempts_equal_lanes_times_waves():
    wl = YCSBWorkload.make(n_keys=500)
    for cc in (t.CC_OCC, t.CC_TICTOC, t.CC_2PL, t.CC_SWISS, t.CC_ADAPTIVE,
               t.CC_AUTOGRAN):
        cfg = mk(cc, wl, lanes=8)
        r = run(cfg, wl, n_waves=15, seed=1)
        assert r.commits + r.aborts == 8 * 15, t.CC_NAMES[cc]


def test_aborted_txn_retries_not_regenerated():
    """With heavy contention a lane's aborted txn must re-run (pending
    buffer): with retries, commits by type track the original mix."""
    wl = TPCCWorkload.make(n_warehouses=1, scale=0.05)
    cfg = mk(t.CC_OCC, wl, lanes=32)
    r = run(cfg, wl, n_waves=40, seed=0)
    assert r.commits > 0
    assert sum(r.commits_by_type) == r.commits


def test_fine_granularity_reduces_tpcc_aborts():
    wl = TPCCWorkload.make(n_warehouses=2, scale=0.2)
    coarse = run(mk(t.CC_OCC, wl, lanes=32, gran=0), wl, 40, seed=1)
    fine = run(mk(t.CC_OCC, wl, lanes=32, gran=1), wl, 40, seed=1)
    assert fine.abort_rate < coarse.abort_rate
    assert fine.throughput > coarse.throughput


def test_ycsb_parity_split_reduces_aborts():
    wl = YCSBWorkload.make(n_keys=64, theta=0.9)   # tiny => hot
    coarse = run(mk(t.CC_OCC, wl, lanes=16, gran=0), wl, 30, seed=2)
    fine = run(mk(t.CC_OCC, wl, lanes=16, gran=1), wl, 30, seed=2)
    assert fine.abort_rate <= coarse.abort_rate


def test_autogran_promotes_hot_records():
    """Auto-granularity must converge toward fine-grained behaviour."""
    wl = TPCCWorkload.make(n_warehouses=2, scale=0.2)
    coarse = run(mk(t.CC_OCC, wl, lanes=32, gran=0), wl, 60, seed=3)
    auto = run(mk(t.CC_AUTOGRAN, wl, lanes=32, gran=0), wl, 60, seed=3,
               keep_state=True)
    fine = run(mk(t.CC_OCC, wl, lanes=32, gran=1), wl, 60, seed=3)
    assert int(auto.final_state.store.fine_mode.sum()) > 0   # promotions
    assert auto.throughput > coarse.throughput
    assert auto.throughput > 0.5 * fine.throughput


def test_swisstm_ages_win_claims():
    """SwissTM's contention manager must starve less: with age priority a
    retried txn eventually beats fresh ones (commits monotone over waves)."""
    wl = YCSBWorkload.make(n_keys=16, theta=0.99)  # brutal contention
    r = run(mk(t.CC_SWISS, wl, lanes=16), wl, 60, seed=0)
    assert r.commits > 0


def test_tpcc_ring_cursors_advance():
    wl = TPCCWorkload.make(n_warehouses=1, scale=0.1)
    cfg = mk(t.CC_OCC, wl, lanes=16)
    r = run(cfg, wl, n_waves=10, seed=0, keep_state=True)
    tails = np.asarray(r.final_state.store.ring_tails)
    assert tails.sum() > 0          # New-order lanes drew order slots
