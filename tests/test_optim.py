"""AdamW: schedule shape, clipping, master-weight precision."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW


def test_schedule_warmup_then_cosine():
    opt = AdamW(peak_lr=1.0, warmup_steps=10, total_steps=110,
                min_lr_frac=0.1)
    assert float(opt.lr(jnp.int32(0))) == 0.0
    assert float(opt.lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt.lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt.lr(jnp.int32(110))) == pytest.approx(0.1)
    assert float(opt.lr(jnp.int32(60))) < 1.0


def test_clipping_bounds_update():
    opt = AdamW(peak_lr=1e-1, warmup_steps=0, total_steps=10, clip_norm=1.0,
                weight_decay=0.0)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    s = opt.init(p)
    g = {"w": jnp.full((4,), 100.0)}           # gnorm 200 >> clip
    p2, s2, m = opt.update(g, s, p, jnp.int32(1))
    assert float(m["gnorm"]) == pytest.approx(200.0)
    assert np.abs(np.asarray(p2["w"])).max() < 1.0


def test_master_weights_accumulate_small_updates():
    """bf16 params lose sub-eps updates; the f32 master must keep them."""
    opt = AdamW(peak_lr=1e-5, warmup_steps=0, total_steps=1000,
                weight_decay=0.0, master_f32=True)
    p = {"w": jnp.ones((1,), jnp.bfloat16)}
    s = opt.init(p)
    g = {"w": jnp.full((1,), 1e-3, jnp.bfloat16)}
    master0 = float(s["master"]["w"][0])
    for i in range(5):
        p, s, _ = opt.update(g, s, p, jnp.int32(i))
    assert float(s["master"]["w"][0]) != master0


def test_moment_dtype_honored():
    opt = AdamW(moment_dtype="bfloat16")
    s = opt.init({"w": jnp.zeros((2,), jnp.float32)})
    assert s["m"]["w"].dtype == jnp.bfloat16


def test_descends_quadratic():
    opt = AdamW(peak_lr=0.1, warmup_steps=2, total_steps=120,
                weight_decay=0.0)
    p = {"w": jnp.asarray([3.0, -2.0])}
    s = opt.init(p)
    for i in range(120):
        g = {"w": 2 * p["w"]}
        p, s, _ = opt.update(g, s, p, jnp.int32(i))
    assert float(jnp.abs(p["w"]).max()) < 0.5
