"""Property tests for the wave-scoped claim tables (core/claims.py) — the
primitive every CC mechanism is built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import claims
from repro.core import types as t

N_REC, G = 16, 2


def np_scatter_min(table, keys, groups, words, mask):
    out = np.array(table)
    for k, g, w, m in zip(keys.ravel(), groups.ravel(), words.ravel(),
                          mask.ravel()):
        if m and 0 <= k < out.shape[0]:
            out[k, g] = min(out[k, g], w)
    return out


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_scatter_claims_matches_oracle(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    T, K = 4, 5
    keys = rng.integers(-1, N_REC, (T, K)).astype(np.int32)
    groups = rng.integers(0, G, (T, K)).astype(np.int32)
    words = rng.integers(0, 2 ** 32, (T, K), dtype=np.uint32)
    mask = rng.random((T, K)) < 0.7
    table = np.full((N_REC, G), 0xFFFFFFFF, np.uint32)
    got = claims.scatter_claims(jnp.asarray(table), jnp.asarray(keys),
                                jnp.asarray(groups), jnp.asarray(words),
                                jnp.asarray(mask))
    want = np_scatter_min(table, keys, groups, words, mask)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_probe_ignores_stale_waves():
    table = jnp.full((N_REC, G), t.NO_CLAIM, jnp.uint32)
    w0, w1 = jnp.uint32(3), jnp.uint32(4)
    word = claims.claim_word(w0, jnp.uint32(7))
    table = table.at[5, 1].set(word)
    # current wave sees it
    got = claims.probe(table, jnp.array([[5]]), jnp.array([[1]]), w0)
    assert int(got[0, 0]) == 7
    # next wave: stale claim invisible, no reset needed
    got = claims.probe(table, jnp.array([[5]]), jnp.array([[1]]), w1)
    assert int(got[0, 0]) == int(claims.NO_PRIO)


def test_probe_negative_and_oob_keys_return_no_prio():
    table = jnp.zeros((N_REC, G), jnp.uint32)  # all cells claim prio 0 wave 0
    # ... but masked / OOB keys must not see it
    keys = jnp.array([[-1, N_REC + 3]])
    groups = jnp.zeros_like(keys)
    got = claims.probe(table, keys, groups, jnp.uint32(0xFFFF))
    assert (np.asarray(got) == int(claims.NO_PRIO)).all()


def test_coarse_probe_is_row_min():
    table = jnp.full((N_REC, G), t.NO_CLAIM, jnp.uint32)
    wave = jnp.uint32(0)
    table = table.at[3, 1].set(claims.claim_word(wave, jnp.uint32(9)))
    fine = claims.probe(table, jnp.array([[3]]), jnp.array([[0]]), wave)
    coarse = claims.probe_any_group(table, jnp.array([[3]]), wave)
    assert int(fine[0, 0]) == int(claims.NO_PRIO)   # other group: no claim
    assert int(coarse[0, 0]) == 9                   # whole row: sees it


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_cell_counts_matches_bincount(seed):
    rng = np.random.default_rng(seed)
    T, K = 6, 7
    keys = rng.integers(0, 5, (T, K)).astype(np.int32)
    groups = rng.integers(0, G, (T, K)).astype(np.int32)
    mask = rng.random((T, K)) < 0.6
    got = np.asarray(claims.cell_counts(
        jnp.asarray(keys), jnp.asarray(groups), G, jnp.asarray(mask)))
    cells = keys * G + groups
    from collections import Counter
    c = Counter(cells[mask].ravel().tolist())
    want = np.where(mask, np.vectorize(lambda x: c.get(x, 0))(cells), 0)
    np.testing.assert_array_equal(got, want.astype(np.float32))


def test_lazy_decay_equals_eager():
    heat = jnp.zeros((4,), jnp.float32).at[2].set(1.0)
    heat_wave = jnp.zeros((4,), jnp.int32).at[2].set(10)
    got = claims.lazy_decayed(heat, heat_wave, jnp.array([2]),
                              jnp.uint32(13), 0.9)
    assert np.isclose(float(got[0]), 0.9 ** 3)


def test_hash01_uniform_and_deterministic():
    ids = claims.lane_op_ids(64, 16)
    u1 = np.asarray(claims.hash01(jnp.uint32(5), ids))
    u2 = np.asarray(claims.hash01(jnp.uint32(5), ids))
    u3 = np.asarray(claims.hash01(jnp.uint32(6), ids))
    np.testing.assert_array_equal(u1, u2)
    assert not np.array_equal(u1, u3)
    assert 0.4 < u1.mean() < 0.6 and u1.min() >= 0.0 and u1.max() < 1.0
