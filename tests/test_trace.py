"""analysis/trace.py: the wave-level Chrome-trace exporter — event
structure from real sweep points, the minimal schema validator CI runs on
every emitted file, and the refuse-to-write-invalid guard."""
import json

import numpy as np
import pytest

from repro.analysis.trace import (PH_COMPLETE, PH_COUNTER, PH_METADATA,
                                  point_events, sweep_trace,
                                  validate_chrome_trace, write_trace)
from repro.core import types as t
from repro.core.engine import sweep
from repro.core.types import EngineConfig
from repro.workloads import YCSBWorkload

WL = YCSBWorkload.make(n_keys=64, theta=0.9)


def _points(per_wave=True):
    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=WL.slots,
                       n_records=WL.n_records, n_groups=WL.n_groups,
                       n_cols=WL.n_cols, n_txn_types=WL.n_txn_types,
                       n_rings=WL.n_rings)
    return sweep(cfg, WL, 6, ccs=[t.CC_OCC, t.CC_TICTOC], grans=(1,),
                 lane_counts=(8,), per_wave=per_wave)


def test_sweep_trace_valid_and_loadable(tmp_path):
    """Acceptance criterion: the exported trace passes the schema check
    (the shape chrome://tracing / Perfetto require) and round-trips
    through JSON."""
    trace = sweep_trace(_points())
    assert validate_chrome_trace(trace) == []
    path = write_trace(str(tmp_path / "trace.json"), trace)
    again = json.loads(open(path).read())
    assert validate_chrome_trace(again) == []
    assert again["displayTimeUnit"] == "ms"


def test_trace_structure_matches_points():
    """One process row per grid point (M name + M thread + per-wave X/C
    pairs), X args carry the wave's commit/abort/per-cause deltas, and
    the cause args sum to the wave's aborts (the conservation invariant,
    visible in the viewer)."""
    pts = _points()
    trace = sweep_trace(pts)
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == PH_METADATA
            and e["name"] == "process_name"]
    assert [m["args"]["name"] for m in meta] == ["occ/fine/T8",
                                                "tictoc/fine/T8"]
    xs = [e for e in evs if e["ph"] == PH_COMPLETE]
    cs = [e for e in evs if e["ph"] == PH_COUNTER]
    assert len(xs) == len(cs) == 2 * 6          # two points x six waves
    for e in xs:
        assert e["dur"] > 0
        causes = sum(v for k, v in e["args"].items()
                     if k.startswith("abort_"))
        assert causes == e["args"]["aborts"]
    p0 = [e for e in xs if e["pid"] == 1]
    assert sum(e["args"]["commits"] for e in p0) == pts[0].commits
    # ts is cumulative simulated us: strictly increasing within a row
    ts = [e["ts"] for e in p0]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)


def test_points_without_per_wave_data_are_skipped():
    assert sweep_trace(_points(per_wave=False))["traceEvents"] == []


def test_point_events_without_causes_or_us():
    evs = point_events("x", 3, [5, 4], [1, 0], None)
    xs = [e for e in evs if e["ph"] == PH_COMPLETE]
    assert [e["dur"] for e in xs] == [1.0, 1.0]    # no us -> unit waves
    assert "abort_ww" not in xs[0]["args"]


def test_zero_duration_waves_get_min_width():
    (e,) = [e for e in point_events("x", 1, [1], [0], np.asarray([0.0]))
            if e["ph"] == PH_COMPLETE]
    assert e["dur"] >= 1e-3


def test_validator_rejects_broken_events():
    ok = sweep_trace(_points())
    assert validate_chrome_trace("nope") != []
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": "x"}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    bad_ph = {"traceEvents": [dict(ok["traceEvents"][2], ph=7)]}
    assert any("ph" in e for e in validate_chrome_trace(bad_ph))
    no_ts = {"traceEvents": [{"ph": "X", "name": "w", "pid": 1, "tid": 0,
                              "dur": 1.0, "ts": "soon"}]}
    assert any("ts" in e for e in validate_chrome_trace(no_ts))
    no_args = {"traceEvents": [{"ph": "M", "name": "process_name"}]}
    assert any("args" in e for e in validate_chrome_trace(no_args))


def test_write_trace_refuses_invalid(tmp_path):
    with pytest.raises(ValueError, match="invalid Chrome trace"):
        write_trace(str(tmp_path / "bad.json"), {"traceEvents": []})
    assert not (tmp_path / "bad.json").exists()
