"""Open-loop traffic front-end (DESIGN.md section 11): the goodput
conservation oracle, incarnation/queue safety properties, distributed
open-loop waves, and the open-loop config validation.

The conservation oracle is the module's spine: a numpy sequential replay
of the engine's per-wave trace that tracks every admitted transaction by
its admission serial and asserts the exact partition — every admitted
transaction is committed exactly once, still queued at the end, or
dropped at the incarnation cap — reconciling bit-for-bit with the
engine's own counters for occ/tictoc/mvcc at both granularities on both
backends.

Multi-shard behaviour scales with available devices like
tests/test_distributed.py; the subprocess test forces 8 host devices.
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import admission
from repro.core import distributed as D
from repro.core import types as t
from repro.core.cc import occ_validate
from repro.core.engine import run, sweep
from repro.core.types import CostModel, EngineConfig, TxnBatch, store_init
from repro.workloads import PoissonArrivals, YCSBWorkload

# Small but contended: aborts, retries, and incarnation drops all fire.
WL = YCSBWorkload.make(n_keys=64, theta=0.9)


def _cfg(cc, gran=1, backend="jnp", lanes=8, rate=8.0, cap=32,
         max_inc=2, mv_depth=None, **kw):
    return EngineConfig(
        cc=cc, lanes=lanes, slots=WL.slots, n_records=WL.n_records,
        n_groups=WL.n_groups, n_cols=WL.n_cols, n_txn_types=WL.n_txn_types,
        granularity=gran, n_rings=WL.n_rings, backend=backend,
        mv_depth=(3 if cc in t.MV_CCS else 0) if mv_depth is None
        else mv_depth,
        arrival_rate=rate, queue_cap=cap, max_incarnations=max_inc,
        lat_bins=16, **kw)


def _replay_oracle(res, max_incarnations):
    """Sequential numpy replay of the trace: track every admitted txn by
    its serial; assert per-id sanity (no resurrection after commit/drop,
    incarnations count 0,1,2,... with a bit-identical read/write set and a
    stable admit wave) and return (committed, dropped) id -> wave maps."""
    txn_id, incarn, got, admit_w, op_key, op_kind, commit = (
        np.asarray(x) for x in res.trace)
    W, T = txn_id.shape
    committed, dropped, last = {}, {}, {}
    for w in range(W):
        for lane in range(T):
            if not got[w, lane]:
                continue
            i = int(txn_id[w, lane])
            inc = int(incarn[w, lane])
            assert i not in committed, f"txn {i} ran again after commit"
            assert i not in dropped, f"txn {i} ran again after inc-drop"
            assert inc <= max_incarnations
            sig = (op_key[w, lane].tobytes(), op_kind[w, lane].tobytes(),
                   int(admit_w[w, lane]))
            if i in last:
                prev_sig, prev_inc = last[i]
                assert sig == prev_sig, \
                    f"txn {i}: ops/admit_wave changed across incarnations"
                assert inc == prev_inc + 1, \
                    f"txn {i}: incarnation {prev_inc} -> {inc}"
            else:
                assert inc == 0, f"txn {i} first ran at incarnation {inc}"
            last[i] = (sig, inc)
            if commit[w, lane]:
                committed[i] = w
            elif inc == max_incarnations:
                dropped[i] = w
    return committed, dropped


# --------------------------------------------- goodput conservation oracle
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("gran", [0, 1])
@pytest.mark.parametrize("cc", [t.CC_OCC, t.CC_TICTOC, t.CC_MVCC])
def test_conservation_oracle(cc, gran, backend):
    """ISSUE acceptance criterion: every admitted transaction is committed
    exactly once, still queued, or dropped at the incarnation cap — the
    replayed trace reconciles exactly with the engine's counters."""
    res = run(_cfg(cc, gran, backend), WL, n_waves=25, seed=3, trace=True)
    committed, dropped = _replay_oracle(res, 2)
    assert res.commits == len(committed)       # exactly-once by dict key
    assert res.inc_drops == len(dropped)
    assert res.admitted == res.commits + res.queued_final + res.inc_drops
    assert res.offered == res.admitted + res.arrival_drops
    assert res.reenq_drops == 0                # structural ring invariant
    assert res.commits > 0 and res.aborts > 0  # the oracle saw real traffic
    assert res.inc_drops > 0                   # ...including inc drops
    # The latency histogram counts exactly the committed transactions, and
    # every recorded time-to-commit is >= 1 wave.
    assert int(res.lat_hist.sum()) == res.commits
    for i, w in committed.items():
        assert w >= 0


def test_time_to_commit_matches_replay():
    """The histogram percentiles come from the same ttc the replay
    computes: commit_wave - admit_wave + 1, clipped to the last bin."""
    res = run(_cfg(t.CC_OCC), WL, n_waves=25, seed=3, trace=True)
    txn_id, incarn, got, admit_w, op_key, op_kind, commit = (
        np.asarray(x) for x in res.trace)
    ttcs = []
    W, T = txn_id.shape
    for w in range(W):
        for lane in range(T):
            if got[w, lane] and commit[w, lane]:
                ttcs.append(min(w - int(admit_w[w, lane]) + 1, 15))
    hist = np.bincount(np.asarray(ttcs, np.int64), minlength=16)
    np.testing.assert_array_equal(np.asarray(res.lat_hist)[0], hist)
    p50, p99 = admission.ttc_percentiles(res.lat_hist)
    s = np.sort(np.asarray(ttcs))
    assert p50[0] == float(s[int(np.ceil(0.5 * len(s))) - 1])
    assert p99[0] == float(s[int(np.ceil(0.99 * len(s))) - 1])


def test_goodput_counts_unique_commits():
    """Goodput is unique committed txns per simulated us: in the open loop
    a committed transaction leaves the system, so commits == unique
    committed serials (the oracle's dict) and goodput uses that count."""
    res = run(_cfg(t.CC_OCC), WL, n_waves=20, seed=5, trace=True)
    committed, _ = _replay_oracle(res, 2)
    assert res.commits == len(set(committed))
    assert res.goodput == pytest.approx(
        res.commits / max(res.sim_time_us, 1e-9))


def test_max_incarnations_zero_drops_every_abort():
    """max_incarnations=0 is drop-on-first-abort: nothing ever retries, so
    admitted == commits + drops + queued with no second incarnations."""
    res = run(_cfg(t.CC_OCC, max_inc=0), WL, n_waves=20, seed=1,
              trace=True)
    _, incarn, got, *_ = (np.asarray(x) for x in res.trace)
    assert int(incarn[np.asarray(got)].max(initial=0)) == 0
    assert res.inc_drops > 0
    assert res.admitted == res.commits + res.queued_final + res.inc_drops


# ------------------------------------------------- hypothesis properties
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 3), st.integers(0, 2 ** 31 - 1))
def test_property_incarnations_bounded_and_bit_identical(max_inc, seed):
    """Property (a): incarnation counters never exceed max_incarnations,
    and a re-enqueued transaction's read/write set is bit-identical to its
    first incarnation — whatever the cap and seed."""
    res = run(_cfg(t.CC_OCC, max_inc=max_inc), WL, n_waves=12, seed=seed,
              trace=True)
    # _replay_oracle asserts both properties per transaction.
    committed, dropped = _replay_oracle(res, max_inc)
    assert res.admitted == len(committed) + len(dropped) + res.queued_final


@pytest.fixture(scope="module")
def queue_batch():
    """A fixed 8-lane batch for driving the admission ring directly."""
    rng = np.random.default_rng(0)
    T, K = 8, 2
    return TxnBatch(
        op_key=jnp.asarray(rng.integers(0, 32, (T, K), dtype=np.int32)),
        op_group=jnp.asarray(rng.integers(0, 2, (T, K), dtype=np.int32)),
        op_col=jnp.zeros((T, K), jnp.int32),
        op_kind=jnp.asarray(rng.choice([t.READ, t.WRITE],
                                       (T, K)).astype(np.int32)),
        op_val=jnp.zeros((T, K), jnp.float32),
        txn_type=jnp.zeros((T,), jnp.int32),
        n_ops=jnp.full((T,), K, jnp.int32))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8)),
                min_size=1, max_size=12))
def test_property_occupancy_bounded_and_overflow_counted(queue_batch, cap,
                                                         seq):
    """Property (b): under any enqueue/dequeue sequence the ring's
    occupancy never exceeds its capacity, and every offered lane is either
    accepted or counted as an overflow drop — nothing vanishes."""
    q = admission.queue_init(cap, queue_batch.slots)
    zero = jnp.zeros((8,), jnp.int32)
    for n_enq, n_deq in seq:
        mask = jnp.arange(8) < n_enq
        before = int(q.size)
        q, n_acc, n_ovf = admission.enqueue(q, queue_batch, zero, zero,
                                            zero, mask)
        assert int(n_acc) + int(n_ovf) == n_enq          # drops counted
        assert int(n_acc) == min(n_enq, cap - before)
        assert 0 <= int(q.size) <= cap                   # never over cap
        before = int(q.size)
        q, _b, _aw, _inc, _id, got = admission.dequeue(q, 8, n_deq)
        assert int(got.sum()) == min(before, n_deq)
        assert int(q.size) == before - min(before, n_deq)


def test_dequeue_returns_fifo_bit_identical(queue_batch):
    """What goes into the ring comes out FIFO and bit-identical — the
    queue stores the transaction, not a summary of it."""
    q = admission.queue_init(16, queue_batch.slots)
    ids = jnp.arange(8, dtype=jnp.int32) * 10
    aw = jnp.full((8,), 4, jnp.int32)
    inc = jnp.arange(8, dtype=jnp.int32) % 3
    q, _, _ = admission.enqueue(q, queue_batch, aw, inc, ids,
                                jnp.ones((8,), bool))
    q, batch, aw2, inc2, ids2, got = admission.dequeue(q, 8)
    assert bool(got.all())
    np.testing.assert_array_equal(np.asarray(batch.op_key),
                                  np.asarray(queue_batch.op_key))
    np.testing.assert_array_equal(np.asarray(batch.op_kind),
                                  np.asarray(queue_batch.op_kind))
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(aw2), np.asarray(aw))
    np.testing.assert_array_equal(np.asarray(inc2), np.asarray(inc))


# ------------------------------------------------------- sweep integration
def test_open_loop_sweep_matches_run_at_bucket_max():
    """The sweep contract extends to the open loop: a point at its
    bucket's max lane count is bit-identical to run() (same queue, same
    counters, same percentiles)."""
    cfg = _cfg(t.CC_OCC, gran=0, lanes=8, mv_depth=3)
    pts = sweep(cfg, WL, 15, ccs=[t.CC_OCC, t.CC_MVCC], grans=(0, 1),
                lane_counts=(8,), seeds=(3,))
    for p in pts:
        assert p.open_loop
        assert p.admitted == p.commits + p.queued_final + p.inc_drops
    r = run(dataclasses.replace(cfg, cc=t.CC_MVCC, granularity=1,
                                mv_depth=3), WL, 15, seed=3)
    p = [x for x in pts if x.cc == t.CC_MVCC and x.granularity == 1][0]
    assert (r.commits, r.aborts, r.admitted, r.arrival_drops, r.inc_drops,
            r.queued_final) == (p.commits, p.aborts, p.admitted,
                                p.arrival_drops, p.inc_drops,
                                p.queued_final)
    assert r.p50_ttc == p.p50_ttc and r.p99_ttc == p.p99_ttc


def test_closed_loop_unaffected_by_open_loop_fields():
    """A closed-loop run carries only the placeholder OpenLoopState: same
    commits as ever, no open-loop row fields."""
    cfg = EngineConfig(cc=t.CC_OCC, lanes=8, slots=WL.slots,
                       n_records=WL.n_records, n_groups=WL.n_groups,
                       n_cols=WL.n_cols, n_txn_types=WL.n_txn_types)
    res = run(cfg, WL, n_waves=10, seed=0)
    assert not res.open_loop
    assert res.commits + res.aborts == 8 * 10   # every lane, every wave
    assert res.p50_ttc is None and res.lat_hist is None


# --------------------------------------------------- config validation
def test_engine_config_queue_without_rate_rejected():
    with pytest.raises(ValueError, match="open-loop admission queue"):
        _cfg(t.CC_OCC, rate=0.0, cap=8)


def test_engine_config_open_loop_needs_queue():
    with pytest.raises(ValueError, match="queue_cap"):
        _cfg(t.CC_OCC, rate=4.0, cap=0)


def test_engine_config_negative_rate_rejected():
    with pytest.raises(ValueError, match="arrival_rate"):
        _cfg(t.CC_OCC, rate=-1.0)


def test_engine_config_lat_bins_floor():
    with pytest.raises(ValueError, match="lat_bins"):
        EngineConfig(cc=t.CC_OCC, lanes=8, slots=4, n_records=64,
                     n_groups=2, n_cols=0, n_txn_types=1,
                     arrival_rate=4.0, queue_cap=8, lat_bins=1)


def test_dist_config_open_loop_validation():
    with pytest.raises(ValueError, match="queue_cap"):
        D.DistConfig(n_records=64, lanes_per_shard=8, slots=8,
                     queue_cap=-1)
    with pytest.raises(ValueError, match="max_incarnations"):
        D.DistConfig(n_records=64, lanes_per_shard=8, slots=8,
                     max_incarnations=3)        # no queue_cap switch
    with pytest.raises(ValueError, match="lat_bins"):
        D.DistConfig(n_records=64, lanes_per_shard=8, slots=8,
                     queue_cap=16, lat_bins=1)
    with pytest.raises(ValueError, match="queue_cap"):
        D.make_open_wave_fn(
            D.DistConfig(n_records=64, lanes_per_shard=8, slots=8),
            jax.make_mesh((1,), ("data",)))


# ----------------------------------------------- distributed open loop
def _dist_gen(n_total, K, N, seed_base=900):
    def gen(w):
        rng = np.random.default_rng(seed_base + w)
        keys = jnp.asarray(rng.integers(0, N, (n_total, K),
                                        dtype=np.int32))
        groups = jnp.asarray(rng.integers(0, 2, (n_total, K),
                                          dtype=np.int32))
        kinds = jnp.asarray(rng.choice([t.READ, t.WRITE],
                                       (n_total, K)).astype(np.int32))
        prio = jnp.asarray(rng.permutation(n_total).astype(np.uint32))
        return keys, groups, kinds, prio
    return gen


@pytest.mark.parametrize("cc", ["occ", "mvcc"])
def test_distributed_open_loop_conservation(cc):
    """The sharded admission rings obey the same conservation identity,
    over every available host device."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ns = len(jax.devices())
    N, T, K = 128, 8, 4
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T,
                       slots=K, cc=cc, mv_depth=3 if cc != "occ" else 0,
                       queue_cap=24, max_incarnations=2, lat_bins=8)
    arr = PoissonArrivals(rate=0.9 * ns * T, seed=5).shard_counts(
        18, ns, T)
    s = D.run_open_loop(cfg, mesh, arr, _dist_gen(ns * T, K, N), 18)
    assert s["admitted"] == (s["commits"] + s["queued_final"]
                             + s["inc_drops"])
    assert s["offered"] == s["admitted"] + s["arrival_drops"]
    assert int(s["lat_hist"].sum()) == s["commits"]
    assert s["commits"] > 0


def test_distributed_one_shard_matches_local_composition():
    """Parity: the 1-shard distributed open-loop wave == the local
    admission ring (core/admission.py) composed with the local OCC
    validator, wave by wave — same commit masks, same counters."""
    mesh = jax.make_mesh((1,), ("data",))
    N, T, K, CAP, MAXI = 64, 8, 4, 24, 2
    cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T,
                       slots=K, queue_cap=CAP, max_incarnations=MAXI,
                       lat_bins=8)
    wave_fn = jax.jit(D.make_open_wave_fn(cfg, mesh))
    tables = D.init_tables(cfg, mesh)
    qstate = D.init_open_queue(cfg, mesh)
    arr = PoissonArrivals(rate=6.0, seed=2).counts(12, T)
    gen = _dist_gen(T, K, N, seed_base=70)

    ecfg = EngineConfig(cc=t.CC_OCC, lanes=T, slots=K, n_records=N,
                        n_groups=2, n_cols=0, n_txn_types=1, granularity=1,
                        cost=CostModel(opt_overlap=1.0, phase_overlap=1.0))
    store = store_init(N, 2, 0)
    q = admission.queue_init(CAP, K)
    next_id = 0
    for w in range(12):
        keys, groups, kinds, prio = gen(w)
        commit_d, tables, qstate, stats = wave_fn(
            keys, groups, kinds, prio, jnp.asarray(arr[w:w + 1]),
            tables, qstate, jnp.uint32(w))
        # local composition: enqueue arrivals -> dequeue -> validate ->
        # re-enqueue, exactly the open-loop wave step's ring discipline
        fresh = TxnBatch(op_key=keys, op_group=groups,
                         op_col=jnp.zeros_like(keys), op_kind=kinds,
                         op_val=jnp.zeros(keys.shape, jnp.float32),
                         txn_type=jnp.zeros((T,), jnp.int32),
                         n_ops=jnp.full((T,), K, jnp.int32))
        mask = jnp.arange(T) < int(arr[w])
        ids = next_id + jnp.arange(T, dtype=jnp.int32)
        next_id += int(arr[w])
        q, n_acc, _ = admission.enqueue(
            q, fresh, jnp.full((T,), w, jnp.int32),
            jnp.zeros((T,), jnp.int32), ids, mask)
        q, batch, aw, inc, tid, got = admission.dequeue(q, T)
        store, res = occ_validate(store, batch, prio, jnp.uint32(w), ecfg)
        commit_l = res.commit & got
        retry = got & ~commit_l & (inc < MAXI)
        q, _, _ = admission.enqueue(q, batch, aw, inc + 1, tid, retry)
        np.testing.assert_array_equal(np.asarray(commit_d),
                                      np.asarray(commit_l), err_msg=f"w{w}")
        s = np.asarray(stats)
        assert s[D.STAT_ADMITTED] == int(n_acc)
        assert s[D.STAT_QUEUED] == int(q.size)


def test_distributed_open_loop_backend_parity_8shard_subprocess():
    """8 forced host devices: the open-loop routed wave's summary — queue
    counters AND per-shard latency histograms — is bit-identical between
    the jnp and pallas(interpret) backends (CI runs this in both jobs)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed as D
        from repro.core import types as t
        from repro.workloads.arrivals import PoissonArrivals
        mesh = jax.make_mesh((8,), ("data",))
        N, T, K = 256, 8, 4
        def gen(w):
            rng = np.random.default_rng(40 + w)
            return (jnp.asarray(rng.integers(0, N, (64, K), dtype=np.int32)),
                    jnp.asarray(rng.integers(0, 2, (64, K), dtype=np.int32)),
                    jnp.asarray(rng.choice([t.READ, t.WRITE],
                                           (64, K)).astype(np.int32)),
                    jnp.asarray(rng.permutation(64).astype(np.uint32)))
        arr = PoissonArrivals(rate=48.0, seed=9).shard_counts(10, 8, T)
        outs = {}
        for backend in ("jnp", "pallas"):
            cfg = D.DistConfig(n_records=N, n_groups=2, lanes_per_shard=T,
                               slots=K, backend=backend, queue_cap=24,
                               max_incarnations=2, lat_bins=8)
            outs[backend] = D.run_open_loop(cfg, mesh, arr, gen, 10)
        a, b = outs["jnp"], outs["pallas"]
        for k in ("commits", "aborts", "offered", "admitted",
                  "arrival_drops", "inc_drops", "queued_final"):
            assert a[k] == b[k], (k, a[k], b[k])
        np.testing.assert_array_equal(a["lat_hist"], b["lat_hist"])
        np.testing.assert_array_equal(a["per_shard_stats"],
                                      b["per_shard_stats"])
        assert a["admitted"] == (a["commits"] + a["queued_final"]
                                 + a["inc_drops"])
        assert a["commits"] > 0
        print("OPEN_LOOP_8SHARD_PARITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "OPEN_LOOP_8SHARD_PARITY_OK" in r.stdout, r.stdout + r.stderr
