"""Roofline analysis: HLO collective parser against known programs, and the
analytic model's structural properties."""
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.roofline import (_shape_bytes, _split_computations,
                                     analytic_cell, collective_bytes_from_hlo)
from repro.configs.base import SHAPES


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("(f32[2,2], s32[])") == 16 + 4
    assert _shape_bytes("pred[]") == 1        # scalar


def test_collective_parser_counts_loop_trips():
    """Compile a scan whose body does a per-iteration psum on 8 host devices
    (subprocess: device count must be set before jax init)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, "src")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.roofline import collective_bytes_from_hlo
        from repro.core.compat import shard_map

        mesh = jax.make_mesh((8,), ("model",))
        def f(x, w):
            def body(c, _):
                def mm(cc, ww):
                    return jax.lax.psum(cc @ ww, "model")
                y = shard_map(mm, mesh=mesh,
                              in_specs=(P(None, "model"), P("model", None)),
                              out_specs=P())(c, w)
                return y, None
            return jax.lax.scan(body, x, None, length=5)[0]
        x = jax.ShapeDtypeStruct((128, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P()))
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P("model",
                                                                None)))
        hlo = jax.jit(f).lower(x, w).compile().as_text()
        b = collective_bytes_from_hlo(hlo)
        assert b == 5 * 128 * 512 * 4, b
        print("PARSER_OK", b)
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert "PARSER_OK" in r.stdout, r.stdout + r.stderr


def test_analytic_terms_structure():
    from repro import configs
    qwen = configs.get("qwen3-32b")
    train = analytic_cell(qwen, SHAPES["train_4k"], 256, tp=16,
                          coll_bytes=1e9)
    assert train.compute_s > 0 and train.memory_s > 0
    assert train.bottleneck in ("compute", "memory", "collective")
    assert 0 < train.usefulness <= 1.0
    # train on a dense arch at 4k seq: compute must dominate memory
    assert train.compute_s > train.memory_s

    dec = analytic_cell(qwen, SHAPES["decode_32k"], 256, tp=16)
    # single-token decode: memory-bound (weights + KV cache stream)
    assert dec.bottleneck == "memory"
    assert dec.memory_s > dec.compute_s


def test_moe_capacity_inflation_shows_in_usefulness():
    import dataclasses
    from repro import configs
    l4 = configs.get("llama4-maverick-400b-a17b")
    base = analytic_cell(l4, SHAPES["train_4k"], 512, tp=16)
    wide = analytic_cell(l4, SHAPES["train_4k"], 512, tp=16,
                         overrides={"cap_factor": 2.5})
    assert wide.flops > base.flops
    assert wide.usefulness < base.usefulness


def test_remat_override_moves_compute_term():
    from repro import configs
    q = configs.get("qwen2-7b")
    a = analytic_cell(q, SHAPES["train_4k"], 256, tp=16)
    b = analytic_cell(q, SHAPES["train_4k"], 256, tp=16,
                      overrides={"remat": False})
    assert a.compute_s > b.compute_s          # remat re-runs the forward
