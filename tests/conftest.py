import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# exclusively the dry-run's; see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))  # hypothesis_compat shim
