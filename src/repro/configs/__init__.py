"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.configs import (llama4_maverick_400b_a17b, llava_next_34b,
                           mixtral_8x22b, qwen2_5_32b, qwen2_7b, qwen3_32b,
                           recurrentgemma_9b, rwkv6_3b, starcoder2_3b,
                           whisper_medium)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (recurrentgemma_9b, llama4_maverick_400b_a17b, mixtral_8x22b,
              starcoder2_3b, qwen2_7b, qwen3_32b, qwen2_5_32b, llava_next_34b,
              whisper_medium, rwkv6_3b)
}

SMOKES = {
    m.CONFIG.name: m.SMOKE
    for m in (recurrentgemma_9b, llama4_maverick_400b_a17b, mixtral_8x22b,
              starcoder2_3b, qwen2_7b, qwen3_32b, qwen2_5_32b, llava_next_34b,
              whisper_medium, rwkv6_3b)
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ModelConfig:
    return SMOKES[name]


__all__ = ["ARCHS", "SMOKES", "SHAPES", "ModelConfig", "ShapeSpec", "get",
           "get_smoke"]
