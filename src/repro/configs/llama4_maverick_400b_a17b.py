import dataclasses

from repro.configs.base import ModelConfig

# Llama-4 Maverick class MoE: 128 experts, top-1 routing, early fusion
# (text-only backbone here; the fusion frontend is out of assigned scope).
# [hf:meta-llama/Llama-4-*; unverified pool entry].  40 heads pad to 48
# for the 16-way model axis.
CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48, d_model=5120, n_heads_raw=40, n_kv=8, d_head=128,
    d_ff=8192, vocab_raw=202_048,
    n_experts=128, top_k=1, moe_mode="ep",
    rope_theta=500_000.0,
    n_micro=8,
    # ~773B total / ~17B-class active: bf16 moments, no f32 master --
    # the v5e HBM budget at 512 chips (see EXPERIMENTS.md dry-run table).
    adam_master_f32=False, adam_moment_dtype="bfloat16",
        grad_dtype="bfloat16",
    skip_notes="long_500k skipped: full attention (quadratic decode).",
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, moe_cap_factor=4.0, param_dtype="float32", grad_dtype="float32", n_layers=4, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_experts=8, top_k=1, n_micro=1,
    adam_master_f32=True, adam_moment_dtype="float32")
