import dataclasses

from repro.configs.base import ModelConfig

# RWKV-6 "Finch" 3B [arXiv:2404.05892]: attention-free, data-dependent
# decay wkv recurrence + channel mix.  40 wkv heads (d_head 64) pad to 48
# for the model axis.  O(1) state => long_500k runs.
CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32, d_model=2560, n_heads_raw=40, n_kv=40, d_head=64,
    d_ff=8960, vocab_raw=65_536,
    pattern=("rwkv",),
    pos="none",
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=3, d_model=64, n_heads_raw=4, n_kv=4, d_head=16,
    d_ff=128, vocab_raw=512, n_micro=1)
