import dataclasses

from repro.configs.base import ModelConfig

# RG-LRU + local attention, 1:2 pattern (2 recurrent : 1 local-attn per
# super-block), per Griffin / RecurrentGemma [arXiv:2402.19427].
# 38 layers = 12 x (rec, rec, attn) + (rec, rec) tail.
CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38, d_model=4096, n_heads_raw=16, n_kv=1, d_head=256,
    d_ff=12288, vocab_raw=256_000,
    pattern=("rec", "rec", "attn"),
    window=2048,                       # local attention window
    lru_width=4096,
    rope_theta=10_000.0,
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    # RG-LRU state + 2048-window KV cache => O(window) decode: long_500k runs.
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=5, d_model=64, n_heads_raw=2, n_kv=1, d_head=32,
    d_ff=128, vocab_raw=512, lru_width=64, window=32, n_micro=1)
