import dataclasses

from repro.configs.base import ModelConfig

# Qwen3-32B class [hf:Qwen/Qwen3-*]: qk-norm (RMSNorm on per-head q/k),
# GQA kv=8, no QKV bias.
CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64, d_model=5120, n_heads_raw=64, n_kv=8, d_head=128,
    d_ff=25600, vocab_raw=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    skip_notes="long_500k skipped: full attention (quadratic decode).",
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=3, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_micro=1)
