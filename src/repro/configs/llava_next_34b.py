import dataclasses

from repro.configs.base import ModelConfig

# LLaVA-NeXT-34B class [hf:llava-hf/llava-v1.6-*]: Yi-34B-shape decoder
# backbone; the anyres vision tower is a STUB per the brief --
# input_specs() provides precomputed patch embeddings (B, 2880, d_model)
# prepended to the token embeddings.  56 heads pad to 64.
CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60, d_model=7168, n_heads_raw=56, n_kv=8, d_head=128,
    d_ff=20480, vocab_raw=64_000,
    rope_theta=5_000_000.0,
    n_patches=2880,
    n_micro=8,   # activation temps: 34B x d7168 at nm=4 overflow HBM
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    skip_notes="long_500k skipped: full attention (quadratic decode).",
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=3, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_patches=8, n_micro=1)
