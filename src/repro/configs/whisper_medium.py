import dataclasses

from repro.configs.base import ModelConfig

# Whisper-medium [arXiv:2212.04356]: encoder-decoder, 24+24 layers,
# LayerNorm + GELU + learned positions (pre-RoPE lineage).  The conv/mel
# frontend is a STUB per the brief -- input_specs() provides precomputed
# frame embeddings (B, 1500, d_model).  vocab 51865 pads to 51872.
CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24, enc_layers=24,
    d_model=1024, n_heads_raw=16, n_kv=16, d_head=64,
    d_ff=4096, vocab_raw=51_865,
    norm="layernorm", mlp="gelu", pos="learned", max_pos=32_768,
    n_frames=1500,
    tie_embeddings=True,
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    skip_notes=("long_500k skipped: enc-dec; decoder attends <=1500 "
                "encoder frames, 500k target tokens out of family. "
                "decode_32k exercised (out-of-family length, lowers)."),
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=2, enc_layers=2, d_model=64, n_heads_raw=4, n_kv=4,
    d_head=16, d_ff=128, vocab_raw=512, n_frames=16, max_pos=256)
