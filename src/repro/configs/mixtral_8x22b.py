import dataclasses

from repro.configs.base import ModelConfig

# Mixtral 8x22B [arXiv:2401.04088]: 8 experts top-2, sliding-window
# attention.  8 experts < 16-way model axis => TP-in-expert sharding
# (d_ff sharded, experts replicated), per DESIGN.md.
CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56, d_model=6144, n_heads_raw=48, n_kv=8, d_head=128,
    d_ff=16384, vocab_raw=32_768,
    n_experts=8, top_k=2, moe_mode="tp",
    window=4096,                      # SWA => rolling cache, O(window)
    rope_theta=1_000_000.0,
    n_micro=8,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, moe_cap_factor=4.0, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=4, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_experts=4, top_k=2, window=32, n_micro=1)
