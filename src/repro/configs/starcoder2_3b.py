import dataclasses

from repro.configs.base import ModelConfig

# StarCoder2-3B [arXiv:2402.19173]: GQA kv=2, RoPE, learned bias on QKV.
# 24 heads pad to 32 for the 16-way model axis (largest pad in the pool;
# charged to the roofline usefulness ratio).
CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30, d_model=3072, n_heads_raw=24, n_kv=2, d_head=128,
    d_ff=12288, vocab_raw=49_152,
    qkv_bias=True,
    rope_theta=100_000.0,
    norm="layernorm", mlp="gelu",      # starcoder2 keeps GPT-style blocks
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    skip_notes="long_500k skipped: full attention (quadratic decode).",
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=3, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_micro=1)
