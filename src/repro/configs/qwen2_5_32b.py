import dataclasses

from repro.configs.base import ModelConfig

# Qwen2.5-32B [hf:Qwen/Qwen2.5-*]: GQA kv=8, QKV bias.  40 heads pad 48.
CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64, d_model=5120, n_heads_raw=40, n_kv=8, d_head=128,
    d_ff=27648, vocab_raw=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    n_micro=4,
        fsdp_params=False,   # ZeRO-2: TP slice fits HBM
    skip_notes="long_500k skipped: full attention (quadratic decode).",
)

SMOKE = dataclasses.replace(
    CONFIG, head_pad=1, param_dtype="float32",
        grad_dtype="float32", adam_master_f32=False, adam_moment_dtype="float32", n_layers=3, d_model=64, n_heads_raw=4, n_kv=2, d_head=16,
    d_ff=128, vocab_raw=512, n_micro=1)
