"""Architecture configuration schema + the shape grid.

Every assigned architecture is a ``ModelConfig`` (one module per arch under
``repro/configs/``).  A config is pure data — the model code in
``repro/models`` interprets it; the launcher resolves ``--arch <id>`` through
``repro.configs.registry``.

Head padding
------------
The production mesh has a 16-way ``model`` axis, and attention heads are the
natural TP unit, so head counts are padded up to the next multiple of 16
(zero-initialized heads; their ``wo`` rows are zero so they are exact no-ops
at init and train like normal capacity afterwards).  ``n_heads_raw`` keeps the
paper value; the roofline report charges the padding to the usefulness ratio.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

TP = 16  # production model-axis width; head counts padded to multiples of it


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) column of the assigned grid."""
    name: str
    kind: str             # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads_raw: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab_raw: int

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_mode: str = ""            # "ep" (experts sharded) | "tp" (d_ff sharded)
    moe_cap_factor: float = 1.25
    aux_loss_coef: float = 0.01

    # Attention flavor
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window size; None = full attention
    attn_logit_softcap: Optional[float] = None

    # Block pattern, cycled over layers: "attn" | "rec" (RG-LRU) | "rwkv"
    pattern: tuple = ("attn",)
    lru_width: int = 0            # RG-LRU channel width (0 = d_model)
    conv_width: int = 4           # RG block temporal-conv taps

    # Norm / MLP flavor
    norm: str = "rmsnorm"         # rmsnorm | layernorm (whisper)
    mlp: str = "swiglu"           # swiglu | gelu (whisper)
    pos: str = "rope"             # rope | learned (whisper)
    max_pos: int = 0              # learned-pos table size

    # Enc-dec / frontends (stubs provide precomputed embeddings)
    enc_layers: int = 0
    n_frames: int = 0             # whisper: encoder frame embeddings
    n_patches: int = 0            # llava: patch-embedding prefix

    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # dtypes / memory policy
    param_dtype: str = "bfloat16"
    adam_master_f32: bool = True  # f32 master copy in the optimizer
    adam_moment_dtype: str = "float32"
    grad_dtype: str = "float32"   # gradient-accumulation dtype

    # training knobs
    n_micro: int = 1              # gradient-accumulation microbatches
    remat: bool = True
    fsdp_params: bool = True      # shard weights over "data" (FSDP/ZeRO-3
                                  # style, per-layer gathers).  False =
                                  # ZeRO-2: weights replicated across data
                                  # (still TP-sharded over "model"), only
                                  # optimizer state + grads stay sharded —
                                  # for archs whose TP slice fits HBM this
                                  # removes every per-layer weight gather
                                  # (EXPERIMENTS.md Perf iteration 2)
    head_pad: int = TP            # pad n_heads to a multiple of this
                                  # (smoke configs use 1: no padding)

    # which assigned shapes run (long_500k only for sub-quadratic archs)
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""

    # ---- derived ----
    @property
    def n_heads(self) -> int:
        return pad_to(self.n_heads_raw, self.head_pad)

    @property
    def vocab(self) -> int:
        return pad_to(self.vocab_raw, self.head_pad * 2)

    @property
    def d_lru(self) -> int:
        return self.lru_width or self.d_model

    @property
    def dec_layers(self) -> int:
        return self.n_layers

    def kv_eff(self, tp: int) -> int:
        """KV heads as stored/sharded: replicated up to the TP width when the
        raw count is smaller (each rank keeps its group's copy)."""
        return max(self.n_kv, min(tp, self.n_heads)) if tp > 1 else self.n_kv

    def layer_types(self) -> list:
        """Per-layer block type, cycling ``pattern`` over decoder layers."""
        p = self.pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    def stage_split(self):
        """Decoder stages as [(pattern, n_repeats), ...]: a scan of n_repeats
        super-blocks per stage.  The remainder after cycling ``pattern``
        becomes a trailing homogeneous stage (recurrentgemma: 12 x
        (rec,rec,attn) + 2 x (rec,))."""
        n_super = self.n_layers // len(self.pattern)
        stages = []
        if n_super:
            stages.append((self.pattern, n_super))
        tail = self.layer_types()[n_super * len(self.pattern):]
        if tail:
            assert len(set(tail)) == 1, "tail must be homogeneous"
            stages.append(((tail[0],), len(tail)))
        return stages

    # ---- parameter counting (for roofline MODEL_FLOPS) ----
    def param_count(self, padded: bool = True) -> int:
        H = self.n_heads if padded else self.n_heads_raw
        V = self.vocab if padded else self.vocab_raw
        D, Dh, F = self.d_model, self.d_head, self.d_ff
        kv = self.n_kv

        def attn():
            n = D * (H + 2 * kv) * Dh + H * Dh * D
            if self.qkv_bias:
                n += (H + 2 * kv) * Dh
            return n

        def mlp():
            return D * F * (3 if self.mlp == "swiglu" else 2)

        def moe():
            return self.n_experts * D * F * 3 + D * self.n_experts

        def rec():
            # w_x/w_g/w_a in-projections, w_o out, conv taps+bias, lambda
            W = self.d_lru
            return 3 * D * W + W * D + (self.conv_width + 2) * W

        def rwkv():
            # time mix: r/k/v/g/w in-projections + o out (attention width
            # A = H*Dh, padded), u/w0/ln_x; channel mix: in/out + receptance
            A = H * Dh
            return 6 * D * A + 3 * A + 2 * D * F + D * D

        n = V * D * (1 if self.tie_embeddings else 2)
        if self.pos == "learned":
            n += self.max_pos * D
        for lt in self.layer_types():
            if lt == "attn":
                n += attn() + (moe() if self.n_experts else mlp())
            elif lt == "rec":
                n += rec() + mlp()
            elif lt == "rwkv":
                n += rwkv()
        n += self.enc_layers * (attn() + mlp())
        if self.enc_layers:           # decoder cross-attention
            n += self.n_layers * attn()
        return n

    def active_param_count(self, padded: bool = True) -> int:
        """Per-token active params (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count(padded)
        full = self.param_count(padded)
        moe_all = self.n_layers * self.n_experts * self.d_model * self.d_ff * 3
        moe_act = self.n_layers * self.top_k * self.d_model * self.d_ff * 3
        return full - moe_all + moe_act
