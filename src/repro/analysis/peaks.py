"""Hardware peak table — the single source of truth for roofline math.

One dict per chip: peak_flops (FLOP/s), hbm_bw (B/s), and link_bw (B/s,
one interconnect link, conservative).  Both the model roofline
(``analysis/roofline.py``) and the transaction-engine cost model
(``analysis/txn_cost.py``) read THESE numbers — a chip is added or
corrected in exactly one place.

``ridge(chip)`` is the chip's arithmetic-intensity ridge point
(FLOP/byte): kernels below it are memory-bound, above it compute-bound.
"""
from __future__ import annotations

HW_PEAKS = {
    # bf16 matmul peak, HBM stream, one ICI link (see EXPERIMENTS.md for
    # the multi-link caveat).
    "tpu_v5e": {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9},
    # A100 SXM 80G: bf16 tensor-core peak, HBM2e, one NVLink3 direction.
    "gpu_a100": {"peak_flops": 312e12, "hbm_bw": 2039e9, "link_bw": 300e9},
    # H100 SXM: bf16 tensor-core peak (dense), HBM3, one NVLink4 direction.
    "gpu_h100": {"peak_flops": 989e12, "hbm_bw": 3350e9, "link_bw": 450e9},
}

#: The repro's reference part (every report that does not name a chip).
DEFAULT_CHIP = "tpu_v5e"

PEAK_FLOPS = HW_PEAKS[DEFAULT_CHIP]["peak_flops"]
HBM_BW = HW_PEAKS[DEFAULT_CHIP]["hbm_bw"]
LINK_BW = HW_PEAKS[DEFAULT_CHIP]["link_bw"]


def ridge(chip: str = DEFAULT_CHIP) -> float:
    """Arithmetic-intensity ridge point (FLOP/byte) of ``chip``."""
    p = HW_PEAKS[chip]
    return p["peak_flops"] / p["hbm_bw"]
