"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

Three terms (seconds, per step):

  compute    = FLOPs / (chips x 197e12 bf16 FLOP/s)      [TPU v5e]
  memory     = HBM bytes per device / 819e9 B/s
  collective = per-device collective bytes / 50e9 B/s (one ICI link,
               conservative; v5e has more links — see EXPERIMENTS.md)

Accounting sources (DESIGN.md section 7):

  - collective bytes: parsed from the *optimized, SPMD-partitioned* HLO —
    the program is per-device, so summed operand sizes of all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute are
    per-device bytes.  Collectives inside while bodies multiply by the
    loop trip count (parsed from the loop condition).
  - FLOPs and HBM bytes: *analytic*, from the config and shape.  XLA's
    ``cost_analysis`` counts a ``lax.scan`` body ONCE regardless of trip
    count (verified in this container; all layer stacks, microbatch loops,
    flash-attention inner loops and recurrences here are scans), so the
    compiled number under-counts by the layer count; we report it only as a
    cross-check column.  The analytic model knows the exact graph structure
    (head padding, MoE capacity slots, remat recompute, causal/window
    visibility) so it also feeds the usefulness ratio.
"""
from __future__ import annotations

import dataclasses
import math
import re

# ------------------------------------------------------------ hardware model
# The chip peaks live in analysis/peaks.py (shared with the transaction
# cost model, analysis/txn_cost.py); the module-level names stay importable
# here for back-compat.
from repro.analysis.peaks import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: F401

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# ------------------------------------------------------------- HLO parsing
def _shape_bytes(type_str: str) -> int:
    """bytes of 'bf16[8,128]{1,0}' or tuple '(f32[2], s32[])'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict:
    """comp name -> list of instruction lines.

    Computation headers look like
      %name (params...: types...) -> result_type {
      ENTRY %main.3_spmd (param.2: f32[...]) -> f32[...] {
    (parameter types may nest parentheses — match on the trailing '{' plus
    '->' rather than balancing parens)."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and " -> " in s and "=" not in s.split("(")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(s)
    return comps


def _trip_count(cond_lines) -> int:
    """Trip count of a canonical XLA counted loop condition."""
    consts = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" not in ln:
            continue
        m = re.search(r"compare\(([^)]*)\)", ln)
        if not m:
            continue
        args = [a.strip().lstrip("%") for a in m.group(1).split(",")]
        args = [a.split(" ")[-1].lstrip("%") for a in args]
        dirn = re.search(r"direction=(\w+)", ln)
        dirn = dirn.group(1) if dirn else "LT"
        for a in args:
            if a in consts:
                n = consts[a]
                return n + 1 if dirn == "LE" else n
    return 1


# The CPU backend legalizes every bf16 dot to an f32 dot (verified in this
# container: `%all-reduce = f32[...] all-reduce(%dot)` for a bf16 einsum), so
# dot-partial all-reduces and weight all-gathers appear at twice their TPU
# byte width.  Collectives whose metadata ties them to a dot (forward, jvp,
# transpose or checkpointed recompute) are therefore counted at bf16 when
# dtype_correct=True (the default for the roofline reports; raw counts are
# recorded alongside).  Genuinely-f32 collectives (f32 gradient reductions,
# optimizer state) carry no such metadata and stay full-width.
_DOT_META = re.compile(r"dot_general|jvp\(|transpose\(|checkpoint")


def _corrected_bytes(result_type: str, line: str, dtype_correct: bool):
    b = _shape_bytes(result_type)
    if not dtype_correct:
        return b
    om = re.search(r'op_name="([^"]+)"', line)
    if om and _DOT_META.search(om.group(1)) and "f32[" in result_type:
        return b / 2
    return b


def collective_bytes_from_hlo(hlo: str, dtype_correct: bool = True) -> float:
    """Per-device collective operand bytes, while-loop trip-count aware."""
    comps = _split_computations(hlo)

    # collective operand bytes directly inside each computation
    direct = {}
    # (while body, cond) pairs per computation
    whiles = {}
    coll_re = re.compile(
        r"=\s*(.*?)\s(" + "|".join(COLLECTIVES) + r")(?:-start)?\(")
    for name, lines in comps.items():
        tot = 0.0
        wl = []
        for ln in lines:
            if "-done(" in ln:                 # async pair: count -start only
                continue
            cm_ = coll_re.search(ln)
            if cm_:
                # result-type bytes = bytes received per device (for
                # all-gather that is the gathered buffer; for the others it
                # equals the operand size)
                tot += _corrected_bytes(cm_.group(1), ln, dtype_correct)
            if " while(" in ln and "condition=" in ln:
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                cnd = re.search(r"condition=%?([\w\.\-]+)", ln)
                # XLA annotates counted loops:
                # backend_config={"known_trip_count":{"n":"5"},...}
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', ln)
                if bm and cnd:
                    wl.append((bm.group(1), cnd.group(1),
                               int(tm.group(1)) if tm else None))
        direct[name] = tot
        whiles[name] = wl

    memo = {}

    def total(name, depth=0):
        if name in memo:
            return memo[name]
        if depth > 12 or name not in direct:
            return 0.0
        t = direct[name]
        for body, cond, known in whiles.get(name, ()):
            trips = known if known is not None else _trip_count(
                comps.get(cond, []))
            t += trips * total(body, depth + 1)
        # calls/fusions into other computations that contain collectives
        memo[name] = t
        return t

    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = list(comps)[0]
    return total(entry) if entry else 0.0


# --------------------------------------------------------- analytic model
@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    chips: int
    flops: float                 # executed (analytic, incl. padding/remat)
    model_flops: float           # 6 N_active D (the brief's usefulness ref)
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self):
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hbm_bytes_per_dev / HBM_BW
        self.collective_s = self.coll_bytes_per_dev / LINK_BW
        return self

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline this step achieves if it runs at
        max(terms): compute_s / step_s."""
        return self.compute_s / max(self.step_s, 1e-30)


def _attn_visible(S: int, window) -> float:
    """Average visible keys per query under causal (+ window) masking, as
    the *blocked* schedule computes it (block 512 granularity)."""
    if window is not None and window < S:
        return min(window + 256, S)      # window + half-block slack
    return (S + 1) / 2 + 256             # triangle + half-block slack


def analytic_cell(cfg, shape, mesh_chips: int, tp: int = 16,
                  coll_bytes: float = 0.0, *, arch: str = "",
                  overrides: dict | None = None) -> CellRoofline:
    """Closed-form flop/byte model of one grid cell.

    overrides: perf-iteration knobs {'remat': bool, 'cap_factor': float,
    'grad_bytes': int, ...} so hillclimb variants reuse one model.
    """
    o = overrides or {}
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    D, V = cfg.d_model, cfg.vocab
    H, Dh, kv = cfg.n_heads, cfg.d_head, cfg.n_kv
    L = cfg.n_layers
    ltypes = cfg.layer_types()
    n_attn = sum(1 for t in ltypes if t == "attn")
    n_rec = sum(1 for t in ltypes if t == "rec")
    n_rwkv = sum(1 for t in ltypes if t == "rwkv")

    pbytes = 2 if cfg.param_dtype == "bfloat16" else 4
    cap = o.get("cap_factor", cfg.moe_cap_factor)
    remat = o.get("remat", cfg.remat)

    # --- per-token matmul params actually multiplied (padded, capacity) ---
    def attn_p():
        n = D * (H + 2 * kv) * Dh + H * Dh * D
        return n

    mlp_p = D * cfg.d_ff * (3 if cfg.mlp == "swiglu" else 2)
    if cfg.n_experts:
        ffn_p = cfg.top_k * cap * 3 * D * cfg.d_ff   # capacity slots computed
    else:
        ffn_p = mlp_p
    rec_p = 3 * D * cfg.d_lru + cfg.d_lru * D
    rwkv_p = 6 * D * (H * Dh) + 2 * D * cfg.d_ff + D * D

    per_tok = 0.0
    for t in ltypes:
        if t == "attn":
            per_tok += attn_p() + ffn_p
        elif t == "rec":
            per_tok += rec_p + mlp_p
        elif t == "rwkv":
            per_tok += rwkv_p
    per_tok_enc = cfg.enc_layers * (attn_p() + mlp_p)
    if cfg.enc_layers:
        per_tok += attn_p()          # decoder cross-attention projections
    logits_p = V * D

    # --- token counts ---
    if kind == "train":
        T = B * S
    elif kind == "prefill":
        T = B * S
    else:
        T = B                        # one token per sequence

    T_enc = B * cfg.n_frames if cfg.enc_layers else 0

    # --- attention score flops (q@k + p@v) ---
    def attn_score_flops(T_q, S_kv):
        return 4 * T_q * H * Dh * S_kv

    if kind in ("train", "prefill"):
        vis = _attn_visible(S, cfg.window)
        score = n_attn * attn_score_flops(T, vis)
        if cfg.enc_layers:
            score += cfg.enc_layers * attn_score_flops(T_enc, cfg.n_frames)
            score += L * attn_score_flops(T, cfg.n_frames)   # cross
        # rwkv/rec recurrences: elementwise, O(T x width) — matmul-free
        seq_ops = (n_rec * 6 * T * cfg.d_lru
                   + n_rwkv * 4 * T * H * Dh * Dh)
    else:
        s_kv = min(S, cfg.window) if cfg.window else S
        score = n_attn * attn_score_flops(T, s_kv)
        if cfg.enc_layers:
            score += L * attn_score_flops(T, cfg.n_frames)
        seq_ops = n_rec * 6 * T * cfg.d_lru + n_rwkv * 4 * T * H * Dh * Dh

    fwd = 2 * T * (per_tok + logits_p) + 2 * T_enc * per_tok_enc + score \
        + seq_ops
    if kind == "train":
        factor = 4.0 if remat else 3.0   # fwd + 2x bwd (+1x remat refwd)
        flops = factor * fwd
    else:
        flops = fwd

    # --- usefulness reference: 6 N_active D on true (unpadded) config ---
    n_active = cfg.active_param_count(padded=False)
    if kind == "train":
        model_flops = 6.0 * n_active * T
    else:
        model_flops = 2.0 * n_active * T

    # --- HBM bytes per device ---
    n_params = cfg.param_count(padded=True)
    # Every device streams its TP slice of the weights per use (after the
    # FSDP all-gather the gathered layer is read from HBM on each device).
    w_read = n_params * pbytes / tp
    uses = (3 if remat else 2) if kind == "train" else 1
    hbm = uses * w_read
    if kind == "train":
        gb = o.get("grad_bytes", 4 if cfg.grad_dtype == "float32" else 2)
        mb = 2 if cfg.adam_moment_dtype == "bfloat16" else 4
        mast = 4 if cfg.adam_master_f32 else 0
        opt_bytes = n_params * (2 * mb + mast + gb)
        hbm += 2.0 * opt_bytes / mesh_chips          # read+write, ZeRO-shard
        hbm += cfg.n_micro * 2.0 * n_params * gb / mesh_chips  # grad accum
    # activations (coarse): ~10 x L x tokens-per-device x D x bytes
    act_bytes = 2 if cfg.param_dtype == "bfloat16" else 4
    hbm += 10.0 * L * (T / mesh_chips) * D * act_bytes * \
        (2 if kind == "train" else 1)
    if kind == "decode":
        # the whole KV cache (or recurrent state) streams once per token;
        # it is sharded over (batch-shards x kv-head shards) devices.
        s_c = min(S, cfg.window) if cfg.window else S
        G = cfg.kv_eff(tp)
        cache = n_attn * 2 * B * G * s_c * Dh * 2
        if cfg.enc_layers:
            cache += L * 2 * B * G * cfg.n_frames * Dh * 2
        cache += n_rwkv * B * H * Dh * Dh * 4 + n_rec * B * cfg.d_lru * 4
        cache_shards = max(min(B, mesh_chips // tp), 1) * tp
        hbm += cache / cache_shards

    return CellRoofline(
        arch=arch or cfg.name, shape=shape.name, chips=mesh_chips,
        flops=flops, model_flops=model_flops, hbm_bytes_per_dev=hbm,
        coll_bytes_per_dev=coll_bytes).finalize()


def top_collectives(hlo: str, k: int = 15):
    """(bytes x trips, op, shape, metadata-op-name) of the largest
    collectives — the perf loop's profile view."""
    comps = _split_computations(hlo)
    # computation -> multiplier (product of enclosing loop trips)
    mult = {name: 0.0 for name in comps}

    entry = next((n for n in comps if "main" in n), None)
    if entry is None:
        return []
    mult[entry] = 1.0
    # propagate trip counts breadth-first through while nests
    frontier = [entry]
    while frontier:
        nxt = []
        for name in frontier:
            for ln in comps[name]:
                if " while(" in ln and "condition=" in ln:
                    bm = re.search(r"body=%?([\w\.\-]+)", ln)
                    cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                    tm = re.search(r'known_trip_count[^0-9]*(\d+)', ln)
                    if bm and bm.group(1) in comps:
                        trips = int(tm.group(1)) if tm else _trip_count(
                            comps.get(cm.group(1), []))
                        if mult[bm.group(1)] == 0.0:
                            nxt.append(bm.group(1))
                        mult[bm.group(1)] += mult[name] * trips
        frontier = nxt

    coll_re = re.compile(
        r"=\s*(.*?)\s(" + "|".join(COLLECTIVES) + r")(?:-start)?\(")
    rows = []
    for name, lines in comps.items():
        if mult.get(name, 0.0) == 0.0:
            continue
        for ln in lines:
            if "-done(" in ln:
                continue
            m = coll_re.search(ln)
            if not m:
                continue
            b = _corrected_bytes(m.group(1), ln, True) * mult[name]
            om = re.search(r'op_name="([^"]+)"', ln)
            rows.append((b, m.group(2), m.group(1)[:48],
                         (om.group(1) if om else "")[:90]))
    rows.sort(reverse=True)
    return rows[:k]
