"""Wave-level trace timeline -> Chrome-trace / Perfetto JSON.

The engine's per-wave counters (``sweep(..., per_wave=True)`` /
``run(...)``: per_wave_commits / per_wave_aborts / per_wave_causes /
per_wave_us) become one trace row per grid point: an X "complete" event
per wave on the SIMULATED-time axis (ts = cumulative simulated
microseconds, dur = the wave's simulated microseconds; Chrome trace ts is
in microseconds, so simulated us map 1:1), with the wave's commit /
abort / per-cause deltas in ``args``, plus C "counter" events so the
commit and abort series plot as stacked tracks.  Load the file straight
into chrome://tracing or https://ui.perfetto.dev.

This is the OFFLINE, always-available exporter (CPU container included) —
``REPRO_TRACE=1`` / ``--trace`` in launch/txn_bench.py and
benchmarks/open_loop.py write it next to the bench JSON.  On a real
accelerator the same phase structure shows up in ``jax.profiler`` traces
via the ``jax.named_scope("repro:...")`` annotations around route / claim
/ validate / install in the engine (DESIGN.md "Observability": the two
timelines share phase names, one simulated, one measured).

``validate_chrome_trace`` is the minimal schema check CI runs on every
emitted file — the JSON Chrome actually rejects is the JSON it rejects.
"""
from __future__ import annotations

import json

from repro.core import types as t

#: Trace-event phase codes this exporter emits.
PH_COMPLETE = "X"
PH_COUNTER = "C"
PH_METADATA = "M"


def _args_for_wave(commits: int, aborts: int, causes) -> dict:
    a = {"commits": int(commits), "aborts": int(aborts)}
    if causes is not None:
        for code, name in t.CAUSE_NAMES.items():
            a[f"abort_{name}"] = int(causes[code])
    return a


def point_events(label: str, pid: int, per_wave_commits, per_wave_aborts,
                 per_wave_us, per_wave_causes=None) -> list:
    """Trace events for ONE grid point (one process row in the viewer)."""
    evs = [{"ph": PH_METADATA, "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": label}},
           {"ph": PH_METADATA, "name": "thread_name", "pid": pid, "tid": 0,
            "args": {"name": "waves"}}]
    ts = 0.0
    for w in range(len(per_wave_commits)):
        dur = float(per_wave_us[w]) if per_wave_us is not None else 1.0
        dur = max(dur, 1e-3)       # zero-width slices vanish in the viewer
        c, a = int(per_wave_commits[w]), int(per_wave_aborts[w])
        causes = (per_wave_causes[w] if per_wave_causes is not None
                  else None)
        evs.append({"ph": PH_COMPLETE, "name": f"wave {w}", "cat": "wave",
                    "pid": pid, "tid": 0, "ts": ts, "dur": dur,
                    "args": _args_for_wave(c, a, causes)})
        evs.append({"ph": PH_COUNTER, "name": "txns", "pid": pid,
                    "ts": ts, "args": {"commits": c, "aborts": a}})
        ts += dur
    return evs


def sweep_trace(points, label_fn=None) -> dict:
    """Chrome-trace dict from SweepPoints carrying per-wave timelines
    (``sweep(..., per_wave=True)``).  Points without per-wave data are
    skipped; ``label_fn(point) -> str`` names each process row (default:
    ``"<cc>/<granularity>/T<lanes>"``)."""
    if label_fn is None:
        def label_fn(p):
            return (f"{t.CC_NAMES.get(p.cc, p.cc)}/"
                    f"{'fine' if p.granularity else 'coarse'}/T{p.lanes}")
    events = []
    pid = 0
    for p in points:
        if getattr(p, "per_wave_commits", None) is None:
            continue
        pid += 1
        events += point_events(label_fn(p), pid, p.per_wave_commits,
                               p.per_wave_aborts, p.per_wave_us,
                               p.per_wave_causes)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"source": "repro wave-level trace",
                          "time_axis": "simulated microseconds"}}


def validate_chrome_trace(trace: dict) -> list:
    """Minimal Chrome-trace schema check; returns a list of problem
    strings (empty = valid).  Checks the shape chrome://tracing actually
    requires: a traceEvents list of dicts, every event with a string
    ``ph``, X events with numeric ts/dur and pid/tid, M events with a
    name."""
    errs = []
    if not isinstance(trace, dict):
        return [f"trace must be a dict, got {type(trace).__name__}"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents must be a list"]
    if not evs:
        errs.append("traceEvents is empty")
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not a dict")
            continue
        ph = e.get("ph")
        if not isinstance(ph, str) or not ph:
            errs.append(f"event {i}: missing ph")
            continue
        if ph == PH_COMPLETE:
            for k in ("ts", "dur"):
                if not isinstance(e.get(k), (int, float)):
                    errs.append(f"event {i}: X event needs numeric {k}")
            for k in ("pid", "tid"):
                if not isinstance(e.get(k), int):
                    errs.append(f"event {i}: X event needs int {k}")
            if not e.get("name"):
                errs.append(f"event {i}: X event needs a name")
        elif ph == PH_METADATA:
            if not e.get("name"):
                errs.append(f"event {i}: M event needs a name")
            if not isinstance(e.get("args"), dict):
                errs.append(f"event {i}: M event needs args")
        elif ph == PH_COUNTER:
            if not isinstance(e.get("ts"), (int, float)):
                errs.append(f"event {i}: C event needs numeric ts")
            if not isinstance(e.get("args"), dict):
                errs.append(f"event {i}: C event needs args")
    return errs


def write_trace(path: str, trace: dict) -> str:
    """Validate then write ``trace`` as JSON; raises on schema errors so a
    bench run can never silently emit a file the viewer rejects."""
    errs = validate_chrome_trace(trace)
    if errs:
        raise ValueError("refusing to write an invalid Chrome trace: "
                         + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(trace, f)
    return path
