"""Per-op roofline cost model of the transaction engine's backend surface.

Every mechanism's wave is a fixed pipeline of the fourteen kernel-backend
ops (core/backend.py); each op's traffic is analytic in the wave shape —
T lanes x K op slots against uint32 claim/version tables of ``cells``
words per op probe (``n_groups`` at coarse granularity, 1 at fine; the
paper's switch is literally the probe width, which is why coarse and fine
have different bytes-per-txn here).  From the per-op descriptors we roll
up bytes/flops per wave per mechanism, divide by the lane count for the
dashboard's **bytes-per-txn / flops-per-txn** columns (per *attempt* — an
aborted incarnation pays the same traffic), and place each mechanism on
the roofline of ``analysis/peaks.py`` (the shared hardware peak table):

    intensity       = flops_per_wave / bytes_per_wave        [FLOP/B]
    frac_of_roofline= min(1, intensity / ridge(chip))
    bound           = memory below the ridge, compute above

The engine's ops are all gather/scatter over uint32 words with a handful
of compares per cell, so intensities sit far below any chip's ridge: the
model says (and the dashboard shows) the engine is **memory-bound
everywhere**, and mechanism cost differences are byte differences.

The op-call counts per wave (``WAVE_OPS``) mirror the mechanism sources
one-to-one — e.g. tictoc's 1 claim_probe + 2 ts_gather + 2 segment_count
+ 3 ts_install_max is exactly cc/tictoc.py's backend call sequence —
and tests/test_txn_cost.py pins them against the source so they cannot
drift silently.  ``DIST_WAVE_OPS`` does the same for the routed
distributed wave (core/distributed.py), whose exchange payload is already
accounted honestly by ``distributed.wire_bytes_per_wave``.

Nothing here imports jax — the model is closed-form, cheap enough to run
inside the bench row builder (launch/txn_bench.py) for every grid point.
"""
from __future__ import annotations

import dataclasses

from repro.analysis import peaks

#: Claim / version tables are packed uint32 words (core/claims.py).
WORD = 4


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytic traffic of ONE backend-op call at a given wave shape."""
    bytes_per_call: float
    flops_per_call: float


@dataclasses.dataclass(frozen=True)
class WaveShape:
    """The shape terms the per-op descriptors depend on."""
    lanes: int                 # T
    slots: int                 # K ops per txn
    n_groups: int = 2          # G column groups per record
    granularity: int = 1       # 0 coarse / 1 fine — the paper's switch
    mv_depth: int = 0          # version-ring depth D (mv mechanisms)
    n_shards: int = 1          # distributed: mesh size
    route_cap: int = 0         # distributed: per-destination buffer cap

    @property
    def ops(self) -> int:
        return self.lanes * self.slots

    @property
    def cells(self) -> int:
        """Claim words touched per op probe: the whole row at coarse
        granularity, one group word at fine — the byte-level face of the
        paper's timestamp-granularity switch."""
        fine = self.granularity == 1 and self.n_groups > 1
        return 1 if fine else self.n_groups


def op_costs(s: WaveShape) -> dict:
    """OpCost per backend-surface op name at shape ``s``.

    Reads and read-modify-writes count actual table words (WORD bytes
    each; RMW = read + write).  Flops are the compare/select ALU work per
    cell — deliberately generous, and still orders of magnitude below any
    ridge point.
    """
    n, c, D = s.ops, s.cells, max(s.mv_depth, 1)
    ns, cap = s.n_shards, max(s.route_cap, 1)
    return {
        # one claim-word read + priority compare per cell
        "validate": OpCost(WORD * n * c, 2.0 * n * c),
        # both widths in one pass (autogran's dual verdict)
        "validate_dual": OpCost(WORD * n * (1 + s.n_groups),
                                2.0 * n * (1 + s.n_groups)),
        "probe": OpCost(WORD * n * c, 1.0 * n * c),
        # fused min-install + probe: one RMW pass answers both
        "claim_probe": OpCost(2 * WORD * n * c, 3.0 * n * c),
        # scatter-min RMW
        "claim_scatter": OpCost(2 * WORD * n * c, 1.0 * n * c),
        "ts_gather": OpCost(WORD * n * c, 1.0 * n),
        # scatter-add RMW (version bumps / conflict-hit histogram)
        "commit_install": OpCost(2 * WORD * n * c, 1.0 * n * c),
        # scatter-max RMW
        "ts_install_max": OpCost(2 * WORD * n * c, 1.0 * n * c),
        # sort-free per-cell counts: key read + counter scatter-add
        "segment_count": OpCost(2 * WORD * n, 2.0 * n),
        # 3 int32 channels in, 3 [ns, cap] buffers out + offset scan
        "route_pack": OpCost(WORD * 3 * (n + ns * cap), 4.0 * n),
        # ring scan: D slots x cells begin-words + head read per op
        "mv_gather": OpCost(WORD * n * (D * c + 1), 2.0 * n * D * c),
        # slot claim + begin publish (RMW) + head bump
        "mv_install": OpCost(2 * WORD * n * (c + 1), 2.0 * n * c),
        # 16 2-bit verdicts per int32 word + the int8 source/dest
        "verdict_pack": OpCost(n + WORD * -(-n // 16), 1.0 * n),
        "verdict_unpack": OpCost(n + WORD * -(-n // 16), 1.0 * n),
    }


#: Backend-op calls per wave per LOCAL mechanism — a one-to-one mirror of
#: each cc/*.py source (claim_and_probe -> claim_probe, write_claims /
#: plain_write_claims -> claim_scatter, bump_versions -> commit_install).
WAVE_OPS = {
    "occ": {"claim_probe": 1, "commit_install": 1},
    "tictoc": {"claim_probe": 1, "ts_gather": 2, "segment_count": 2,
               "ts_install_max": 3},
    "2pl": {"claim_probe": 2, "commit_install": 1},
    "swisstm": {"claim_probe": 1, "commit_install": 1},
    "adaptive": {"claim_probe": 2, "commit_install": 1},
    "autogran": {"claim_scatter": 1, "validate_dual": 1,
                 "commit_install": 1},
    "mvcc": {"claim_scatter": 2, "validate": 2, "mv_gather": 1,
             "mv_install": 1},
    "mvocc": {"claim_scatter": 2, "validate": 3, "mv_gather": 1,
              "mv_install": 1},
}

#: Shard-local op calls per wave of the routed DISTRIBUTED wave
#: (core/distributed.py _make_phases; wire bytes live in
#: distributed.wire_bytes_per_wave, not here).
DIST_WAVE_OPS = {
    "occ": {"route_pack": 1, "claim_probe": 1, "verdict_pack": 2,
            "verdict_unpack": 2, "commit_install": 1},
    "mvcc": {"route_pack": 1, "claim_probe": 2, "mv_gather": 1,
             "verdict_pack": 2, "verdict_unpack": 2, "mv_install": 1},
    "mvocc": {"route_pack": 1, "claim_probe": 2, "mv_gather": 1,
              "verdict_pack": 2, "verdict_unpack": 2, "mv_install": 1},
}


def wave_cost(cc: str, s: WaveShape, distributed: bool = False) -> dict:
    """Roll up mechanism ``cc``'s per-wave traffic at shape ``s``:
    {bytes_per_wave, flops_per_wave, ops: {name: count}}."""
    table = DIST_WAVE_OPS if distributed else WAVE_OPS
    if cc not in table:
        raise KeyError(f"unknown mechanism {cc!r} (expected one of "
                       f"{sorted(table)})")
    costs = op_costs(s)
    counts = table[cc]
    b = sum(costs[op].bytes_per_call * k for op, k in counts.items())
    f = sum(costs[op].flops_per_call * k for op, k in counts.items())
    return {"bytes_per_wave": b, "flops_per_wave": f, "ops": dict(counts)}


def txn_cost(cc: str, s: WaveShape, distributed: bool = False,
             chip: str = peaks.DEFAULT_CHIP) -> dict:
    """The dashboard row fields: per-ATTEMPT per-transaction traffic and
    the mechanism's place on ``chip``'s roofline.

    bytes_per_txn / flops_per_txn divide the wave rollup by the lane
    count — each incarnation of an aborted transaction pays this again,
    so goodput-per-byte divides further by the commit rate (the dashboard
    already carries commit rates; this model stays traffic-only).
    """
    w = wave_cost(cc, s, distributed)
    lanes = max(s.lanes, 1)
    intensity = w["flops_per_wave"] / max(w["bytes_per_wave"], 1.0)
    r = peaks.ridge(chip)
    return {
        "bytes_per_txn": w["bytes_per_wave"] / lanes,
        "flops_per_txn": w["flops_per_wave"] / lanes,
        "intensity": intensity,
        "roofline_frac": min(1.0, intensity / r),
        "bound": "memory" if intensity < r else "compute",
        "chip": chip,
    }
