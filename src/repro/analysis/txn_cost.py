"""Per-op roofline cost model of the transaction engine's backend surface.

Every mechanism's wave is a fixed pipeline drawn from the
``backend.N_OPS``-op kernel surface (core/backend.py); each op's traffic
is analytic in the wave shape — T lanes x K op slots against uint32
claim/version tables of ``cells`` words per op probe (``n_groups`` at
coarse granularity, 1 at fine; the paper's switch is literally the probe
width, which is why coarse and fine have different bytes-per-txn here).
Interval reads (``max_extent > 1``) add the ``iterate_validate`` pass,
whose traffic scales with the per-op scan span — ``max_extent`` rows at
fine granularity, the bucket-expanded span at coarse (the same
``scan_span`` law as kernels/ref.py).  From the per-op descriptors we roll
up bytes/flops per wave per mechanism, divide by the lane count for the
dashboard's **bytes-per-txn / flops-per-txn** columns (per *attempt* — an
aborted incarnation pays the same traffic), and place each mechanism on
the roofline of ``analysis/peaks.py`` (the shared hardware peak table):

    intensity       = flops_per_wave / bytes_per_wave        [FLOP/B]
    frac_of_roofline= min(1, intensity / ridge(chip))
    bound           = memory below the ridge, compute above

The engine's ops are gather/scatter over uint32 words with a handful of
compares per cell — PLUS, for the in-wave-minimum family (segment_count,
claim_probe, wave_commit), the all-pairs same-cell wave term: every op
compares its (key, group) against every other op's, O((T*K)^2) compares
per call.  At small waves that term is noise and the engine is
**memory-bound everywhere**; at large waves (T*K in the thousands) the
quadratic flops dominate the linear table bytes and the probe family
climbs toward — and past — the ridge.  Both regimes are pinned in
tests/test_txn_cost.py.

``probe_chain`` models the fused-wave launch accounting (ISSUE 9): the
unfused probe chain (claim/probe RMW, XLA verdict reduction, version
bump — per claim table) is 2–4 launches per wave, each re-visiting the
wave's touched-row working set; the fused ``wave_commit`` megakernel is
ONE launch and ONE row visit.  ``launches_per_wave`` and
``dma_rows_per_wave`` (visits x ops) are the dashboard columns showing
the >= 2x modeled row-traffic cut per mechanism.

The op-call counts per wave (``WAVE_OPS``) mirror the mechanism sources
one-to-one — e.g. tictoc's 1 claim_probe + 2 ts_gather + 2 segment_count
+ 3 ts_install_max is exactly cc/tictoc.py's backend call sequence —
and tests/test_txn_cost.py pins them against the source so they cannot
drift silently.  ``DIST_WAVE_OPS`` does the same for the routed
distributed wave (core/distributed.py), whose exchange payload is already
accounted honestly by ``distributed.wire_bytes_per_wave``.

Nothing here imports jax — the model is closed-form, cheap enough to run
inside the bench row builder (launch/txn_bench.py) for every grid point.
"""
from __future__ import annotations

import dataclasses

from repro.analysis import peaks

#: Claim / version tables are packed uint32 words (core/claims.py).
WORD = 4


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytic traffic of ONE backend-op call at a given wave shape."""
    bytes_per_call: float
    flops_per_call: float


@dataclasses.dataclass(frozen=True)
class WaveShape:
    """The shape terms the per-op descriptors depend on."""
    lanes: int                 # T
    slots: int                 # K ops per txn
    n_groups: int = 2          # G column groups per record
    granularity: int = 1       # 0 coarse / 1 fine — the paper's switch
    mv_depth: int = 0          # version-ring depth D (mv mechanisms)
    n_shards: int = 1          # distributed: mesh size
    route_cap: int = 0         # distributed: per-destination buffer cap
    max_extent: int = 1        # interval reads: static scan-length bound
    bucket_size: int = 8       # coarse bucket-claim width B (records)

    @property
    def ops(self) -> int:
        return self.lanes * self.slots

    @property
    def cells(self) -> int:
        """Claim words touched per op probe: the whole row at coarse
        granularity, one group word at fine — the byte-level face of the
        paper's timestamp-granularity switch."""
        fine = self.granularity == 1 and self.n_groups > 1
        return 1 if fine else self.n_groups

    @property
    def scan_span(self) -> int:
        """Rows an ``iterate_validate`` probe walks per scan op — the
        same law as kernels/ref.py ``scan_span``: the raw extent bound at
        fine granularity, the worst-case bucket expansion
        ``(1 + ceil((ext-1)/B)) * B`` at coarse (an interval can straddle
        one more bucket than its length suggests)."""
        if self.max_extent <= 1 or self.granularity == 1:
            return self.max_extent
        b = self.bucket_size
        return (1 + -(-(self.max_extent - 1) // b)) * b


def op_costs(s: WaveShape) -> dict:
    """OpCost per backend-surface op name at shape ``s``.

    Reads and read-modify-writes count actual table words (WORD bytes
    each; RMW = read + write).  Flops are the compare/select ALU work per
    cell — deliberately generous, and still orders of magnitude below any
    ridge point.
    """
    n, c, D = s.ops, s.cells, max(s.mv_depth, 1)
    ns, cap = s.n_shards, max(s.route_cap, 1)
    return {
        # one claim-word read + priority compare per cell
        "validate": OpCost(WORD * n * c, 2.0 * n * c),
        # both widths in one pass (autogran's dual verdict)
        "validate_dual": OpCost(WORD * n * (1 + s.n_groups),
                                2.0 * n * (1 + s.n_groups)),
        "probe": OpCost(WORD * n * c, 1.0 * n * c),
        # interval (phantom) validation: each op walks its scan span —
        # ``max_extent`` rows at fine, the bucket-expanded span at coarse
        # — reading ``cells`` claim words per row with a decode + strict
        # priority compare.  At max_extent == 1 this degenerates exactly
        # to ``validate`` (the extent-1 bit-identity guard, in traffic
        # terms).
        "iterate_validate": OpCost(WORD * n * s.scan_span * c,
                                   2.0 * n * s.scan_span * c),
        # fused min-install + probe: one RMW pass answers both; the
        # in-wave min is the all-pairs same-cell term — O(n^2) compares
        "claim_probe": OpCost(2 * WORD * n * c, 3.0 * n * c + 2.0 * n * n),
        # scatter-min RMW
        "claim_scatter": OpCost(2 * WORD * n * c, 1.0 * n * c),
        "ts_gather": OpCost(WORD * n * c, 1.0 * n),
        # scatter-add RMW (version bumps / conflict-hit histogram)
        "commit_install": OpCost(2 * WORD * n * c, 1.0 * n * c),
        # scatter-max RMW
        "ts_install_max": OpCost(2 * WORD * n * c, 1.0 * n * c),
        # sort-free per-cell counts: key read + counter scatter-add; the
        # per-cell count is an all-pairs key-equality reduction — O(n^2)
        "segment_count": OpCost(2 * WORD * n, 2.0 * n + 2.0 * n * n),
        # ISSUE 9 megakernel: claim-row RMW (install + probe, like
        # claim_probe) + the all-pairs wave term + the in-VMEM verdict
        # reduction, all in one launch.  Dual-table mechanisms count the
        # op twice (one per claim table); the version bump rides the same
        # launch but is still listed as commit_install (its version-row
        # traffic is unchanged by fusion).
        "wave_commit": OpCost(2 * WORD * n * c,
                              4.0 * n * c + 2.0 * n * n),
        # 3 int32 channels in, 3 [ns, cap] buffers out + offset scan
        "route_pack": OpCost(WORD * 3 * (n + ns * cap), 4.0 * n),
        # ring scan: D slots x cells begin-words + head read per op
        "mv_gather": OpCost(WORD * n * (D * c + 1), 2.0 * n * D * c),
        # slot claim + begin publish (RMW) + head bump
        "mv_install": OpCost(2 * WORD * n * (c + 1), 2.0 * n * c),
        # 16 2-bit verdicts per int32 word + the int8 source/dest
        "verdict_pack": OpCost(n + WORD * -(-n // 16), 1.0 * n),
        "verdict_unpack": OpCost(n + WORD * -(-n // 16), 1.0 * n),
    }


#: Backend-op calls per wave per LOCAL mechanism — a one-to-one mirror of
#: each cc/*.py source (claim_probe_commit -> wave_commit, once per claim
#: table; write_claims / plain_write_claims -> claim_scatter;
#: bump_versions -> commit_install, which the probe family's fused launch
#: absorbs without changing its version-row traffic).
#: Every mechanism that validates scans makes ONE phantom pass per wave
#: (base.phantom_validate, inside claim_probe_commit or appended after
#: the point verdicts) — iterate_validate: 1 across the board.  mvcc is
#: the deliberate absence: snapshot scans read a consistent cut and SI
#: admits phantoms by design (cc/mvcc.py).
WAVE_OPS = {
    "occ": {"wave_commit": 1, "commit_install": 1, "iterate_validate": 1},
    "tictoc": {"wave_commit": 1, "ts_gather": 2, "segment_count": 2,
               "ts_install_max": 3, "iterate_validate": 1},
    "2pl": {"wave_commit": 2, "commit_install": 1, "iterate_validate": 1},
    "swisstm": {"wave_commit": 1, "commit_install": 1,
                "iterate_validate": 1},
    "adaptive": {"wave_commit": 2, "commit_install": 1,
                 "iterate_validate": 1},
    "autogran": {"claim_scatter": 1, "validate_dual": 1,
                 "commit_install": 1, "iterate_validate": 1},
    "mvcc": {"claim_scatter": 2, "validate": 2, "mv_gather": 1,
             "mv_install": 1},
    "mvocc": {"claim_scatter": 2, "validate": 3, "mv_gather": 1,
              "mv_install": 1, "iterate_validate": 1},
}

#: Shard-local op calls per wave of the routed DISTRIBUTED wave
#: (core/distributed.py _make_phases; wire bytes live in
#: distributed.wire_bytes_per_wave, not here).
DIST_WAVE_OPS = {
    "occ": {"route_pack": 1, "wave_commit": 1, "verdict_pack": 2,
            "verdict_unpack": 2, "commit_install": 1,
            "iterate_validate": 1},
    "mvcc": {"route_pack": 1, "claim_probe": 2, "mv_gather": 1,
             "verdict_pack": 2, "verdict_unpack": 2, "mv_install": 1},
    "mvocc": {"route_pack": 1, "claim_probe": 2, "mv_gather": 1,
              "verdict_pack": 2, "verdict_unpack": 2, "mv_install": 1,
              "iterate_validate": 1},
}

#: Launches in the UNFUSED probe chain per wave — the claim/probe RMW
#: pass(es), the XLA verdict reduction, and the version bump that
#: ``wave_commit`` collapses into ONE launch (base.claim_probe_commit's
#: fuse_wave=False path).  occ/swisstm: claim_probe + verdict + bump = 3;
#: tictoc: claim_probe + verdict = 2 (no bump — ts_install_max owns the
#: timestamp writes); 2pl/adaptive: two claim tables + verdict + bump = 4.
PROBE_CHAIN_LAUNCHES = {
    "occ": 3,
    "tictoc": 2,
    "2pl": 4,
    "swisstm": 3,
    "adaptive": 4,
}


def probe_chain(cc: str, s: WaveShape, fused: bool = True) -> dict:
    """Launch/row-traffic accounting of mechanism ``cc``'s probe chain at
    shape ``s`` — the ISSUE 9 dashboard columns.

    Each launch in the unfused chain re-visits the wave's touched-row
    working set (the claim RMW fetches it, the verdict pass re-reads the
    probe outputs derived from it, the bump re-fetches the version rows):
    ``dma_rows_per_wave`` = visits x (T*K) row slots.  Fused, the whole
    chain is ONE launch and each touched row rides ONE DMA round-trip —
    the >= 2x modeled row-traffic cut per mechanism.
    """
    if cc not in PROBE_CHAIN_LAUNCHES:
        raise KeyError(f"{cc!r} is not a probe-family mechanism (expected "
                       f"one of {sorted(PROBE_CHAIN_LAUNCHES)})")
    visits = 1 if fused else PROBE_CHAIN_LAUNCHES[cc]
    return {
        "launches_per_wave": visits,
        "dma_rows_per_wave": visits * s.ops,
    }


def wave_cost(cc: str, s: WaveShape, distributed: bool = False) -> dict:
    """Roll up mechanism ``cc``'s per-wave traffic at shape ``s``:
    {bytes_per_wave, flops_per_wave, ops: {name: count}}."""
    table = DIST_WAVE_OPS if distributed else WAVE_OPS
    if cc not in table:
        raise KeyError(f"unknown mechanism {cc!r} (expected one of "
                       f"{sorted(table)})")
    costs = op_costs(s)
    counts = table[cc]
    b = sum(costs[op].bytes_per_call * k for op, k in counts.items())
    f = sum(costs[op].flops_per_call * k for op, k in counts.items())
    return {"bytes_per_wave": b, "flops_per_wave": f, "ops": dict(counts)}


def txn_cost(cc: str, s: WaveShape, distributed: bool = False,
             chip: str = peaks.DEFAULT_CHIP) -> dict:
    """The dashboard row fields: per-ATTEMPT per-transaction traffic and
    the mechanism's place on ``chip``'s roofline.

    bytes_per_txn / flops_per_txn divide the wave rollup by the lane
    count — each incarnation of an aborted transaction pays this again,
    so goodput-per-byte divides further by the commit rate (the dashboard
    already carries commit rates; this model stays traffic-only).
    """
    w = wave_cost(cc, s, distributed)
    lanes = max(s.lanes, 1)
    intensity = w["flops_per_wave"] / max(w["bytes_per_wave"], 1.0)
    r = peaks.ridge(chip)
    return {
        "bytes_per_txn": w["bytes_per_wave"] / lanes,
        "flops_per_txn": w["flops_per_wave"] / lanes,
        "intensity": intensity,
        "roofline_frac": min(1.0, intensity / r),
        "bound": "memory" if intensity < r else "compute",
        "chip": chip,
    }
