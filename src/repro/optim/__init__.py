from repro.optim.adamw import AdamW

__all__ = ["AdamW"]
