"""AdamW from scratch: f32 master weights (optional), configurable moment
dtype (bf16 for the HBM-bound MoE archs), global-norm clipping, linear-warmup
cosine schedule.

ZeRO-1 placement: the optimizer state mirrors the parameter pytree, and
models/sharding.py shards it over ("pod", "data") where parameters shard over
"data" alone — XLA's SPMD partitioner then emits the reduce-scatter(grads) /
all-gather(params) pair that implements the distributed update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    master_f32: bool = True

    @staticmethod
    def from_config(cfg, **kw) -> "AdamW":
        return AdamW(moment_dtype=cfg.adam_moment_dtype,
                     master_f32=cfg.adam_master_f32, **kw)

    # ------------------------------------------------------------- schedule
    def lr(self, step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = step / max(self.warmup_steps, 1)
        t = jnp.clip((step - self.warmup_steps)
                     / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = self.min_lr_frac + (1 - self.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return self.peak_lr * jnp.where(step < self.warmup_steps, warm, cos)

    # ---------------------------------------------------------------- state
    def _needs_master(self, p) -> bool:
        return self.master_f32 and p.dtype != jnp.float32

    def init(self, params) -> dict:
        mdt = jnp.dtype(self.moment_dtype)
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        }
        if self.master_f32:
            state["master"] = jax.tree.map(
                lambda p: (p.astype(jnp.float32) if self._needs_master(p)
                           else jnp.zeros((), jnp.float32)), params)
        return state

    # --------------------------------------------------------------- update
    def update(self, grads, state, params, step):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr = self.lr(step)
        stepf = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** stepf
        c2 = 1.0 - self.b2 ** stepf
        mdt = jnp.dtype(self.moment_dtype)

        def one(p, g, m, v, master):
            g = g * scale
            m = (self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g)
            v = (self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g)
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            base = master if (master is not None and master.ndim == p.ndim
                              and self._needs_master(p)) \
                else p.astype(jnp.float32)
            new = base - lr * (upd + self.weight_decay * base)
            new_master = new if (master is not None and master.ndim == p.ndim
                                 and self._needs_master(p)) \
                else (jnp.zeros((), jnp.float32) if master is not None
                      else None)
            return new.astype(p.dtype), m.astype(mdt), v.astype(mdt), \
                new_master

        ps, gs = jax.tree.leaves(params), jax.tree.leaves(gf)
        ms, vs = jax.tree.leaves(state["m"]), jax.tree.leaves(state["v"])
        mas = (jax.tree.leaves(state["master"]) if "master" in state
               else [None] * len(ps))
        out = [one(p, g, m, v, ma)
               for p, g, m, v, ma in zip(ps, gs, ms, vs, mas)]
        td = jax.tree.structure(params)
        new_params = jax.tree.unflatten(td, [o[0] for o in out])
        new_state = {"m": jax.tree.unflatten(td, [o[1] for o in out]),
                     "v": jax.tree.unflatten(td, [o[2] for o in out])}
        if "master" in state:
            new_state["master"] = jax.tree.unflatten(
                td, [o[3] for o in out])
        return new_params, new_state, {"gnorm": gnorm, "lr": lr}

    # ------------------------------------------------------ sharding helper
    def state_axes(self, param_axes) -> dict:
        ax = {"m": param_axes, "v": param_axes}
        if self.master_f32:
            # scalar placeholders for f32 params get no axes
            ax["master"] = jax.tree.map(
                lambda a: a, param_axes,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, str) for e in x))
        return ax
