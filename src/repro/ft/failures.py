"""Failure injection for the fault-tolerance path.

``FailureInjector`` raises ``SimulatedFailure`` at configured steps (or at a
seeded random rate) *after* the step's computation is dispatched — modeling a
node loss mid-run.  The trainer's supervisor loop (launch/train.py) catches
it, tears down in-memory state, and resumes from the last durable checkpoint;
tests assert bit-exact continuation.
"""
from __future__ import annotations

import dataclasses
import random


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    at_steps: tuple = ()            # deterministic failures
    rate: float = 0.0               # plus Bernoulli(rate) per step
    seed: int = 0
    max_failures: int = 10 ** 9

    def __post_init__(self):
        self._rng = random.Random(self.seed)
        self._fired = 0
        self._tripped = set()

    def maybe_fail(self, step: int):
        if self._fired >= self.max_failures:
            return
        hit = (step in self.at_steps and step not in self._tripped) \
            or (self.rate > 0 and self._rng.random() < self.rate)
        if hit:
            self._tripped.add(step)
            self._fired += 1
            raise SimulatedFailure(f"injected node failure at step {step}")
