from repro.ft.failures import FailureInjector

__all__ = ["FailureInjector"]
