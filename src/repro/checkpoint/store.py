"""Checkpointing: sharded-npz + JSON manifest, atomic, async, elastic.

Orbax is not installed in this container, so this is a from-scratch store
with the properties a 1000-node run needs:

  - atomic: write to ``step_K.tmp/`` then ``os.rename`` — a crash mid-save
    never corrupts the latest durable checkpoint;
  - async: ``save(..., blocking=False)`` snapshots to host memory and writes
    on a daemon thread (training continues); ``wait()`` joins before exit;
  - elastic: the manifest stores only *logical* shapes; ``restore`` rebuilds
    arrays and ``jax.device_put``s them to whatever mesh/sharding the new
    run uses — device counts may change between runs;
  - retention: keep the newest ``keep`` checkpoints;
  - contents: params + optimizer state + step + data cursor + a config
    fingerprint (refuses to restore a mismatched architecture).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def _encode(a: np.ndarray):
    """npz cannot serialize bfloat16: store as a uint16 view + dtype tag."""
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode(a: np.ndarray, dtype: str):
    if dtype == "bfloat16" and a.dtype == np.uint16:
        import ml_dtypes
        return a.view(ml_dtypes.bfloat16)
    return a


def save(ckpt_dir: str, step: int, tree, *, fingerprint: str = "",
         extra: dict | None = None, blocking: bool = True, keep: int = 3):
    """Serialize ``tree`` under ckpt_dir/step_<step>/ atomically."""
    flat, _ = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}   # snapshot now
    dtypes = {}
    for k in list(host):
        host[k], dtypes[k] = _encode(host[k])

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "fingerprint": fingerprint,
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _retain(ckpt_dir, keep)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, fingerprint: str = "",
            shardings=None):
    """Load step_<step> into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding — arrays are device_put
    straight to the *current* mesh layout (elastic resharding: the saved and
    restored device counts are unrelated).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if fingerprint and manifest["fingerprint"] != fingerprint:
        raise ValueError(
            f"checkpoint fingerprint {manifest['fingerprint']!r} does not "
            f"match the current config {fingerprint!r}")
    arrays = np.load(os.path.join(d, "arrays.npz"))
    flat, treedef = _flatten(like_tree)
    out = {}
    for k, like in flat.items():
        a = _decode(arrays[k], manifest["leaves"][k]["dtype"])
        if tuple(a.shape) != tuple(like.shape):
            raise ValueError(f"leaf {k}: saved {a.shape} != {like.shape}")
        out[k] = a
    leaves = [out[k] for k in flat]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    tree = jax.tree.map(
        lambda a, like: jax.numpy.asarray(a, dtype=like.dtype), tree,
        like_tree)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest


class CheckpointManager:
    """Save-loop helper: interval policy + async handle + preemption flush."""

    def __init__(self, ckpt_dir: str, *, interval: int = 100, keep: int = 3,
                 fingerprint: str = ""):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self.fingerprint = fingerprint
        self._pending = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree, *, extra=None, force=False):
        if not force and (step == 0 or step % self.interval):
            return
        self.wait()
        self._pending = save(self.dir, step, tree,
                             fingerprint=self.fingerprint, extra=extra,
                             blocking=False, keep=self.keep)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self):
        return latest_step(self.dir)

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest()
        if step is None:
            return None, None
        tree, manifest = restore(self.dir, step, like_tree,
                                 fingerprint=self.fingerprint,
                                 shardings=shardings)
        return tree, manifest
