"""Mixture-of-Experts FFN: grouped capacity dispatch (GShard-style), pure
pjit + sharding constraints.

Dispatch is *per group*: tokens reshape to [G, T_g, D] where G equals the
mesh's batch-shard count, so every sort/cumsum/scatter in the dispatch is
local to a device under GSPMD — no distributed sorts.  The expert compute is
two batched einsums over a [G, E, C, D] dispatch buffer.

Sharding modes (config.moe_mode, per DESIGN.md section 6):
  "ep"  experts sharded over the model axis (llama4: 128 experts / 16 ranks);
        the dispatch buffer is (G x E)-sharded, combine is a scatter-add back
        to the token layout.
  "tp"  d_ff sharded over the model axis (mixtral: 8 experts < 16 ranks);
        experts replicated, the down-projection contraction inserts the usual
        TP all-reduce.

Tokens overflowing an expert's capacity (cap_factor x fair share) are dropped
(standard Switch/GShard behavior); the combine leaves their residual stream
untouched.  The router adds the Switch load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def moe_schema(cfg) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = cfg.param_dtype
    return {
        "router": ParamSpec((D, E), ("embed_r", "none"), dtype="float32",
                            fan_in_dims=(0,)),
        "w_gate": ParamSpec((E, D, F), ("experts", "embed", "expert_mlp"),
                            dtype=pd, fan_in_dims=(1,)),
        "w_in": ParamSpec((E, D, F), ("experts", "embed", "expert_mlp"),
                          dtype=pd, fan_in_dims=(1,)),
        "w_out": ParamSpec((E, F, D), ("experts", "expert_mlp", "embed"),
                           dtype=pd, fan_in_dims=(1,)),
    }


def capacity(cfg, tokens_per_group: int) -> int:
    fair = tokens_per_group * cfg.top_k / cfg.n_experts
    return max(4, int(fair * cfg.moe_cap_factor + 0.5))


def moe_ffn(p, x, cfg, n_groups: int, constrain=None):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    constrain(tensor, logical_axes) applies a sharding constraint (injected
    by models/sharding.py; identity in single-device tests).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cst = constrain or (lambda t, a: t)

    T = B * S
    G = n_groups if T % max(n_groups, 1) == 0 else 1
    Tg = T // G
    C = capacity(cfg, Tg)
    xg = x.reshape(G, Tg, D)
    xg = cst(xg, ("moe_groups", "none", "none"))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)              # [G, Tg, E]
    gate, eidx = jax.lax.top_k(probs, k)                 # [G, Tg, k]
    if k > 1:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * P_e (f = token fraction, P = mean prob)
    sel1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(sel1, axis=1) * jnp.mean(probs, axis=1))

    # --- dispatch: rank of each (token, slot) within its expert, per group
    fe = eidx.reshape(G, Tg * k)                         # flat expert ids
    order = jnp.argsort(fe, axis=-1)                     # stable
    se = jnp.take_along_axis(fe, order, axis=-1)
    counts = jax.vmap(lambda v: jnp.bincount(v, length=E))(se)   # [G, E]
    offs = jnp.cumsum(counts, axis=-1) - counts          # group starts
    pos = jnp.arange(Tg * k)[None, :] - jnp.take_along_axis(offs, se, axis=-1)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)          # E*C = drop slot

    tok = order // k                                     # token of sorted row
    gsel = jnp.take_along_axis(gate.reshape(G, Tg * k), order, axis=-1)

    # slot -> token / gate tables (scatter; dropped rows land on slot E*C)
    def scatter_tables(slot_g, tok_g, gsel_g):
        t = jnp.full((E * C + 1,), Tg, jnp.int32).at[slot_g].set(
            tok_g.astype(jnp.int32), mode="drop")
        g = jnp.zeros((E * C + 1,), jnp.float32).at[slot_g].set(
            gsel_g, mode="drop")
        return t[:-1], g[:-1]

    slot_tok, slot_gate = jax.vmap(scatter_tables)(slot, tok, gsel)
    slot_tok = slot_tok.reshape(G, E, C)
    slot_gate = slot_gate.reshape(G, E, C)

    xpad = jnp.concatenate([xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, :, None, :],
        slot_tok.reshape(G, E * C)[:, :, None, None], axis=1
    ).reshape(G, E, C, D)
    xe = cst(xe, ("moe_groups", "experts", "none", "none"))

    # --- expert compute (batched einsum; MXU-shaped) ---
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"],
                   preferred_element_type=jnp.float32)
    hg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * h).astype(xe.dtype)
    h = cst(h, ("moe_groups", "experts", "none", "expert_mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"],
                    preferred_element_type=jnp.float32)
    ye = ye * slot_gate[..., None]
    ye = cst(ye.astype(x.dtype), ("moe_groups", "experts", "none", "none"))

    # --- combine: scatter-add back to token layout ---
    def combine(slot_tok_g, ye_g):
        out = jnp.zeros((Tg + 1, D), ye_g.dtype)
        return out.at[slot_tok_g.reshape(-1)].add(
            ye_g.reshape(-1, D), mode="drop")[:-1]

    out = jax.vmap(combine)(slot_tok, ye)
    out = cst(out, ("moe_groups", "none", "none"))
    return out.reshape(B, S, D), aux * cfg.aux_loss_coef


# ----------------------------------------------------- token-routed EP path
def moe_ffn_ep(p, x, cfg, mesh, constrain=None):
    """Explicit expert parallelism under shard_map (Perf iteration 5).

    Experts shard over "data" (weights fully resident: E over data x d_ff
    over model), tokens move: each device dispatches its tokens to their
    experts' owner ranks with one ``all_to_all`` over "data", computes the
    resident experts, and routes results back.  Traffic scales with tokens
    (vs. per-layer weight gathers that scale with parameters — the llama4
    profile's dominant term, EXPERIMENTS.md §Perf).

    The "pod" axis stays pure data parallelism (experts replicated across
    pods), and "model" ranks replicate the dispatch and psum the d_ff-sharded
    expert output — the same TP contract as the dense MLP.
    """
    import math as _math

    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ax = mesh.axis_names
    ba = tuple(a for a in ("pod", "data") if a in ax)
    n_data = mesh.shape.get("data", 1)
    E_loc = E // n_data
    B_loc = max(B // _math.prod(mesh.shape[a] for a in ba), 1)
    T_loc = B_loc * S
    C = max(4, int(T_loc * k / E * cfg.moe_cap_factor + 0.5))

    def local(x_loc, router, w_gate, w_in, w_out):
        Bl = x_loc.shape[0]
        xt = x_loc.reshape(Bl * S, D)
        T = xt.shape[0]

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, k)
        if k > 1:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        sel1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)
        aux = E * jnp.mean(jnp.mean(sel1, axis=0) * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, ba) if ba else aux

        # per-expert capacity dispatch (local tokens -> E global slots)
        fe = eidx.reshape(T * k)
        order = jnp.argsort(fe)
        se = fe[order]
        counts = jnp.bincount(se, length=E)
        offs = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * k) - offs[se]
        keep = pos < C
        slot = jnp.where(keep, se * C + pos, E * C)
        tok = order // k
        gsel = gate.reshape(T * k)[order]

        slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
            tok.astype(jnp.int32), mode="drop")[:-1]
        slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
            gsel, mode="drop")[:-1]

        xpad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        xe = xpad[slot_tok].reshape(E, C, D)

        # ---- route tokens to expert owners over "data" ----
        if n_data > 1:
            xe = xe.reshape(n_data, E_loc * C, D)
            xe = jax.lax.all_to_all(xe, "data", split_axis=0, concat_axis=0,
                                    tiled=True)          # [n_data, Eloc*C, D]
            xe = xe.reshape(n_data, E_loc, C, D).transpose(1, 0, 2, 3) \
                .reshape(E_loc, n_data * C, D)
        else:
            xe = xe.reshape(E_loc, C, D)

        h = jnp.einsum("ecd,edf->ecf", xe, w_in,
                       preferred_element_type=jnp.float32)
        hg = jnp.einsum("ecd,edf->ecf", xe, w_gate,
                        preferred_element_type=jnp.float32)
        h = (jax.nn.silu(hg) * h).astype(xe.dtype)
        ye = jnp.einsum("ecf,efd->ecd", h, w_out,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        if "model" in ax and mesh.shape.get("model", 1) > 1:
            ye = jax.lax.psum(ye, "model")   # d_ff is model-sharded

        # ---- route results back ----
        if n_data > 1:
            ye = ye.reshape(E_loc, n_data, C, D).transpose(1, 0, 2, 3) \
                .reshape(n_data, E_loc * C, D)
            ye = jax.lax.all_to_all(ye, "data", split_axis=0, concat_axis=0,
                                    tiled=True)
            ye = ye.reshape(E * C, D)
        else:
            ye = ye.reshape(E * C, D)

        ye = ye * slot_gate[:, None].astype(ye.dtype)
        out = jnp.zeros((T + 1, D), ye.dtype).at[slot_tok].add(
            ye, mode="drop")[:-1]
        return out.reshape(Bl, S, D), aux

    bspec = P(ba if len(ba) > 1 else (ba[0] if ba else None), None, None)
    mspec = "model" if "model" in ax else None
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(), P("data" if "data" in ax else None, None,
                              mspec),
                  P("data" if "data" in ax else None, None, mspec),
                  P("data" if "data" in ax else None, mspec, None)),
        out_specs=(bspec, P()),
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    return out, aux * cfg.aux_loss_coef
