"""Shared model machinery: parameter schemas, norms, RoPE, initializers.

Parameters are declared as a *schema* — a pytree of ``ParamSpec`` leaves, each
carrying shape, dtype, logical axis names, and an init rule.  One schema
drives three materializations:

  init_from_schema     real arrays (seeded, fan-in-scaled)
  abstract_from_schema ShapeDtypeStructs (dry-run lowering; no allocation)
  axes_from_schema     logical-axes pytree (models/sharding.py maps these to
                       PartitionSpecs for a given mesh)

Logical axis names: vocab, embed (d_model), heads, kv, head (d_head), mlp
(d_ff), experts, lru, pos, stack (scan dim), none.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical axis names, len == len(shape)
    init: str = "normal"        # normal | zeros | ones | lambda_lru
    dtype: str = "bfloat16"
    fan_in_dims: tuple = ()     # dims whose product scales the normal init
    zero_rows: Optional[tuple] = None  # (dim, start): zero slices >= start
                                       # (padded attention heads)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack(schema, n: int):
    """Prepend a scan (layer-stack) dimension to every spec in ``schema``."""
    def one(spec: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            spec, shape=(n,) + spec.shape, axes=("stack",) + spec.axes,
            zero_rows=(None if spec.zero_rows is None
                       else (spec.zero_rows[0] + 1, spec.zero_rows[1])))

    return jax.tree.map(one, schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        x = jnp.zeros(spec.shape, dtype)
    elif spec.init == "ones":
        x = jnp.ones(spec.shape, dtype)
    elif spec.init == "decay_bias":
        # RWKV-6 decay bias: spread channel half-lives across the spectrum
        n = 1
        for d in spec.shape:
            n *= d
        x = jnp.linspace(-6.0, 1.0, n).reshape(spec.shape).astype(dtype)
    elif spec.init == "lambda_lru":
        # RG-LRU Lambda: a = exp(-8 softplus(lam) * gate) ~ U[0.9, 0.999]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))
        x = lam.astype(dtype)
    else:
        dims = spec.fan_in_dims or tuple(range(max(len(spec.shape) - 1, 0)))
        fan_in = 1
        for i in dims:
            fan_in *= spec.shape[i]
        std = min(0.02, (1.0 / max(fan_in, 1)) ** 0.5)
        x = (jax.random.normal(key, spec.shape, jnp.float32) * std
             ).astype(dtype)
    if spec.zero_rows is not None:
        dim, start = spec.zero_rows
        idx = jnp.arange(spec.shape[dim])
        shape = [1] * len(spec.shape)
        shape[dim] = spec.shape[dim]
        x = jnp.where(idx.reshape(shape) < start, x, jnp.zeros_like(x))
    return x


def init_from_schema(schema, rng) -> dict:
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)])


def abstract_from_schema(schema):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_from_schema(schema):
    return jax.tree.map(lambda s: s.axes, schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------- norms
def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * (1.0 + scale.astype(x.dtype))


def layernorm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def norm_schema(cfg, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("none",), "ones", "float32"),
                "bias": ParamSpec((d,), ("none",), "zeros", "float32")}
    return {"scale": ParamSpec((d,), ("none",), "zeros", "float32")}


def apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float):
    """x: [..., S, n, d_head]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq   # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
