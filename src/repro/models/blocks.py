"""Transformer layer assembly: per-type schemas, caches, and apply fns.

Layer types (config.pattern entries):
  attn  pre-norm GQA attention + FFN (dense MLP or MoE per config)
  rec   pre-norm RG-LRU recurrent block + MLP (recurrentgemma)
  rwkv  RWKV-6 time mix + channel mix

Encoder layers and cross-attention decoder layers (whisper) reuse ``attn``
with ``causal=False`` / an extra cross sub-block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.attention import ModelCtx
from repro.models.common import ParamSpec, apply_norm, norm_schema


# ---------------------------------------------------------------------- MLP
def mlp_schema(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    s = {"w_in": ParamSpec((D, F), ("embed", "mlp"), dtype=pd,
                           fan_in_dims=(0,)),
         "w_out": ParamSpec((F, D), ("mlp", "embed"), dtype=pd,
                            fan_in_dims=(0,))}
    if cfg.mlp == "swiglu":
        s["w_gate"] = ParamSpec((D, F), ("embed", "mlp"), dtype=pd,
                                fan_in_dims=(0,))
    else:
        s["b_in"] = ParamSpec((F,), ("mlp",), "zeros", pd)
        s["b_out"] = ParamSpec((D,), ("none",), "zeros", pd)
    return s


def mlp_apply(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * h
    else:
        h = jax.nn.gelu(h + p["b_in"])
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    if "b_out" in p:
        out = out + p["b_out"]
    return out


# -------------------------------------------------------------------- layer
def layer_schema(cfg, ltype: str, cross: bool = False) -> dict:
    if ltype == "attn":
        s = {"norm1": norm_schema(cfg),
             "attn": attn_mod.attn_schema(cfg),
             "norm2": norm_schema(cfg),
             "ffn": (moe_mod.moe_schema(cfg) if cfg.n_experts
                     else mlp_schema(cfg))}
        if cross:
            s["norm_x"] = norm_schema(cfg)
            s["xattn"] = attn_mod.attn_schema(cfg, cross=True)
        return s
    if ltype == "rec":
        return {"norm1": norm_schema(cfg),
                "rec": rec_mod.rec_schema(cfg),
                "norm2": norm_schema(cfg),
                "ffn": mlp_schema(cfg)}
    if ltype == "rwkv":
        return {"norm1": norm_schema(cfg),
                "time": rec_mod.rwkv_schema(cfg),
                "norm2": norm_schema(cfg)}
    raise ValueError(f"unknown layer type {ltype}")


def layer_cache(cfg, ltype: str, batch: int, s_cache: int, tp: int,
                enc_len: int = 0):
    """Zero cache pytree for one layer (None entries for stateless parts)."""
    if ltype == "attn":
        s_c = min(s_cache, cfg.window) if cfg.window else s_cache
        c = {"self": attn_mod.cache_schema(cfg, batch, s_c, tp)}
        if enc_len:
            c["cross"] = attn_mod.cache_schema(cfg, batch, enc_len, tp)
        return c
    if ltype == "rec":
        return rec_mod.rec_cache(cfg, batch)
    if ltype == "rwkv":
        return rec_mod.rwkv_cache(cfg, batch)
    raise ValueError(ltype)


def apply_layer(p, x, ltype: str, cfg, ctx: ModelCtx, *, cache=None,
                enc_out=None, causal: bool = True, constrain=None):
    """One layer.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    use_rope = cfg.pos == "rope"
    if ltype == "attn":
        h = apply_norm(p["norm1"], x, cfg)
        a, self_cache = attn_mod.attention(
            p["attn"], h, cfg, ctx, causal=causal, window=cfg.window,
            use_rope=use_rope,
            cache=None if cache is None else cache["self"],
            pos=ctx_pos(ctx))
        x = x + a
        new_cache = None if cache is None else {"self": self_cache}
        if "xattn" in p:
            h = apply_norm(p["norm_x"], x, cfg)
            xa, xc = attn_mod.attention(
                p["xattn"], h, cfg, ctx, causal=False, use_rope=False,
                kv_src=enc_out, is_cross=True,
                cache=None if cache is None else cache.get("cross"),
                pos=ctx_pos(ctx))
            x = x + xa
            if new_cache is not None:
                new_cache["cross"] = xc
        h = apply_norm(p["norm2"], x, cfg)
        if cfg.n_experts and cfg.moe_mode == "ep" and ctx.mesh is not None:
            f, aux = moe_mod.moe_ffn_ep(p["ffn"], h, cfg, ctx.mesh,
                                        constrain=constrain)
        elif cfg.n_experts:
            f, aux = moe_mod.moe_ffn(p["ffn"], h, cfg, ctx.n_groups,
                                     constrain=constrain)
        else:
            f = mlp_apply(p["ffn"], h, cfg)
        return x + f, new_cache, aux
    if ltype == "rec":
        h = apply_norm(p["norm1"], x, cfg)
        r, new_cache = rec_mod.rec_apply(p["rec"], h, cfg, cache=cache)
        x = x + r
        h = apply_norm(p["norm2"], x, cfg)
        return x + mlp_apply(p["ffn"], h, cfg), new_cache, aux
    if ltype == "rwkv":
        h = apply_norm(p["norm1"], x, cfg)
        t, tc = rec_mod.rwkv_time_mix(p["time"], h, cfg, cache=cache)
        x = x + t
        h = apply_norm(p["norm2"], x, cfg)
        c, cc = rec_mod.rwkv_channel_mix(p["time"], h, cfg, cache=cache)
        x = x + c
        new_cache = None
        if cache is not None:
            new_cache = {**tc, **cc}
        return x, new_cache, aux
    raise ValueError(ltype)


def ctx_pos(ctx):
    return ctx.pos
