"""Logical-axis -> PartitionSpec mapping (the MaxText-style indirection).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  - batch/token dims shard over ("pod", "data") — pure DP across pods;
  - weights FSDP-shard their d_model dim over "data" only (no cross-pod
    weight all-gathers: the pod axis carries one gradient reduce per step);
  - optimizer state additionally shards over "pod" (ZeRO-1): the update's
    reduce-scatter + the param all-gather together cost one all-reduce;
  - TP dims (heads / d_ff / vocab / experts-or-expert_mlp / lru) over "model".

Every rule is divisibility-guarded: a dim that does not divide its mesh axes
falls back to replication (e.g. n_kv=8 over the 16-way model axis — the
attention layer instead replicates KV per head-group, see attention.py).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_width(mesh) -> int:
    return mesh.shape.get("model", 1)


def n_batch_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def logical_map(cfg, mesh, *, opt: bool = False) -> dict:
    ba = batch_axes(mesh)
    fsdp = ba if opt else (
        ("data",) if ("data" in mesh.axis_names and cfg.fsdp_params) else ())
    ep = cfg.moe_mode == "ep"
    # Token-routed EP (Perf iteration 4): experts shard over "data" and
    # their d_ff over "model", so expert weights are fully resident
    # (2D-sharded, no per-layer FSDP gathers — those dominated the llama4
    # profile at ~2.3TiB/step); the *tokens* move instead: the dispatch
    # buffer's expert dim is data-sharded, so GSPMD lowers dispatch/combine
    # to all-to-all-class collectives whose bytes scale with tokens, not
    # parameters.  Dispatch groups then shard over "pod" only.
    return {
        "vocab": ("model",),
        "embed": fsdp,
        # embedding/head tables: vocab over model is plenty (the TP slice is
        # ~100MB); FSDP-sharding their d_model dim forced a per-step
        # resharding gather (SPMD "involuntary full rematerialization").
        # The optimizer state still ZeRO-shards them.
        "embed_r": ba if opt else (),
        "heads": ("model",),
        "kv": ("model",),
        "kv_eff": ("model",),
        "head": (),
        "mlp": ("model",),
        "lru": ("model",),
        "experts": ("data",) if ep else (),
        "expert_mlp": ("model",),
        "act_batch": ba,
        "moe_groups": (("pod",) if "pod" in mesh.axis_names else ()) if ep
        else ba,
        "stack": (),
        "none": (),
        "pos": (),
    }


def pspec(axes: tuple, shape: tuple, cfg, mesh, *, opt: bool = False) -> P:
    lmap = logical_map(cfg, mesh, opt=opt)
    parts = []
    used = set()
    for dim, name in zip(shape, axes):
        ax = tuple(a for a in lmap.get(name, ()) if a not in used)
        size = math.prod(mesh.shape[a] for a in ax) if ax else 1
        if ax and size > 1 and dim % size == 0:
            parts.append(ax if len(ax) > 1 else ax[0])
            used.update(ax)          # a mesh axis shards at most one dim
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings_for(axes_tree, abstract_tree, cfg, mesh, *, opt: bool = False):
    """NamedSharding pytree for (axes, ShapeDtypeStruct) pytrees."""
    return jax.tree.map(
        lambda a, s: NamedSharding(
            mesh, pspec(a, s.shape, cfg, mesh, opt=opt)),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) for e in x))


def with_shardings(axes_tree, abstract_tree, cfg, mesh, *, opt: bool = False):
    """Attach shardings to ShapeDtypeStructs (dry-run lowering inputs)."""
    sh = shardings_for(axes_tree, abstract_tree, cfg, mesh, opt=opt)
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        abstract_tree, sh)


def make_constrain(cfg, mesh):
    """constrain(tensor, logical_axes) -> tensor with sharding constraint."""
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return lambda t, a: t

    def constrain(t, axes):
        spec = pspec(axes, t.shape, cfg, mesh)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return constrain
