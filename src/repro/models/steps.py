"""Step factories: train / prefill / decode, with shardings — the single
entry point used by the trainer, the server, the dry-run, and the tests.

``build_cell(cfg, shape_name, mesh)`` returns (fn, abstract_args) for one
(architecture x input-shape) grid cell: ``jax.jit(fn).lower(*abstract_args)``
is exactly the multi-pod dry-run. The abstract args carry NamedShardings, so
in_shardings are inferred; out_shardings are constrained where it matters
(params/opt state keep their layout across steps).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models import model as model_mod
from repro.models import sharding as shd
from repro.models.attention import ModelCtx
from repro.optim import AdamW


# -------------------------------------------------------------------- loss
def xent_loss(logits, labels, mask, constrain):
    """Mean next-token cross-entropy over masked positions.

    logits stay vocab-sharded: max/logsumexp reduce over the sharded axis
    (one tiny all-reduce), take_along_axis gathers the label logit — no
    [B, S, V] replication.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    lab = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    per_tok = (lse - lab) * mask
    return per_tok.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg, ctx, batch, constrain):
    tokens = batch["tokens"]                      # [B, S+1]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    logits, _, aux, n_prefix = model_mod.forward(
        params, cfg, ctx, inp, patches=batch.get("patches"),
        frames=batch.get("frames"), constrain=constrain)
    if n_prefix:
        logits = logits[:, n_prefix:]             # loss only on text tokens
    mask = jnp.ones(labels.shape, jnp.float32)
    loss = xent_loss(logits, labels, mask, constrain) + aux
    return loss


# ------------------------------------------------------------------- train
def build_train_step(cfg: ModelConfig, mesh, optimizer: AdamW):
    constrain = shd.make_constrain(cfg, mesh)
    ctx = ModelCtx(tp=shd.tp_width(mesh), n_groups=shd.n_batch_shards(mesh),
                   mode="train", mesh=mesh)
    nm = cfg.n_micro
    gdt = jnp.dtype(cfg.grad_dtype)
    p_axes = model_mod.param_axes(cfg)

    def grad_shard(tree):
        """Pin the grad accumulator to the ZeRO (opt-state) layout: the
        per-microbatch cross-pod gradient reduction then lowers to a
        reduce-scatter into the shard instead of a full all-reduce into a
        replicated buffer (Perf iteration 6)."""
        if mesh is None:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda t, a: jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, shd.pspec(a, t.shape, cfg, mesh,
                                                 opt=True))),
            tree, p_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, str) for e in x))

    def train_step(params, opt_state, batch, step):
        if nm > 1:
            mbatch = jax.tree.map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                batch)

            def micro(acc, mb):
                mb = jax.tree.map(
                    lambda x: constrain(x, ("none", "act_batch") + ("none",)
                                        * (x.ndim - 2)), mb)
                l, g = jax.value_and_grad(loss_fn)(params, cfg, ctx, mb,
                                                   constrain)
                acc_g, acc_l = acc
                acc_g = grad_shard(jax.tree.map(
                    lambda a, b: a + b.astype(gdt), acc_g, g))
                return (acc_g, acc_l + l), None

            zeros = grad_shard(jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params))
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0)), mbatch)
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = loss / nm
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, ctx,
                                                      batch, constrain)
        params, opt_state, om = optimizer.update(grads, opt_state, params,
                                                 step)
        return params, opt_state, {"loss": loss, **om}

    return train_step


# ----------------------------------------------------------------- serving
def build_prefill_step(cfg: ModelConfig, mesh, s_cache: int):
    constrain = shd.make_constrain(cfg, mesh)
    tp = shd.tp_width(mesh)
    ctx = ModelCtx(tp=tp, n_groups=shd.n_batch_shards(mesh), mode="prefill",
                   mesh=mesh)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        cache = model_mod.init_cache(cfg, tokens.shape[0], s_cache, tp)
        logits, cache, _, _ = model_mod.forward(
            params, cfg, ctx, tokens, patches=batch.get("patches"),
            frames=batch.get("frames"), cache=cache, constrain=constrain)
        return cache, logits[:, -1]

    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh):
    constrain = shd.make_constrain(cfg, mesh)
    tp = shd.tp_width(mesh)
    ng = shd.n_batch_shards(mesh)

    def decode_step(params, cache, tokens, pos):
        ctx = ModelCtx(tp=tp, n_groups=ng, mode="decode", pos=pos,
                       mesh=mesh)
        frames = None
        logits, cache, _, _ = model_mod.forward(
            params, cfg, ctx, tokens, frames=frames, cache=cache,
            constrain=constrain)
        return logits[:, -1], cache

    return decode_step


# ----------------------------------------------------------- abstract args
def abstract_batch(cfg: ModelConfig, shape: ShapeSpec, *, train: bool):
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    extra = 1 if train else 0
    batch = {}
    s_text = S
    if cfg.n_patches:
        s_text = S - cfg.n_patches
        batch["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, D),
                                                jnp.bfloat16)
    if cfg.n_frames:
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, D),
                                               jnp.bfloat16)
    batch["tokens"] = jax.ShapeDtypeStruct((B, s_text + extra), jnp.int32)
    return batch


def batch_axes_tree(batch):
    return {k: ("act_batch",) + ("none",) * (v.ndim - 1)
            for k, v in batch.items()}


def abstract_cache(cfg: ModelConfig, batch: int, s_cache: int, tp: int):
    return jax.eval_shape(
        partial(model_mod.init_cache, cfg, batch, s_cache, tp))


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               optimizer: AdamW = None):
    """(fn, abstract_args) for one dry-run grid cell."""
    shape = SHAPES[shape_name]
    tp = shd.tp_width(mesh)
    p_abs = model_mod.abstract_params(cfg)
    p_axes = model_mod.param_axes(cfg)
    p_in = shd.with_shardings(p_axes, p_abs, cfg, mesh)

    if shape.kind == "train":
        opt = optimizer or AdamW.from_config(cfg)
        o_abs = jax.eval_shape(opt.init, p_abs)
        o_in = shd.with_shardings(opt.state_axes(p_axes), o_abs, cfg, mesh,
                                  opt=True)
        b_abs = abstract_batch(cfg, shape, train=True)
        b_in = shd.with_shardings(batch_axes_tree(b_abs), b_abs, cfg, mesh)
        step0 = jax.ShapeDtypeStruct((), jnp.int32)
        return build_train_step(cfg, mesh, opt), (p_in, o_in, b_in, step0)

    if shape.kind == "prefill":
        b_abs = abstract_batch(cfg, shape, train=False)
        b_in = shd.with_shardings(batch_axes_tree(b_abs), b_abs, cfg, mesh)
        return build_prefill_step(cfg, mesh, shape.seq_len), (p_in, b_in)

    # decode: one new token against an S-deep cache
    B = shape.global_batch
    s_c = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    c_abs = abstract_cache(cfg, B, s_c, tp)
    c_axes = model_mod.cache_axes(cfg, tp)
    c_in = shd.with_shardings(c_axes, c_abs, cfg, mesh)
    t_in = shd.with_shardings(
        {"t": ("act_batch", "none")},
        {"t": jax.ShapeDtypeStruct((B, 1), jnp.int32)}, cfg, mesh)["t"]
    pos0 = jax.ShapeDtypeStruct((), jnp.int32)
    return build_decode_step(cfg, mesh), (p_in, c_in, t_in, pos0)
