"""Recurrent blocks: RG-LRU (Griffin / recurrentgemma) and RWKV-6 time/
channel mix.  Both route their recurrences through repro.kernels.ops (Pallas
on TPU, jnp oracle elsewhere), so the model code is backend-agnostic.

Decode caches:
  rec : {"h": [B, W] f32 LRU state, "conv": [B, cw-1, W] conv tail}
  rwkv: {"state": [B, H, Dh, Dh] f32 wkv state,
         "prev_t"/"prev_c": [B, D] token-shift tails}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ParamSpec, rmsnorm


# ------------------------------------------------------------------- RG-LRU
def rec_schema(cfg) -> dict:
    D, W, cw = cfg.d_model, cfg.d_lru, cfg.conv_width
    pd = cfg.param_dtype
    return {
        "w_x": ParamSpec((D, W), ("embed", "lru"), dtype=pd,
                         fan_in_dims=(0,)),
        "w_g": ParamSpec((D, W), ("embed", "lru"), dtype=pd,
                         fan_in_dims=(0,)),
        "w_a": ParamSpec((D, W), ("embed", "lru"), dtype=pd,
                         fan_in_dims=(0,)),
        "lam": ParamSpec((W,), ("lru",), "lambda_lru", "float32"),
        "conv_w": ParamSpec((cw, W), ("none", "lru"), dtype=pd,
                            fan_in_dims=(0,)),
        "conv_b": ParamSpec((W,), ("lru",), "zeros", pd),
        "w_o": ParamSpec((W, D), ("lru", "embed"), dtype=pd,
                         fan_in_dims=(0,)),
    }


def rec_cache(cfg, batch: int) -> dict:
    return {"h": jnp.zeros((batch, cfg.d_lru), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_lru),
                              jnp.bfloat16)}


def rec_apply(p, x, cfg, cache=None):
    """x: normed input [B, S, D] -> (out [B, S, D], new_cache)."""
    B, S, D = x.shape
    cw = cfg.conv_width
    xx = jnp.einsum("bsd,dw->bsw", x, p["w_x"])

    tail = (cache["conv"].astype(xx.dtype) if cache is not None
            else jnp.zeros((B, cw - 1, xx.shape[-1]), xx.dtype))
    ext = jnp.concatenate([tail, xx], axis=1)            # [B, S+cw-1, W]
    conv = sum(ext[:, i:i + S] * p["conv_w"][i] for i in range(cw))
    conv = conv + p["conv_b"]

    gate_a = jax.nn.sigmoid(
        jnp.einsum("bsd,dw->bsw", x, p["w_a"]).astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * gate_a    # [B, S, W] f32

    h0 = cache["h"] if cache is not None else None
    h, h_last = ops.rglru(log_a, conv, h0=h0)

    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_g"]))
    out = jnp.einsum("bsw,wd->bsd", (h * g).astype(x.dtype), p["w_o"])
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_last,
                     "conv": ext[:, -(cw - 1):].astype(cache["conv"].dtype)}
    return out, new_cache


# -------------------------------------------------------------------- RWKV6
def rwkv_schema(cfg) -> dict:
    D, F, H, Dh = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.d_head
    pd = cfg.param_dtype
    proj = dict(dtype=pd, fan_in_dims=(0,))
    return {
        "mu": ParamSpec((5, D), ("none", "none"), "zeros", "float32"),
        "w_r": ParamSpec((D, H, Dh), ("embed", "heads", "head"), **proj),
        "w_k": ParamSpec((D, H, Dh), ("embed", "heads", "head"), **proj),
        "w_v": ParamSpec((D, H, Dh), ("embed", "heads", "head"), **proj),
        "w_g": ParamSpec((D, H, Dh), ("embed", "heads", "head"), **proj),
        "w_w": ParamSpec((D, H, Dh), ("embed", "heads", "head"), **proj),
        "w0": ParamSpec((H, Dh), ("heads", "head"), "decay_bias", "float32"),
        "u": ParamSpec((H, Dh), ("heads", "head"), dtype="float32"),
        "ln_x": ParamSpec((H, Dh), ("heads", "head"), "zeros", "float32"),
        "w_o": ParamSpec((H, Dh, D), ("heads", "head", "embed"), dtype=pd,
                         fan_in_dims=(0, 1)),
        "mu_c": ParamSpec((2, D), ("none", "none"), "zeros", "float32"),
        "w_cin": ParamSpec((D, F), ("embed", "mlp"), **proj),
        "w_cr": ParamSpec((D, D), ("embed", "none"), **proj),
        "w_cout": ParamSpec((F, D), ("mlp", "embed"), **proj),
    }


def rwkv_cache(cfg, batch: int) -> dict:
    H, Dh, D = cfg.n_heads, cfg.d_head, cfg.d_model
    return {"state": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
            "prev_t": jnp.zeros((batch, D), jnp.bfloat16),
            "prev_c": jnp.zeros((batch, D), jnp.bfloat16)}


def _shift(x, prev):
    """Token shift: x_{t-1} (prev carries across calls)."""
    B, S, D = x.shape
    first = (prev.astype(x.dtype)[:, None] if prev is not None
             else jnp.zeros((B, 1, D), x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def rwkv_time_mix(p, x, cfg, cache=None):
    """x: normed [B,S,D] -> (out, (state_last, prev_last))."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    xs = _shift(x, cache["prev_t"] if cache is not None else None)

    def lerp(i):
        return x + (xs - x) * p["mu"][i].astype(x.dtype)

    r = jnp.einsum("bsd,dhk->bhsk", lerp(0), p["w_r"])
    k = jnp.einsum("bsd,dhk->bhsk", lerp(1), p["w_k"])
    v = jnp.einsum("bsd,dhk->bhsk", lerp(2), p["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", lerp(3), p["w_g"]))
    wexp = jnp.einsum("bsd,dhk->bhsk", lerp(4), p["w_w"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"][None, :, None] + wexp))   # (0,1) decay

    s0 = cache["state"] if cache is not None else None
    out, s_last = ops.rwkv6(r, k, v, w, p["u"], s0=s0)      # [B,H,S,Dh]
    out = out.transpose(0, 2, 1, 3)                          # [B,S,H,Dh]
    out = rmsnorm(out, jnp.broadcast_to(p["ln_x"], out.shape[-2:]),
                  cfg.norm_eps) * g.astype(out.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["w_o"])
    new = None
    if cache is not None:
        new = {"state": s_last, "prev_t": x[:, -1].astype(jnp.bfloat16)}
    return y, new


def rwkv_channel_mix(p, x, cfg, cache=None):
    xs = _shift(x, cache["prev_c"] if cache is not None else None)
    mk = x + (xs - x) * p["mu_c"][0].astype(x.dtype)
    mr = x + (xs - x) * p["mu_c"][1].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", mk, p["w_cin"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_cout"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mr, p["w_cr"])) * kv
    new = None
    if cache is not None:
        new = {"prev_c": x[:, -1].astype(jnp.bfloat16)}
    return out, new
