"""The composable LM: schema, forward (train / prefill / decode), caches.

One decoder family covers all 10 assigned architectures; whisper adds an
encoder stack + cross-attention, llava a patch-embedding prefix (both
frontends are stubs per the brief — ``input_specs`` provides precomputed
embeddings).

Layers run as ``lax.scan`` over stacked per-stage parameters (HLO size and
compile time stay O(1) in depth); each scan body is ``jax.checkpoint``-ed in
training so activations rematerialize in the backward pass.  NOTE for the
roofline: XLA's ``cost_analysis`` counts a scan body once — the analytic
accounting in ``analysis/roofline.py`` owns flop totals (DESIGN.md section 7).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.attention import ModelCtx
from repro.models.common import (ParamSpec, abstract_from_schema,
                                 apply_norm, axes_from_schema,
                                 init_from_schema, norm_schema, stack)


# ------------------------------------------------------------------- schema
def model_schema(cfg) -> dict:
    D, V = cfg.d_model, cfg.vocab
    pd = cfg.param_dtype
    s = {"embed": {"tok": ParamSpec((V, D), ("vocab", "embed_r"), dtype=pd,
                                    fan_in_dims=(1,))}}
    if cfg.pos == "learned":
        s["embed"]["pos"] = ParamSpec((cfg.max_pos, D), ("none", "embed_r"),
                                      dtype=pd, fan_in_dims=(1,))
    s["stages"] = [
        {str(i): stack(blocks.layer_schema(cfg, t, cross=bool(cfg.enc_layers)),
                       n)
         for i, t in enumerate(pattern)}
        for pattern, n in cfg.stage_split()
    ]
    s["final_norm"] = norm_schema(cfg)
    if cfg.enc_layers:
        s["enc"] = {
            "stages": [{"0": stack(blocks.layer_schema(cfg, "attn"),
                                   cfg.enc_layers)}],
            "final_norm": norm_schema(cfg),
        }
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((V, D), ("vocab", "embed_r"), dtype=pd,
                              fan_in_dims=(1,))
    return s


def init_params(cfg, rng):
    return init_from_schema(model_schema(cfg), rng)


def abstract_params(cfg):
    return abstract_from_schema(model_schema(cfg))


def param_axes(cfg):
    return axes_from_schema(model_schema(cfg))


# ------------------------------------------------------------------- caches
def init_cache(cfg, batch: int, s_cache: int, tp: int) -> list:
    """Decode cache: list of per-stage pytrees, stacked on the scan dim."""
    enc_len = cfg.n_frames if cfg.enc_layers else 0
    out = []
    for pattern, n in cfg.stage_split():
        stage = {}
        for i, t in enumerate(pattern):
            one = blocks.layer_cache(cfg, t, batch, s_cache, tp,
                                     enc_len=enc_len)
            stage[str(i)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)
        out.append(stage)
    return out


def cache_axes(cfg, tp: int) -> list:
    """Logical axes for the cache pytree (sharding rules)."""
    def axes_for(leaf_path_type, arr):
        nd = arr.ndim
        if nd == 5:       # stacked attn kv: [R, B, G, S, Dh]
            return ("stack", "act_batch", "kv_eff", "none", "none")
        if nd == 4:       # rwkv state: [R, B, H, Dh] ... or [R,B,cw-1,W]
            return ("stack", "act_batch", "none", "none")
        if nd == 3:       # rec h / prev: [R, B, W|D]
            return ("stack", "act_batch", "none")
        return ("stack",) * nd

    enc_len = cfg.n_frames if cfg.enc_layers else 0
    cache = init_cache(cfg, 1, 2, tp)
    out = []
    for stage in cache:
        out.append(jax.tree.map(lambda a: axes_for(None, a), stage))
    return out


def rwkv_state_axes():
    return ("stack", "act_batch", "heads", "none", "none")


# ------------------------------------------------------------------ forward
def _run_stages(params_stages, cfg, ctx, x, stages_cfg, *, cache=None,
                enc_out=None, causal=True, constrain=None, remat=False):
    """Scan each stage; returns (x, new_cache_list, aux)."""
    aux = jnp.float32(0.0)
    new_cache = []
    for si, (pattern, n) in enumerate(stages_cfg):
        p_stage = params_stages[si]
        c_stage = None if cache is None else cache[si]

        def body(carry, xs, _pattern=pattern):
            xx, aa = carry
            p_l, c_l = xs
            out_c = {}
            for i, t in enumerate(_pattern):
                ci = None if c_l is None else c_l[str(i)]
                xx, nc, a = blocks.apply_layer(
                    p_l[str(i)], xx, t, cfg, ctx, cache=ci, enc_out=enc_out,
                    causal=causal, constrain=constrain)
                if nc is not None:
                    out_c[str(i)] = nc
                aa = aa + a
            return (xx, aa), (out_c if out_c else None)

        fn = jax.checkpoint(body) if remat else body
        (x, aux), cs = jax.lax.scan(fn, (x, aux), (p_stage, c_stage),
                                    length=n)
        new_cache.append(cs)
    return x, new_cache, aux


def _embed(params, cfg, tokens, constrain):
    x = params["embed"]["tok"][tokens]          # gather over sharded vocab
    return constrain(x.astype(jnp.dtype(cfg.param_dtype)),
                     ("act_batch", "none", "none"))


def _positions(cfg, params, start, length):
    pos = start + jnp.arange(length)
    return params["embed"]["pos"][jnp.clip(pos, 0, cfg.max_pos - 1)]


def logits_fn(params, cfg, x, constrain):
    x = apply_norm(params["final_norm"], x, cfg)
    table = params["head"] if "head" in params else params["embed"]["tok"]
    # Keep logits in the param dtype: an f32 einsum here makes the *loss
    # cotangent* f32, and that dtype propagates backward through every
    # layer — 2x bytes on every TP all-reduce, FSDP re-gather and gradient
    # (measured in EXPERIMENTS.md §Perf iteration 1).  The cross-entropy
    # itself upcasts to f32 internally (steps.xent_loss), and its backward
    # casts the cotangent back down at this boundary.
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return constrain(logits, ("act_batch", "none", "vocab"))


def encode(params, cfg, ctx, frames, constrain):
    """Whisper encoder over stub frame embeddings [B, S_enc, D]."""
    x = frames.astype(jnp.dtype(cfg.param_dtype))
    if cfg.pos == "learned":
        x = x + _positions(cfg, params, 0, x.shape[1])
    x = constrain(x, ("act_batch", "none", "none"))
    x, _, _ = _run_stages(
        [params["enc"]["stages"][0]], cfg, ctx, x,
        [(("attn",), cfg.enc_layers)], causal=False, constrain=constrain,
        remat=(ctx.mode == "train" and cfg.remat))
    return apply_norm(params["enc"]["final_norm"], x, cfg)


def forward(params, cfg, ctx: ModelCtx, tokens, *, patches=None, frames=None,
            cache=None, constrain=None):
    """Unified forward.

    train/prefill: tokens [B, S]; llava prepends ``patches`` [B, P, D];
    whisper runs the encoder on ``frames`` [B, F, D] first.
    decode: tokens [B, 1] with ``cache`` + ``ctx.pos``; returns new cache.

    Returns (logits, new_cache, aux_loss).
    """
    constrain = constrain or (lambda t, a: t)
    enc_out = None
    if cfg.enc_layers:
        if frames is not None:
            enc_out = encode(params, cfg, ctx, frames, constrain)
        # decode: cross K/V live in the cache; enc_out unused.

    x = _embed(params, cfg, tokens, constrain)
    n_prefix = 0
    if patches is not None:
        pre = patches.astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
        n_prefix = pre.shape[1]
    if cfg.pos == "learned":
        start = ctx.pos if ctx.mode == "decode" else 0
        x = x + _positions(cfg, params, start, x.shape[1])

    x, new_cache, aux = _run_stages(
        params["stages"], cfg, ctx, x, cfg.stage_split(), cache=cache,
        enc_out=enc_out, causal=True, constrain=constrain,
        remat=(ctx.mode == "train" and cfg.remat))
    logits = logits_fn(params, cfg, x, constrain)
    return logits, new_cache, aux, n_prefix
