"""GQA attention: RoPE / qk-norm / QKV-bias / sliding-window flavors, with a
memory-bounded blocked softmax ("jnp-flash") for long sequences.

TPU adaptation notes (DESIGN.md):
  - Heads are the TP unit.  KV heads are *replicated* up to the model-axis
    width when n_kv < TP (``cfg.kv_eff``) — each rank keeps its head-group's
    copy, the standard TP resolution — so every einsum below contracts
    locally under the production mesh.
  - Long sequences use a static python loop over query blocks and a
    ``lax.scan`` over key blocks with an online softmax: O(bq*bk) live
    memory, causal/window block skipping is *static* (the loop bounds), so
    sliding-window prefill is linear in sequence length.
  - The Pallas flash kernel (kernels/flash_attention.py) implements the same
    schedule for the TPU serving path; this jnp version is the differentiable
    reference the kernel is tested against, and what CPU smoke tests run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ParamSpec

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Per-call context: mesh widths + execution mode.  ``pos`` may hold a
    traced decode position; the ctx never crosses a jit boundary itself."""
    tp: int = 1                 # model-axis width (head sharding / kv_eff)
    n_groups: int = 1           # batch shards (MoE dispatch groups)
    mode: str = "train"         # train | prefill | decode
    pos: object = None          # decode position (scalar int32 tracer)
    mesh: object = None         # jax Mesh (shard_map EP dispatch); None =
                                # single-device / constraint-only paths


# ------------------------------------------------------------------- schema
def attn_schema(cfg, cross: bool = False) -> dict:
    D, H, kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    pd = cfg.param_dtype
    zr = (1, cfg.n_heads_raw) if cfg.n_heads_raw < H else None
    s = {
        "wq": ParamSpec((D, H, Dh), ("embed", "heads", "head"), dtype=pd,
                        fan_in_dims=(0,), zero_rows=zr),
        "wk": ParamSpec((D, kv, Dh), ("embed", "kv", "head"), dtype=pd,
                        fan_in_dims=(0,)),
        "wv": ParamSpec((D, kv, Dh), ("embed", "kv", "head"), dtype=pd,
                        fan_in_dims=(0,)),
        "wo": ParamSpec((H, Dh, D), ("heads", "head", "embed"), dtype=pd,
                        fan_in_dims=(0, 1),
                        zero_rows=(0, cfg.n_heads_raw) if zr else None),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = ParamSpec((H, Dh), ("heads", "head"), "zeros", pd)
        s["bk"] = ParamSpec((kv, Dh), ("kv", "head"), "zeros", pd)
        s["bv"] = ParamSpec((kv, Dh), ("kv", "head"), "zeros", pd)
    if cfg.qk_norm and not cross:
        s["q_norm"] = ParamSpec((Dh,), ("none",), "zeros", "float32")
        s["k_norm"] = ParamSpec((Dh,), ("none",), "zeros", "float32")
    return s


def cache_schema(cfg, batch: int, s_cache: int, tp: int) -> dict:
    G = cfg.kv_eff(tp)
    Dh = cfg.d_head
    shp = (batch, G, s_cache, Dh)
    return {"k": jnp.zeros(shp, jnp.bfloat16),
            "v": jnp.zeros(shp, jnp.bfloat16)}


# ------------------------------------------------------------- inner softmax
def _dense(q, k, v, mask):
    """q: [B,G,R,Sq,Dh]; k,v: [B,G,Sk,Dh]; mask broadcastable [Sq,Sk]."""
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q, k,
                   preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _flash(q, k, v, *, causal: bool, window: Optional[int],
           block_q: int = 512, block_k: int = 512):
    """Blocked online-softmax attention, linear memory; static block skip."""
    B, G, R, Sq, Dh = q.shape
    Sk = k.shape[2]
    scale = Dh ** -0.5
    if Sq * Sk <= 2048 * 2048 or Sq % block_q or Sk % block_k:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        return _dense(q * scale, k, v, mask)

    bq, bk = block_q, block_k
    out = []
    for qi in range(Sq // bq):
        qb = q[:, :, :, qi * bq:(qi + 1) * bq] * scale
        q0 = qi * bq + (Sk - Sq)
        k_end = min(Sk, q0 + bq) if causal else Sk
        k_end = -(-k_end // bk) * bk
        k_start = 0
        if window is not None:
            k_start = max(0, (q0 - window + 1) // bk * bk)
        n_blk = (k_end - k_start) // bk
        ks = k[:, :, k_start:k_end].reshape(B, G, n_blk, bk, Dh)
        vs = v[:, :, k_start:k_end].reshape(B, G, n_blk, bk, Dh)
        starts = k_start + jnp.arange(n_blk) * bk

        def body(carry, xs):
            m, l, acc = carry
            kb, vb, st = xs
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32)
            kpos = st + jnp.arange(bk)[None, :]
            qpos = q0 + jnp.arange(bq)[:, None]
            msk = jnp.ones((bq, bk), bool)
            if causal:
                msk &= kpos <= qpos
            if window is not None:
                msk &= kpos > qpos - window
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, R, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, bq), jnp.float32)
        a0 = jnp.zeros((B, G, R, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(ks, 2, 0), jnp.moveaxis(vs, 2, 0), starts))
        out.append((acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype))
    return jnp.concatenate(out, axis=3)


# ------------------------------------------------------------------ the op
def _group(q, kv_eff):
    B, S, H, Dh = q.shape
    rep = H // kv_eff
    return q.reshape(B, S, kv_eff, rep, Dh).transpose(0, 2, 3, 1, 4)


def _repeat_kv(k, kv_eff):
    B, S, kv, Dh = k.shape
    if kv == kv_eff:
        return k.transpose(0, 2, 1, 3)
    return jnp.repeat(k.transpose(0, 2, 1, 3), kv_eff // kv, axis=1)


def attention(p, x, cfg, ctx: ModelCtx, *, causal: bool = True,
              window: Optional[int] = None, kv_src=None, use_rope=True,
              cache=None, pos=None, is_cross: bool = False):
    """Returns (out [B,S,D], new_cache).

    is_cross: cross-attention.  Train: K/V projected from ``kv_src``
    (encoder output).  Prefill: projected from ``kv_src`` and written to
    ``cache``.  Decode: read from ``cache`` (kv_src absent), cache unchanged.
    cache:  {"k","v"} [B, kv_eff, S_c, Dh]; self-decode updates slot pos
    (rolling when S_c == window).
    """
    B, S, D = x.shape
    H, kv, Dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    G = cfg.kv_eff(ctx.tp)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if "q_norm" in p:
        q = common.rmsnorm(q, p["q_norm"], cfg.norm_eps)

    if is_cross:
        if kv_src is not None:
            kc = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
            vc = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
            kg, vg = _repeat_kv(kc, G), _repeat_kv(vc, G)
            o = _flash(_group(q, G), kg, vg, causal=False, window=None)
            new_cache = None
            if cache is not None:        # prefill: persist encoder K/V
                new_cache = {"k": kg.astype(cache["k"].dtype),
                             "v": vg.astype(cache["v"].dtype)}
        else:                            # decode: cached encoder K/V
            qg = _group(q, G)
            o = _dense(qg * Dh ** -0.5, cache["k"].astype(qg.dtype),
                       cache["v"].astype(qg.dtype), jnp.bool_(True))
            new_cache = cache
        B_, G_, R_, S_, Dh_ = o.shape
        o = o.transpose(0, 3, 1, 2, 4).reshape(B_, S_, G_ * R_, Dh_)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_cache

    if cache is not None and ctx.mode == "decode":
        # self-attention decode: project this token, append to cache
        knew = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        vnew = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bk" in p:
            knew, vnew = knew + p["bk"], vnew + p["bv"]
        if "k_norm" in p:
            knew = common.rmsnorm(knew, p["k_norm"], cfg.norm_eps)
        if use_rope:
            pp = jnp.full((B, S), pos, jnp.int32)
            q = common.rope(q, pp, cfg.rope_theta)
            knew = common.rope(knew, pp, cfg.rope_theta)
        knew = _repeat_kv(knew, G)[:, :, 0]          # [B, G, Dh]
        vnew = _repeat_kv(vnew, G)[:, :, 0]
        S_c = cache["k"].shape[2]
        slot = pos % S_c if (window is not None and S_c == window) \
            else jnp.minimum(pos, S_c - 1)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], knew[:, :, None].astype(cache["k"].dtype),
            (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], vnew[:, :, None].astype(cache["v"].dtype),
            (0, 0, slot, 0))
        valid = (jnp.arange(S_c) <= pos) | (pos >= S_c)
        qg = _group(q, G)                             # [B,G,R,1,Dh]
        o = _dense(qg * Dh ** -0.5, ck.astype(qg.dtype),
                   cv.astype(qg.dtype), valid[None, :])
        new_cache = {"k": ck, "v": cv}
    elif cache is not None:
        # prefill: compute K/V for the whole prompt, fill cache
        o, kr, vr = _self_attn(p, x, q, cfg, G, causal, window, use_rope)
        S_c = cache["k"].shape[2]
        if window is not None and S_c == window:
            # rolling cache: absolute position p lives at slot p % S_c
            # (matches the decode write rule); keep the last S_c keys.
            if S >= S_c:
                base = S - S_c
                take = base + ((jnp.arange(S_c) - base) % S_c)
                ck = kr[:, :, take].astype(cache["k"].dtype)
                cv = vr[:, :, take].astype(cache["v"].dtype)
            else:         # partially-filled rolling cache: slot p = p
                take = jnp.clip(jnp.arange(S_c), 0, S - 1)
                keep = (jnp.arange(S_c) < S)[None, None, :, None]
                ck = jnp.where(keep, kr[:, :, take], 0).astype(
                    cache["k"].dtype)
                cv = jnp.where(keep, vr[:, :, take], 0).astype(
                    cache["v"].dtype)
        else:
            pad = S_c - S
            ck = jnp.pad(kr, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
                cache["k"].dtype)
            cv = jnp.pad(vr, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
                cache["v"].dtype)
        new_cache = {"k": ck, "v": cv}
    else:
        o, _, _ = _self_attn(p, x, q, cfg, G, causal, window, use_rope)
        new_cache = None

    B_, G_, R_, S_, Dh_ = o.shape
    o = o.transpose(0, 3, 1, 2, 4).reshape(B_, S_, G_ * R_, Dh_)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_cache


def _self_attn(p, x, q, cfg, G, causal, window, use_rope):
    B, S, _ = x.shape
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if "k_norm" in p:
        k = common.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        pp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = common.rope(q, pp, cfg.rope_theta)
        k = common.rope(k, pp, cfg.rope_theta)
    kg, vg = _repeat_kv(k, G), _repeat_kv(v, G)
    o = _flash(_group(q, G), kg, vg, causal=causal, window=window)
    return o, kg, vg
