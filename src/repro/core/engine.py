"""The wave executor: generation -> validation -> commit -> retry, under scan.

One *wave* simulates all T threads each running one transaction concurrently
(DESIGN.md section 2).  The executor is a single jitted ``lax.scan`` whose
carry is the whole engine state (store, retry buffer, metrics), so a full
benchmark datapoint (thousands of waves) is one XLA program.  Every
shared-state touch inside the scan body goes through the ``backend.N_OPS``-op
kernel-backend surface (core/backend.py): the probe family's whole
claim+probe+verdict+bump wave runs as the single ``wave_commit`` megakernel
(``claim_probe`` remains the unfused ``fuse_wave=False`` chain) and the cost
model's same-row contention counts as ``segment_count``, so the compiled wave
carries no per-wave sort and no duplicated claim-table traffic on either
backend.

Throughput model
----------------
Each lane accrues simulated microseconds from the CostModel: committed
transactions cost their full execution; aborted optimistic transactions waste
their full execution (validation is at the end); aborted eager mechanisms
(2PL, SwissTM write conflicts, Adaptive's pessimistic records) cut losses at
the first conflicting op.  Reported throughput = commits / (sum(lane_time)/T),
i.e. committed transactions per simulated wall-microsecond with T threads.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims
from repro.core import types as t
from repro.core.cc import VALIDATORS, ValidationResult
from repro.core.types import (EngineConfig, EngineState, StoreState, TxnBatch,
                              engine_state_init)


class Workload(Protocol):
    """What the engine needs from a workload (YCSB, TPC-C, ...)."""
    n_records: int
    n_groups: int
    n_cols: int
    n_rings: int
    n_txn_types: int
    slots: int

    def init_store(self, track_values: bool,
                   mv_depth: int = 0) -> StoreState: ...

    def gen(self, rng: jax.Array, wave: jax.Array, lanes: int,
            ring_tails: jax.Array) -> tuple[TxnBatch, jax.Array]: ...


def _init_store(workload: Workload, cfg: EngineConfig) -> StoreState:
    """Workload store init honoring the config's MV-ring depth.  The
    mv_depth keyword is only passed when a ring is requested, so legacy
    workload objects without the parameter keep working."""
    if cfg.mv_depth:
        return workload.init_store(cfg.track_values, mv_depth=cfg.mv_depth)
    return workload.init_store(cfg.track_values)


def _kappa(cfg: EngineConfig, res: ValidationResult) -> jax.Array:
    c = cfg.cost
    if cfg.cc == t.CC_OCC or cfg.cc == t.CC_AUTOGRAN:
        return jnp.float32(c.kappa_occ)
    if cfg.cc == t.CC_TICTOC:
        return jnp.float32(c.kappa_tictoc)
    if cfg.cc == t.CC_2PL:
        return jnp.float32(c.kappa_2pl)
    if cfg.cc == t.CC_SWISS:
        return jnp.float32(c.kappa_swiss)
    if cfg.cc == t.CC_ADAPTIVE:
        return (c.kappa_adaptive_opt
                + res.pess_frac * (c.kappa_adaptive_pess
                                   - c.kappa_adaptive_opt))
    if cfg.cc == t.CC_MVCC:
        return jnp.float32(c.kappa_mvcc)
    if cfg.cc == t.CC_MVOCC:
        return jnp.float32(c.kappa_mvocc)
    raise ValueError(f"unknown cc {cfg.cc}")


def _optimistic(cfg: EngineConfig) -> bool:
    """Mechanisms paying commit-time read validation (c_validate per read).
    MVCC is excluded: snapshot reads validate nothing (its chain-walk cost
    sits in kappa_mvcc instead)."""
    return cfg.cc in (t.CC_OCC, t.CC_TICTOC, t.CC_SWISS, t.CC_AUTOGRAN,
                      t.CC_ADAPTIVE, t.CC_MVOCC)


def apply_values(values: jax.Array, batch: TxnBatch, commit: jax.Array,
                 prio: jax.Array,
                 slot_of: Optional[jax.Array] = None) -> jax.Array:
    """Install committed writes in wave-serialization (ascending prio) order.

    Exactness over speed: lanes are applied sequentially in priority order and
    a lane's ops in slot order, so the result matches a serial execution of
    the committed transactions — this is what the serializability property
    tests check the CC mechanisms against.  Only used when track_values=True
    (correctness tests / semantic demos), never in the throughput benchmarks.

    ``slot_of`` (int32[n_records] or None) is the multi-version hook: when
    given, writes land in ``values[key, slot_of[key], col]`` — the MV ring's
    freshly-claimed slots (core/mvstore.install_values) — instead of the flat
    ``values[key, col]``.  One implementation defines the serial-replay
    discipline for both stores, so the value oracle comparing them cannot be
    broken by one side drifting.
    """
    order = jnp.argsort(prio)
    K = batch.slots

    def lane_step(vals, i):
        ok = commit[i]
        for k in range(K):
            key, col = batch.op_key[i, k], batch.op_col[i, k]
            kind, v = batch.op_kind[i, k], batch.op_val[i, k]
            kk = jnp.where(ok & (kind == t.WRITE) & (key >= 0), key,
                           t.OOB_KEY)
            ka = jnp.where(ok & (kind == t.ADD) & (key >= 0), key, t.OOB_KEY)
            if slot_of is None:
                vals = vals.at[kk, col].set(v, mode="drop")
                vals = vals.at[ka, col].add(v, mode="drop")
            else:
                hn = slot_of[jnp.maximum(key, 0)]
                vals = vals.at[kk, hn, col].set(v, mode="drop")
                vals = vals.at[ka, hn, col].add(v, mode="drop")
        return vals, None

    values, _ = jax.lax.scan(lane_step, values, order)
    return values


def _lane_cost(cfg: EngineConfig, batch: TxnBatch, commit: jax.Array,
               res: ValidationResult) -> tuple[jax.Array, jax.Array]:
    """Per-lane simulated microseconds for one wave (DESIGN.md section 4).

    Returns ``(lane_dt, has_write)``: committed lanes pay execution +
    install contention, aborted optimistic lanes waste their full
    execution, eager mechanisms cut losses at the first conflict.
    ``has_write`` is the one definition of "read-only lane" (no live write
    ops) shared by the MV-OCC validation-cost exemption and the ro
    metrics.  Shared verbatim by the closed-loop and open-loop wave steps
    — one cost model, two traffic models.
    """
    c = cfg.cost
    kappa = _kappa(cfg, res)
    n_ops = batch.n_ops.astype(jnp.float32)
    n_reads = (batch.is_read() & batch.live()).sum(axis=1).astype(
        jnp.float32)
    has_write = (batch.is_write() & batch.live()).any(axis=1)
    t_exec = c.c_txn + n_ops * c.c_op * kappa
    if cfg.max_extent > 1:
        # Interval reads: a scan op touches ``extent`` rows, so both its
        # execution work and its commit-time validation (iterate_validate
        # walks the whole interval) scale with the extent.  Gated on the
        # static max_extent so point configs trace the exact pre-scan
        # cost graph (bit-identity guard in tests).
        rd = batch.is_read() & batch.live()
        ext = batch.extent().astype(jnp.float32)
        n_reads = jnp.where(rd, ext, 0.0).sum(axis=1)
        t_exec = t_exec + jnp.where(rd, ext - 1.0, 0.0).sum(axis=1) \
            * c.c_op * kappa
    if _optimistic(cfg):
        val_reads = n_reads
        if cfg.cc == t.CC_MVOCC:
            # MV-OCC exempts read-only transactions from commit-time
            # validation (they serialize at their snapshot — see
            # cc/mvocc.py), so they don't pay for it either.
            val_reads = jnp.where(has_write, n_reads, 0.0)
        t_exec = t_exec + val_reads * c.c_validate
    # Install contention: committed writers of the same *row* serialize
    # on its cacheline (lock + version + data write): quadratic chain in
    # the number of same-row committers.  Mechanism-agnostic, and
    # granularity-independent — a row's version words share a cacheline
    # whether there are one or two of them (the paper's "fine-grained
    # timestamps show no measurable slowdown").  Same-row counts route
    # through the backend's segment_count op like every shared-state
    # access, so the pallas wave program carries no XLA sort.
    be = kb.resolve(cfg)
    wmask = batch.is_write() & batch.live() & commit[:, None]
    n_w = be.segment_count(batch.op_key,
                           jnp.zeros_like(batch.op_group), 1, wmask)
    # Concurrent readers of the line interleave their probes with the
    # writer chain, stretching each hold (the 8-socket effect that bends
    # every optimistic curve past ~96 threads in the paper's Fig 3a).
    rmask = batch.is_read() & batch.live()
    n_r = be.segment_count(batch.op_key,
                           jnp.zeros_like(batch.op_group), 1, rmask)
    install_pen = (0.5 * jnp.float32(c.lam_w)
                   * jnp.maximum(n_w - 1.0, 0.0)
                   * (1.0 + 0.15 * n_r)).sum(axis=1)
    t_commit = t_exec + res.ext_penalty + install_pen
    if res.eager:
        done = jnp.minimum(res.first_conflict.astype(jnp.float32), n_ops)
        t_abort = c.c_txn + done * c.c_op * kappa + c.c_abort + c.backoff
    else:
        t_abort = t_exec + c.c_abort + c.backoff
    return jnp.where(commit, t_commit, t_abort), has_write


def _conflict_histogram(cfg: EngineConfig, hits: jax.Array, peak: jax.Array,
                        batch: TxnBatch, res: ValidationResult
                        ) -> tuple[jax.Array, jax.Array]:
    """Hot-record accounting (cfg.track_conflicts): per-cell conflicting-op
    totals via the backend's ``commit_install`` +1 scatter, and the
    per-wave same-cell conflict peak via ``segment_count`` maxed into the
    table through ``ts_install_max`` — everything stays on the
    ``backend.N_OPS``-op surface, so both backends agree bit-for-bit.  Cells are always fine
    resolution (claims are scattered fine regardless of granularity)."""
    be = kb.resolve(cfg)
    conf = res.conflict_op & batch.live()
    hits = be.commit_install(hits, batch.op_key, batch.op_group, conf)
    n_conf = be.segment_count(batch.op_key, batch.op_group,
                              cfg.n_groups, conf)
    peak = be.ts_install_max(peak, batch.op_key, batch.op_group,
                             n_conf.astype(jnp.uint32), conf)
    return hits, peak


def make_wave_step(cfg: EngineConfig, workload: Workload,
                   active: Optional[jax.Array] = None) -> Callable:
    """Build the scan body for one wave.

    ``active`` (bool[T] or None) marks live lanes: the sweep runner pads every
    grid point to a common lane count and masks the padding here, so grids of
    different thread counts share one compiled program.  Inactive lanes carry
    empty transactions (no ops, no claims) and are excluded from every metric.
    ``None`` (the single-run path) means all lanes are active.
    """
    validator = VALIDATORS[cfg.cc]
    c = cfg.cost
    T = cfg.lanes

    def wave_step(state: EngineState, _):
        rng, rng_gen, rng_perm = jax.random.split(state.rng, 3)
        wave = state.wave

        fresh, tails = workload.gen(rng_gen, wave, T, state.store.ring_tails)
        # Lanes with an aborted transaction retry it; the rest draw fresh.
        sel = state.pending_live
        batch = jax.tree.map(
            lambda p, f: jnp.where(
                sel.reshape((T,) + (1,) * (p.ndim - 1)), p, f),
            state.pending, fresh)
        age = jnp.where(sel, state.age, 0)
        if active is not None:
            # Padding lanes run empty transactions: no ops => no claims, no
            # conflicts, and the accounting below masks them out.
            batch = dataclasses.replace(
                batch,
                op_key=jnp.where(active[:, None], batch.op_key, -1),
                op_kind=jnp.where(active[:, None], batch.op_kind, t.NOP),
                n_ops=jnp.where(active, batch.n_ops, 0))
        store = dataclasses.replace(state.store, ring_tails=tails)

        perm = jax.random.permutation(rng_perm, T).astype(jnp.uint32)
        prio = claims.prio16(age, perm, use_age=(cfg.cc == t.CC_SWISS))

        with jax.named_scope("repro:validate"):
            store, res = validator(store, batch, prio, wave, cfg)
        commit = res.commit

        if cfg.track_values:
            vals = apply_values(store.values, batch, commit, prio)
            store = dataclasses.replace(store, values=vals)

        # ---- cost model ----
        with jax.named_scope("repro:cost"):
            lane_dt, has_write = _lane_cost(cfg, batch, commit, res)

        # ---- metrics + retry bookkeeping ----
        if active is None:
            committed, aborted = commit, ~commit
        else:
            committed, aborted = commit & active, ~commit & active
            lane_dt = jnp.where(active, lane_dt, 0.0)
        causes_wave = t.cause_counts(res.lane_cause(), aborted)
        if cfg.track_conflicts:
            hits, peak = _conflict_histogram(
                cfg, state.conflict_hits, state.conflict_peak, batch, res)
        else:
            hits, peak = state.conflict_hits, state.conflict_peak
        commits_by_type = state.commits_by_type.at[batch.txn_type].add(
            committed.astype(state.commits_by_type.dtype))
        # Read-only lanes: the MV mechanisms' headline is that these never
        # abort.  Padding lanes are empty and therefore "read-only", but
        # committed/aborted already mask them out.
        ro = ~has_write
        new_state = EngineState(
            rng=rng,
            wave=wave + 1,
            store=store,
            pending=batch,
            pending_live=aborted,
            age=jnp.where(commit, 0, age + 1),
            lane_time=state.lane_time + lane_dt,
            commits=state.commits
                    + committed.sum().astype(state.commits.dtype),
            aborts=state.aborts + aborted.sum().astype(state.aborts.dtype),
            commits_by_type=commits_by_type,
            wasted_time=state.wasted_time
                        + jnp.where(committed, 0.0, lane_dt).sum(),
            ext_events=state.ext_events + res.ext_count,
            ro_commits=state.ro_commits
                       + (committed & ro).sum().astype(state.ro_commits.dtype),
            ro_aborts=state.ro_aborts
                      + (aborted & ro).sum().astype(state.ro_aborts.dtype),
            abort_causes=state.abort_causes + causes_wave,
            conflict_hits=hits,
            conflict_peak=peak,
            ol=state.ol,
        )
        ys = (committed.sum().astype(jnp.int32),
              aborted.sum().astype(jnp.int32),
              causes_wave, lane_dt.sum())
        return new_state, ys

    return wave_step


def make_open_wave_step(cfg: EngineConfig, workload: Workload,
                        active: Optional[jax.Array] = None,
                        trace: bool = False) -> Callable:
    """Build the scan body for one OPEN-LOOP wave (DESIGN.md section 11).

    Instead of the closed-loop one-transaction-per-lane retry buffer,
    lanes are filled each wave from the admission queue
    (core/admission.py): Poisson arrivals enqueue first (overflow drops
    counted), the queue then fills up to T lanes FIFO, the wave runs, and
    aborted lanes re-enqueue the SAME transaction with incarnation + 1 —
    or drop (counted) past ``cfg.max_incarnations``.  Committed lanes
    record time-to-commit = commit_wave - admit_wave + 1 waves into the
    per-class latency histogram.  ``active`` is the sweep runner's padded
    live-lane prefix mask, as in make_wave_step.

    ``trace=True`` adds per-wave lane forensics to the scan output
    (txn_id, incarnation, got, admit_wave, op_key, op_kind, commit) — the
    conservation-oracle and incarnation-property tests replay them
    (tests/test_open_loop.py); benchmarks leave it off.
    """
    from repro.core import admission
    from repro.workloads.arrivals import poisson_offered
    validator = VALIDATORS[cfg.cc]
    T = cfg.lanes
    n_active = T if active is None else active.sum().astype(jnp.int32)

    def wave_step(state: EngineState, _):
        rng, rng_gen, rng_perm, rng_arr = jax.random.split(state.rng, 4)
        wave = state.wave
        ol = state.ol

        # ---- arrivals: the wave's fresh transactions, Poisson-thinned ---
        fresh, tails = workload.gen(rng_gen, wave, T, state.store.ring_tails)
        if active is not None:
            fresh = dataclasses.replace(
                fresh,
                op_key=jnp.where(active[:, None], fresh.op_key, -1),
                op_kind=jnp.where(active[:, None], fresh.op_kind, t.NOP),
                n_ops=jnp.where(active, fresh.n_ops, 0))
        offered = poisson_offered(rng_arr, cfg.arrival_rate, T)
        offered = jnp.minimum(offered, n_active)
        arr_mask = jnp.arange(T, dtype=jnp.int32) < offered
        ids = state.ol.next_id + jnp.arange(T, dtype=jnp.int32)
        queue, n_adm, n_ovf = admission.enqueue(
            ol.queue, fresh, jnp.full((T,), wave, jnp.int32),
            jnp.zeros((T,), jnp.int32), ids, arr_mask)

        # ---- admit: fill the lane grid FIFO from the queue -------------
        queue, batch, admit_w, incarn, txn_id, got = admission.dequeue(
            queue, T, n_active)
        store = dataclasses.replace(state.store, ring_tails=tails)

        perm = jax.random.permutation(rng_perm, T).astype(jnp.uint32)
        prio = claims.prio16(incarn, perm, use_age=(cfg.cc == t.CC_SWISS))

        with jax.named_scope("repro:validate"):
            store, res = validator(store, batch, prio, wave, cfg)
        commit = res.commit & got

        if cfg.track_values:
            vals = apply_values(store.values, batch, commit, prio)
            store = dataclasses.replace(store, values=vals)

        # ---- cost model (shared with the closed loop) ------------------
        with jax.named_scope("repro:cost"):
            lane_dt, has_write = _lane_cost(cfg, batch, commit, res)
        lane_dt = jnp.where(got, lane_dt, 0.0)

        # ---- retry incarnations / latency accounting -------------------
        aborted = got & ~commit
        retry = aborted & (incarn < cfg.max_incarnations)
        inc_drop = aborted & ~retry
        # Abort-cause attribution: the TERMINAL abort of a transaction at
        # its incarnation cap is the one that ejects it from the system —
        # reclassified CAUSE_INC_CAP (it dominates every validation
        # cause), so cause[CAUSE_INC_CAP] == inc_drops exactly and the
        # per-cause counts still sum to total aborts.
        lane_cause = jnp.where(inc_drop, jnp.int32(t.CAUSE_INC_CAP),
                               res.lane_cause())
        causes_wave = t.cause_counts(lane_cause, aborted)
        if cfg.track_conflicts:
            hits, peak = _conflict_histogram(
                cfg, state.conflict_hits, state.conflict_peak, batch, res)
        else:
            hits, peak = state.conflict_hits, state.conflict_peak
        # Arrivals enqueued before the dequeue freed these lanes, so the
        # re-enqueue can never overflow (module invariant); reenq_drops
        # stays 0 and the conservation oracle asserts it.
        queue, _, n_re_ovf = admission.enqueue(
            queue, batch, admit_w, incarn + 1, txn_id, retry)
        ttc = wave.astype(jnp.int32) - admit_w + 1
        new_ol = admission.record_commits(
            dataclasses.replace(
                ol, queue=queue,
                next_id=ol.next_id + offered,
                offered=ol.offered + offered,
                admitted=ol.admitted + n_adm,
                arrival_drops=ol.arrival_drops + n_ovf,
                inc_drops=ol.inc_drops
                          + inc_drop.sum().astype(jnp.int32),
                reenq_drops=ol.reenq_drops + n_re_ovf),
            batch.txn_type, ttc, commit)

        # ---- metrics ---------------------------------------------------
        committed = commit
        commits_by_type = state.commits_by_type.at[batch.txn_type].add(
            committed.astype(state.commits_by_type.dtype))
        ro = ~has_write
        new_state = EngineState(
            rng=rng,
            wave=wave + 1,
            store=store,
            pending=state.pending,           # unused in open loop: the
            pending_live=state.pending_live,  # queue owns every retry
            age=state.age,
            lane_time=state.lane_time + lane_dt,
            commits=state.commits
                    + committed.sum().astype(state.commits.dtype),
            aborts=state.aborts + aborted.sum().astype(state.aborts.dtype),
            commits_by_type=commits_by_type,
            wasted_time=state.wasted_time
                        + jnp.where(committed, 0.0, lane_dt).sum(),
            ext_events=state.ext_events + res.ext_count,
            ro_commits=state.ro_commits
                       + (committed & ro).sum().astype(state.ro_commits.dtype),
            ro_aborts=state.ro_aborts
                      + (aborted & ro).sum().astype(state.ro_aborts.dtype),
            abort_causes=state.abort_causes + causes_wave,
            conflict_hits=hits,
            conflict_peak=peak,
            ol=new_ol,
        )
        ys = (committed.sum().astype(jnp.int32),
              aborted.sum().astype(jnp.int32),
              offered, n_adm, n_ovf,
              inc_drop.sum().astype(jnp.int32),
              causes_wave, lane_dt.sum())
        if trace:
            ys = ys + ((txn_id, incarn, got, admit_w, batch.op_key,
                        batch.op_kind, commit),)
        return new_state, ys

    return wave_step


@dataclasses.dataclass
class SimResult:
    commits: int
    aborts: int
    abort_rate: float
    throughput: float          # committed txns per simulated microsecond
    sim_time_us: float
    commits_by_type: list
    ext_events: int
    lanes: int
    waves: int
    ro_commits: int = 0        # read-only transaction commits/aborts: the
    ro_aborts: int = 0         #   multi-version headline metric (snapshot
                               #   readers never abort — DESIGN.md section 9)
    ro_abort_rate: float = 0.0
    abort_causes: Optional[list] = None  # int[N_ABORT_CAUSES], ordered by
                               #   types.CAUSE_* code; sums to `aborts`
                               #   (the conservation invariant)
    per_wave_commits: Optional[jax.Array] = None
    per_wave_aborts: Optional[jax.Array] = None
    per_wave_causes: Optional[jax.Array] = None  # int32[waves, N_ABORT_CAUSES]
    per_wave_us: Optional[jax.Array] = None      # f32[waves] simulated us
    hot_records: Optional[list] = None  # track_conflicts top-k:
                               #   (record, group, total_hits, peak_per_wave)
    final_state: Optional[EngineState] = None
    # ---- open-loop front-end (cfg.open_loop; DESIGN.md section 11) ----
    open_loop: bool = False
    goodput: float = 0.0       # unique committed txns per simulated us (an
                               #   admitted txn commits at most once)
    offered: int = 0           # Poisson arrivals offered (post lane cap)
    admitted: int = 0          # arrivals accepted into the admission queue
    arrival_drops: int = 0     # arrivals lost to a full queue
    inc_drops: int = 0         # txns dropped past max_incarnations
    reenq_drops: int = 0       # re-enqueue overflow (structurally 0)
    queued_final: int = 0      # entries still queued at the end of the run
    p50_ttc: Optional[list] = None  # per-txn-class time-to-commit (waves)
    p99_ttc: Optional[list] = None
    lat_hist: Optional[jax.Array] = None  # int32[n_txn_types, lat_bins]
    trace: Optional[tuple] = None  # per-wave lane forensics (run(trace=True))


@dataclasses.dataclass
class SweepPoint:
    """One datapoint of a sweep grid (a SimResult plus its coordinates)."""
    cc: int
    granularity: int
    lanes: int
    seed: int
    commits: int
    aborts: int
    abort_rate: float
    throughput: float          # committed txns per simulated microsecond
    sim_time_us: float
    ext_events: int
    waves: int
    ro_commits: int = 0
    ro_aborts: int = 0
    ro_abort_rate: float = 0.0
    # ---- open-loop front-end (cfg.open_loop) ----
    open_loop: bool = False
    goodput: float = 0.0
    offered: int = 0
    admitted: int = 0
    arrival_drops: int = 0
    inc_drops: int = 0
    queued_final: int = 0
    p50_ttc: Optional[list] = None  # per-txn-class time-to-commit (waves)
    p99_ttc: Optional[list] = None
    abort_causes: Optional[list] = None  # int[N_ABORT_CAUSES] (types.CAUSE_*)
    # Per-wave timeline (sweep(..., per_wave=True); analysis/trace.py):
    per_wave_commits: Optional[jax.Array] = None
    per_wave_aborts: Optional[jax.Array] = None
    per_wave_causes: Optional[jax.Array] = None
    per_wave_us: Optional[jax.Array] = None


def lane_buckets(lane_counts: Sequence[int],
                 ratio: Optional[float] = 2.0) -> list[list[int]]:
    """Group lane counts so padding waste stays bounded.

    Every count in a bucket is padded to the bucket's max, so the masked-work
    waste for a count T is bucket_max / T.  Greedy ascending grouping keeps
    that factor <= ``ratio``: a grid mixing 16 and 128 lanes splits into
    [16], [128] instead of padding the 16-lane point 8x.  ``ratio=None``
    disables bucketing (one bucket padded to the global max — the legacy
    behavior)."""
    uniq = sorted(set(lane_counts))
    if ratio is None:
        return [uniq]
    buckets: list[list[int]] = []
    for T in uniq:
        if buckets and T <= ratio * buckets[-1][0]:
            buckets[-1].append(T)
        else:
            buckets.append([T])
    return buckets


#: Compiled-sweep memo: {static grid spec: (jitted program, workload)}.
#: The workload strong-ref pins the id() in the key; insertion-ordered
#: FIFO eviction bounds the executables (and workloads) kept alive.
_SWEEP_PROGRAMS: dict = {}
_SWEEP_PROGRAMS_CAP = 8


def sweep(cfg: EngineConfig, workload: Workload, n_waves: int, *,
          ccs: Sequence[int], grans: Sequence[int] = (0, 1),
          lane_counts: Sequence[int] = (16, 64, 128),
          seeds: Sequence[int] = (0,),
          lane_bucket_ratio: Optional[float] = 2.0,
          per_wave: bool = False) -> list[SweepPoint]:
    """Run an entire benchmark grid as ONE jitted XLA program.

    The grid is ccs x grans x lane_counts x seeds.  (cc, granularity) pairs
    select different validator code, so they are unrolled as branches inside
    the single jitted function; the (lane_count, seed) axis is *vmapped* in
    **lane buckets** (``lane_buckets``): counts within a factor of
    ``lane_bucket_ratio`` of each other share one vmapped program padded to
    the bucket max, with a per-point active mask silencing the padding (see
    make_wave_step).  Bucketing bounds the masked-work waste — a grid mixing
    16 and 128 lanes no longer pads everything 8x to 128 — while still
    compiling once and dispatching once per sweep (ROADMAP: one-XLA-program
    benchmark grids).

    A point with lane_count == its bucket's max is bit-identical to
    ``run(replace(cfg, cc=cc, granularity=g, lanes=T), workload, n_waves,
    seed)`` — padding only changes points below their bucket max (their PRNG
    stream spans the padded lane count).  Tested in tests/test_sweep.py.
    """
    store = _init_store(workload, cfg)
    buckets = lane_buckets(lane_counts, lane_bucket_ratio)
    combos = [(cc, g) for g in grans for cc in ccs]

    # One (lane_grid, seed_grid) pair per bucket, vmapped per (combo, bucket).
    grids = tuple(
        (jnp.repeat(jnp.asarray(b, jnp.int32), len(seeds)),
         jnp.tile(jnp.asarray(seeds, jnp.uint32), len(b)))
        for b in buckets)

    # Everything the jitted program closes over, as a memo key: re-sweeping
    # the SAME grid in one process must re-execute the cached executable,
    # not re-trace — that is what makes the benchmarks' shared
    # warm-then-time helper (benchmarks/common.py) actually exclude
    # compile time from the timed call.  Keyed on workload IDENTITY (the
    # value holds a strong ref so the id can never be recycled); the
    # launch layer's lru-cached workload maker gives identical grid specs
    # the same object.
    memo_key = (id(workload), dataclasses.astuple(cfg), n_waves,
                tuple(combos), tuple(tuple(b) for b in buckets),
                tuple(seeds), per_wave)
    cached = _SWEEP_PROGRAMS.get(memo_key)
    if cached is not None:
        go = cached[0]
        raw = jax.device_get(go(grids))
        return _sweep_points(cfg, raw, combos, buckets, lane_counts, seeds,
                             n_waves, per_wave)

    def point_fn(ccfg, T_pad):
        mk = make_open_wave_step if ccfg.open_loop else make_wave_step

        def point(n_lanes, seed):
            active = jnp.arange(T_pad, dtype=jnp.int32) < n_lanes
            state0 = engine_state_init(ccfg, jax.random.PRNGKey(seed), store)
            step = mk(ccfg, workload, active=active)
            state, ys = jax.lax.scan(step, state0, None, length=n_waves)
            ol = state.ol
            out = (state.commits, state.aborts, state.lane_time.sum(),
                   state.ext_events, state.ro_commits, state.ro_aborts,
                   ol.offered, ol.admitted, ol.arrival_drops, ol.inc_drops,
                   ol.queue.size, ol.lat_hist, state.abort_causes)
            if per_wave:
                # Per-wave timeline (commits, aborts, cause deltas, sim
                # us) for the trace exporter; the cause/us slots sit at
                # different ys indices in the two traffic models.
                ci, ui = (6, 7) if ccfg.open_loop else (2, 3)
                out = out + (ys[0], ys[1], ys[ci], ys[ui])
            return out
        return point

    @jax.jit
    def go(grids):
        out = []
        for cc, g in combos:
            per_bucket = []
            for b, (lane_grid, seed_grid) in zip(buckets, grids):
                ccfg = dataclasses.replace(cfg, cc=cc, granularity=g,
                                           lanes=max(b))
                per_bucket.append(
                    jax.vmap(point_fn(ccfg, max(b)))(lane_grid, seed_grid))
            out.append(per_bucket)
        return out

    _SWEEP_PROGRAMS[memo_key] = (go, workload)
    while len(_SWEEP_PROGRAMS) > _SWEEP_PROGRAMS_CAP:
        _SWEEP_PROGRAMS.pop(next(iter(_SWEEP_PROGRAMS)))
    raw = jax.device_get(go(grids))
    return _sweep_points(cfg, raw, combos, buckets, lane_counts, seeds,
                         n_waves, per_wave)


def _sweep_points(cfg, raw, combos, buckets, lane_counts, seeds, n_waves,
                  per_wave) -> list:
    """Reassemble sweep()'s raw per-bucket outputs into SweepPoints in
    grid order (shared by the traced and memo-hit paths)."""
    # Index (T, seed) -> (bucket, position) to reassemble rows in grid order.
    where = {}
    for bi, b in enumerate(buckets):
        for i, (T, sd) in enumerate((T, sd) for T in b for sd in seeds):
            where[(T, sd)] = (bi, i)
    points = []
    for (cc, g), per_bucket in zip(combos, raw):
        for T in lane_counts:
            for sd in seeds:
                bi, i = where[(T, sd)]
                (commits, aborts, lane_time, ext, roc, roa,
                 off, adm, adrop, idrop, qsz, lhist,
                 acauses, *pw) = per_bucket[bi]
                c, a = int(commits[i]), int(aborts[i])
                rc, ra = int(roc[i]), int(roa[i])
                wall = float(lane_time[i]) / T
                extra = {}
                if cfg.open_loop:
                    from repro.core.admission import ttc_percentiles
                    p50, p99 = ttc_percentiles(lhist[i])
                    extra = dict(
                        open_loop=True, goodput=c / max(wall, 1e-9),
                        offered=int(off[i]), admitted=int(adm[i]),
                        arrival_drops=int(adrop[i]),
                        inc_drops=int(idrop[i]), queued_final=int(qsz[i]),
                        p50_ttc=p50, p99_ttc=p99)
                if per_wave:
                    extra.update(per_wave_commits=pw[0][i],
                                 per_wave_aborts=pw[1][i],
                                 per_wave_causes=pw[2][i],
                                 per_wave_us=pw[3][i])
                points.append(SweepPoint(
                    cc=cc, granularity=g, lanes=T, seed=sd, commits=c,
                    aborts=a, abort_rate=a / max(c + a, 1),
                    throughput=c / max(wall, 1e-9), sim_time_us=wall,
                    ext_events=int(ext[i]), waves=n_waves,
                    ro_commits=rc, ro_aborts=ra,
                    ro_abort_rate=ra / max(rc + ra, 1),
                    abort_causes=[int(x) for x in acauses[i]], **extra))
    return points


def run(cfg: EngineConfig, workload: Workload, n_waves: int,
        seed: int = 0, keep_state: bool = False,
        trace: bool = False) -> SimResult:
    """Run a simulation: jit(scan(wave_step)) and summarize.

    cfg.open_loop selects the open-loop wave step (Poisson arrivals +
    admission queue + retry incarnations); ``trace=True`` (open loop only)
    returns per-wave lane forensics in ``SimResult.trace`` for the
    conservation-oracle tests.
    """
    rng = jax.random.PRNGKey(seed)
    store = _init_store(workload, cfg)
    state0 = engine_state_init(cfg, rng, store)
    if cfg.open_loop:
        step = make_open_wave_step(cfg, workload, trace=trace)
    else:
        step = make_wave_step(cfg, workload)

    @jax.jit
    def go(state0):
        return jax.lax.scan(step, state0, None, length=n_waves)

    state, ys = go(state0)
    cw = ys[0]
    ci, ui = (6, 7) if cfg.open_loop else (2, 3)
    commits = int(state.commits)
    aborts = int(state.aborts)
    ro_c, ro_a = int(state.ro_commits), int(state.ro_aborts)
    total_time = float(state.lane_time.sum())
    wall = total_time / cfg.lanes if cfg.lanes else 0.0
    extra = {}
    if cfg.open_loop:
        from repro.core.admission import ttc_percentiles
        ol = state.ol
        p50, p99 = ttc_percentiles(ol.lat_hist)
        extra = dict(
            open_loop=True,
            goodput=commits / max(wall, 1e-9),
            offered=int(ol.offered), admitted=int(ol.admitted),
            arrival_drops=int(ol.arrival_drops),
            inc_drops=int(ol.inc_drops),
            reenq_drops=int(ol.reenq_drops),
            queued_final=int(ol.queue.size),
            p50_ttc=p50, p99_ttc=p99,
            lat_hist=jax.device_get(ol.lat_hist),
            trace=jax.device_get(ys[8]) if trace else None)
    hot = None
    if cfg.track_conflicts:
        hot = hot_records(state, k=16)
    return SimResult(
        commits=commits,
        aborts=aborts,
        abort_rate=aborts / max(commits + aborts, 1),
        throughput=commits / max(wall, 1e-9),
        sim_time_us=wall,
        commits_by_type=[int(x) for x in state.commits_by_type],
        ext_events=int(state.ext_events),
        lanes=cfg.lanes,
        waves=n_waves,
        ro_commits=ro_c,
        ro_aborts=ro_a,
        ro_abort_rate=ro_a / max(ro_c + ro_a, 1),
        abort_causes=[int(x) for x in state.abort_causes],
        per_wave_commits=cw,
        per_wave_aborts=ys[1],
        per_wave_causes=ys[ci],
        per_wave_us=ys[ui],
        hot_records=hot,
        final_state=state if keep_state else None,
        **extra,
    )


def hot_records(state: EngineState, k: int = 16) -> list:
    """Top-k hot cells of the conflict histogram (track_conflicts runs):
    ``(record, group, total_conflict_hits, peak_same_wave_conflicts)``
    sorted by total hits, zero-hit cells omitted."""
    import numpy as np
    hits = np.asarray(jax.device_get(state.conflict_hits))
    peak = np.asarray(jax.device_get(state.conflict_peak))
    G = hits.shape[1]
    flat = hits.ravel()
    order = np.argsort(flat, kind="stable")[::-1][:k]
    return [(int(i // G), int(i % G), int(flat[i]), int(peak.ravel()[i]))
            for i in order if flat[i] > 0]
