"""Core pytree types for the wave-based transaction engine.

The engine executes transactions in *waves*: a wave is a batch of T lanes, each
lane running one transaction in lockstep (the TPU analogue of T hardware
threads).  Everything is a fixed-shape array so the whole simulator jits and
scans.

Operation encoding
------------------
Each transaction is a fixed-length list of K operation slots:

  op_key   int32[T, K]   flat record id (see workloads), -1 or masked = unused
  op_group int32[T, K]   conflict-unit (timestamp) group within the record.
                         THIS is where timestamp granularity enters: coarse
                         granularity maps every column to group 0, fine
                         granularity maps disjoint column sets to distinct
                         groups (the paper's contribution).
  op_col   int32[T, K]   column index (only used when values are tracked)
  op_kind  int32[T, K]   NOP / READ / WRITE / ADD (ADD = blind commutative
                         increment; in the write set for versioning purposes
                         but never aborts against other ADDs)
  op_val   f32[T, K]     value or delta for WRITE/ADD
  op_extent int32[T, K]  interval width: the op covers records
                         [op_key, op_key + op_extent).  extent 1 is a point
                         op (every pre-scan call site); extent > 1 is a
                         range SCAN, validated at commit through the
                         iterate_validate backend op so concurrently
                         claimed rows inside the interval abort the scan
                         with CAUSE_PHANTOM (DESIGN.md section 13)

Priorities
----------
`prio` is a uint32 per lane; *lower wins*.  The in-wave serialization order is
ascending priority.  Contention managers (SwissTM) encode age in high bits so
starved transactions win claims.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Operation kinds.
NOP: int = 0
READ: int = 1
WRITE: int = 2
ADD: int = 3  # blind commutative increment (STO-style commutative update)

# Concurrency-control mechanism ids (used by lax.switch in the engine).
CC_OCC: int = 0
CC_TICTOC: int = 1
CC_2PL: int = 2
CC_SWISS: int = 3
CC_ADAPTIVE: int = 4
CC_AUTOGRAN: int = 5
CC_MVCC: int = 6     # multi-version snapshot reads + first-committer-wins
CC_MVOCC: int = 7    # multi-version OCC: read-set validation on the chain

#: Mechanisms that need the multi-version ring (EngineConfig.mv_depth >= 1).
MV_CCS = (CC_MVCC, CC_MVOCC)

CC_NAMES = {
    CC_OCC: "occ",
    CC_TICTOC: "tictoc",
    CC_2PL: "2pl",
    CC_SWISS: "swisstm",
    CC_ADAPTIVE: "adaptive",
    CC_AUTOGRAN: "autogran",
    CC_MVCC: "mvcc",
    CC_MVOCC: "mvocc",
}
CC_IDS = {v: k for k, v in CC_NAMES.items()}

# Abort-cause taxonomy (DESIGN.md "Observability").  Every abort is
# attributed to exactly ONE cause so per-cause counts sum to total aborts
# at every layer (local sweep, distributed stats, open loop).  Codes are
# ordered by precedence: when a lane carries several conflicting ops the
# lane's cause is the MINIMUM over its per-op cause codes, so the most
# structural cause (capacity drop) dominates the most incidental one
# (read validation).  CAUSE_NONE is the min-identity for clean ops and
# sits one past the histogram so scatter-adds of clean lanes drop.
CAUSE_INC_CAP: int = 0         # open loop: terminal abort at the
                               #   incarnation cap (txn leaves the system)
CAUSE_CAPACITY: int = 1        # distributed: route-buffer capacity drop
CAUSE_STALE_SNAPSHOT: int = 2  # MV ring reclamation: the reader's aged
                               #   snapshot outlived the version ring
CAUSE_LOCK_WOUND: int = 3      # eager lock conflict (2PL, SwissTM w-w,
                               #   Adaptive's pessimistic path)
CAUSE_WW: int = 4              # claim / write-write conflict
                               #   (first-committer-wins)
CAUSE_READ_VAL: int = 5        # commit-time read-validation failure
                               #   (the paper's false-conflict channel)
CAUSE_PHANTOM: int = 6         # interval (scan) validation failure: a
                               #   concurrent writer claimed a record inside
                               #   a committed scan's [key, key+extent)
                               #   interval (iterate_validate; DESIGN.md
                               #   section 13)
N_ABORT_CAUSES: int = 7
CAUSE_NONE: int = N_ABORT_CAUSES  # sentinel: op not conflicting

CAUSE_NAMES = {
    CAUSE_INC_CAP: "inc_cap",
    CAUSE_CAPACITY: "capacity",
    CAUSE_STALE_SNAPSHOT: "stale_snapshot",
    CAUSE_LOCK_WOUND: "lock_wound",
    CAUSE_WW: "ww",
    CAUSE_READ_VAL: "read_val",
    CAUSE_PHANTOM: "phantom",
}


def cause_counts(lane_cause: jax.Array, aborted: jax.Array) -> jax.Array:
    """Histogram lane cause codes over aborted lanes -> int32[N_ABORT_CAUSES].

    Non-aborted lanes are steered to CAUSE_NONE, which is out of bounds
    for the histogram and drops on scatter — the counts therefore sum to
    exactly ``aborted.sum()`` as long as every aborted lane carries a
    real cause (< CAUSE_NONE), which each validator guarantees by
    construction (cause codes are set under the same final conflict
    masks that decide the abort)."""
    idx = jnp.where(aborted, lane_cause, N_ABORT_CAUSES)
    return jnp.zeros((N_ABORT_CAUSES,), jnp.int32).at[idx].add(
        1, mode="drop")

# Priority layout: (inverse-age << AGE_SHIFT) | lane-permutation rank.
# Lower priority value = earlier in the wave serialization order.
PRIO_LANE_BITS = 10  # up to 1024 lanes
PRIO_LANE_MASK = (1 << PRIO_LANE_BITS) - 1
NO_CLAIM = jnp.uint32(0xFFFFFFFF)

# Masked-op scatter sentinel.  JAX wraps *negative* indices Python-style even
# under mode="drop"/"fill" (verified in this container: x.at[-1].add(1,
# mode="drop") hits x[-1]).  A large positive out-of-bounds index is the only
# value that actually drops on scatter and fills on gather, so every scatter
# site masks keys to OOB_KEY, never to -1.  (-1 remains the *marker* for an
# unused op slot in op_key; TxnBatch.live() screens it out of semantics.)
OOB_KEY: int = 0x7F000000


def field(**kw):
    return dataclasses.field(**kw)


@partial(jax.tree_util.register_dataclass,
         data_fields=["op_key", "op_group", "op_col", "op_kind", "op_val",
                      "txn_type", "n_ops", "op_extent"],
         meta_fields=[])
@dataclasses.dataclass
class TxnBatch:
    """A wave's worth of transactions (T lanes x K op slots)."""
    op_key: jax.Array    # int32[T, K]
    op_group: jax.Array  # int32[T, K]
    op_col: jax.Array    # int32[T, K]
    op_kind: jax.Array   # int32[T, K]
    op_val: jax.Array    # f32[T, K]
    txn_type: jax.Array  # int32[T]      workload-defined transaction type
    n_ops: jax.Array     # int32[T]      number of live ops (for the cost model)
    op_extent: jax.Array = None  # int32[T, K]  interval width
                          #   [key, key+extent); 1 = point op.  Defaults
                          #   to all-ones (every op a point op) so
                          #   pre-extent construction sites stay valid.

    def __post_init__(self):
        if self.op_extent is None:
            self.op_extent = jnp.ones_like(self.op_key)

    @property
    def lanes(self) -> int:
        return self.op_key.shape[0]

    @property
    def slots(self) -> int:
        return self.op_key.shape[1]

    def is_read(self) -> jax.Array:
        return self.op_kind == READ

    def is_write(self) -> jax.Array:
        """Version-bumping accesses (WRITE and ADD)."""
        return (self.op_kind == WRITE) | (self.op_kind == ADD)

    def is_plain_write(self) -> jax.Array:
        return self.op_kind == WRITE

    def is_add(self) -> jax.Array:
        return self.op_kind == ADD

    def live(self) -> jax.Array:
        return (self.op_kind != NOP) & (self.op_key >= 0)

    def is_scan(self) -> jax.Array:
        """Interval ops (extent > 1) — validated via iterate_validate."""
        return self.op_extent > 1

    def extent(self) -> jax.Array:
        """Effective interval width, clamped to >= 1 so legacy callers
        that fill op_extent with zeros still mean point ops."""
        return jnp.maximum(self.op_extent, 1)


@partial(jax.tree_util.register_dataclass,
         data_fields=["values", "wts", "rts", "claim_w", "claim_r",
                      "pess_mode", "abort_heat", "fine_mode", "false_heat",
                      "heat_wave", "ring_tails", "mv_begin", "mv_head",
                      "mv_vals"],
         meta_fields=[])
@dataclasses.dataclass
class StoreState:
    """The database: values + version metadata + CC bookkeeping tables.

    All tables are flat over a unified record space (workloads lay out their
    tables at offsets inside [0, n_records)).

    wts/rts are the paper's version timestamps, shape [n_records, G] where G is
    the max number of timestamp groups per record (1 = coarse, 2 = the paper's
    fine granularity).  `claim_*` are wave-scoped claim tables (see claims.py)
    that never need resetting thanks to a monotone wave tag.
    """
    values: jax.Array      # f32[n_records, n_cols] (may be zero-width when untracked)
    wts: jax.Array         # uint32[n_records, G]   write timestamps
    rts: jax.Array         # uint32[n_records, G]   read timestamps (TicToc only)
    claim_w: jax.Array     # uint32[n_records, G]   writer claim table
    claim_r: jax.Array     # uint32[n_records, G]   reader claim table (2PL/Swiss)
    pess_mode: jax.Array   # bool[n_records]        Adaptive: pessimistic mode
    abort_heat: jax.Array  # f32[n_records]         Adaptive: abort EWMA
    fine_mode: jax.Array   # bool[n_records]        AutoGran: fine granularity on
    false_heat: jax.Array  # f32[n_records]         AutoGran: false-conflict EWMA
    heat_wave: jax.Array   # int32[n_records]       last wave a heat was touched
                           #   (lazy exponential decay: full-table decay per wave
                           #    would be O(n_records) memory traffic; instead decay
                           #    decay**(wave - heat_wave) is applied at touch time)
    ring_tails: jax.Array  # int32[n_rings]         append-ring cursors (inserts)
    mv_begin: jax.Array    # uint32[n_records, D, G] multi-version ring begin
                           #   timestamps (core/mvstore.py; [1,1,1] when the
                           #   MV store is disabled, mv_depth=0)
    mv_head: jax.Array     # int32[n_records]       newest ring slot per record
    mv_vals: jax.Array     # f32[n_records, D, n_cols] version values
                           #   (track_values only; [1,1,1] otherwise)

    @property
    def n_records(self) -> int:
        return self.wts.shape[0]

    @property
    def n_groups(self) -> int:
        return self.wts.shape[1]

    @property
    def mv_depth(self) -> int:
        """Ring depth D of the multi-version store (1 when disabled —
        the placeholder's single slot)."""
        return self.mv_begin.shape[1]


@partial(jax.tree_util.register_dataclass,
         data_fields=["rng", "wave", "store", "pending", "pending_live",
                      "age", "lane_time", "commits", "aborts",
                      "commits_by_type", "wasted_time", "ext_events",
                      "ro_commits", "ro_aborts", "abort_causes",
                      "conflict_hits", "conflict_peak", "ol"],
         meta_fields=[])
@dataclasses.dataclass
class EngineState:
    """Carried state of the wave scan."""
    rng: jax.Array          # PRNG key
    wave: jax.Array         # uint32 scalar, current wave index
    store: StoreState
    pending: TxnBatch       # retry buffer: aborted txns re-run next wave
    pending_live: jax.Array  # bool[T] lane has a pending (aborted) txn
    age: jax.Array          # int32[T] retry count of the lane's current txn
    lane_time: jax.Array    # f32[T]   simulated microseconds consumed per lane
    commits: jax.Array      # int64 scalar
    aborts: jax.Array       # int64 scalar
    commits_by_type: jax.Array  # int64[n_txn_types]
    wasted_time: jax.Array  # f32 scalar, simulated time lost to aborts
    ext_events: jax.Array   # int64 scalar, TicToc rts-extension CAS events
    ro_commits: jax.Array   # int scalar: commits of read-only transactions
    ro_aborts: jax.Array    # int scalar: aborts of read-only transactions
                            #   (the MV headline metric: snapshot readers
                            #   never abort — DESIGN.md section 9)
    abort_causes: jax.Array = None  # int32[N_ABORT_CAUSES] per-cause abort
                            #   counts; sums to `aborts` exactly (the
                            #   conservation invariant)
    conflict_hits: jax.Array = None  # uint32[n_records, G] total conflicting
                            #   ops per cell (track_conflicts only;
                            #   [1, 1] placeholder otherwise)
    conflict_peak: jax.Array = None  # uint32[n_records, G] max same-cell
                            #   conflicting ops in any single wave
                            #   (segment_count fed through ts_install_max)
    ol: Any = None          # core/admission.OpenLoopState: the open-loop
                            #   front-end (admission queue + goodput
                            #   counters + time-to-commit histograms);
                            #   a minimal placeholder on closed-loop runs
                            #   (DESIGN.md section 11)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Simulated-time constants (microseconds).  See DESIGN.md section 4.

    The paper measures wall-clock throughput of a C++ STM on a 192-core Xeon;
    we reproduce the *structure* of those curves with a calibrated per-op cost
    model.  All constants live here so the calibration is auditable.
    """
    c_op: float = 0.12          # base cost of one record access
    c_txn: float = 0.80         # per-transaction fixed overhead (setup/commit)
    c_validate: float = 0.03    # OCC per-read-op validation pass cost
    kappa_occ: float = 1.0
    kappa_tictoc: float = 1.12  # TicToc read-timestamp maintenance: the
                                # paper runs the 128-bit (uncompressed)
                                # variant (their section 3.2) — a two-word
                                # atomic per tracked read
    kappa_2pl: float = 1.38     # rw-lock acquire/release writes shared cachelines
    kappa_swiss: float = 1.18   # eager w-locks + CM table updates
    kappa_adaptive_opt: float = 1.12   # mode check on the optimistic path
    kappa_adaptive_pess: float = 1.42  # rw-lock path
    kappa_mvcc: float = 1.30    # multi-version overhead: version-chain
                                # traversal on every read, allocate+publish
                                # on every write, GC bookkeeping (Larson et
                                # al.'s measured penalty vs single-version)
    kappa_mvocc: float = 1.24   # same chain costs minus the SI visibility
                                # check writes (read validation is charged
                                # through c_validate like the OCC family)
    c_ext: float = 0.04        # uncontended rts-extension CAS (+fence); the
                                # 128-bit two-word variant the paper runs
    lam_ext: float = 1.35       # TicToc rts-extension contention: extra cost per
                                # concurrent extender of the same (record, group)
    lam_w: float = 0.55         # install contention: committed writers of the
                                # same (record, group) serialize on its
                                # cacheline (all mechanisms; the universal
                                # optimistic degradation at high core counts)
    opt_overlap: float = 0.60    # an optimistic read is vulnerable between
                                 # first read and commit-time validation; a
                                 # concurrent writer's install lands in that
                                 # window with this probability (lockstep
                                 # waves over-align the windows)
    phase_overlap: float = 0.55  # eager-lock conflicts require temporal
                                 # overlap of hold windows; the lockstep wave
                                 # over-aligns them — conflicts are thinned
                                 # to this probability (2PL/Swiss/Adaptive-
                                 # pessimistic only; see DESIGN.md section 4)
    c_abort: float = 0.35       # abort bookkeeping + backoff
    backoff: float = 0.25       # inter-retry backoff


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of a simulation run."""
    cc: int                     # CC_* mechanism id
    lanes: int                  # T: number of simulated threads
    slots: int                  # K: op slots per transaction
    n_records: int
    n_groups: int               # G: timestamp groups per record (physical width)
    n_cols: int                 # value columns (0 = untracked)
    n_txn_types: int
    granularity: int = 1        # 0 = coarse (one timestamp per row),
                                # 1 = fine (the paper's mechanism).
                                # Claims are always scattered at fine group
                                # resolution; granularity selects the probe/
                                # observe width of the backend surface ops
                                # (core/backend.py validate/probe/ts_gather).
    n_rings: int = 1
    track_values: bool = False
    mv_depth: int = 0           # D: version-ring depth of the multi-version
                                # store (core/mvstore.py).  0 disables the MV
                                # tables entirely (placeholder arrays); the
                                # MV mechanisms (mvcc/mvocc) require >= 1 and
                                # benchmarks default to 4.  Depth bounds how
                                # far behind a snapshot may trail before its
                                # version is reclaimed and the reader aborts.
    snapshot_age: int = 0       # MV readers pin their snapshot this many
                                # waves in the past (0 = wave-fresh, the
                                # classic path).  Age > 0 models long-lived
                                # reader snapshots: once writers have pushed
                                # a record's ring past the aged snapshot,
                                # mv_gather reports reclamation and the
                                # reader aborts cleanly (ok=False) — the
                                # knob that makes epoch reclamation actually
                                # fire under load (mvstore.snapshot_ts).
    # Open-loop traffic front-end (core/admission.py; DESIGN.md section
    # 11).  arrival_rate > 0 switches the engine from the closed-loop
    # one-txn-per-lane retry model to open-loop admission: transactions
    # arrive ~ Poisson(arrival_rate) per wave (capped at the lane width),
    # queue in a fixed-capacity ring, and an abort re-enqueues the SAME
    # transaction with an incremented incarnation counter.
    arrival_rate: float = 0.0   # expected arrivals per wave (0 = closed)
    queue_cap: int = 0          # admission-queue ring capacity (>= 1 when
                                # open-loop; overflow arrivals are dropped
                                # and counted)
    max_incarnations: int = 0   # max re-executions after the first attempt;
                                # an abort at this incarnation drops the
                                # transaction (counted, never silent)
    lat_bins: int = 64          # time-to-commit histogram width in waves,
                                # per txn class (last bin = overflow)
    track_conflicts: bool = False  # maintain the hot-record conflict
                                # histogram: per-cell total conflicting-op
                                # hits plus the per-wave same-cell peak
                                # (segment_count), surfaced as
                                # SimResult.hot_records top-k
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    # Adaptive CC state machine:
    adapt_up: float = 0.20      # abort-heat threshold -> pessimistic
    adapt_down: float = 0.02    # decay floor -> back to optimistic
    adapt_decay: float = 0.95
    # Auto-granularity (beyond-paper, paper section 5 future work):
    autogran_up: float = 0.10
    autogran_decay: float = 0.97
    backend: str = "jnp"        # Substrate for the kernel-backend surface
                                # (core/backend.py) every CC mechanism calls:
                                # "jnp": XLA gather/scatter; "pallas": the
                                # TPU-native kernels (interpret mode off-TPU).
                                # Both read the same claim words
                                # (core/claimword.py) and are bit-identical —
                                # see DESIGN.md section 5.
    fuse_wave: bool = True      # Probe family (occ/tictoc/2pl/swisstm/
                                # adaptive) runs its whole claim -> verdict ->
                                # install chain as the ONE fused wave_commit
                                # op (kernels/wave_commit.py): each touched
                                # row rides one DMA per wave.  False = the
                                # unfused claim_probe + commit_install chain;
                                # bit-identical either way (DESIGN.md
                                # section 5, tests/test_wave_commit.py).
    lane_block: int = 0         # Lanes per pallas grid step (LB): the
                                # kernels tile (T, K) into (T // LB) lane
                                # blocks, LB*K row DMAs in flight per step.
                                # 0 = auto from the table width
                                # (kernels/wave_commit.pick_lane_block);
                                # explicit values snap down to a divisor of
                                # `lanes`.  jnp backend ignores it.
    max_extent: int = 1         # Widest op interval the workload emits
                                # ([key, key+extent) — TxnBatch.op_extent).
                                # 1 = point ops only: the scan validation
                                # pass is compiled OUT and the wave is
                                # bit-identical to the pre-extent engine.
                                # > 1 compiles the iterate_validate pass
                                # (static loop bound; DESIGN.md section 13).
    bucket_size: int = 8        # Coarse-granularity interval claims: one
                                # claim word stands for `bucket_size`
                                # consecutive records, so a coarse scan
                                # validates the bucket-expanded interval
                                # [floor(key/B)*B, ceil((key+extent)/B)*B)
                                # — fewer probes, more false phantoms (the
                                # granularity trade-off, now for intervals).
                                # Fine granularity probes every gap row and
                                # ignores this knob.

    def __post_init__(self):
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'jnp' or 'pallas')")
        if self.lane_block < 0:
            raise ValueError(
                f"lane_block must be >= 0 (0 = auto), got {self.lane_block}")
        if self.mv_depth < 0:
            raise ValueError(f"mv_depth must be >= 0, got {self.mv_depth}")
        if self.cc in MV_CCS and self.mv_depth < 1:
            raise ValueError(
                f"{CC_NAMES[self.cc]} needs the multi-version store: "
                "set EngineConfig.mv_depth >= 1 (benchmarks use 4)")
        if self.snapshot_age < 0:
            raise ValueError(
                f"snapshot_age must be >= 0, got {self.snapshot_age}")
        if self.snapshot_age > 0 and self.cc not in MV_CCS:
            raise ValueError(
                f"snapshot_age={self.snapshot_age} needs a multi-version "
                f"mechanism (mvcc/mvocc): {CC_NAMES[self.cc]} has no "
                "snapshots to age")
        if self.arrival_rate < 0:
            raise ValueError(
                f"arrival_rate must be >= 0, got {self.arrival_rate}")
        if self.open_loop:
            if self.queue_cap < 1:
                raise ValueError(
                    f"open-loop runs (arrival_rate={self.arrival_rate}) "
                    "need an admission queue: set queue_cap >= 1")
            if self.max_incarnations < 0:
                raise ValueError(f"max_incarnations must be >= 0, got "
                                 f"{self.max_incarnations}")
            if self.lat_bins < 2:
                raise ValueError(
                    f"lat_bins={self.lat_bins}: the time-to-commit "
                    "histogram needs >= 2 bins (last bin = overflow)")
        elif self.queue_cap or self.max_incarnations:
            raise ValueError(
                f"queue_cap={self.queue_cap} / max_incarnations="
                f"{self.max_incarnations} shape the open-loop admission "
                "queue only: set arrival_rate > 0 (closed-loop lanes "
                "retry in place and never queue)")
        if self.max_extent < 1:
            raise ValueError(
                f"max_extent must be >= 1 (1 = point ops), got "
                f"{self.max_extent}")
        if self.max_extent > self.n_records:
            raise ValueError(
                f"max_extent={self.max_extent} exceeds n_records="
                f"{self.n_records}: no interval can be wider than the "
                "record space")
        if self.bucket_size < 1:
            raise ValueError(
                f"bucket_size must be >= 1, got {self.bucket_size}")
        if self.max_extent > 1 and self.snapshot_age > 0:
            raise ValueError(
                f"max_extent={self.max_extent} with snapshot_age="
                f"{self.snapshot_age}: scans validate intervals against "
                "the CURRENT wave's claim tables, which aged snapshots "
                "have already drifted past — scan workloads need "
                "wave-fresh snapshots (the pipeline_depth >= 2 analogue "
                "of this rule lives in DistConfig)")

    @property
    def open_loop(self) -> bool:
        """Open-loop traffic front-end active (DESIGN.md section 11)."""
        return self.arrival_rate > 0


def txn_batch_zeros(lanes: int, slots: int) -> TxnBatch:
    zi = jnp.zeros((lanes, slots), jnp.int32)
    return TxnBatch(
        op_key=jnp.full((lanes, slots), -1, jnp.int32),
        op_group=zi, op_col=zi, op_kind=zi,
        op_val=jnp.zeros((lanes, slots), jnp.float32),
        op_extent=jnp.ones((lanes, slots), jnp.int32),
        txn_type=jnp.zeros((lanes,), jnp.int32),
        n_ops=jnp.zeros((lanes,), jnp.int32),
    )


def store_init(n_records: int, n_groups: int, n_cols: int,
               n_rings: int = 1, values: Optional[jax.Array] = None,
               need_rts: bool = True, mv_depth: int = 0) -> StoreState:
    from repro.core import mvstore
    G = n_groups
    if values is None:
        values = jnp.zeros((n_records, max(n_cols, 1)), jnp.float32)
    if mv_depth > 0:
        mv_begin, mv_head, mv_vals = mvstore.mv_init(
            n_records, mv_depth, G, n_cols,
            values if n_cols > 0 else None)
    else:
        mv_begin, mv_head, mv_vals = mvstore.mv_placeholder()
    return StoreState(
        values=values,
        wts=jnp.zeros((n_records, G), jnp.uint32),
        rts=(jnp.zeros((n_records, G), jnp.uint32) if need_rts
             else jnp.zeros((1, 1), jnp.uint32)),
        claim_w=jnp.full((n_records, G), NO_CLAIM, jnp.uint32),
        claim_r=jnp.full((n_records, G), NO_CLAIM, jnp.uint32),
        pess_mode=jnp.zeros((n_records,), jnp.bool_),
        abort_heat=jnp.zeros((n_records,), jnp.float32),
        fine_mode=jnp.zeros((n_records,), jnp.bool_),
        false_heat=jnp.zeros((n_records,), jnp.float32),
        heat_wave=jnp.zeros((n_records,), jnp.int32),
        ring_tails=jnp.zeros((n_rings,), jnp.int32),
        mv_begin=mv_begin,
        mv_head=mv_head,
        mv_vals=mv_vals,
    )


def engine_state_init(cfg: EngineConfig, rng: jax.Array,
                      store: StoreState) -> EngineState:
    from repro.core import admission
    T = cfg.lanes
    ol = (admission.open_loop_init(cfg.queue_cap, cfg.slots,
                                   cfg.n_txn_types, cfg.lat_bins)
          if cfg.open_loop else admission.open_loop_placeholder())
    return EngineState(
        rng=rng,
        wave=jnp.uint32(0),
        store=store,
        pending=txn_batch_zeros(T, cfg.slots),
        pending_live=jnp.zeros((T,), jnp.bool_),
        age=jnp.zeros((T,), jnp.int32),
        lane_time=jnp.zeros((T,), jnp.float32),
        commits=jnp.int64(0) if jax.config.jax_enable_x64 else jnp.int32(0),
        aborts=jnp.int64(0) if jax.config.jax_enable_x64 else jnp.int32(0),
        commits_by_type=jnp.zeros((cfg.n_txn_types,),
                                  jnp.int64 if jax.config.jax_enable_x64 else jnp.int32),
        wasted_time=jnp.float32(0),
        ext_events=jnp.int32(0),
        ro_commits=jnp.int64(0) if jax.config.jax_enable_x64 else jnp.int32(0),
        ro_aborts=jnp.int64(0) if jax.config.jax_enable_x64 else jnp.int32(0),
        abort_causes=jnp.zeros((N_ABORT_CAUSES,), jnp.int32),
        conflict_hits=jnp.zeros(
            (cfg.n_records, cfg.n_groups) if cfg.track_conflicts else (1, 1),
            jnp.uint32),
        conflict_peak=jnp.zeros(
            (cfg.n_records, cfg.n_groups) if cfg.track_conflicts else (1, 1),
            jnp.uint32),
        ol=ol,
    )
