"""Fixed-capacity admission queue for the open-loop traffic front-end.

Closed-loop benchmarks (the paper's) pin one transaction per lane and
retry it in place; production traffic is *open-loop*: transactions arrive
on a Poisson schedule (workloads/arrivals.py), queue for admission into
the next wave, and an abort re-enqueues the SAME transaction — same
read/write set, incremented **incarnation** counter — until it commits or
exceeds ``max_incarnations`` (then it is dropped, and counted).  This
module is the queue: a fixed-capacity ring of transaction entries whose
every operation is a fixed-shape gather/scatter, so the whole open-loop
wave stays inside one jitted ``lax.scan`` (and under ``vmap`` in the
sweep grid runner) exactly like the closed-loop engine.

Ring discipline (DESIGN.md section 11)
--------------------------------------
``head``/``size`` scalars index a capacity-``C`` ring.  Within one wave:

  1. ``enqueue`` the wave's arrivals (admit_wave = now, incarnation 0).
     Arrivals beyond the free space overflow — dropped and counted.
  2. ``dequeue`` up to T entries into the lane grid (FIFO from ``head``).
  3. run the wave; committed lanes leave the system, recording
     ``time-to-commit = commit_wave - admit_wave + 1`` waves.
  4. ``enqueue`` the aborted lanes back (same ops, incarnation + 1) unless
     the new incarnation would exceed the cap.

Because arrivals enqueue BEFORE the dequeue and re-enqueues come after,
step 4 can never overflow: dequeuing d entries frees d slots and at most
d lanes abort.  The conservation oracle (tests/test_open_loop.py) checks
the resulting invariant exactly: every admitted transaction is committed
exactly once, still queued, or dropped at the incarnation cap.

Occupancy never exceeds capacity and every overflow is counted — the
hypothesis properties in tests/test_open_loop.py drive random
enqueue/dequeue sequences against both.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import types as t
from repro.core.types import OOB_KEY, TxnBatch

#: lat_hist's last bin is the overflow bin: a time-to-commit of
#: >= lat_bins - 1 waves lands there (percentiles saturate at it).
MIN_LAT_BINS = 2


@partial(jax.tree_util.register_dataclass,
         data_fields=["op_key", "op_group", "op_col", "op_kind", "op_val",
                      "txn_type", "n_ops", "op_extent", "admit_wave",
                      "incarnation", "txn_id", "head", "size"],
         meta_fields=[])
@dataclasses.dataclass
class QueueState:
    """A capacity-C ring of queued transactions (C entries x K op slots).

    Entry fields mirror TxnBatch row-for-row — a re-enqueued transaction's
    ops are stored bit-identically to its first incarnation (the property
    tests assert this) — plus the open-loop metadata: ``admit_wave`` (the
    wave the transaction FIRST entered the queue; retries keep it),
    ``incarnation`` (0 on arrival, +1 per re-enqueue), and ``txn_id``
    (unique admission serial, the conservation oracle's tracking key).
    """
    op_key: jax.Array       # int32[C, K]
    op_group: jax.Array     # int32[C, K]
    op_col: jax.Array       # int32[C, K]
    op_kind: jax.Array      # int32[C, K]
    op_val: jax.Array       # f32[C, K]
    txn_type: jax.Array     # int32[C]
    n_ops: jax.Array        # int32[C]
    op_extent: jax.Array    # int32[C, K]  interval width per op (1 = point;
                            #   re-enqueued incarnations keep it bit-identical
                            #   like every other op column)
    admit_wave: jax.Array   # int32[C]  wave of FIRST admission (kept on retry)
    incarnation: jax.Array  # int32[C]  execution attempt counter
    txn_id: jax.Array       # int32[C]  unique admission serial number
    head: jax.Array         # int32 scalar: ring read cursor
    size: jax.Array         # int32 scalar: live entries (never > cap)

    @property
    def cap(self) -> int:
        return self.op_key.shape[0]

    @property
    def slots(self) -> int:
        return self.op_key.shape[1]


def queue_init(cap: int, slots: int) -> QueueState:
    zi2 = jnp.zeros((cap, slots), jnp.int32)
    zi1 = jnp.zeros((cap,), jnp.int32)
    return QueueState(
        op_key=jnp.full((cap, slots), -1, jnp.int32),
        op_group=zi2, op_col=zi2, op_kind=zi2,
        op_val=jnp.zeros((cap, slots), jnp.float32),
        txn_type=zi1, n_ops=zi1,
        op_extent=jnp.ones((cap, slots), jnp.int32),
        admit_wave=zi1, incarnation=zi1,
        txn_id=zi1,
        head=jnp.int32(0), size=jnp.int32(0))


def ring_enqueue(cap: int, head: jax.Array, size: jax.Array,
                 mask: jax.Array, tables: tuple, cols: tuple) -> tuple[
                     tuple, jax.Array, jax.Array, jax.Array]:
    """The one ring-append primitive: scatter masked lanes of each column
    in ``cols`` into the matching capacity-``cap`` ring table, packed by
    cumsum rank in ascending lane order.  Rejected lanes route to the
    ``OOB_KEY`` sentinel slot — the one scatter index that actually drops
    under ``mode="drop"`` (types.OOB_KEY rationale; ``cap`` itself is
    already out of bounds but keep the convention of one loud sentinel).
    Shared by the local QueueState ``enqueue`` and the distributed
    per-shard rings (core/distributed.py carries no hand-rolled scatters).
    Returns ``(tables', size', n_accepted, n_overflow)``.
    """
    m = mask.astype(jnp.int32)
    rank = jnp.cumsum(m) - m                    # rank among masked lanes
    accept = mask & (rank < cap - size)
    slot = jnp.where(accept, (head + size + rank) % cap, OOB_KEY)
    n_acc = accept.sum().astype(jnp.int32)
    tabs = tuple(tab.at[slot].set(col, mode="drop")
                 for tab, col in zip(tables, cols))
    return tabs, size + n_acc, n_acc, m.sum().astype(jnp.int32) - n_acc


def enqueue(q: QueueState, batch: TxnBatch, admit_wave: jax.Array,
            incarnation: jax.Array, txn_id: jax.Array,
            mask: jax.Array) -> tuple[QueueState, jax.Array, jax.Array]:
    """Append ``batch`` lanes where ``mask`` into the ring, FIFO order.

    admit_wave/incarnation/txn_id: int32[T] per-lane metadata stored with
    the entry.  Lanes are packed in ascending lane order; once the ring is
    full the remaining masked lanes overflow (dropped, counted).  Returns
    ``(q', n_accepted, n_overflow)``.
    """
    tabs, size, n_acc, n_ovf = ring_enqueue(
        q.cap, q.head, q.size, mask,
        (q.op_key, q.op_group, q.op_col, q.op_kind, q.op_val,
         q.txn_type, q.n_ops, q.op_extent, q.admit_wave, q.incarnation,
         q.txn_id),
        (batch.op_key, batch.op_group, batch.op_col, batch.op_kind,
         batch.op_val, batch.txn_type, batch.n_ops, batch.op_extent,
         admit_wave.astype(jnp.int32), incarnation.astype(jnp.int32),
         txn_id.astype(jnp.int32)))
    (op_key, op_group, op_col, op_kind, op_val, txn_type, n_ops, op_ext,
     admit_w, incarn, tid) = tabs
    q = dataclasses.replace(
        q, op_key=op_key, op_group=op_group, op_col=op_col,
        op_kind=op_kind, op_val=op_val, txn_type=txn_type, n_ops=n_ops,
        op_extent=op_ext, admit_wave=admit_w, incarnation=incarn,
        txn_id=tid, size=size)
    return q, n_acc, n_ovf


def dequeue(q: QueueState, lanes: int, n_active=None) -> tuple[
        QueueState, TxnBatch, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Pop up to ``min(size, n_active)`` entries into a ``lanes``-wide
    TxnBatch (FIFO).  ``n_active`` (int scalar, default ``lanes``) is the
    sweep runner's live-lane count: padded grid points fill only their
    real lane prefix.  Unfilled lanes carry the empty transaction (no ops,
    no claims — the engine's padding convention).  Returns
    ``(q', batch, admit_wave, incarnation, txn_id, got)`` with ``got``
    bool[lanes] marking filled lanes.
    """
    if n_active is None:
        n_active = lanes
    take = jnp.minimum(q.size, jnp.asarray(n_active, jnp.int32))
    i = jnp.arange(lanes, dtype=jnp.int32)
    got = i < take
    pos = (q.head + i) % q.cap

    def take2(tab, fill):
        return jnp.where(got[:, None], tab[pos, :], fill)

    def take1(tab, fill=0):
        return jnp.where(got, tab[pos], fill)

    batch = TxnBatch(
        op_key=take2(q.op_key, -1),
        op_group=take2(q.op_group, 0),
        op_col=take2(q.op_col, 0),
        op_kind=take2(q.op_kind, t.NOP),
        op_val=jnp.where(got[:, None], q.op_val[pos, :], 0.0),
        txn_type=take1(q.txn_type),
        n_ops=take1(q.n_ops),
        op_extent=take2(q.op_extent, 1))
    admit_wave = take1(q.admit_wave)
    incarnation = take1(q.incarnation)
    txn_id = take1(q.txn_id, -1)
    q = dataclasses.replace(q, head=(q.head + take) % q.cap,
                            size=q.size - take)
    return q, batch, admit_wave, incarnation, txn_id, got


@partial(jax.tree_util.register_dataclass,
         data_fields=["queue", "next_id", "offered", "admitted",
                      "arrival_drops", "inc_drops", "reenq_drops",
                      "lat_hist"],
         meta_fields=[])
@dataclasses.dataclass
class OpenLoopState:
    """Open-loop front-end state carried through the wave scan
    (EngineState.ol): the admission queue plus the goodput-conservation
    counters and the per-class time-to-commit histogram."""
    queue: QueueState
    next_id: jax.Array       # int32: next admission serial number
    offered: jax.Array       # int32: Poisson arrivals offered (post lane-cap)
    admitted: jax.Array      # int32: arrivals accepted into the queue
    arrival_drops: jax.Array  # int32: arrivals lost to a full queue
    inc_drops: jax.Array     # int32: txns dropped past max_incarnations
    reenq_drops: jax.Array   # int32: re-enqueue overflow — structurally 0
                             #   (arrivals land before the dequeue frees
                             #   lanes; the oracle asserts it stays 0)
    lat_hist: jax.Array      # int32[n_txn_types, lat_bins] time-to-commit
                             #   histogram, bin = min(ttc_waves, bins - 1)

    @property
    def lat_bins(self) -> int:
        return self.lat_hist.shape[1]


def open_loop_init(cap: int, slots: int, n_txn_types: int,
                   lat_bins: int) -> OpenLoopState:
    z = jnp.int32(0)
    return OpenLoopState(
        queue=queue_init(cap, slots),
        next_id=z, offered=z, admitted=z, arrival_drops=z, inc_drops=z,
        reenq_drops=z,
        lat_hist=jnp.zeros((n_txn_types, lat_bins), jnp.int32))


def open_loop_placeholder() -> OpenLoopState:
    """Minimal-footprint stand-in carried by closed-loop runs (the
    mvstore.mv_placeholder pattern): EngineState keeps one pytree
    structure either way."""
    return open_loop_init(1, 1, 1, MIN_LAT_BINS)


def record_commits(ol: OpenLoopState, txn_type: jax.Array, ttc: jax.Array,
                   commit: jax.Array) -> OpenLoopState:
    """Accumulate committed lanes' time-to-commit (waves, >= 1) into the
    per-class histogram; the last bin absorbs overflow."""
    b = jnp.clip(ttc, 0, ol.lat_bins - 1)
    tt = jnp.where(commit, txn_type, OOB_KEY)
    return dataclasses.replace(
        ol, lat_hist=ol.lat_hist.at[tt, b].add(1, mode="drop"))


def record_ttc(lat_hist: jax.Array, ttc: jax.Array,
               commit: jax.Array) -> jax.Array:
    """Classless 1-D time-to-commit scatter: ``lat_hist`` is int32[bins],
    committed lanes land in ``min(ttc, bins - 1)`` (last bin = overflow),
    others route to the OOB_KEY drop sentinel.  The distributed engine's
    per-shard histogram (core/distributed.py); ``record_commits`` is the
    local engine's per-txn-class variant."""
    b = jnp.where(commit, jnp.clip(ttc, 0, lat_hist.shape[0] - 1), OOB_KEY)
    return lat_hist.at[b].add(1, mode="drop")


def ttc_percentiles(lat_hist, qs=(0.5, 0.99)) -> list[list[float]]:
    """Host-side percentile read-out of a time-to-commit histogram.

    lat_hist: int[n_classes, bins] with bin index == time-to-commit in
    waves (last bin = overflow).  Returns, per quantile in ``qs``, a list
    of per-class values in waves; a class with no commits reports 0.0.
    """
    h = np.asarray(lat_hist)
    out: list[list[float]] = []
    for q in qs:
        row = []
        for c in range(h.shape[0]):
            cum = np.cumsum(h[c])
            total = int(cum[-1]) if cum.size else 0
            if total == 0:
                row.append(0.0)
                continue
            k = int(np.searchsorted(cum, np.ceil(q * total)))
            row.append(float(k))
        out.append(row)
    return out
