"""2PL — reader-writer lock two-phase locking with non-waiting deadlock
prevention (STO's default policy, per the paper's section 3.2).

Both reads and writes acquire locks during execution, so — unlike the
optimistic mechanisms — conflicts surface at the op that fails to acquire, and
an aborted transaction only wastes the work done up to that op (``eager=True``
in the cost model).  The price: every read writes the lock word's cacheline,
the overhead the paper's cost discussion attributes to pessimistic mechanisms
(kappa_2pl in the cost model).

Lock compatibility: R/R compatible; R/W, W/R, W/W conflict.  Non-waiting =
the lower-priority lane of a conflicting pair aborts immediately.

Lock claims and probes route through the kernel-backend surface
(core/backend.py) — Pallas kernels or XLA gather/scatter per
``EngineConfig.backend`` (DESIGN.md section 5).  Each lock table (writer
claims, reader claims) is acquired AND probed by one fused ``claim_probe``
op, so a 2PL wave makes exactly two claim-table passes instead of four.
"""
from __future__ import annotations

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live
    myp = base.my_prio_per_op(batch, prio)

    store, wprio = base.claim_and_probe(store, batch, prio, wave, cfg, fine)
    store, rprio = base.claim_and_probe(store, batch, prio, wave, cfg, fine,
                                        table="r")

    conflict = ((rd & (wprio < myp))                      # read vs writer lock
                | (wr & (wprio < myp))                    # write vs writer lock
                | (wr & (rprio < myp)))                   # write vs reader lock
    # Phase-overlap thinning: the lockstep wave over-aligns lock-hold
    # windows; in real time two conflicting holds only overlap part of the
    # time (DESIGN.md section 4).
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    conflict = conflict & (u < cfg.cost.phase_overlap)
    # All three terms are failed eager lock acquisitions: the younger lane
    # of the pair is wounded.
    res = base.result_from_conflicts(batch, conflict, eager=True,
                                     cause_op=t.CAUSE_LOCK_WOUND)
    store = base.bump_versions(store, batch, res.commit, cfg)
    return store, res
