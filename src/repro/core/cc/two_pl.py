"""2PL — reader-writer lock two-phase locking with non-waiting deadlock
prevention (STO's default policy, per the paper's section 3.2).

Both reads and writes acquire locks during execution, so — unlike the
optimistic mechanisms — conflicts surface at the op that fails to acquire, and
an aborted transaction only wastes the work done up to that op (``eager=True``
in the cost model).  The price: every read writes the lock word's cacheline,
the overhead the paper's cost discussion attributes to pessimistic mechanisms
(kappa_2pl in the cost model).

Lock compatibility: R/R compatible; R/W, W/R, W/W conflict.  Non-waiting =
the lower-priority lane of a conflicting pair aborts immediately.

Lock claims, probes, verdicts, and version bumps route through the
kernel-backend surface (core/backend.py) — Pallas kernels or XLA
gather/scatter per ``EngineConfig.backend`` (DESIGN.md section 5).  Both
lock tables (writer claims via check_w, reader claims via the dual
check_r channel) are acquired AND probed by ONE fused ``wave_commit`` op
(base.claim_probe_commit), so a 2PL wave makes exactly one launch where
it previously chained four table passes.
"""
from __future__ import annotations

import dataclasses

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live

    # Phase-overlap thinning: the lockstep wave over-aligns lock-hold
    # windows; in real time two conflicting holds only overlap part of the
    # time (DESIGN.md section 4).
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    lock_ok = u < cfg.cost.phase_overlap
    # read vs writer lock | write vs writer lock (check_w), write vs
    # reader lock (the dual check_r channel); the megakernel ANDs in the
    # strictness compares against both tables' probes.
    store, conflict = base.claim_probe_commit(
        store, batch, prio, wave, cfg, fine,
        check_w=(rd | wr) & lock_ok, check_r=wr & lock_ok, dual=True)
    # All three point terms are failed eager lock acquisitions: the younger
    # lane of the pair is wounded.  Scan ops take no locks — they validate
    # optimistically at commit (the interval pass), so a phantom conflict
    # never cuts work early: first_conflict only counts lock losses.
    res = base.result_from_conflicts(batch, conflict, eager=True,
                                     cause_op=t.CAUSE_LOCK_WOUND)
    first_lock = claims.first_true_index(conflict & ~batch.is_scan(), K)
    res = dataclasses.replace(res, first_conflict=first_lock)
    return store, res
