"""MVCC — multi-version snapshot isolation with first-committer-wins
(Hekaton-style; Larson et al., "High-Performance Concurrency Control
Mechanisms for Main-Memory Databases"), wave-vectorized.

Reads never block and never abort: every read selects the newest version of
its (record, group) visible at the transaction's snapshot (the wave's start)
from the fixed-depth version ring of ``core/mvstore.py`` — the backend's
``mv_gather`` op.  The only in-wave conflicts are write-write: of the
concurrent writers of a cell, the first committer (strongest priority) wins
and the rest abort — detected against the same wave-scoped claim tables the
single-version mechanisms use.  Blind commutative ADDs keep their STO
semantics (never abort against other ADDs): ADD ops probe a second claim
channel holding only plain WRITEs (``base.plain_write_claims``).

Timestamp granularity enters exactly as in the paper, but one level down:
fine granularity makes both the write-write conflict rule AND version
visibility per column group (a group-1 update neither conflicts with nor
invalidates group-0 accesses); coarse granularity treats the record as one
unit on both paths.  So the paper's question — do fine timestamps still pay
off when readers never block? — is answered by the same granularity switch.

The one way a read CAN abort is epoch reclamation: the ring retains only
the D newest versions, and a snapshot older than all of them must abort
cleanly rather than read a recycled slot (``mv_gather``'s ok flag).  With
wave-fresh snapshots this never fires — which is precisely the mechanism's
zero read-only abort rate the abort_rates benchmark demonstrates.

Scan (interval) reads follow the same snapshot rule: an iterator over
``[key, key + extent)`` reads the snapshot's versions of every record in
the interval, which is a consistent cut — so MVCC scans are NEVER
re-validated and never abort with CAUSE_PHANTOM.  That is snapshot
isolation's answer, not serializability's: phantom anomalies are admitted
exactly like write skew (``cc/mvocc.py`` adds the interval re-validation
that closes both — DESIGN.md section 13).

Committed writes claim one ring slot per record per wave and publish their
begin timestamps through the backend's ``mv_install`` op.  Note MVCC is
snapshot isolation, not serializability (write skew is admitted —
``cc/mvocc.py`` adds the read validation that closes it).

All shared-state access routes through the kernel-backend surface
(core/backend.py): claim_scatter / validate / mv_gather / mv_install —
Pallas kernels or XLA gather/scatter, bit-identical (DESIGN.md section 9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims, mvstore
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def fcw_conflicts(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    """(store', conflict bool[T, K]): first-committer-wins write-write
    verdicts, shared by mvcc and mvocc.  Scatters both claim channels, then:
    a plain WRITE conflicts with any stronger writer of its cell, an ADD
    only with a stronger plain WRITE (ADD-ADD pairs commute)."""
    be = kb.resolve(cfg)
    fine = base.is_fine(cfg)
    live = batch.live()
    pw = batch.is_plain_write() & live
    ad = batch.is_add() & live
    myp = base.my_prio_per_op(batch, prio)

    store = base.write_claims(store, batch, prio, wave, cfg)   # all writes
    store = base.plain_write_claims(store, batch, prio, wave, cfg)
    cw = be.validate(store.claim_w, batch.op_key, batch.op_group, myp, pw,
                     wave, fine)
    ca = be.validate(store.claim_r, batch.op_key, batch.op_group, myp, ad,
                     wave, fine)
    return store, cw | ca


def mv_commit(store: StoreState, batch: TxnBatch, commit, prio, wave,
              cfg: EngineConfig) -> StoreState:
    """Install the wave's committed writes into the version ring: one slot
    claim + begin publish per written record (backend ``mv_install``), plus
    the slot's value materialization when values are tracked."""
    be = kb.resolve(cfg)
    do = batch.is_write() & batch.live() & commit[:, None]
    head_old = store.mv_head
    with jax.named_scope("repro:mv_install"):
        mv_begin, mv_head = be.mv_install(store.mv_begin, head_old,
                                          batch.op_key, batch.op_group, do,
                                          mvstore.install_ts(wave))
    store = dataclasses.replace(store, mv_begin=mv_begin, mv_head=mv_head)
    if cfg.track_values:
        vals = mvstore.install_values(store.mv_vals, head_old, mv_head,
                                      batch, commit, prio)
        store = dataclasses.replace(store, mv_vals=vals)
    return store


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    be = kb.resolve(cfg)
    fine = base.is_fine(cfg)
    rd = batch.is_read() & batch.live()

    store, conflict = fcw_conflicts(store, batch, prio, wave, cfg)
    u = claims.hash01(wave, claims.lane_op_ids(*batch.op_key.shape))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning

    # Snapshot visibility: reads select their version; a reclaimed snapshot
    # aborts deterministically (never thinned — it is store state, not a
    # racing-window event).  With wave-fresh snapshots (snapshot_age=0) ok
    # is always True; aged snapshots can outlive the ring and abort here.
    _, ok = be.mv_gather(store.mv_begin, batch.op_key, batch.op_group,
                         mvstore.snapshot_ts(wave, cfg.snapshot_age), fine)
    conflict = conflict | (rd & ~ok)

    # Write-side conflicts are first-committer-wins w-w losses; the only
    # read-side abort is ring reclamation (the disjoint rd & ~ok term).
    cause = jnp.where(rd & ~ok, jnp.int32(t.CAUSE_STALE_SNAPSHOT),
                      jnp.int32(t.CAUSE_WW))
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=cause)
    store = mv_commit(store, batch, res.commit, prio, wave, cfg)
    return store, res
