"""MV-OCC — serializable multi-version OCC: snapshot reads plus commit-time
read-set validation against the version chain (Larson et al.'s optimistic
scheme; the repair-oriented variant of Dashti et al. motivates the
read-only exemption), wave-vectorized.

MVCC's snapshot isolation admits write skew: two transactions each read
what the other writes, neither sees a write-write conflict, both commit —
no serial order explains the result.  MV-OCC closes the gap the classic
way: an UPDATE transaction re-validates its read set at commit — a read
conflicts when a strictly-stronger lane installed a new version of its
(record, group) this wave, exactly the single-version OCC probe, but
against the version chain's claim channel.  The multi-version payoff that
single-version OCC cannot offer survives where it is sound: a READ-ONLY
transaction needs no validation at all — its snapshot is a consistent cut
and it serializes at its snapshot timestamp, so only write-carrying
transactions ever abort on a reader's behalf ("only write-write conflicts
abort readers" in the single-version sense: pure readers are exempt).

Granularity is the same switch as everywhere in this repro: fine validates
and resolves write-write conflicts per column group, coarse per record —
extending the paper's central question to the serializable multi-version
point of the design space.

Write-write conflicts, ring install, value materialization, and snapshot
reclamation aborts are shared with ``cc/mvcc.py``; everything routes
through the kernel-backend surface (validate / claim_scatter / mv_gather /
mv_install), Pallas or XLA, bit-identical (DESIGN.md section 9).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims, mvstore
from repro.core import types as t
from repro.core.cc import base, mvcc
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    be = kb.resolve(cfg)
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    myp = base.my_prio_per_op(batch, prio)

    store, conflict = mvcc.fcw_conflicts(store, batch, prio, wave, cfg)

    # Commit-time read validation, update transactions only: read-only
    # lanes serialize at their snapshot and skip the probe entirely.
    has_write = (batch.is_write() & live).any(axis=1)
    crd = be.validate(store.claim_w, batch.op_key, batch.op_group, myp,
                      rd & ~batch.is_scan(), wave, fine)
    conflict = conflict | (crd & has_write[:, None])
    u = claims.hash01(wave, claims.lane_op_ids(*batch.op_key.shape))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning
    # Scan (interval) reads of update transactions re-validate UNTHINNED
    # through the interval pass against the wave's write claims — the
    # Hekaton iterator re-scan; read-only lanes keep the snapshot
    # exemption (their snapshot is a consistent cut even for intervals).
    conflict = conflict | base.phantom_validate(store, batch, prio, wave,
                                                cfg, fine,
                                                mask=has_write[:, None])

    _, ok = be.mv_gather(store.mv_begin, batch.op_key, batch.op_group,
                         mvstore.snapshot_ts(wave, cfg.snapshot_age), fine)
    conflict = conflict | (rd & ~ok)

    # Three disjoint abort channels by op kind and term: reclaimed aged
    # snapshots (read op, ~ok), first-committer-wins losses (write op),
    # and the update-txn read validation (read op, ok).
    cause = jnp.where(
        rd & ~ok, jnp.int32(t.CAUSE_STALE_SNAPSHOT),
        jnp.where(batch.is_write(), jnp.int32(t.CAUSE_WW),
                  jnp.int32(t.CAUSE_READ_VAL)))
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=cause)
    store = mvcc.mv_commit(store, batch, res.commit, prio, wave, cfg)
    return store, res
