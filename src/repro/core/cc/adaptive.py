"""Adaptive reader-writer locking — the paper's own mixed-CC design
(section 3.2): an adaptive rw-lock per record that switches between an
optimistic mode (reads observe versions, OCC rule) and a pessimistic mode
(strict reader-writer locking, 2PL rule) based on observed contention, with a
unified commit protocol evaluating both rules inside one transaction.

Per-record state machine: ``pess_mode`` flips pessimistic when the record's
abort-heat EWMA exceeds ``adapt_up`` and relaxes back when it decays below
``adapt_down``.  Heat decay is lazy (claims.lazy_decayed) so the state machine
costs O(touched records), not O(table), per wave.

Both claim tables are acquired, probed, and verdict-reduced by ONE fused
``wave_commit`` pass on the kernel-backend surface
(base.claim_probe_commit, core/backend.py) — Pallas kernels or XLA
gather/scatter per ``EngineConfig.backend`` (DESIGN.md section 5); the
reader channel's install mask is narrowed to pessimistic records (visible
reads), while its probe still answers for every op.  The mode bits ride
in the verdict masks: optimistic reads carry the OCC window thinning,
pessimistic ops the 2PL phase-overlap thinning.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import OOB_KEY, EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live

    kp = jnp.where(batch.op_key >= 0, batch.op_key, OOB_KEY)
    pess = store.pess_mode.at[kp].get(mode="fill",
                                      fill_value=False)  # [T, K]

    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    lock_ok = u < cfg.cost.phase_overlap     # phase-overlap thinning
    uo = claims.hash01(wave + jnp.uint32(77),
                       claims.lane_op_ids(T, K))
    # Writer-table channel: optimistic reads (OCC rule, window-thinned) +
    # pessimistic r-lock-vs-w-lock and w-lock-vs-w-lock; reader-table
    # channel: pessimistic w-lock-vs-r-lock.  Visible (lock-acquiring)
    # reads install only on pessimistic records (do_r_mask).
    check_w = ((rd & ~pess & (uo < cfg.cost.opt_overlap))
               | ((rd | wr) & pess & lock_ok))
    store, conflict = base.claim_probe_commit(
        store, batch, prio, wave, cfg, fine, check_w=check_w,
        check_r=wr & pess & lock_ok, dual=True, do_r_mask=pess)
    # Pessimistic-mode conflicts are failed eager lock acquisitions;
    # optimistic-mode conflicts are commit-time read-validation failures.
    cause = jnp.where(pess, jnp.int32(t.CAUSE_LOCK_WOUND),
                      jnp.int32(t.CAUSE_READ_VAL))
    res = base.result_from_conflicts(batch, conflict, eager=True,
                                     cause_op=cause)
    # Eager detection only on pessimistic ops; optimistic conflicts surface
    # at commit-time validation (full work wasted).  Scan ops are always
    # commit-time regardless of the record's mode — they take no locks.
    K = batch.slots
    first_pess = claims.first_true_index(
        conflict & pess & ~batch.is_scan(), K)
    res = dataclasses.replace(
        res,
        first_conflict=first_pess,
        pess_frac=(pess & live).sum(axis=1) /
                  jnp.maximum(batch.n_ops, 1).astype(jnp.float32))

    # --- contention state machine (touched records only) -------------------
    touched = conflict  # records involved in a conflict this wave heat up
    heat, heat_wave = claims.touch_heat(
        store.abort_heat, store.heat_wave, batch.op_key,
        jnp.ones_like(batch.op_val), wave, cfg.adapt_decay, touched)
    # Re-evaluate mode for every record accessed this wave (hot -> pess,
    # decayed-cold -> opt).  Heat for non-conflicting accesses is the lazily
    # decayed current value.
    acc = live
    cur = claims.lazy_decayed(heat, heat_wave, batch.op_key, wave,
                              cfg.adapt_decay)
    new_mode = jnp.where(cur > cfg.adapt_up, True,
                         jnp.where(cur < cfg.adapt_down, False,
                                   pess))
    k = jnp.where(acc, batch.op_key, OOB_KEY).reshape(-1)
    pess_mode = store.pess_mode.at[k].set(new_mode.reshape(-1), mode="drop")

    store = dataclasses.replace(store, abort_heat=heat, heat_wave=heat_wave,
                                pess_mode=pess_mode)
    return store, res
