"""OCC — Silo/STO-style optimistic concurrency control.

Reads never write shared memory during execution; at commit the read set is
validated against record versions.  In the wave model (DESIGN.md section 2):
every lane's write set claims its (record, group) cells with the lane's
priority, then every read op probes the writer-claim table — a read conflicts
iff a strictly-higher-priority lane wrote the cell this wave.  Write-write
pairs do not abort (commit-time locks serialize the installs).

Timestamp granularity is the probe width: coarse probes treat a claim on any
column group of the record as a conflict (one timestamp per row), fine probes
look only at the op's own group — the paper's mechanism.

All shared-state access (claim install + probe, version install) routes
through the kernel-backend surface of core/backend.py — Pallas kernels or
XLA gather/scatter, selected by ``EngineConfig.backend`` (DESIGN.md
section 5).  The claim scatter and the read-set probe are ONE fused
``claim_probe`` op (base.claim_and_probe): a single pass over the writer
claim table installs the wave's write claims and yields every op's
strongest-claimant priority; the OCC verdict is then just the strictness
compare against the lane's own priority.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    store, wprio = base.claim_and_probe(store, batch, prio, wave, cfg)
    check = batch.is_read() & batch.live()
    conflict = check & (wprio < base.my_prio_per_op(batch, prio))
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning
    # Every OCC abort is a commit-time read-validation failure.
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=t.CAUSE_READ_VAL)
    store = base.bump_versions(store, batch, res.commit, cfg)
    return store, res
