"""OCC — Silo/STO-style optimistic concurrency control.

Reads never write shared memory during execution; at commit the read set is
validated against record versions.  In the wave model (DESIGN.md section 2):
every lane's write set claims its (record, group) cells with the lane's
priority, then every read op probes the writer-claim table — a read conflicts
iff a strictly-higher-priority lane wrote the cell this wave.  Write-write
pairs do not abort (commit-time locks serialize the installs).

Timestamp granularity is the probe width: coarse probes treat a claim on any
column group of the record as a conflict (one timestamp per row), fine probes
look only at the op's own group — the paper's mechanism.

All shared-state access (claim scatter, read-set validate, version install)
routes through the kernel-backend surface of core/backend.py — Pallas kernels
or XLA gather/scatter, selected by ``EngineConfig.backend`` (DESIGN.md
section 5).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import claims
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    store = base.write_claims(store, batch, prio, wave, cfg)
    conflict = base.read_set_conflicts(store, batch, prio, wave, cfg)
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning
    res = base.result_from_conflicts(batch, conflict, eager=False)
    store = base.bump_versions(store, batch, res.commit, cfg)
    return store, res
