"""OCC — Silo/STO-style optimistic concurrency control.

Reads never write shared memory during execution; at commit the read set is
validated against record versions.  In the wave model (DESIGN.md section 2):
every lane's write set claims its (record, group) cells with the lane's
priority, then every read op probes the writer-claim table — a read conflicts
iff a strictly-higher-priority lane wrote the cell this wave.  Write-write
pairs do not abort (commit-time locks serialize the installs).

Timestamp granularity is the probe width: coarse probes treat a claim on any
column group of the record as a conflict (one timestamp per row), fine probes
look only at the op's own group — the paper's mechanism.

All shared-state access (claim install + probe, verdicts, version install)
routes through the kernel-backend surface of core/backend.py — Pallas
kernels or XLA gather/scatter, selected by ``EngineConfig.backend``
(DESIGN.md section 5).  The whole wave is ONE fused ``wave_commit`` op
(base.claim_probe_commit): a single pass over the writer claim table
installs the wave's write claims, compares every read's
strongest-claimant priority against the lane's own, and bumps versions
for the committed writes (``fuse_wave=False`` falls back to the unfused
claim_probe + commit_install chain, bit-identically).
"""
from __future__ import annotations

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    # Probe-independent verdict mask: live reads, window-thinned (a writer
    # install only lands in the read's vulnerability window w.p.
    # opt_overlap); the megakernel ANDs in the strictness compare.
    check = (batch.is_read() & batch.live()
             & (u < cfg.cost.opt_overlap))
    store, conflict = base.claim_probe_commit(store, batch, prio, wave, cfg,
                                              check_w=check)
    # Every OCC abort is a commit-time read-validation failure.
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=t.CAUSE_READ_VAL)
    return store, res
