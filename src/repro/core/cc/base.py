"""Shared pieces of the CC mechanism implementations.

All shared-state access goes through the kernel-backend surface
(``core/backend.py``): claim_probe / validate / validate_dual /
iterate_validate / ts_gather / claim_scatter / commit_install /
ts_install_max, resolved once per wave from ``EngineConfig.backend``.  No mechanism in this package branches on the
backend itself — that is the whole point of the layer (DESIGN.md section 5).

The probe family (OCC, TicToc, 2PL, SwissTM, Adaptive) runs its WHOLE
claim -> verdict -> install chain through ONE backend op
(``claim_probe_commit`` below — the backend's ``wave_commit`` megakernel,
kernels/wave_commit.py): a single launch with aliased claim/version tables
installs the wave's write claims, answers every op's strongest-claimant
probe, reduces the per-op conflicts to lane verdicts, and bumps versions
for committed writes — each touched row rides one DMA per wave.
``EngineConfig.fuse_wave=False`` falls back to the unfused chain (the
fused ``claim_probe`` per table + XLA verdict compare + ``commit_install``),
bit-identical by construction: both paths evaluate the same mask algebra
over the same primitives (guard-tested in tests/test_wave_commit.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims
from repro.core import types as t
from repro.core.types import EngineConfig, StoreState, TxnBatch


@partial(jax.tree_util.register_dataclass,
         data_fields=["commit", "conflict_op", "first_conflict",
                      "ext_penalty", "ext_count", "pess_frac", "ext_mask",
                      "cause_op"],
         meta_fields=["eager"])
@dataclasses.dataclass
class ValidationResult:
    commit: jax.Array          # bool[T]
    conflict_op: jax.Array     # bool[T, K] per-op conflict flags
    first_conflict: jax.Array  # int32[T] op index of first conflict (K if none)
    ext_penalty: jax.Array     # f32[T] extra simulated time (TicToc CAS chains)
    ext_count: jax.Array       # int32 scalar: rts-extension events this wave
    pess_frac: jax.Array       # f32[T] fraction of ops on pessimistic records
    ext_mask: jax.Array        # bool[T, K] rts-extension CASes: writes to
                               #   shared lines, so they join the install
                               #   contention chain (TicToc only)
    cause_op: jax.Array        # int32[T, K] ABORT_CAUSE code per conflicting
                               #   op, CAUSE_NONE elsewhere; the lane's abort
                               #   cause is min over its ops (types.CAUSE_*)
    eager: bool                # aborts cut work at first_conflict (2PL/Swiss)

    def lane_cause(self) -> jax.Array:
        """Per-lane abort cause: min cause code over the lane's ops
        (CAUSE_NONE for committing lanes — every cause code is set under
        the same final conflict mask that decides the abort)."""
        return self.cause_op.min(axis=1)


def result_from_conflicts(batch: TxnBatch, conflict_op: jax.Array,
                          eager: bool,
                          cause_op: jax.Array | int = t.CAUSE_READ_VAL
                          ) -> ValidationResult:
    """Build a ValidationResult from per-op conflict flags.

    ``cause_op`` is either one ABORT_CAUSE code for every conflicting op
    (mechanisms with a single abort channel) or an int32[T, K] array of
    codes; either way it is forced to CAUSE_NONE off the conflict mask so
    the per-lane min only sees real causes.

    Scan ops (extent > 1) validate ONLY through the interval pass
    (``phantom_validate`` — they are excluded from every point verdict
    channel), so a conflicting scan op IS a lost interval validation: its
    cause is forced to CAUSE_PHANTOM here, once, for every mechanism."""
    T, K = batch.op_key.shape
    commit = ~conflict_op.any(axis=1)
    if isinstance(cause_op, int):
        cause_op = jnp.full((T, K), cause_op, jnp.int32)
    cause_op = jnp.where(batch.is_scan(), jnp.int32(t.CAUSE_PHANTOM),
                         cause_op.astype(jnp.int32))
    cause_op = jnp.where(conflict_op, cause_op, jnp.int32(t.CAUSE_NONE))
    return ValidationResult(
        commit=commit,
        conflict_op=conflict_op,
        first_conflict=claims.first_true_index(conflict_op, K),
        ext_penalty=jnp.zeros((T,), jnp.float32),
        ext_count=jnp.int32(0),
        pess_frac=jnp.zeros((T,), jnp.float32),
        ext_mask=jnp.zeros((T, K), jnp.bool_),
        cause_op=cause_op,
        eager=eager,
    )


def bump_versions(store: StoreState, batch: TxnBatch, commit: jax.Array,
                  cfg: EngineConfig) -> StoreState:
    """Advance write timestamps for committed write-set ops (commit install).

    OCC-family version semantics: any committed modification of a (record,
    group) invalidates concurrent readers; the absolute value only needs to be
    monotone, so +1 per committed write op is sufficient (duplicates simply
    advance the clock further).  Routed through the backend surface's
    ``commit_install`` op — the sequential-grid Pallas kernel or an XLA
    scatter-add, identical results (DESIGN.md section 5)."""
    w = batch.is_write() & batch.live() & commit[:, None]
    with jax.named_scope("repro:install"):
        wts = kb.resolve(cfg).commit_install(store.wts, batch.op_key,
                                             batch.op_group, w)
    return dataclasses.replace(store, wts=wts)


def my_prio_per_op(batch: TxnBatch, prio: jax.Array) -> jax.Array:
    return jnp.broadcast_to(prio[:, None].astype(jnp.uint32),
                            batch.op_key.shape)


def phantom_validate(store: StoreState, batch: TxnBatch, prio: jax.Array,
                     wave: jax.Array, cfg: EngineConfig,
                     fine: bool | None = None, *,
                     mask: jax.Array | None = None) -> jax.Array:
    """Interval (scan) validation: the phantom check (DESIGN.md section 13).

    Routes the backend's ``iterate_validate`` op against the POST-install
    writer-claim table: a live scan op (extent > 1, read kind) conflicts
    when any record of its validated interval — the exact
    ``[key, key + extent)`` at the op's group column under fine (per-gap
    timestamps), the bucket-expanded interval under coarse
    (bucket-interval claims, one word per ``cfg.bucket_size`` records) —
    carries a live same-wave claim stronger than the lane.  The monotone
    wave tags make the post-install table show exactly this wave's
    writers, i.e. precisely the installs the scan's wave-start snapshot
    could have missed; scans validate UNTHINNED (an iterator's
    vulnerability window spans the whole wave), which is what the
    sequential-replay phantom oracle demands — no committed scan may miss
    a committed same-wave insert/write inside its interval.

    Returns conflict bool[T, K]; all-False (and compiled out — the row
    loop unrolls to ``cfg.max_extent``) when the config admits no scans."""
    if cfg.max_extent <= 1:
        return jnp.zeros(batch.op_key.shape, jnp.bool_)
    if fine is None:
        fine = is_fine(cfg)
    check = batch.is_scan() & batch.is_read() & batch.live()
    if mask is not None:
        check = check & mask
    with jax.named_scope("repro:iterate_validate"):
        return kb.resolve(cfg).iterate_validate(
            store.claim_w, batch.op_key, batch.op_extent, batch.op_group,
            my_prio_per_op(batch, prio), check, wave, fine,
            cfg.bucket_size, cfg.max_extent)


def claim_and_probe(store: StoreState, batch: TxnBatch, prio: jax.Array,
                    wave: jax.Array, cfg: EngineConfig,
                    fine: bool | None = None, *, table: str = "w",
                    mask: jax.Array | None = None
                    ) -> tuple[StoreState, jax.Array]:
    """Fused claim install + strongest-claimant probe on one claim table.

    Routes the backend's ``claim_probe`` op: ONE kernel pass min-installs
    the install-mask ops' claim words and returns the post-install probe
    (uint32 prio16, NO_PRIO where unclaimed/masked) for EVERY op — halving
    kernel launches and claim-row DMAs vs the old claim_scatter-then-probe
    pair on the wave's hottest table.

    ``table`` selects the claim channel ("w" writer / "r" reader); the
    install mask defaults to the channel's natural op set (live writes for
    "w", live reads for "r") and ``mask`` narrows it further (Adaptive's
    pessimistic-only visible reads).  Returns ``(store', wprio [T, K])``.
    """
    if fine is None:
        fine = is_fine(cfg)
    m = (batch.is_write() if table == "w" else batch.is_read()) & batch.live()
    if mask is not None:
        m = m & mask
    field = "claim_w" if table == "w" else "claim_r"
    with jax.named_scope("repro:claim"):
        tbl, wprio = kb.resolve(cfg).claim_probe(
            getattr(store, field), batch.op_key, batch.op_group,
            my_prio_per_op(batch, prio), wave, m, fine)
    return dataclasses.replace(store, **{field: tbl}), wprio


def claim_probe_commit(store: StoreState, batch: TxnBatch, prio: jax.Array,
                       wave: jax.Array, cfg: EngineConfig,
                       fine: bool | None = None, *,
                       check_w: jax.Array, check_w2: jax.Array | None = None,
                       check_r: jax.Array | None = None,
                       extra: jax.Array | None = None, dual: bool = False,
                       do_r_mask: jax.Array | None = None, bump: bool = True
                       ) -> tuple[StoreState, jax.Array]:
    """The probe family's whole wave in one call: claim install + probe +
    per-op conflicts (+ version bumps for committed writes).

    The mechanism hands over its verdict MASKS — probe-independent factors
    it precomputes (op kinds, thinning hashes, mode bits) — and the probe
    compare happens inside:

      conflict = check_w  & (wprio < myprio)                 # strongest-
               | check_w2 & (wprio != NO_PRIO != myprio)     #   claimant
               | check_r  & (rprio < myprio)                 #   channels
               | extra

    with ``wprio``/``rprio`` the post-install strongest-claimant probes of
    the writer / reader claim tables (the reader channel rides only when
    ``dual``; its install mask is live reads narrowed by ``do_r_mask``).
    ``bump`` +1s ``store.wts`` for committed write ops (bump_versions
    semantics).  Returns ``(store', conflict bool[T, K])``.

    ``cfg.fuse_wave`` selects the route: the backend's ``wave_commit``
    megakernel (one launch, one DMA per touched row), or the unfused
    ``claim_probe`` -> XLA verdict -> ``commit_install`` chain.  Both
    evaluate the same mask algebra over the same primitives, so they are
    bit-identical — tests/test_wave_commit.py pins it across mechanisms,
    granularities, and backends.

    Scan support (``cfg.max_extent > 1``): scan ops are carved out of every
    point channel — no read-claim installs, no point verdicts — and
    validated by ONE extra ``iterate_validate`` pass over the post-install
    writer-claim table (``phantom_validate``); version bumps then move
    AFTER the phantom verdicts so a phantom-aborted lane never advances
    versions.  At ``max_extent == 1`` none of this traces and both paths
    are bit-identical to the pre-extent code."""
    if fine is None:
        fine = is_fine(cfg)
    be = kb.resolve(cfg)
    live = batch.live()
    do_w = batch.is_write() & live
    scan = batch.is_scan() if cfg.max_extent > 1 else None
    if scan is not None:
        check_w = check_w & ~scan
        if check_w2 is not None:
            check_w2 = check_w2 & ~scan
        if check_r is not None:
            check_r = check_r & ~scan
    do_r = None
    if dual:
        do_r = batch.is_read() & live
        if do_r_mask is not None:
            do_r = do_r & do_r_mask
        if scan is not None:
            do_r = do_r & ~scan
    myp = my_prio_per_op(batch, prio)

    if getattr(cfg, "fuse_wave", True):
        fuse_bump = bump and scan is None
        with jax.named_scope("repro:wave_commit"):
            cw, cr, wts, conflict, _ = be.wave_commit(
                store.claim_w, store.claim_r if dual else None,
                store.wts if fuse_bump else None, batch.op_key,
                batch.op_group, myp, do_w, do_r, check_w, check_w2,
                check_r, extra, wave, fine, dual, fuse_bump)
        repl = {"claim_w": cw}
        if dual:
            repl["claim_r"] = cr
        if fuse_bump:
            repl["wts"] = wts
        store = dataclasses.replace(store, **repl)
        if scan is not None:
            conflict = conflict | phantom_validate(store, batch, prio,
                                                   wave, cfg, fine)
            if bump:
                store = bump_versions(store, batch,
                                      ~conflict.any(axis=1), cfg)
        return store, conflict

    # Unfused: the pre-megakernel chain, term by term.
    with jax.named_scope("repro:claim"):
        cw, wprio = be.claim_probe(store.claim_w, batch.op_key,
                                   batch.op_group, myp, wave, do_w, fine)
    store = dataclasses.replace(store, claim_w=cw)
    conflict = check_w & (wprio < myp)
    if check_w2 is not None:
        conflict = conflict | (check_w2 & (wprio != claims.NO_PRIO)
                               & (wprio != myp))
    if dual:
        with jax.named_scope("repro:claim"):
            cr, rprio = be.claim_probe(store.claim_r, batch.op_key,
                                       batch.op_group, myp, wave, do_r,
                                       fine)
        store = dataclasses.replace(store, claim_r=cr)
        conflict = conflict | (check_r & (rprio < myp))
    if extra is not None:
        conflict = conflict | extra
    if scan is not None:
        conflict = conflict | phantom_validate(store, batch, prio, wave,
                                               cfg, fine)
    if bump:
        store = bump_versions(store, batch, ~conflict.any(axis=1), cfg)
    return store, conflict


def write_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                 wave: jax.Array, cfg: EngineConfig) -> StoreState:
    """Write-set claims into the writer-claim table (backend
    ``claim_scatter``: the fused pack+scatter-min kernel on pallas, XLA
    scatter-min on jnp)."""
    cw = kb.resolve(cfg).claim_scatter(store.claim_w, batch.op_key,
                                       batch.op_group,
                                       my_prio_per_op(batch, prio), wave,
                                       batch.is_write() & batch.live())
    return dataclasses.replace(store, claim_w=cw)


def plain_write_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                       wave: jax.Array, cfg: EngineConfig) -> StoreState:
    """Plain-WRITE claims into the reader-claim table (MV mechanisms).

    First-committer-wins needs to distinguish overwrites from blind
    commutative ADDs: ADD-vs-ADD pairs never conflict (types.ADD), so an ADD
    op must only probe for stronger plain WRITEs.  The MV mechanisms take no
    visible-read locks, leaving ``claim_r`` free to carry this second claim
    channel — same packed words, same scatter op, no new table."""
    m = batch.is_plain_write() & batch.live()
    cr = kb.resolve(cfg).claim_scatter(store.claim_r, batch.op_key,
                                       batch.op_group,
                                       my_prio_per_op(batch, prio), wave, m)
    return dataclasses.replace(store, claim_r=cr)


def is_fine(cfg: EngineConfig) -> bool:
    return cfg.n_groups > 1 and cfg.granularity == 1
