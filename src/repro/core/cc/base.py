"""Shared pieces of the CC mechanism implementations."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import claims
from repro.core.types import OOB_KEY, EngineConfig, StoreState, TxnBatch


@partial(jax.tree_util.register_dataclass,
         data_fields=["commit", "conflict_op", "first_conflict",
                      "ext_penalty", "ext_count", "pess_frac", "ext_mask"],
         meta_fields=["eager"])
@dataclasses.dataclass
class ValidationResult:
    commit: jax.Array          # bool[T]
    conflict_op: jax.Array     # bool[T, K] per-op conflict flags
    first_conflict: jax.Array  # int32[T] op index of first conflict (K if none)
    ext_penalty: jax.Array     # f32[T] extra simulated time (TicToc CAS chains)
    ext_count: jax.Array       # int32 scalar: rts-extension events this wave
    pess_frac: jax.Array       # f32[T] fraction of ops on pessimistic records
    ext_mask: jax.Array        # bool[T, K] rts-extension CASes: writes to
                               #   shared lines, so they join the install
                               #   contention chain (TicToc only)
    eager: bool                # aborts cut work at first_conflict (2PL/Swiss)


def result_from_conflicts(batch: TxnBatch, conflict_op: jax.Array,
                          eager: bool) -> ValidationResult:
    T, K = batch.op_key.shape
    commit = ~conflict_op.any(axis=1)
    return ValidationResult(
        commit=commit,
        conflict_op=conflict_op,
        first_conflict=claims.first_true_index(conflict_op, K),
        ext_penalty=jnp.zeros((T,), jnp.float32),
        ext_count=jnp.int32(0),
        pess_frac=jnp.zeros((T,), jnp.float32),
        ext_mask=jnp.zeros((T, K), jnp.bool_),
        eager=eager,
    )


def bump_versions(store: StoreState, batch: TxnBatch, commit: jax.Array,
                  cfg: EngineConfig) -> StoreState:
    """Advance write timestamps for committed write-set ops (commit install).

    OCC-family version semantics: any committed modification of a (record,
    group) invalidates concurrent readers; the absolute value only needs to be
    monotone, so +1 per committed write op is sufficient (duplicates simply
    advance the clock further).  The ``pallas`` backend installs through the
    sequential-grid commit kernel; the ``jnp`` backend through an XLA
    scatter-add — identical results (DESIGN.md section 5)."""
    w = batch.is_write() & batch.live() & commit[:, None]
    if cfg.backend == "pallas":
        from repro.kernels import ops
        wts = ops.occ_commit(store.wts, batch.op_key, batch.op_group, w,
                             use_pallas=True)
    else:
        k = jnp.where(w, batch.op_key, OOB_KEY).reshape(-1)
        g = batch.op_group.reshape(-1)
        wts = store.wts.at[k, g].add(jnp.uint32(1), mode="drop")
    return dataclasses.replace(store, wts=wts)


def read_set_conflicts(store: StoreState, batch: TxnBatch, prio: jax.Array,
                       wave: jax.Array, cfg: EngineConfig,
                       fine=None) -> jax.Array:
    """Read-set probe against the writer-claim table (the OCC hot loop).

    Returns conflict bool[T, K]: True where a live read op's (record, group)
    cell was write-claimed this wave by a strictly-higher-priority lane.
    ``fine`` selects the probe width (granularity); it defaults to the
    config's static granularity and may be a per-op bool array
    (auto-granularity) — the kernel path requires a static bool, so per-op
    selectors always take the jnp path.

    Backend routing: ``pallas`` runs the scalar-prefetch DMA kernel
    (kernels/occ_validate.py — interpret mode off-TPU), ``jnp`` the
    gather-based probe.  Both decode the claim words of core/claimword.py and
    produce bit-identical flags (DESIGN.md section 5).
    """
    myp = my_prio_per_op(batch, prio)
    check = batch.is_read() & batch.live()
    if fine is None:
        fine = is_fine(cfg)
    if cfg.backend == "pallas" and isinstance(fine, bool):
        from repro.kernels import ops
        return ops.occ_validate(store.claim_w, batch.op_key, batch.op_group,
                                myp, check, claims.inv_wave(wave), fine,
                                use_pallas=True)
    wprio = claims.effective_probe(store.claim_w, batch.op_key,
                                   batch.op_group, wave, fine)
    return check & (wprio < myp)


def my_prio_per_op(batch: TxnBatch, prio: jax.Array) -> jax.Array:
    return jnp.broadcast_to(prio[:, None].astype(jnp.uint32),
                            batch.op_key.shape)


def write_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                 wave: jax.Array) -> StoreState:
    words = claims.claim_word(wave, my_prio_per_op(batch, prio))
    cw = claims.scatter_claims(store.claim_w, batch.op_key, batch.op_group,
                               words, batch.is_write() & batch.live())
    return dataclasses.replace(store, claim_w=cw)


def read_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                wave: jax.Array, mask=None) -> StoreState:
    m = batch.is_read() & batch.live()
    if mask is not None:
        m = m & mask
    words = claims.claim_word(wave, my_prio_per_op(batch, prio))
    cr = claims.scatter_claims(store.claim_r, batch.op_key, batch.op_group,
                               words, m)
    return dataclasses.replace(store, claim_r=cr)


def is_fine(cfg: EngineConfig) -> bool:
    return cfg.n_groups > 1 and cfg.granularity == 1
