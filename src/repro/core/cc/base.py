"""Shared pieces of the CC mechanism implementations.

All shared-state access goes through the kernel-backend surface
(``core/backend.py``): validate / validate_dual / probe / ts_gather /
claim_scatter / commit_install / ts_install_max, resolved once per wave from
``EngineConfig.backend``.  No mechanism in this package branches on the
backend itself — that is the whole point of the layer (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims
from repro.core.types import EngineConfig, StoreState, TxnBatch


@partial(jax.tree_util.register_dataclass,
         data_fields=["commit", "conflict_op", "first_conflict",
                      "ext_penalty", "ext_count", "pess_frac", "ext_mask"],
         meta_fields=["eager"])
@dataclasses.dataclass
class ValidationResult:
    commit: jax.Array          # bool[T]
    conflict_op: jax.Array     # bool[T, K] per-op conflict flags
    first_conflict: jax.Array  # int32[T] op index of first conflict (K if none)
    ext_penalty: jax.Array     # f32[T] extra simulated time (TicToc CAS chains)
    ext_count: jax.Array       # int32 scalar: rts-extension events this wave
    pess_frac: jax.Array       # f32[T] fraction of ops on pessimistic records
    ext_mask: jax.Array        # bool[T, K] rts-extension CASes: writes to
                               #   shared lines, so they join the install
                               #   contention chain (TicToc only)
    eager: bool                # aborts cut work at first_conflict (2PL/Swiss)


def result_from_conflicts(batch: TxnBatch, conflict_op: jax.Array,
                          eager: bool) -> ValidationResult:
    T, K = batch.op_key.shape
    commit = ~conflict_op.any(axis=1)
    return ValidationResult(
        commit=commit,
        conflict_op=conflict_op,
        first_conflict=claims.first_true_index(conflict_op, K),
        ext_penalty=jnp.zeros((T,), jnp.float32),
        ext_count=jnp.int32(0),
        pess_frac=jnp.zeros((T,), jnp.float32),
        ext_mask=jnp.zeros((T, K), jnp.bool_),
        eager=eager,
    )


def bump_versions(store: StoreState, batch: TxnBatch, commit: jax.Array,
                  cfg: EngineConfig) -> StoreState:
    """Advance write timestamps for committed write-set ops (commit install).

    OCC-family version semantics: any committed modification of a (record,
    group) invalidates concurrent readers; the absolute value only needs to be
    monotone, so +1 per committed write op is sufficient (duplicates simply
    advance the clock further).  Routed through the backend surface's
    ``commit_install`` op — the sequential-grid Pallas kernel or an XLA
    scatter-add, identical results (DESIGN.md section 5)."""
    w = batch.is_write() & batch.live() & commit[:, None]
    wts = kb.resolve(cfg).commit_install(store.wts, batch.op_key,
                                         batch.op_group, w)
    return dataclasses.replace(store, wts=wts)


def read_set_conflicts(store: StoreState, batch: TxnBatch, prio: jax.Array,
                       wave: jax.Array, cfg: EngineConfig,
                       fine: bool | None = None) -> jax.Array:
    """Read-set probe against the writer-claim table (the OCC hot loop).

    Returns conflict bool[T, K]: True where a live read op's (record, group)
    cell was write-claimed this wave by a strictly-higher-priority lane.
    ``fine`` selects the probe width (granularity) and defaults to the
    config's static granularity.  Mechanisms needing BOTH widths at once
    (auto-granularity) call the backend's ``validate_dual`` instead — one row
    fetch, two verdicts.

    Routed through the backend surface's ``validate`` op: the scalar-prefetch
    DMA kernel (kernels/occ_validate.py — interpret mode off-TPU) or the jnp
    gather probe.  Both decode the claim words of core/claimword.py and
    produce bit-identical flags (DESIGN.md section 5).
    """
    myp = my_prio_per_op(batch, prio)
    check = batch.is_read() & batch.live()
    if fine is None:
        fine = is_fine(cfg)
    return kb.resolve(cfg).validate(store.claim_w, batch.op_key,
                                    batch.op_group, myp, check, wave, fine)


def my_prio_per_op(batch: TxnBatch, prio: jax.Array) -> jax.Array:
    return jnp.broadcast_to(prio[:, None].astype(jnp.uint32),
                            batch.op_key.shape)


def write_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                 wave: jax.Array, cfg: EngineConfig) -> StoreState:
    """Write-set claims into the writer-claim table (backend
    ``claim_scatter``: the fused pack+scatter-min kernel on pallas, XLA
    scatter-min on jnp)."""
    cw = kb.resolve(cfg).claim_scatter(store.claim_w, batch.op_key,
                                       batch.op_group,
                                       my_prio_per_op(batch, prio), wave,
                                       batch.is_write() & batch.live())
    return dataclasses.replace(store, claim_w=cw)


def read_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                wave: jax.Array, cfg: EngineConfig,
                mask: jax.Array | None = None) -> StoreState:
    """Visible-read claims into the reader-claim table (2PL/Swiss/Adaptive)."""
    m = batch.is_read() & batch.live()
    if mask is not None:
        m = m & mask
    cr = kb.resolve(cfg).claim_scatter(store.claim_r, batch.op_key,
                                       batch.op_group,
                                       my_prio_per_op(batch, prio), wave, m)
    return dataclasses.replace(store, claim_r=cr)


def plain_write_claims(store: StoreState, batch: TxnBatch, prio: jax.Array,
                       wave: jax.Array, cfg: EngineConfig) -> StoreState:
    """Plain-WRITE claims into the reader-claim table (MV mechanisms).

    First-committer-wins needs to distinguish overwrites from blind
    commutative ADDs: ADD-vs-ADD pairs never conflict (types.ADD), so an ADD
    op must only probe for stronger plain WRITEs.  The MV mechanisms take no
    visible-read locks, leaving ``claim_r`` free to carry this second claim
    channel — same packed words, same scatter op, no new table."""
    m = batch.is_plain_write() & batch.live()
    cr = kb.resolve(cfg).claim_scatter(store.claim_r, batch.op_key,
                                       batch.op_group,
                                       my_prio_per_op(batch, prio), wave, m)
    return dataclasses.replace(store, claim_r=cr)


def is_fine(cfg: EngineConfig) -> bool:
    return cfg.n_groups > 1 and cfg.granularity == 1
