"""Auto-granularity OCC — beyond-paper mechanism from the paper's section 5:

    "We would be interested in designing a CC scheme that can automatically
     detect false conflicts due to coarse-grained timestamps and address them
     by dynamically increasing timestamp granularity."

This is that scheme.  Every record starts with a coarse (whole-row) timestamp.
When a read aborts under the coarse rule but would NOT have conflicted under
the fine rule (the writer hit a different column group) — the definition of a
false conflict — the record accumulates false-conflict heat; past
``autogran_up`` the record is promoted to fine-grained timestamps.  Promotion
is monotone per the paper's wording ("dynamically increasing"); heat decays
lazily so cold records stop accumulating.

The physical version table is always fine-width (G=2); promotion only changes
the probe width per record, so promotion is a metadata bit flip — no copy.

Both probe widths come from ONE ``validate_dual`` call on the kernel-backend
surface (core/backend.py): the dual-output kernel emits the fine and coarse
verdicts from a single claim-row DMA per op, so the double probe no longer
fetches every claim row twice per wave (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import OOB_KEY, EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    store = base.write_claims(store, batch, prio, wave, cfg)
    # Two probe widths, one claim table, ONE row fetch: the record's
    # fine_mode bit picks which verdict applies.
    myp = base.my_prio_per_op(batch, prio)
    check = batch.is_read() & batch.live() & ~batch.is_scan()
    conflict_fine, conflict_coarse = kb.resolve(cfg).validate_dual(
        store.claim_w, batch.op_key, batch.op_group, myp, check, wave)

    kf = jnp.where(batch.op_key >= 0, batch.op_key, OOB_KEY)
    is_fine_rec = store.fine_mode.at[kf].get(mode="fill", fill_value=False)
    conflict = jnp.where(is_fine_rec, conflict_fine, conflict_coarse)
    u = claims.hash01(wave, claims.lane_op_ids(*batch.op_key.shape))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning
    # Scans validate through the unthinned interval pass, always at the
    # COARSE (bucket-claim) layout: an interval spans records of mixed
    # promotion state, and the bucket expansion never misses a phantom.
    conflict = conflict | base.phantom_validate(store, batch, prio, wave,
                                                cfg, fine=False)
    # OCC rule at either probe width: all aborts are read validation.
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=t.CAUSE_READ_VAL)

    # False-conflict evidence: aborted under coarse, clean under fine.
    false_ev = conflict_coarse & ~conflict_fine & ~is_fine_rec
    heat, heat_wave = claims.touch_heat(
        store.false_heat, store.heat_wave, batch.op_key,
        jnp.ones_like(batch.op_val), wave, cfg.autogran_decay, false_ev)
    cur = claims.lazy_decayed(heat, heat_wave, batch.op_key, wave,
                              cfg.autogran_decay)
    promote = false_ev & (cur > cfg.autogran_up)
    k = jnp.where(promote, batch.op_key, OOB_KEY).reshape(-1)
    fine_mode = store.fine_mode.at[k].set(True, mode="drop")

    store = dataclasses.replace(store, false_heat=heat, heat_wave=heat_wave,
                                fine_mode=fine_mode)
    store = base.bump_versions(store, batch, res.commit, cfg)
    return store, res
