"""Concurrency-control mechanisms, vectorized over a wave of transactions.

Each mechanism implements

    wave_validate(store, batch, prio, wave, cfg) -> (store, ValidationResult)

scattering its claims into the wave-scoped claim tables, probing them, and
producing the wave's commit mask plus mechanism-specific bookkeeping (version
bumps, contention-manager state, adaptivity state machines).

The mechanisms mirror the paper's section 3.2 set: OCC (STO's default),
TicToc, 2PL, SwissTM contention management, our Adaptive reader-writer lock —
plus the beyond-paper Auto-granularity mechanism sketched in the paper's
section 5 and the multi-version pair (MVCC snapshot isolation, serializable
MV-OCC) built on the version ring of core/mvstore.py, which extends the
paper's granularity question to stores where readers never block.

Every mechanism touches shared state only through the kernel-backend surface
(core/backend.py): claim_probe (the fused claim install + probe the whole
probe family runs) / validate / validate_dual / ts_gather / claim_scatter /
commit_install / ts_install_max, resolved from ``EngineConfig.backend`` —
XLA gather/scatter or TPU Pallas kernels, bit-identical (DESIGN.md
section 5).  No per-mechanism backend branches live in this package.
"""
from repro.core.cc.base import ValidationResult
from repro.core.cc.occ import wave_validate as occ_validate
from repro.core.cc.tictoc import wave_validate as tictoc_validate
from repro.core.cc.two_pl import wave_validate as two_pl_validate
from repro.core.cc.swisstm import wave_validate as swisstm_validate
from repro.core.cc.adaptive import wave_validate as adaptive_validate
from repro.core.cc.autogran import wave_validate as autogran_validate
from repro.core.cc.mvcc import wave_validate as mvcc_validate
from repro.core.cc.mvocc import wave_validate as mvocc_validate

from repro.core import types as _t

VALIDATORS = {
    _t.CC_OCC: occ_validate,
    _t.CC_TICTOC: tictoc_validate,
    _t.CC_2PL: two_pl_validate,
    _t.CC_SWISS: swisstm_validate,
    _t.CC_ADAPTIVE: adaptive_validate,
    _t.CC_AUTOGRAN: autogran_validate,
    _t.CC_MVCC: mvcc_validate,
    _t.CC_MVOCC: mvocc_validate,
}

__all__ = ["ValidationResult", "VALIDATORS", "occ_validate", "tictoc_validate",
           "two_pl_validate", "swisstm_validate", "adaptive_validate",
           "autogran_validate", "mvcc_validate", "mvocc_validate"]
