"""SwissTM-style CC: eager write locking, invisible reads with commit-time
validation, and a timestamp-based contention manager (Dragojevic et al.,
PLDI'09; paper section 3.2).

The contention manager favors the transaction that has been running (retrying)
longer: priority encodes transaction age in its high bits (claims.prio16 with
use_age=True, supplied by the engine), so when two lanes conflict the *younger*
one aborts regardless of lane order.  Write-write conflicts are detected
eagerly (at the op acquiring the write lock); read-write conflicts are found
at commit-time validation like OCC, so a read-invalidated lane wastes its full
execution.

Claim install and probe are ONE fused ``claim_probe`` pass over the
writer-claim table on the kernel-backend surface (core/backend.py) —
Pallas kernels or XLA gather/scatter per ``EngineConfig.backend``
(DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live
    myp = base.my_prio_per_op(batch, prio)

    store, wprio = base.claim_and_probe(store, batch, prio, wave, cfg, fine)

    ww = wr & (wprio < myp)   # eager: lost the write lock to an older txn
    rw = rd & (wprio < myp)   # late: read invalidated at commit validation
    uo = claims.hash01(wave + jnp.uint32(77),
                       claims.lane_op_ids(*batch.op_key.shape))
    rw = rw & (uo < cfg.cost.opt_overlap)              # window thinning
    # Phase-overlap thinning on the eager lock part (see two_pl.py).
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    ww = ww & (u < cfg.cost.phase_overlap)
    conflict = ww | rw
    # Eager write-lock losses are lock-wounds (the CM wounds the younger
    # txn); invisible-read invalidations are read-validation failures.
    cause = jnp.where(ww, jnp.int32(t.CAUSE_LOCK_WOUND),
                      jnp.int32(t.CAUSE_READ_VAL))
    res = base.result_from_conflicts(batch, conflict, eager=True,
                                     cause_op=cause)
    # Only write conflicts cut work early; a lane whose first conflict is a
    # read conflict wastes the whole execution (commit-time validation).
    K = batch.slots
    first_ww = claims.first_true_index(ww, K)
    res = dataclasses.replace(res, first_conflict=first_ww)
    store = base.bump_versions(store, batch, res.commit, cfg)
    return store, res
