"""SwissTM-style CC: eager write locking, invisible reads with commit-time
validation, and a timestamp-based contention manager (Dragojevic et al.,
PLDI'09; paper section 3.2).

The contention manager favors the transaction that has been running (retrying)
longer: priority encodes transaction age in its high bits (claims.prio16 with
use_age=True, supplied by the engine), so when two lanes conflict the *younger*
one aborts regardless of lane order.  Write-write conflicts are detected
eagerly (at the op acquiring the write lock); read-write conflicts are found
at commit-time validation like OCC, so a read-invalidated lane wastes its full
execution.

Claim install, probe, verdicts, and version bumps are ONE fused
``wave_commit`` pass over the writer-claim table on the kernel-backend
surface (base.claim_probe_commit, core/backend.py) — Pallas kernels or
XLA gather/scatter per ``EngineConfig.backend`` (DESIGN.md section 5).
The eager/late split (which conflicts cut work early) falls out of the
returned conflict mask: write ops' conflicts are exactly the eager
write-lock losses, since the read and write channels are disjoint.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live

    # Probe-independent mask: eager write-lock losses (phase-overlap
    # thinned, see two_pl.py) and commit-time read invalidations (window
    # thinned) share the writer-table strongest-claimant compare, so one
    # check_w channel carries both.
    uo = claims.hash01(wave + jnp.uint32(77),
                       claims.lane_op_ids(*batch.op_key.shape))
    T, K = batch.op_key.shape
    u = claims.hash01(wave, claims.lane_op_ids(T, K))
    check_w = ((wr & (u < cfg.cost.phase_overlap))
               | (rd & (uo < cfg.cost.opt_overlap)))
    store, conflict = base.claim_probe_commit(store, batch, prio, wave, cfg,
                                              fine, check_w=check_w)
    # rd/wr are disjoint, so a write op's conflict IS an eager lock loss.
    ww = conflict & wr
    # Eager write-lock losses are lock-wounds (the CM wounds the younger
    # txn); invisible-read invalidations are read-validation failures.
    cause = jnp.where(ww, jnp.int32(t.CAUSE_LOCK_WOUND),
                      jnp.int32(t.CAUSE_READ_VAL))
    res = base.result_from_conflicts(batch, conflict, eager=True,
                                     cause_op=cause)
    # Only write conflicts cut work early; a lane whose first conflict is a
    # read conflict wastes the whole execution (commit-time validation).
    K = batch.slots
    first_ww = claims.first_true_index(ww, K)
    res = dataclasses.replace(res, first_conflict=first_ww)
    return store, res
