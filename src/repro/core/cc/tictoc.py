"""TicToc — time-traveling OCC (Yu et al., SIGMOD'16), wave-vectorized.

Each (record, group) carries a write timestamp ``wts`` and a read timestamp
``rts`` (rts >= wts).  A transaction computes

    commit_ts = max( max_{reads} wts,  max_{writes} rts + 1 )

and can serialize *before* a concurrent writer of a record it read, as long as
commit_ts <= that record's rts — the paper's Figure 1 reordering.  A read only
aborts when a higher-priority lane writes its cell this wave AND the reader's
commit_ts exceeds the cell's rts (no room to time-travel).

Costs the paper highlights: extending rts is a CAS on shared metadata of a
record that was merely read — undermining OCC's silent-read property.  We
count extension events and charge a serialization penalty when several lanes
extend the same cell in one wave (the many-core degradation of the paper's
Figures 2a/3a).  Per the paper's section 3.2 we model the 128-bit
(non-compressed) timestamp variant — their 64-bit compressed variant aborted
more than OCC due to overflow — and STO's non-waiting deadlock prevention.

Shared-state access routes through the kernel-backend surface
(core/backend.py): claim install + probe + both read-abort verdict
channels are ONE fused ``wave_commit`` op (base.claim_probe_commit; TicToc
installs no version bumps, its timestamps move separately), the (wts, rts)
observation its ``ts_gather`` row-gather (coarse = row max), the monotone
timestamp installs its ``ts_install_max`` scatter-max, and the same-cell
extender/committer counts its ``segment_count`` (the all-pairs kernel that
closed the pallas path's last XLA sort) — Pallas kernels on
``backend="pallas"``, XLA gather/scatter on ``"jnp"``, bit-identical either
way (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import backend as kb
from repro.core import claims
from repro.core import types as t
from repro.core.cc import base
from repro.core.types import EngineConfig, StoreState, TxnBatch


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    be = kb.resolve(cfg)
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live

    # (wts, rts) observation honoring granularity: coarse sees one timestamp
    # per record = the row max (any group modification constrains the row).
    # Reads the pre-wave tables — TicToc installs timestamps separately
    # below, so the fused claim pass never touches them (bump=False).
    wts_op = be.ts_gather(store.wts, batch.op_key, batch.op_group, fine)
    rts_op = be.ts_gather(store.rts, batch.op_key, batch.op_group, fine)

    # commit_ts over live ops (uint32; 0 when no ops).
    ts_term = jnp.where(wr, rts_op + 1, jnp.where(rd, wts_op, 0))
    commit_ts = ts_term.max(axis=1)  # [T]

    # Read validation: a concurrent (same-wave, earlier-priority) writer
    # bumps wts past rts; the read survives iff it can serialize at
    # commit_ts <= rts.  Probe-independent mask (window-thinned); the
    # megakernel ANDs in the strictness compare.  Scan ops never ride the
    # timestamp channels: an iterator cannot CAS-extend rts over an
    # interval, so scans validate solely through the unthinned interval
    # pass (base.claim_probe_commit's phantom check).
    ext_need = rd & (commit_ts[:, None] > rts_op) & ~batch.is_scan()
    u = claims.hash01(wave, claims.lane_op_ids(*batch.op_key.shape))
    check_w = ext_need & (u < cfg.cost.opt_overlap)

    # Extension failure: extending rts requires a CAS on the version word;
    # if another transaction holds the cell's write lock at that moment the
    # non-waiting policy aborts the reader ("leading to more aborts",
    # paper section 4.2).  This is what collapses TicToc under high
    # contention: the hotter the cell, the likelier its lock is held.
    # The any-OTHER-writer compare (wprio != NO_PRIO, != myp) is the
    # megakernel's second writer channel (check_w2).
    u2 = claims.hash01(wave + jnp.uint32(131),
                       claims.lane_op_ids(*batch.op_key.shape))
    check_w2 = ext_need & (u2 < cfg.cost.phase_overlap)

    store, conflict = base.claim_probe_commit(store, batch, prio, wave, cfg,
                                              fine, check_w=check_w,
                                              check_w2=check_w2, bump=False)
    # Both abort channels (no-room-to-time-travel and the failed rts
    # extension CAS) invalidate a READ — one read-validation cause.
    res = base.result_from_conflicts(batch, conflict, eager=False,
                                     cause_op=t.CAUSE_READ_VAL)
    commit = res.commit

    # rts extension: committed reads whose commit_ts > rts CAS rts upward.
    ext = ext_need & commit[:, None]
    ext_count = ext.sum().astype(jnp.int32)

    # Extension contention: n lanes CASing the same (record, group) rts
    # serialize on its cacheline; with retries the expected cost per
    # extender grows with the number of contenders (each failed CAS
    # re-reads the line) — the many-core collapse of the paper's Fig 2a/3a.
    # Same-cell extender counts come from the backend's segment_count op
    # (the all-pairs Pallas kernel or the jnp sort; no O(n_records) table).
    G = store.wts.shape[1]
    n_ext = be.segment_count(batch.op_key, batch.op_group, G, ext)
    # Every extension pays the base CAS (c_ext); same-cell extenders
    # additionally serialize on the line — each waits on average for half
    # the contenders ahead of it (the high-contention collapse of Fig 2a).
    per_op = jnp.where(
        n_ext > 0,
        jnp.float32(cfg.cost.c_ext)
        + 0.5 * jnp.float32(cfg.cost.lam_ext) * jnp.maximum(n_ext - 1.0, 0.0),
        0.0)
    ext_penalty = per_op.sum(axis=1)

    # Timestamp installs (vs the snapshot; monotone scatter-max via the
    # backend's ts_install_max).  Within-wave cts chaining: n same-cell
    # writers serialize their installs (each holds the write lock in turn),
    # so the surviving wts/rts advance by ~n per wave, not 1 — hot-row
    # timestamps inflate with contention and cross-row skew grows, which is
    # what aborts multi-hot-row readers at high thread counts (TicToc's own
    # high-core degradation, paper Fig 3a).
    cts = jnp.broadcast_to(commit_ts[:, None], batch.op_key.shape)
    wmask = wr & commit[:, None]
    n_wcell = be.segment_count(batch.op_key, batch.op_group,
                               store.wts.shape[1], wmask)
    cts = cts + 2 * (jnp.maximum(n_wcell, 1.0).astype(jnp.uint32) - 1)
    wts = be.ts_install_max(store.wts, batch.op_key, batch.op_group, cts,
                            wmask)
    rts = be.ts_install_max(store.rts, batch.op_key, batch.op_group, cts,
                            wmask)
    # rts extension installs; coarse extension raises the whole row's read
    # horizon (one timestamp per record).
    rts = be.ts_install_max(rts, batch.op_key, batch.op_group, cts, ext,
                            whole_row=not fine)
    store = dataclasses.replace(store, wts=wts, rts=rts)

    res = dataclasses.replace(res, ext_penalty=ext_penalty,
                              ext_count=ext_count, ext_mask=ext)
    return store, res
