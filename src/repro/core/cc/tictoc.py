"""TicToc — time-traveling OCC (Yu et al., SIGMOD'16), wave-vectorized.

Each (record, group) carries a write timestamp ``wts`` and a read timestamp
``rts`` (rts >= wts).  A transaction computes

    commit_ts = max( max_{reads} wts,  max_{writes} rts + 1 )

and can serialize *before* a concurrent writer of a record it read, as long as
commit_ts <= that record's rts — the paper's Figure 1 reordering.  A read only
aborts when a higher-priority lane writes its cell this wave AND the reader's
commit_ts exceeds the cell's rts (no room to time-travel).

Costs the paper highlights: extending rts is a CAS on shared metadata of a
record that was merely read — undermining OCC's silent-read property.  We
count extension events and charge a serialization penalty when several lanes
extend the same cell in one wave (the many-core degradation of the paper's
Figures 2a/3a).  Per the paper's section 3.2 we model the 128-bit
(non-compressed) timestamp variant — their 64-bit compressed variant aborted
more than OCC due to overflow — and STO's non-waiting deadlock prevention.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import claims
from repro.core.cc import base
from repro.core.types import OOB_KEY, EngineConfig, StoreState, TxnBatch


def _gather_ts(table, batch: TxnBatch, fine: bool):
    """Per-op timestamp observation honoring granularity.

    Coarse granularity sees one timestamp per record = the row max (any group
    modification invalidates/constrains the whole row)."""
    k = jnp.where(batch.op_key >= 0, batch.op_key, OOB_KEY)
    if fine:
        return table.at[k, batch.op_group].get(mode="fill", fill_value=0)
    rows = table.at[k, :].get(mode="fill", fill_value=0)
    return rows.max(axis=-1)


def wave_validate(store: StoreState, batch: TxnBatch, prio, wave,
                  cfg: EngineConfig):
    fine = base.is_fine(cfg)
    live = batch.live()
    rd = batch.is_read() & live
    wr = batch.is_write() & live
    myp = base.my_prio_per_op(batch, prio)

    store = base.write_claims(store, batch, prio, wave)
    wprio = claims.effective_probe(store.claim_w, batch.op_key,
                                   batch.op_group, wave, fine)

    wts_op = _gather_ts(store.wts, batch, fine)
    rts_op = _gather_ts(store.rts, batch, fine)

    # commit_ts over live ops (uint32; 0 when no ops).
    ts_term = jnp.where(wr, rts_op + 1, jnp.where(rd, wts_op, 0))
    commit_ts = ts_term.max(axis=1)  # [T]

    # Read validation: a concurrent (same-wave, earlier-priority) writer bumps
    # wts past rts; the read survives iff it can serialize at commit_ts <= rts.
    conflict = rd & (wprio < myp) & (commit_ts[:, None] > rts_op)
    u = claims.hash01(wave, claims.lane_op_ids(*batch.op_key.shape))
    conflict = conflict & (u < cfg.cost.opt_overlap)   # window thinning

    # Extension failure: extending rts requires a CAS on the version word;
    # if another transaction holds the cell's write lock at that moment the
    # non-waiting policy aborts the reader ("leading to more aborts",
    # paper section 4.2).  This is what collapses TicToc under high
    # contention: the hotter the cell, the likelier its lock is held.
    ext_need = rd & (commit_ts[:, None] > rts_op)
    other_writer = (wprio != claims.NO_PRIO) & (wprio != myp)
    u2 = claims.hash01(wave + jnp.uint32(131),
                       claims.lane_op_ids(*batch.op_key.shape))
    ext_fail = ext_need & other_writer & (u2 < cfg.cost.phase_overlap)
    conflict = conflict | ext_fail
    res = base.result_from_conflicts(batch, conflict, eager=False)
    commit = res.commit

    # rts extension: committed reads whose commit_ts > rts CAS rts upward.
    ext = ext_need & commit[:, None]
    ext_count = ext.sum().astype(jnp.int32)

    # Extension contention: n lanes CASing the same (record, group) rts
    # serialize on its cacheline; with retries the expected cost per
    # extender grows with the number of contenders (each failed CAS
    # re-reads the line) — the many-core collapse of the paper's Fig 2a/3a.
    # Count same-cell extenders in-wave via a sort (no O(n_records) table).
    T, K = batch.op_key.shape
    G = store.wts.shape[1]
    cell = jnp.where(ext, batch.op_key * G + batch.op_group,
                     jnp.int32(0x7FFFFFFF)).reshape(-1)
    scell = jnp.sort(cell)
    lo = jnp.searchsorted(scell, cell, side="left")
    hi = jnp.searchsorted(scell, cell, side="right")
    n_ext = jnp.where(ext.reshape(-1), (hi - lo).astype(jnp.float32), 0.0)
    # Every extension pays the base CAS (c_ext); same-cell extenders
    # additionally serialize on the line — each waits on average for half
    # the contenders ahead of it (the high-contention collapse of Fig 2a).
    per_op = jnp.where(
        n_ext > 0,
        jnp.float32(cfg.cost.c_ext)
        + 0.5 * jnp.float32(cfg.cost.lam_ext) * jnp.maximum(n_ext - 1.0, 0.0),
        0.0)
    ext_penalty = per_op.reshape(T, K).sum(axis=1)

    # Timestamp installs (vs the snapshot; monotone scatter-max).
    # Within-wave cts chaining: n same-cell writers serialize their installs
    # (each holds the write lock in turn), so the surviving wts/rts advance
    # by ~n per wave, not 1 — hot-row timestamps inflate with contention and
    # cross-row skew grows, which is what aborts multi-hot-row readers at
    # high thread counts (TicToc's own high-core degradation, paper Fig 3a).
    cts = jnp.broadcast_to(commit_ts[:, None], batch.op_key.shape)
    wmask = wr & commit[:, None]
    n_wcell = claims.cell_counts(batch.op_key, batch.op_group,
                                 store.wts.shape[1], wmask)
    cts = cts + 2 * (jnp.maximum(n_wcell, 1.0).astype(jnp.uint32) - 1)
    kw = jnp.where(wmask, batch.op_key, OOB_KEY).reshape(-1)
    ke = jnp.where(ext, batch.op_key, OOB_KEY).reshape(-1)
    g = batch.op_group.reshape(-1)
    ctsf = cts.reshape(-1)
    wts = store.wts.at[kw, g].max(ctsf, mode="drop")
    rts = store.rts.at[kw, g].max(ctsf, mode="drop")
    if fine:
        rts = rts.at[ke, g].max(ctsf, mode="drop")
    else:
        # Coarse extension raises the whole row's read horizon.
        for gg in range(store.rts.shape[1]):
            rts = rts.at[ke, gg].max(ctsf, mode="drop")
    store = dataclasses.replace(store, wts=wts, rts=rts)

    res = dataclasses.replace(res, ext_penalty=ext_penalty,
                              ext_count=ext_count, ext_mask=ext)
    return store, res
