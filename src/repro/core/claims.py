"""Wave-scoped claim tables: the vectorized replacement for CAS races.

On the paper's x86 platform, N threads race compare-and-swap instructions on
per-record version words; the cache-coherence protocol serializes them and one
winner emerges.  On a TPU there is no CAS — but an XLA ``scatter`` with a
``min`` combiner over duplicate indices computes exactly "the strongest
claimant per (record, group)" in one vectorized pass.  That is the only
primitive every CC mechanism in this package needs.

Reset-free tables via a monotone wave tag
-----------------------------------------
Claim tables are as large as the database (10M+ records); memsetting them every
wave would cost O(n_records) memory traffic per wave.  Instead each claim word
embeds the wave number, arranged to be *monotonically decreasing*:

    word = ((MAX_WAVE - wave) << 16) | prio16          (uint32)

A claim from wave w is numerically smaller than every claim from waves < w, so
``scatter-min`` makes the current wave always win and stale entries are simply
ignored at probe time (their tag mismatches).  No reset, ever.  The bit layout
itself lives in ``core/claimword.py``, shared with the Pallas kernels so both
engine backends read the same words; the engine reaches these helpers through
the backend surface of ``core/backend.py``, whose pallas side replaces the
XLA scatter-min with the fused kernels/claim_scatter.py (DESIGN.md
section 5).

``prio16`` is the in-wave priority: ``(inv_age << PRIO_LANE_BITS) | lane_rank``
— lower value = earlier in the wave's serialization order.  Contention-managed
mechanisms (SwissTM) put transaction age in the high bits so starved
transactions win conflicts; the rest use a per-wave random permutation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.claimword import (EMPTY_WORD, MAX_WAVE, NO_PRIO, PRIO16_MASK,
                                  claim_word, inv_wave, live_prio)
from repro.core.types import OOB_KEY, PRIO_LANE_BITS


def prio16(age: jax.Array, lane_rank: jax.Array,
           use_age: bool = False) -> jax.Array:
    """In-wave priority; lower wins.  ``use_age`` enables the SwissTM-style
    contention manager (older transactions win claims)."""
    max_age = (1 << (16 - PRIO_LANE_BITS)) - 1  # 63
    if use_age:
        inv_age = max_age - jnp.clip(age, 0, max_age)
    else:
        inv_age = jnp.full_like(age, max_age)
    return (inv_age.astype(jnp.uint32) << PRIO_LANE_BITS) | (
        lane_rank.astype(jnp.uint32) & ((1 << PRIO_LANE_BITS) - 1))


def scatter_claims(table: jax.Array, keys: jax.Array, groups: jax.Array,
                   words: jax.Array, mask: jax.Array) -> jax.Array:
    """scatter-min claim words into table[record, group].

    keys/groups/words/mask: int32/uint32/bool arrays of identical shape
    (typically [T, K]).  Masked-out entries are dropped via an out-of-bounds
    key (OOB_KEY — negative keys would *wrap*, see types.OOB_KEY).
    """
    k = jnp.where(mask & (keys >= 0), keys, OOB_KEY)
    return table.at[k.reshape(-1), groups.reshape(-1)].min(
        words.reshape(-1), mode="drop")


def probe(table: jax.Array, keys: jax.Array, groups: jax.Array,
          wave: jax.Array) -> jax.Array:
    """Strongest current-wave claimant priority for each (key, group).

    Returns uint16-valued uint32 array shaped like ``keys``; NO_PRIO when no
    live claim exists.  Negative (masked) keys are remapped out-of-bounds so
    the fill value applies (negative gathers would wrap to the last record).
    """
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    words = table.at[k, groups].get(mode="fill", fill_value=EMPTY_WORD)
    return live_prio(words, inv_wave(wave))


def probe_any_group(table: jax.Array, keys: jax.Array,
                    wave: jax.Array) -> jax.Array:
    """Strongest current-wave claimant on *any* group of the record.

    This is how coarse granularity is expressed: a coarse-grained probe treats
    a claim on any column group as a conflict with the whole record, while a
    fine-grained probe (``probe``) only looks at the op's own group.  Claims
    are always scattered at fine granularity; granularity is purely a probe
    width (see DESIGN.md section 2).
    """
    # table: [n_records, G]; gather whole rows then reduce.
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    rows = table.at[k, :].get(mode="fill", fill_value=EMPTY_WORD)  # [..., G]
    return live_prio(rows, inv_wave(wave)).min(axis=-1)


def effective_probe(table: jax.Array, keys: jax.Array, groups: jax.Array,
                    wave: jax.Array, fine: jax.Array) -> jax.Array:
    """Per-op probe honoring a per-op granularity selector ``fine`` (bool).

    ``fine`` may be a scalar python bool (static granularity config) or a
    per-op boolean array (auto-granularity: per-record fine_mode gathered for
    each op)."""
    if isinstance(fine, bool):
        return (probe(table, keys, groups, wave) if fine
                else probe_any_group(table, keys, wave))
    f = probe(table, keys, groups, wave)
    c = probe_any_group(table, keys, wave)
    return jnp.where(fine, f, c)


def lazy_decayed(heat: jax.Array, heat_wave: jax.Array, keys: jax.Array,
                 wave: jax.Array, decay: float) -> jax.Array:
    """Gather heat[keys] with exponential decay applied lazily.

    heat semantics: an EWMA that would be multiplied by ``decay`` every wave.
    Rather than touching the whole table each wave, we record the wave of the
    last touch and apply decay**(now - last) at gather time.
    """
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    h = heat.at[k].get(mode="fill", fill_value=0.0)
    lw = heat_wave.at[k].get(mode="fill", fill_value=0)
    dt = jnp.maximum(wave.astype(jnp.int32) - lw, 0).astype(jnp.float32)
    return h * jnp.power(jnp.float32(decay), dt)


def touch_heat(heat: jax.Array, heat_wave: jax.Array, keys: jax.Array,
               add: jax.Array, wave: jax.Array, decay: float,
               mask: jax.Array):
    """Scatter-update heats for touched records: decayed + add.

    Duplicate keys within the same wave: adds accumulate on top of one decayed
    base (scatter-add after a scatter of the decayed base).  Returns (heat,
    heat_wave)."""
    k = jnp.where(mask, keys, OOB_KEY).reshape(-1)
    decayed = lazy_decayed(heat, heat_wave, keys, wave, decay).reshape(-1)
    # First settle the decayed base for every touched record (duplicates write
    # the same value; unordered scatter is fine), then accumulate adds.
    heat = heat.at[k].set(jnp.where(mask.reshape(-1), decayed, 0.0),
                          mode="drop")
    heat = heat.at[k].add(jnp.where(mask.reshape(-1), add.reshape(-1), 0.0),
                          mode="drop")
    heat_wave = heat_wave.at[k].set(wave.astype(jnp.int32), mode="drop")
    return heat, heat_wave


def hash01(wave: jax.Array, lane_op_ids: jax.Array) -> jax.Array:
    """Deterministic per-(wave, lane, op) uniform in [0, 1) — the stateless
    randomness used by the phase-overlap thinning (no PRNG threading)."""
    h = (lane_op_ids.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
         + wave.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    return h.astype(jnp.float32) / jnp.float32(2 ** 32)


def lane_op_ids(T: int, K: int) -> jax.Array:
    return (jnp.arange(T * K, dtype=jnp.uint32)).reshape(T, K)


def cell_counts(keys: jax.Array, groups: jax.Array, G: int,
                mask: jax.Array) -> jax.Array:
    """#ops in this wave hitting the same (record, group), per op (0 where
    masked).  Sort-based — no O(n_records) table."""
    cell = jnp.where(mask, keys * G + groups, jnp.int32(0x7FFFFFFF))
    flat = cell.reshape(-1)
    s = jnp.sort(flat)
    lo = jnp.searchsorted(s, flat, side="left")
    hi = jnp.searchsorted(s, flat, side="right")
    return jnp.where(mask.reshape(-1), (hi - lo),
                     0).reshape(keys.shape).astype(jnp.float32)


def first_true_index(flags: jax.Array, size: int) -> jax.Array:
    """Index of first True along the last axis, or ``size`` if none."""
    idx = jnp.arange(size, dtype=jnp.int32)
    return jnp.min(jnp.where(flags, idx, size), axis=-1).astype(jnp.int32)
