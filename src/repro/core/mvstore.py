"""The multi-version record store: a fixed-depth ring of versions per record.

Single-version OCC (the paper's subject) aborts a reader whenever a
concurrent writer bumps the version it read.  The strongest competing family
in the literature — multi-versioning (Larson et al., "High-Performance
Concurrency Control Mechanisms for Main-Memory Databases"; Dashti et al.,
"Repairing Conflicts among MVCC Transactions") — keeps the old versions
around instead, so readers *never block and never abort*: they read the
newest version visible at their snapshot timestamp.  This module is the
store-side machinery the MV mechanisms (``cc/mvcc.py``, ``cc/mvocc.py``)
build on, letting the repro ask the paper's question in the multi-version
world: does timestamp granularity still matter when readers never block?
(DESIGN.md section 9.)

Ring layout
-----------
Each record owns a fixed-depth ring of D version slots:

    mv_begin uint32[n_records, D, G]  begin timestamp per slot per
                                      granularity group
    mv_head  int32[n_records]         index of the newest slot
    mv_vals  f32[n_records, D, C]     version values (track_values only)

A slot's *begin* timestamp is per granularity group — THIS is where the
paper's contribution enters the multi-version world.  A committed write that
touches only group g publishes ``begin[g] = install_ts`` in the new slot and
*carries forward* the other groups' begin timestamps (their data did not
change).  A fine-granularity snapshot read of group g looks for the newest
slot whose ``begin[g]`` fits under its snapshot; a coarse read treats the
record as one unit (``max_g begin[g]`` — one timestamp per record), so a
group-g-only update invalidates coarse readers of *every* group: the false
conflicts of the paper's section 3.4, reproduced at the version-chain level.

Timestamps are wave-derived: a transaction in wave w reads at snapshot
``snapshot_ts(w) = w`` (the wave's start) and committed writes install at
``install_ts(w) = w + 1`` — visible to every later wave, never to their own
wave's snapshots.  At most ONE new slot is installed per record per wave
(concurrent committed writers of different groups merge into it; the
first-committer-wins rule serializes same-cell writers), so the head cursor
advances 0 or 1 per record per wave.

Reclamation is epoch-based and free: installing into a full ring overwrites
the oldest slot ((head + 1) mod D).  A reader whose snapshot predates every
retained slot gets ``ok = False`` from the ``mv_gather`` op and aborts
cleanly — it can never read a torn or recycled version, because visibility
is decided purely from the begin timestamps it fetched.  Empty slots carry
``MV_EMPTY`` begins and are invisible to every snapshot.

All state is pure JAX arrays threaded through ``StoreState``/``EngineState``
(sweep-compatible: vmapped grids carry the ring like every other table), and
all shared-state access goes through the backend op surface of
``core/backend.py``: ``mv_gather`` (snapshot version select) and
``mv_install`` (ring-slot claim + version publish), each with jnp and Pallas
implementations (``kernels/mv_gather.py`` / ``kernels/mv_install.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Plain int (not a jnp scalar): baked into Pallas kernel bodies, which may
# not capture traced constants.
MV_EMPTY = 0xFFFFFFFF   # begin value of a never-installed ring slot


def snapshot_ts(wave: jax.Array, age: int = 0) -> jax.Array:
    """A wave-w transaction reads as of the wave's start: installs from
    waves < w (begin <= w) are visible, this wave's (begin = w + 1) are
    not.

    ``age`` (EngineConfig.snapshot_age / DistConfig.snapshot_age) pins the
    snapshot that many waves further in the past — the long-lived-reader
    model: an analytic client that opened its snapshot ``age`` waves ago and
    is still reading.  Saturates at 0 (the initial versions stay visible to
    the earliest waves), so aged snapshots are always well-formed; what they
    are NOT guaranteed is retention — a ring of depth D only keeps the D
    newest versions, so ``age`` beyond the ring's reach makes ``mv_gather``
    report reclamation (ok=False) and the reader aborts cleanly instead of
    reading a recycled slot."""
    w = wave.astype(jnp.uint32)
    if age:
        w = w - jnp.minimum(w, jnp.uint32(age))
    return w


def install_ts(wave: jax.Array) -> jax.Array:
    """Begin timestamp for versions committed in wave w (monotone per wave;
    the ``mv_install`` op's same-wave revisit detection relies on every
    pre-existing begin being strictly smaller)."""
    return wave.astype(jnp.uint32) + jnp.uint32(1)


def mv_init(n_records: int, depth: int, n_groups: int,
            n_cols: int = 0, values=None):
    """Fresh ring tables: slot 0 holds the initial version (begin 0 in every
    group), the other D-1 slots are empty.  Returns (begin, head, vals);
    ``vals`` is a [1, 1, 1] placeholder unless ``n_cols > 0``."""
    begin = jnp.full((n_records, depth, n_groups), MV_EMPTY, jnp.uint32)
    begin = begin.at[:, 0, :].set(jnp.uint32(0))
    head = jnp.zeros((n_records,), jnp.int32)
    if n_cols > 0:
        vals = jnp.zeros((n_records, depth, n_cols), jnp.float32)
        if values is not None:
            vals = vals.at[:, 0, :].set(values)
    else:
        vals = jnp.zeros((1, 1, 1), jnp.float32)
    return begin, head, vals


def mv_placeholder():
    """Zero-size stand-ins for runs without an MV store (mv_depth = 0) so
    StoreState keeps one pytree structure everywhere."""
    return (jnp.zeros((1, 1, 1), jnp.uint32),
            jnp.zeros((1,), jnp.int32),
            jnp.zeros((1, 1, 1), jnp.float32))


def install_values(vals: jax.Array, head_old: jax.Array,
                   head_new: jax.Array, batch, commit: jax.Array,
                   prio: jax.Array) -> jax.Array:
    """Materialize the wave's new ring slots (track_values only).

    Two steps, mirroring the begin-table install of the ``mv_install`` op:
    first every installed slot is copied from its record's previous newest
    slot (carry-forward of unwritten columns), then committed writes are
    applied by ``engine.apply_values`` targeting the new slots — the ONE
    implementation of the serial-replay discipline (ascending prio, slot
    order within a lane), so the ring and the flat store cannot drift apart.
    Never used by the throughput benchmarks (they run untracked)."""
    from repro.core import engine
    from repro.core import types as t

    do = batch.is_write() & batch.live() & commit[:, None]
    k = jnp.where(do, batch.op_key, t.OOB_KEY).reshape(-1)
    h_old = head_old.at[k].get(mode="fill", fill_value=0)
    h_new = head_new.at[k].get(mode="fill", fill_value=0)
    # Copy: duplicates (several committed ops on one record) write the same
    # source row, so the unordered scatter is deterministic.
    old = vals.at[k, h_old, :].get(mode="fill", fill_value=0.0)
    vals = vals.at[k, h_new, :].set(old, mode="drop")
    return engine.apply_values(vals, batch, commit, prio, slot_of=head_new)


def snapshot_values(vals: jax.Array, begin: jax.Array, keys: jax.Array,
                    groups: jax.Array, cols: jax.Array, ts: jax.Array,
                    fine: bool):
    """Snapshot value read for tests/demos: (value f32, ok bool) per op.
    ``ok`` is False where the snapshot's version has been reclaimed (or the
    op is masked) — the caller must treat the value as garbage then."""
    from repro.core.types import OOB_KEY
    from repro.kernels import ref

    slot, ok = ref.mv_gather(begin, keys, groups, ts, fine)
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    v = vals.at[k, slot, cols].get(mode="fill", fill_value=0.0)
    return jnp.where(ok, v, 0.0), ok
