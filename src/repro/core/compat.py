"""JAX version-compatibility shims (jax 0.4.x through current).

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check flag (``check_rep`` -> ``check_vma``).
Every shard_map user in this repo goes through this wrapper so version drift
stays in one file.
"""
from __future__ import annotations

try:                                    # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map with replication checking off (the repo-wide convention:
    out-specs here describe data layout, not replication proofs)."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:                   # jax 0.4.x spells it check_rep
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
