"""The packed claim-word layout, shared by every backend.

A claim table cell is one uint32:

    word = (inv_wave << WAVE_SHIFT) | prio16
    inv_wave = MAX_WAVE - (wave & MAX_WAVE)      (monotone decreasing)
    prio16   = (inv_age << PRIO_LANE_BITS) | lane_rank   (lower wins)

Both engine backends interpret this layout: the jnp backend through the
gather/scatter helpers in ``core/claims.py``, the Pallas backend inside the
TPU kernels (``kernels/occ_validate.py`` / ``occ_commit.py``) and their jnp
oracles (``kernels/ref.py``).  Keeping the bit layout in exactly one module is
what makes the backends bit-identical by construction — see DESIGN.md
section 2 for the semantics and DESIGN.md section 5 for the backend contract.

Only ``jax.numpy`` is used, and every helper operates on plain arrays, so the
same code runs inside a Pallas kernel body, inside a jitted scan, and in
eager test code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Plain ints (not jnp scalars): these are baked into Pallas kernel bodies,
# which may not capture traced constants.
WAVE_SHIFT = 16                 # wave tag occupies the high 16 bits
MAX_WAVE = 0xFFFF
PRIO16_MASK = 0xFFFF
NO_PRIO = 0xFFFF                # probe result when nobody claims
EMPTY_WORD = 0xFFFFFFFF         # fill value for absent/masked cells


def inv_wave(wave: jax.Array) -> jax.Array:
    """Monotone-decreasing wave tag: the current wave's claims are numerically
    smaller than every stale wave's, so scatter-min never needs a reset."""
    return MAX_WAVE - (wave.astype(jnp.uint32) & MAX_WAVE)


def claim_word(wave: jax.Array, prio: jax.Array) -> jax.Array:
    """Pack (wave, prio16) into one claim word."""
    return (inv_wave(wave) << WAVE_SHIFT) | (prio.astype(jnp.uint32)
                                             & PRIO16_MASK)


def live_prio(words: jax.Array, ivw: jax.Array) -> jax.Array:
    """Unpack claim words: prio16 where the wave tag matches ``ivw``
    (a value produced by ``inv_wave``), NO_PRIO where the claim is stale
    or absent."""
    live = (words >> WAVE_SHIFT) == ivw
    return jnp.where(live, words & PRIO16_MASK, NO_PRIO)
