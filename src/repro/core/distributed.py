"""Distributed CC: the paper's section-5 future work ("evaluate in a
distributed setting"), mapped onto a TPU mesh with shard_map + all_to_all.

Layout
------
The record space is range-sharded over every mesh axis combined (an
``n_shards``-way partition); each device owns its slice of the version /
claim / multi-version tables.  Lanes (transactions) are sharded the same
way.  One wave is:

  1. route    every op is routed to its key's owner shard.  Per-destination
              fixed-capacity buffers [n_shards, cap, words] are built by the
              backend's ``route_pack`` op — a counting/offset scan (the
              placement a stable argsort by owner would give, WITHOUT the
              sort; kernels/route_pack.py) — and exchanged through the one
              ``_make_exchange`` collective.  Ops beyond a pair's capacity
              abort their lane (counted; capacity is sized for the
              workload).
  2. claim    owners run the backend's fused ``claim_probe`` op on their
              claim-table shard(s): ONE pass min-installs the routed write
              claims and answers every routed op's strongest-claimant
              probe — the same reset-free wave-tag tables as the local
              engine (core/claims.py).  The MV mechanisms claim TWO
              channels (all writes in claim_w, plain WRITEs in claim_r —
              the ADD-commutes rule of cc/base.plain_write_claims) and
              additionally run ``mv_gather`` on their shard of the version
              ring: the snapshot-visibility read that replaces read
              validation, honoring ``snapshot_age`` (aged snapshots that
              outlive the ring report reclamation and abort — never read a
              recycled slot).
  3. verdict  per-op conflict flags return through the inverse exchange,
              BIT-PACKED 16 ops per int32 word by the backend's
              ``verdict_pack`` op (2 bits per op — a 4x wire cut vs the
              old 1-int8-per-op scheme); the sender unpacks and *gathers*
              its verdicts back by each op's (owner, pos) routing
              coordinates from route_pack — no return scatter.  A lane
              commits iff none of its routed ops conflicted and none were
              capacity-dropped.  The verdict carries two bits:
              unconditional conflicts (FCW write-write + snapshot
              reclamation; single-version OCC uses only this bit) and the
              read-validation bit, which only mvocc applies — and only to
              lanes that also write, a fact the *sender* knows (read-only
              lanes serialize at their snapshot; cc/mvocc.py), so it never
              travels.
  4. install  committed write ops publish through the backend on the same
              return trip (the commit bits ride the inverse exchange
              packed like the verdicts, so installation reuses the routed
              buffer — no extra payload): ``commit_install`` bumps
              (record, group) versions for occ; ``mv_install`` claims one
              ring slot per written record and publishes begin timestamps
              for mvcc/mvocc (concurrent group writers of a record merge
              into the slot, exactly the local mv_commit).

Software pipeline (``pipeline_depth >= 2``; DESIGN.md section 10)
-----------------------------------------------------------------
The synchronous wave serializes three exchanges against shard-local
compute.  The scanned runners (``make_run_fn`` / the pipelined open loop
behind ``run_open_loop``) overlap them: ``route_pack`` never reads the CC
tables, so wave N's routing runs while owners claim/probe/gather wave
N-1, and the verdict + commit return words are FUSED with the next wave's
outbound buffers into ONE ``all_to_all`` per steady-state wave.  Step s
of the scan (wave w = wave0 + s):

    1. owner-install  wave w-3  (commit bits arrived last step)
    2. owner-claim    wave w-1  (routed buffers arrived last step) -> V
    3. sender-commit  wave w-2  (verdict words arrived last step)  -> C
    4. route          wave w                                       -> O
    5. one fused exchange of [O_key | O_meta | V | C]

In-flight wave buffers thread through the scan carry (three owner-side
routed-buffer slots, two sender-side coordinate slots); warmup steps run
on NO_OP-filled buffers (masked everywhere, so they are table no-ops) and
three trailing NOP-padded waves drain the pipe (wave w's verdicts land at
step w + 2, its installs at w + 3).  Depth 1 keeps today's
synchronous schedule bit-identically; depth >= 2 is bit-identical to it
for occ always and for mvcc/mvocc at ``snapshot_age == 0`` (the claim
scatter-min commutes across waves, probes only see current-wave claims,
occ's wts is write-only inside the wave, and a wave-fresh MV snapshot
never depends on the one install the pipelined gather has not seen yet);
aged snapshots are validation-rejected at depth >= 2 because that missing
install's reclamation CAN flip an aged reader's verdict.

Every shard-local table touch goes through ``backend.resolve(cfg)``
(core/backend.py): ``DistConfig.backend`` selects XLA gather/scatter or the
Pallas kernels exactly like the local engine, bit-identically — the
sharded wave is the local wave's op pipeline behind one exchange
(DESIGN.md section 10).

Granularity (the paper's mechanism) is carried per op exactly as in the
local engine: coarse probes the whole row (and the MV visibility check
reduces each ring slot over the row), fine probes the op's group.

Interval (scan) ops — ``max_extent > 1`` (DESIGN.md section 13)
---------------------------------------------------------------
The caller packs each op's extent into the kind channel's high bits
(``kind = kinds & 3``, ``extent = max(kinds >> 2, 1)``), so every wave
signature, the admission ring, and the pipeline carries are untouched —
a re-enqueued incarnation automatically retries the identical interval.
``route`` splits an interval at its range-shard boundary into at most two
fragments (``max_extent <= rec_per`` is enforced), each riding the wire
with its width in meta bits 19..30 (0 for point ops — pure-point waves
stay byte-identical).  Owners validate scan fragments with the
``iterate_validate`` op against the post-install claim shard (fine:
per-row probes at the op's group; coarse: bucket-expanded row-min,
``rec_per % bucket_size == 0`` keeps expansion inside the shard); the
verdict rides the existing bits and the SENDER — who kept the packed
kinds — classifies scan conflicts as ``CAUSE_PHANTOM`` and AND-reduces
fragment verdicts per lane.  Aged snapshots are rejected with
``max_extent > 1`` exactly like the local engine (and independently at
``pipeline_depth >= 2``, the pre-existing rule).

In-wave conflict semantics match the local engine (DESIGN.md sections 2
and 9): a single-version read aborts iff a *higher-priority* lane claimed
its cell this wave, regardless of that lane's own fate — STO's non-waiting
prevention — which is what makes one round trip sufficient; an MV read
never aborts on writers, only on reclamation (plus mvocc's update-lane
read validation).

State threading: ``make_wave_fn`` takes and returns one ``tables`` tuple
whose layout depends on the mechanism — ``(wts, claim_w)`` for occ,
``(claim_w, claim_r, mv_begin, mv_head)`` for mvcc/mvocc (the version ring
of core/mvstore.py, range-sharded like every other table).  Values are not
tracked on the distributed path (``mv_vals`` stays local-engine-only, as in
the throughput benchmarks — the wire carries no value channel).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import admission
from repro.core import backend as kb
from repro.core import mvstore
from repro.core import types as t

# Python ints (not jnp scalars): route_pack bakes the buffer fills into the
# Pallas kernel body, which may not capture traced constants.
NO_OP = 0x7FFFFFFF       # empty buffer cell in the key channel
META_FILL = 0x7FFF8      # empty meta: group 0, kind NOP, prio16 NO_PRIO
LANE_FILL = -1           # empty cell in the local slot -> lane map

#: Mechanisms the routed wave implements (string-keyed like
#: DistConfig.backend; the local engine's int ids stay in core/types.py).
DIST_CCS = ("occ", "mvcc", "mvocc")
DIST_MV_CCS = ("mvcc", "mvocc")

#: Exchange factorings of the routed wave (DistConfig.topology).
TOPOLOGIES = ("flat", "axiswise")

#: stats vector layout per shard (int32[STATS_LEN]; ro = read-only lanes,
#: the multi-version headline split SimResult/dashboard rows expect).
#: Slots 6..9 are the open-loop front-end counters (make_open_wave_fn);
#: the closed wave reports zeros there.  ADMITTED / ARRIVAL_DROPS /
#: INC_DROPS are per-wave deltas the driver accumulates; QUEUED is the
#: post-wave queue-occupancy snapshot (NOT a delta).  Slots 10 onward are
#: the N_ABORT_CAUSES per-cause abort counts, indexed by types.CAUSE_*
#: code; they sum to the ABORTS slot exactly, at every shard count and
#: pipeline depth (the conservation invariant
#: tests/test_abort_causes.py asserts).
STATS_LEN = 10 + t.N_ABORT_CAUSES
STAT_COMMITS, STAT_ABORTS, STAT_DROPPED_LANES, STAT_DROPPED_OPS, \
    STAT_RO_COMMITS, STAT_RO_ABORTS, STAT_ADMITTED, STAT_ARRIVAL_DROPS, \
    STAT_INC_DROPS, STAT_QUEUED = range(10)
STAT_CAUSE0 = 10
STAT_CAUSES = slice(STAT_CAUSE0, STAT_CAUSE0 + t.N_ABORT_CAUSES)


def verdict_words(cap: int) -> int:
    """int32 wire words per ``cap``-op verdict row: 2 bits per op, 16 ops
    per word (kernels/verdict_pack.py)."""
    return -(-cap // 16)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    n_records: int
    n_groups: int = 2
    lanes_per_shard: int = 64      # T_loc
    slots: int = 16                # K ops per txn
    route_cap: int = 0             # 0 = auto: 4x fair share, 8-aligned
    granularity: int = 1           # 0 coarse / 1 fine (probe width)
    backend: str = "jnp"           # kernel-backend surface substrate for
                                   # every shard-local table touch
                                   # (core/backend.py): "jnp" XLA, "pallas"
                                   # TPU kernels (interpret mode off-TPU)
    cc: str = "occ"                # routed mechanism: "occ" (single-version
                                   # timestamps) or "mvcc"/"mvocc" (the
                                   # multi-version ring of core/mvstore.py,
                                   # sharded with the claim tables)
    mv_depth: int = 0              # version-ring depth D (mvcc/mvocc only;
                                   # required >= 1 there, must stay 0 for
                                   # occ — it has no ring)
    snapshot_age: int = 0          # MV readers pin snapshots this many
                                   # waves back (mvstore.snapshot_ts); > 0
                                   # makes ring reclamation fire under load
    pipeline_depth: int = 1        # software-pipeline depth of the scanned
                                   # runners: 1 = the synchronous wave
                                   # (bit-identical to make_wave_fn), >= 2
                                   # overlaps wave N's route/exchange with
                                   # wave N-1's owner compute behind ONE
                                   # fused all_to_all per wave (module
                                   # docstring; 1-shard meshes auto-fall
                                   # back to 1 — see ``depth()``)
    topology: str = "flat"         # exchange factoring: "flat" = one
                                   # n_shards-way all_to_all over the
                                   # combined mesh axes, "axiswise" = one
                                   # smaller exchange per mesh axis on
                                   # >= 2-D meshes (falls back to flat on
                                   # 1-axis meshes)
    # ---- open-loop front-end (make_open_wave_fn; DESIGN.md section 11).
    # queue_cap >= 1 turns on the per-shard admission ring; arrival counts
    # are driver-supplied per wave (workloads/arrivals.PoissonArrivals
    # .shard_counts), so there is no arrival_rate knob here.
    queue_cap: int = 0             # per-SHARD admission-ring capacity
                                   # (0 = closed loop)
    max_incarnations: int = 0      # max re-executions after first attempt;
                                   # past it a txn drops (counted)
    lat_bins: int = 32             # per-shard time-to-commit histogram
                                   # width in waves (last bin = overflow)
    max_extent: int = 1            # widest op interval [key, key+extent):
                                   # 1 = point ops only (the wire and the
                                   # compiled wave are byte-identical to
                                   # the pre-scan engine); > 1 enables
                                   # interval (scan) ops — routed by
                                   # splitting each interval at its range-
                                   # shard boundary (route), validated
                                   # owner-side by iterate_validate, abort
                                   # cause CAUSE_PHANTOM (DESIGN.md
                                   # section 13)
    bucket_size: int = 8           # coarse interval-claim bucket width B
                                   # (records per claim word on the scan
                                   # path; rec_per must divide by it so
                                   # bucket expansion never crosses a
                                   # shard boundary)
    fuse_wave: bool = True         # owner claim step runs as the fused
                                   # wave_commit op (one table pass answers
                                   # the probe AND installs the claims);
                                   # False = claim_probe + XLA verdict
                                   # compare.  Bit-identical either way.
    lane_block: int = 0            # lanes per pallas grid step, 0 = auto
                                   # (EngineConfig.lane_block semantics)

    def __post_init__(self):
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'jnp' or 'pallas')")
        if self.cc not in DIST_CCS:
            raise ValueError(f"unknown distributed cc {self.cc!r} "
                             f"(expected one of {DIST_CCS})")
        if self.lane_block < 0:
            raise ValueError(
                f"lane_block must be >= 0 (0 = auto), got {self.lane_block}")
        if self.cc in DIST_MV_CCS and self.mv_depth < 1:
            raise ValueError(
                f"cc={self.cc!r} needs the multi-version ring: set "
                "DistConfig.mv_depth >= 1 (the local benchmarks use 4)")
        if self.cc not in DIST_MV_CCS and self.mv_depth:
            raise ValueError(
                f"mv_depth={self.mv_depth} is set but cc={self.cc!r} has "
                "no version ring — use cc='mvcc' or 'mvocc'")
        if self.snapshot_age < 0:
            raise ValueError(
                f"snapshot_age must be >= 0, got {self.snapshot_age}")
        if self.snapshot_age > 0 and self.cc not in DIST_MV_CCS:
            raise ValueError(
                f"snapshot_age={self.snapshot_age} needs a multi-version "
                f"cc (mvcc/mvocc): {self.cc!r} has no snapshots to age")
        if self.pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth={self.pipeline_depth} must be >= 1 "
                "(1 = the synchronous wave; >= 2 = the software pipeline "
                "of the scanned runners)")
        if self.pipeline_depth > 1 and self.snapshot_age > 0:
            raise ValueError(
                f"pipeline_depth={self.pipeline_depth} with snapshot_age="
                f"{self.snapshot_age}: the pipelined wave's mv_gather runs "
                "one wave before the previous wave's mv_install lands, so "
                "an AGED snapshot could read a ring slot the synchronous "
                "engine had already reclaimed — wave-fresh snapshots "
                "(age 0) are provably unaffected (module docstring), aged "
                "readers must run at pipeline_depth=1")
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.topology!r} (expected one of "
                f"{TOPOLOGIES}; 'axiswise' falls back to 'flat' on 1-axis "
                "meshes)")
        if self.route_cap < 0:
            raise ValueError(
                f"route_cap={self.route_cap} is negative (0 = auto, "
                "positive = explicit per-destination capacity)")
        if 0 < self.route_cap < self.slots:
            raise ValueError(
                f"route_cap={self.route_cap} < slots={self.slots}: one "
                "lane sending its whole transaction to a single shard "
                "could never fit, so every wave would drop it — set "
                "route_cap >= slots (or 0 for auto)")
        if self.route_cap % 8:
            raise ValueError(
                f"route_cap={self.route_cap} must be a multiple of 8: "
                "exchange buffers are the Pallas kernels' lane dimension "
                "and must never be ragged (auto capacity rounds itself)")
        if not 1 <= self.n_groups <= 2:
            raise ValueError(
                f"n_groups={self.n_groups}: the wire meta word packs the "
                "group id into one bit (group | kind << 1 | prio16 << 3)")
        if self.queue_cap < 0:
            raise ValueError(
                f"queue_cap={self.queue_cap} is negative (0 = closed "
                "loop, >= 1 = per-shard admission-ring capacity)")
        if self.max_incarnations < 0:
            raise ValueError(f"max_incarnations must be >= 0, got "
                             f"{self.max_incarnations}")
        if self.queue_cap and self.lat_bins < 2:
            raise ValueError(
                f"lat_bins={self.lat_bins}: the time-to-commit histogram "
                "needs >= 2 bins (the last bin is the overflow bin)")
        if self.max_incarnations and not self.queue_cap:
            raise ValueError(
                f"max_incarnations={self.max_incarnations} shapes the "
                "open-loop admission queue only — set queue_cap >= 1 "
                "(the open-loop switch) to use it")
        if self.max_extent < 1:
            raise ValueError(
                f"max_extent must be >= 1 (1 = point ops), got "
                f"{self.max_extent}")
        if self.max_extent > 0xFFF:
            raise ValueError(
                f"max_extent={self.max_extent} does not fit the wire: the "
                "meta word carries a fragment's scan width in bits 19..30 "
                "(group | kind << 1 | prio16 << 3 | width << 19), so "
                "intervals cap at 4095 records")
        if self.bucket_size < 1:
            raise ValueError(
                f"bucket_size must be >= 1, got {self.bucket_size}")
        if self.max_extent > 1 and self.snapshot_age > 0:
            raise ValueError(
                f"max_extent={self.max_extent} with snapshot_age="
                f"{self.snapshot_age}: interval validation runs against "
                "the CURRENT wave's claim tables, but an aged snapshot "
                "serializes in the past — a scan validated today cannot "
                "protect a cut taken waves ago (the local engine rejects "
                "this identically; EngineConfig)")

    @property
    def open_loop(self) -> bool:
        return self.queue_cap >= 1

    @property
    def is_mv(self) -> bool:
        return self.cc in DIST_MV_CCS

    def cap(self, n_shards: int) -> int:
        """Per-destination buffer capacity: explicit, or 4x the fair share
        — but never below ``slots``, so one lane routing its whole
        transaction to a single shard always fits (the invariant the
        explicit-cap validation enforces).  Always a multiple of 8 (auto
        rounds up, explicit is validated) so Pallas lane tiling never sees
        ragged exchange buffers.  Interval configs (max_extent > 1) double
        the fair share: every op routes up to TWO fragments (one per side
        of a range-shard boundary)."""
        if self.route_cap:
            return self.route_cap
        nfrag = 2 if self.max_extent > 1 else 1
        fair = nfrag * self.lanes_per_shard * self.slots / max(n_shards, 1)
        return -(-max(8, int(4 * fair), self.slots) // 8) * 8

    def depth(self, n_shards: int) -> int:
        """Effective pipeline depth on an ``n_shards`` mesh: 1-shard
        meshes auto-fall back to the synchronous wave (the exchange is a
        local copy there — nothing to overlap), larger meshes run the
        configured ``pipeline_depth``."""
        return 1 if n_shards <= 1 else self.pipeline_depth


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def n_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in mesh.axis_names)


def wire_bytes_per_wave(cfg: DistConfig, mesh) -> dict:
    """Modeled steady-state exchange payload per shard per wave, in bytes
    — the honest-wire columns of the perf dashboard (this CPU container
    cannot time real interconnects, so the speed story reports what the
    fused collective actually carries):

    - ``route_bytes_per_wave``:   key + meta int32 channels,
      ``n_shards * cap * 8``;
    - ``verdict_bytes_per_wave``: the bit-packed verdict return,
      ``n_shards * verdict_words(cap) * 4``;
    - ``commit_bytes_per_wave``:  the packed commit-bit return, same words;
    - ``verdict_bytes_per_wave_legacy``: the retired 1-int8-per-op scheme
      (``n_shards * cap``), the >= 4x-reduction baseline for 16-aligned
      caps;
    - ``wire_bytes_per_wave``: route + verdict + commit.

    The axiswise topology re-sends the payload once per mesh axis (each
    exchange only crosses one axis), so its bytes count ``len(axes)``
    times on >= 2-D meshes.
    """
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    W = verdict_words(cap)
    ax = _axes(mesh)
    hops = len(ax) if (cfg.topology == "axiswise" and len(ax) > 1) else 1
    route = ns * cap * 2 * 4
    verdict = ns * W * 4
    commit = ns * W * 4
    return {"route_bytes_per_wave": route * hops,
            "verdict_bytes_per_wave": verdict * hops,
            "commit_bytes_per_wave": commit * hops,
            "verdict_bytes_per_wave_legacy": ns * cap * hops,
            "wire_bytes_per_wave": (route + verdict + commit) * hops}


def _make_exchange(cfg: DistConfig, mesh):
    """The ONE exchange collective of the routed wave.

    Returns ``exchange(buf [n_shards, B]) -> [n_shards, B]`` (arrived row
    i = what shard i sent us), for use inside shard_map over ``mesh``.
    ``topology="flat"`` runs a single n_shards-way ``all_to_all`` over the
    combined mesh axes; ``"axiswise"`` factors it on >= 2-D meshes into
    one exchange per mesh axis (reshape [n_shards, B] to mesh.shape + [B]
    and exchange dim i over axis i — the row-major composition equals the
    flat exchange exactly, with a smaller peer fan-out per collective at
    len(axes)x the wire bytes), falling back to flat on 1-axis meshes.

    Every wave body routes its exchanges through this closure — the AST
    guard in tests/test_pipeline.py pins ``all_to_all`` to this function
    and counts one ``exchange(`` call in the pipelined step bodies.
    """
    ax = _axes(mesh)
    dims = tuple(mesh.shape[a] for a in ax)
    if cfg.topology == "axiswise" and len(ax) > 1:
        steps = [(dims, i, ax[i]) for i in range(len(ax))]
    else:
        steps = [((math.prod(dims),), 0, ax if len(ax) > 1 else ax[0])]

    def exchange(buf):
        x = buf.reshape(steps[0][0] + buf.shape[1:])
        for _, i, name in steps:
            x = jax.lax.all_to_all(x, axis_name=name, split_axis=i,
                                   concat_axis=i, tiled=True)
        return x.reshape(buf.shape)

    return exchange


def _make_phases(cfg: DistConfig, mesh):
    """The four shard-local phases of the routed wave, factored so the
    synchronous body (``_make_shard_body``) and the software-pipelined
    steps (``_make_pipeline_step`` / ``_make_open_pipeline_step``) share
    one implementation:

    - ``route(keys, groups, kinds, prio) -> (out [ns, 2*cap], send)`` —
      sender side; ``out`` is the concatenated key|meta wire buffer and
      ``send`` the sender's coordinate state ``(owner, pos, took, b_lane,
      lane_dropped, has_write, dropped_op, kinds_flat)`` (the kind channel
      never travels — the sender keeps it to classify abort causes);
    - ``owner_claim(tables, r_buf, wave) -> (tables', v_words [ns, W])`` —
      owner side: fused claim install + probe (and MV snapshot gather),
      verdicts bit-packed for the wire;
    - ``sender_commit(send, v_words) -> (commit [T], c_words [ns, W],
      cause [T])`` — sender side: unpack + gather verdicts by routing
      coordinates, pack the commit bits for the return trip, and classify
      each aborted lane's ABORT_CAUSE code (types.CAUSE_*: min over the
      lane's per-op codes, CAUSE_NONE for committing lanes);
    - ``owner_install(tables, r_buf, c_words, wave) -> tables'`` — owner
      side: version bumps (occ) or ring publishes (mvcc/mvocc) for
      committed writes.

    route never touches the CC tables — the fact that makes the pipeline
    overlap semantics-free (module docstring).
    """
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    fine = cfg.granularity == 1 and G > 1
    be = kb.resolve(cfg)
    mv = cfg.is_mv
    # Interval (scan) support: the caller's kind channel packs each op's
    # extent in bits 2+ (kind = kinds & 3, extent = max(kinds >> 2, 1) —
    # point workloads leave the high bits zero, so nothing changes for
    # them).  An interval splits into at most TWO fragments at its
    # range-shard boundary, doubling the flat-op axis.
    scans = cfg.max_extent > 1
    nfrag = 2 if scans else 1
    if scans and cfg.max_extent > rec_per:
        raise ValueError(
            f"max_extent={cfg.max_extent} > rec_per={rec_per}: an "
            "interval may cross at most ONE range-shard boundary (two "
            "fragments) — shrink the interval or the shard count")
    if scans and not fine and rec_per % cfg.bucket_size:
        raise ValueError(
            f"bucket_size={cfg.bucket_size} does not divide rec_per="
            f"{rec_per}: coarse interval validation expands fragments to "
            "bucket boundaries, which must never cross a shard boundary")

    def route(keys, groups, kinds, prio):
        # keys/groups/kinds: [T, K] local lanes; prio: [T]
        kind = (kinds & 3) if scans else kinds
        live = (kind != t.NOP) & (keys >= 0)
        owner = jnp.where(live, keys // rec_per, ns)         # dest shard
        lkey = jnp.where(live, keys % rec_per, NO_OP)
        # Pack (group | kind | prio16) into ONE int32 rider word — 2 words
        # per op on the wire; the lane id never travels (the sender keeps
        # the slot->lane map).  Scan fragments add their width in bits
        # 19..30 (0 = point op, keeping pure-point waves byte-identical).
        meta = (groups | (kind << 1)
                | (jnp.broadcast_to(prio[:, None], (T, K)).astype(jnp.int32)
                   << 3))
        lane = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                                (T, K))
        kflat = kinds.reshape(-1)
        if scans:
            # Split each interval [key, key + ext) at its range-shard
            # boundary: fragment 1 stays with the start key's owner,
            # fragment 2 (the remainder, possibly empty) routes to the
            # NEXT shard and starts at its row 0.  Verdicts AND-reduce
            # back on the sender like any other op of the lane.
            ext = jnp.maximum(kinds >> 2, 1)
            is_sc = (kinds >> 2) > 1
            bound = (keys // rec_per + 1) * rec_per
            w1 = jnp.minimum(keys + ext, bound) - keys
            w2 = keys + ext - jnp.minimum(keys + ext, bound)
            meta = meta | (jnp.where(live & is_sc, w1, 0) << 19)
            live2 = live & is_sc & (w2 > 0)
            owner2 = jnp.where(live2, owner + 1, ns)
            lkey2 = jnp.where(live2, 0, NO_OP)
            meta2 = jnp.where(live2, (meta & ((1 << 19) - 1)) | (w2 << 19),
                              META_FILL)
            owner_f = jnp.concatenate([owner.reshape(-1),
                                       owner2.reshape(-1)])
            vals = jnp.stack([
                jnp.concatenate([lkey.reshape(-1), lkey2.reshape(-1)]),
                jnp.concatenate([meta.reshape(-1), meta2.reshape(-1)]),
                jnp.concatenate([lane.reshape(-1), lane.reshape(-1)])])
            kflat = jnp.concatenate(
                [kflat, jnp.where(live2, kinds, t.NOP).reshape(-1)])
        else:
            owner_f = owner.reshape(-1)
            vals = jnp.stack([lkey.reshape(-1), meta.reshape(-1),
                              lane.reshape(-1)])
        buf, pos, took = be.route_pack(owner_f, vals, ns, cap,
                                       (NO_OP, META_FILL, LANE_FILL))
        b_key, b_meta, b_lane = buf[0], buf[1], buf[2]
        # capacity-dropped ops abort their lane (no scatter: took is
        # flat-op aligned, so a reshape + any does the lane reduce)
        dropped_op = ~took & (owner_f < ns)
        if scans:
            lane_dropped = dropped_op.reshape(2, T, K).any(axis=(0, 2))
        else:
            lane_dropped = dropped_op.reshape(T, K).any(axis=1)
        has_write = (live & ((kind == t.WRITE)
                             | (kind == t.ADD))).any(axis=1)
        out = jnp.concatenate([b_key, b_meta], axis=-1)      # [ns, 2*cap]
        send = (jnp.clip(owner_f, 0, ns - 1),
                jnp.clip(pos, 0, cap - 1), took, b_lane,
                lane_dropped, has_write, dropped_op, kflat)
        return out, send

    def _decode(r_buf):
        """Arrived [ns, 2*cap] wire buffer -> owner-side op arrays."""
        r_key, r_meta = r_buf[:, :cap], r_buf[:, cap:]
        r_live = r_key != NO_OP
        rk = jnp.where(r_live, r_key, -1)    # masked-op convention of the
        r_grp = r_meta & 1                   # backend surface: key -1
        r_kind = (r_meta >> 1) & 3
        r_prio = ((r_meta >> 3) & 0xFFFF).astype(jnp.uint32)
        return rk, r_grp, r_kind, r_prio, r_live

    def owner_claim(tables, r_buf, wave_idx):
        rk, r_grp, r_kind, r_prio, r_live = _decode(r_buf)
        is_w = r_live & ((r_kind == t.WRITE) | (r_kind == t.ADD))
        is_r = r_live & (r_kind == t.READ)
        if scans:
            # Scan fragments (meta width bits > 0) leave the point verdict
            # channel and validate their whole local interval against the
            # POST-install claim shard instead — op sixteen,
            # iterate_validate (DESIGN.md section 13).  The owner never
            # learns lane composition, so the phantom verdict rides the
            # existing bits and the SENDER classifies CAUSE_PHANTOM by
            # the op's packed kind.
            r_w = (r_buf[:, cap:] >> 19) & 0xFFF
            is_sc = r_live & (r_w > 0)
            is_rp = is_r & ~is_sc
        else:
            is_rp = is_r
        if not mv:
            # Single-version OCC: ONE table pass; verdict bit 0 = read
            # claimed by a stronger lane.  Fused (default): the
            # wave_commit megakernel answers the verdicts directly from
            # its in-VMEM reduction; unfused: claim_probe + XLA compare.
            # Bit-identical — the kernel evaluates the same mask algebra.
            wts, claim_w = tables
            if cfg.fuse_wave:
                claim_w, _, _, conflict, _ = be.wave_commit(
                    claim_w, None, None, rk, r_grp, r_prio, is_w, None,
                    is_rp, None, None, None, wave_idx, fine, False, False)
                v = conflict.astype(jnp.int8)
            else:
                claim_w, wprio = be.claim_probe(claim_w, rk, r_grp, r_prio,
                                                wave_idx, is_w, fine)
                v = (is_rp & (wprio < r_prio)).astype(jnp.int8)
            if scans:
                ph = be.iterate_validate(
                    claim_w, rk, jnp.maximum(r_w, 1), r_grp, r_prio,
                    is_sc, wave_idx, fine, cfg.bucket_size, cfg.max_extent)
                v = v | ph.astype(jnp.int8)
            tables = (wts, claim_w)
        else:
            # The local fcw_conflicts + mv snapshot check (cc/mvcc.py),
            # per shard: claim_w carries ALL writes, claim_r only plain
            # WRITEs (so ADD-ADD pairs commute); reads consult the ring.
            claim_w, claim_r, mv_begin, mv_head = tables
            is_pw = r_live & (r_kind == t.WRITE)
            is_ad = r_live & (r_kind == t.ADD)
            claim_w, wprio_w = be.claim_probe(claim_w, rk, r_grp, r_prio,
                                              wave_idx, is_w, fine)
            claim_r, wprio_r = be.claim_probe(claim_r, rk, r_grp, r_prio,
                                              wave_idx, is_pw, fine)
            _, ok = be.mv_gather(
                mv_begin, rk, r_grp,
                mvstore.snapshot_ts(wave_idx, cfg.snapshot_age), fine)
            # bit 0: unconditional — FCW write-write (a plain WRITE loses
            # to any stronger writer, an ADD only to a stronger plain
            # WRITE) and snapshot reclamation (the aged-reader abort).
            uncond = ((is_pw & (wprio_w < r_prio))
                      | (is_ad & (wprio_r < r_prio))
                      | (is_r & ~ok))
            # bit 1: read-validation — only mvocc applies it, and only to
            # update lanes; the sender owns that mask (lane composition
            # never travels).  Scan fragments re-route through the
            # interval pass (mvocc only — mvcc scans read a consistent
            # snapshot cut and never re-validate; cc/mvcc.py).
            rdval = is_rp & (wprio_w < r_prio)
            if scans and cfg.cc == "mvocc":
                ph = be.iterate_validate(
                    claim_w, rk, jnp.maximum(r_w, 1), r_grp, r_prio,
                    is_sc, wave_idx, fine, cfg.bucket_size, cfg.max_extent)
                rdval = rdval | ph
            v = uncond.astype(jnp.int8) | (rdval.astype(jnp.int8) << 1)
            tables = (claim_w, claim_r, mv_begin, mv_head)
        return tables, be.verdict_pack(v)

    def sender_commit(send, v_words):
        # Gathered back by each op's routing coordinates — sort-free and
        # scatter-free, the inverse of route_pack's placement.
        (owner_c, pos_c, took, b_lane, lane_dropped, has_write, dropped_op,
         kind_f) = send
        if scans:
            # The kind channel packs extents (route); a conflict on a scan
            # fragment IS a phantom — no extra wire bit needed.
            is_sc_f = (kind_f >> 2) > 1
            kind_f = kind_f & 3
        vv = be.verdict_unpack(v_words, cap)[owner_c, pos_c]
        bit0 = ((vv & 1) > 0) & took
        op_conf = bit0
        # Per-op ABORT_CAUSE codes mirror the verdict channels exactly
        # (the owner's bit semantics in owner_claim): the sender holds the
        # op-kind channel, so no cause ever travels on the wire.
        if not mv:
            cause = jnp.where(bit0, jnp.int32(t.CAUSE_READ_VAL),
                              jnp.int32(t.CAUSE_NONE))
            if scans:
                cause = jnp.where(bit0 & is_sc_f,
                                  jnp.int32(t.CAUSE_PHANTOM), cause)
        else:
            cause = jnp.full_like(kind_f, t.CAUSE_NONE)
            if cfg.cc == "mvocc":
                hw_op = jnp.broadcast_to(has_write[:, None],
                                         (T, K)).reshape(-1)
                if scans:
                    hw_op = jnp.concatenate([hw_op, hw_op])
                rdval = ((vv & 2) > 0) & hw_op & took
                op_conf = op_conf | rdval
                cause = jnp.where(rdval, jnp.int32(t.CAUSE_READ_VAL),
                                  cause)
                if scans:
                    cause = jnp.where(rdval & is_sc_f,
                                      jnp.int32(t.CAUSE_PHANTOM), cause)
            # bit 0 on a write op is a first-committer-wins w-w loss; on a
            # read op it is snapshot reclamation (cc/mvcc.py's disjoint
            # channels) — reclamation outranks the mvocc read validation.
            is_wr = (kind_f == t.WRITE) | (kind_f == t.ADD)
            cause = jnp.where(bit0 & is_wr, jnp.int32(t.CAUSE_WW), cause)
            cause = jnp.where(bit0 & ~is_wr,
                              jnp.int32(t.CAUSE_STALE_SNAPSHOT), cause)
        cause = jnp.where(dropped_op, jnp.int32(t.CAUSE_CAPACITY), cause)
        if scans:
            # Fragment verdicts AND-reduce per lane (both fragments of an
            # interval must survive); causes min-reduce like any op.
            commit = (~op_conf.reshape(2, T, K).any(axis=(0, 2))
                      & ~lane_dropped)
            lane_cause = cause.reshape(2, T, K).min(axis=(0, 2))
        else:
            commit = ~op_conf.reshape(T, K).any(axis=1) & ~lane_dropped
            lane_cause = cause.reshape(T, K).min(axis=1)
        b_commit = jnp.where(
            b_lane >= 0,
            commit[jnp.clip(b_lane, 0, T - 1)].astype(jnp.int8),
            jnp.int8(0))
        return commit, be.verdict_pack(b_commit), lane_cause

    def owner_install(tables, r_buf, c_words, wave_idx):
        rk, r_grp, r_kind, _, r_live = _decode(r_buf)
        is_w = r_live & ((r_kind == t.WRITE) | (r_kind == t.ADD))
        bump = is_w & (be.verdict_unpack(c_words, cap) > 0)
        if not mv:
            wts, claim_w = tables
            wts = be.commit_install(wts, rk, r_grp, bump)
            return (wts, claim_w)
        claim_w, claim_r, mv_begin, mv_head = tables
        mv_begin, mv_head = be.mv_install(
            mv_begin, mv_head, rk, r_grp, bump,
            mvstore.install_ts(wave_idx))
        return (claim_w, claim_r, mv_begin, mv_head)

    # Profiler-visible phase attribution (jax.profiler / Perfetto): each
    # phase's ops group under one named scope in the trace viewer.
    route = jax.named_scope("repro:route")(route)
    owner_claim = jax.named_scope("repro:claim")(owner_claim)
    sender_commit = jax.named_scope("repro:commit")(sender_commit)
    owner_install = jax.named_scope("repro:install")(owner_install)
    return route, owner_claim, sender_commit, owner_install


def _make_shard_body(cfg: DistConfig, mesh):
    """The SYNCHRONOUS (pipeline_depth 1) shard-local routed wave: route ->
    claim -> verdict -> install within one call (module docstring), three
    ``exchange`` round trips.  Returns ``body(keys, groups, kinds, prio,
    tables, wave_idx) -> (commit, tables', lane_dropped, has_write,
    dropped_op)`` — the op pipeline shared by the closed-loop wave
    (make_wave_fn) and the open-loop wave (make_open_wave_fn); only the
    traffic model around it differs.  Must be called inside shard_map over
    ``mesh``'s axes (the exchange closure names them).
    """
    route, owner_claim, sender_commit, owner_install = _make_phases(cfg,
                                                                    mesh)
    exchange = _make_exchange(cfg, mesh)

    def body(keys, groups, kinds, prio, tables, wave_idx):
        out, send = route(keys, groups, kinds, prio)
        r_buf = exchange(out)
        tables, v_words = owner_claim(tables, r_buf, wave_idx)
        commit, c_words, cause = sender_commit(send, exchange(v_words))
        tables = owner_install(tables, r_buf, exchange(c_words), wave_idx)
        _, _, _, _, lane_dropped, has_write, dropped_op, _ = send
        return commit, tables, lane_dropped, has_write, dropped_op, cause

    return body


def _closed_stats(commit, lane_dropped, has_write, dropped_op, cause):
    ro = ~has_write
    z = jnp.int32(0)
    head = jnp.stack([commit.sum(), (~commit).sum(), lane_dropped.sum(),
                      dropped_op.sum(), (commit & ro).sum(),
                      (~commit & ro).sum(), z, z, z, z]).astype(jnp.int32)
    return jnp.concatenate([head, t.cause_counts(cause, ~commit)])


def _pipe_carry_init(cfg: DistConfig, ns: int, tables):
    """Zero pipeline state: NO_OP-filled routed buffers and empty sender
    coordinates, so the warmup steps' owner/sender phases are fully masked
    table no-ops (every op dead, every commit bit 0)."""
    cap = cfg.cap(ns)
    T, K = cfg.lanes_per_shard, cfg.slots
    W = verdict_words(cap)
    # Interval configs route up to two fragments per op (_make_phases), so
    # the flat-op coordinate axis doubles.
    M = T * K * (2 if cfg.max_extent > 1 else 1)
    rb = jnp.concatenate([jnp.full((ns, cap), NO_OP, jnp.int32),
                          jnp.full((ns, cap), META_FILL, jnp.int32)],
                         axis=-1)
    vz = jnp.zeros((ns, W), jnp.int32)
    st = (jnp.zeros((M,), jnp.int32),                  # owner (clipped)
          jnp.zeros((M,), jnp.int32),                  # pos (clipped)
          jnp.zeros((M,), jnp.bool_),                  # took
          jnp.full((ns, cap), LANE_FILL, jnp.int32),   # b_lane
          jnp.zeros((T,), jnp.bool_),                  # lane_dropped
          jnp.zeros((T,), jnp.bool_),                  # has_write
          jnp.zeros((M,), jnp.bool_),                  # dropped_op
          jnp.full((M,), t.NOP, jnp.int32))            # kinds_flat
    return (tables, rb, rb, rb, vz, vz, st, st)


def _make_pipeline_step(cfg: DistConfig, mesh):
    """One steady-state step of the software-pipelined CLOSED-LOOP wave
    (module docstring schedule): install wave w-3, claim wave w-1, commit
    wave w-2, route wave w, then ONE fused exchange of
    ``[O_key | O_meta | V_{w-1} | C_{w-2}]``.  Emits wave w-2's (commit,
    stats); the scanned runner drops the two warmup rows and appends three
    NOP drain waves (the third flushes the final wave's installs)."""
    route, owner_claim, sender_commit, owner_install = _make_phases(cfg,
                                                                    mesh)
    exchange = _make_exchange(cfg, mesh)
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    W = verdict_words(cap)

    def step(carry, x):
        tables, rb1, rb2, rb3, v_in, c_in, st1, st2 = carry
        keys, groups, kinds, prio, wave = x
        tables = owner_install(tables, rb3, c_in, wave - jnp.uint32(3))
        tables, v_words = owner_claim(tables, rb1, wave - jnp.uint32(1))
        commit, c_words, cause = sender_commit(st2, v_in)
        out, st0 = route(keys, groups, kinds, prio)
        arrived = exchange(jnp.concatenate([out, v_words, c_words],
                                           axis=-1))
        r_out = arrived[:, :2 * cap]
        v_nxt = arrived[:, 2 * cap:2 * cap + W]
        c_nxt = arrived[:, 2 * cap + W:]
        stats = _closed_stats(commit, st2[4], st2[5], st2[6], cause)
        carry = (tables, r_out, rb1, rb2, v_nxt, c_nxt, st0, st1)
        return carry, (commit, stats)

    return step


def _spec_ops(mesh):
    ax = _axes(mesh)
    return P(ax if len(ax) > 1 else ax[0])


def _spec_stack(mesh):
    """Sharding for wave-stacked arrays ([n_waves, ...]: wave axis
    replicated, lane/shard axis split)."""
    ax = _axes(mesh)
    return P(None, ax if len(ax) > 1 else ax[0])


def make_wave_fn(cfg: DistConfig, mesh):
    """Returns wave(keys, groups, kinds, prio, tables, wave_idx) ->
    (commit [T], tables', stats) — all arguments globally shaped, sharded
    over the combined mesh axes.  ``tables`` is the mechanism's state tuple
    (see module docstring / ``init_tables``); ``stats`` is
    int32[STATS_LEN] per shard: [commits, aborts, capacity-dropped lanes,
    dropped ops, read-only commits, read-only aborts, zeros in the
    open-loop slots (this is the closed-loop wave), then the six per-cause
    abort counts (slots STAT_CAUSES, summing exactly to aborts)].

    This is the one-wave-per-call SYNCHRONOUS driver: it cannot overlap
    waves, so configs whose effective depth exceeds 1 are rejected — use
    ``make_run_fn`` for the pipelined scanned runner (on a 1-shard mesh
    ``pipeline_depth`` auto-falls back to 1 and this driver still works).

    The resolved backend (``cfg.backend``) is threaded into the
    shard-local wave; route/claim/probe/gather/install all run through its
    surface ops on the shard's table slices.
    """
    ns = n_shards(mesh)
    if cfg.depth(ns) > 1:
        raise ValueError(
            f"make_wave_fn is the one-wave-per-call synchronous driver: "
            f"pipeline_depth={cfg.pipeline_depth} on a {ns}-shard mesh "
            "needs the scanned runner — use make_run_fn(cfg, mesh, "
            "n_waves) (1-shard meshes auto-fall back to depth 1)")
    body = _make_shard_body(cfg, mesh)
    mv = cfg.is_mv

    def local_wave(keys, groups, kinds, prio, tables, wave_idx):
        commit, tables, lane_dropped, has_write, dropped_op, cause = body(
            keys, groups, kinds, prio, tables, wave_idx)
        stats = _closed_stats(commit, lane_dropped, has_write, dropped_op,
                              cause)
        return commit, tables, stats

    spec_ops = _spec_ops(mesh)
    tab_spec = (spec_ops,) * (4 if mv else 2)
    wave = shard_map(
        local_wave, mesh=mesh,
        in_specs=(spec_ops, spec_ops, spec_ops, spec_ops, tab_spec, P()),
        out_specs=(spec_ops, tab_spec, spec_ops))
    return wave


def make_run_fn(cfg: DistConfig, mesh, n_waves: int):
    """The scanned CLOSED-LOOP runner: returns ``run(keys [n_waves, ns*T,
    K], groups, kinds, prio [n_waves, ns*T], tables, wave0) -> (commit
    [n_waves, ns*T], tables', stats [n_waves, ns*STATS_LEN])`` — the whole
    run is ONE XLA program (lax.scan inside shard_map), so waves/s
    measures the wave, not host dispatch.

    ``cfg.depth(n_shards)`` selects the schedule: depth 1 scans the
    synchronous body (three exchanges per wave — bit-identical to a
    make_wave_fn loop), depth >= 2 scans the software-pipelined step (ONE
    fused exchange per wave; the scan runs ``n_waves + 3`` steps, the
    three NOP-padded drain waves flushing the in-flight buffers, and the
    two warmup output rows are dropped) — bit-identical to depth 1 for occ
    always and mvcc/mvocc at snapshot_age 0 (module docstring)."""
    ns = n_shards(mesh)
    depth = cfg.depth(ns)
    mv = cfg.is_mv
    T, K = cfg.lanes_per_shard, cfg.slots

    if depth == 1:
        body = _make_shard_body(cfg, mesh)

        def local_run(keys, groups, kinds, prio, tables, wave0):
            def step(tables, x):
                k, g, i, p, w = x
                (commit, tables, lane_dropped, has_write, dropped_op,
                 cause) = body(k, g, i, p, tables, w)
                stats = _closed_stats(commit, lane_dropped, has_write,
                                      dropped_op, cause)
                return tables, (commit, stats)

            waves = wave0 + jnp.arange(n_waves, dtype=jnp.uint32)
            tables, (commit, stats) = jax.lax.scan(
                step, tables, (keys, groups, kinds, prio, waves))
            return commit, tables, stats
    else:
        pstep = _make_pipeline_step(cfg, mesh)

        def local_run(keys, groups, kinds, prio, tables, wave0):
            keys = jnp.concatenate(
                [keys, jnp.full((3, T, K), -1, jnp.int32)])
            groups = jnp.concatenate(
                [groups, jnp.zeros((3, T, K), jnp.int32)])
            kinds = jnp.concatenate(
                [kinds, jnp.full((3, T, K), t.NOP, jnp.int32)])
            prio = jnp.concatenate([prio, jnp.zeros((3, T), jnp.uint32)])
            waves = wave0 + jnp.arange(n_waves + 3, dtype=jnp.uint32)
            carry = _pipe_carry_init(cfg, ns, tables)
            carry, (commit, stats) = jax.lax.scan(
                pstep, carry, (keys, groups, kinds, prio, waves))
            return (commit[2:2 + n_waves], carry[0],
                    stats[2:2 + n_waves])

    spec = _spec_stack(mesh)
    tab_spec = (_spec_ops(mesh),) * (4 if mv else 2)
    run = shard_map(
        local_run, mesh=mesh,
        in_specs=(spec, spec, spec, spec, tab_spec, P()),
        out_specs=(spec, tab_spec, spec))
    return run


def make_open_wave_fn(cfg: DistConfig, mesh):
    """The OPEN-LOOP routed wave (DESIGN.md section 11): each shard runs a
    fixed-capacity admission ring in front of the shared shard body
    (_make_shard_body), mirroring the local engine's core/admission.py.
    Like ``make_wave_fn`` this is the one-wave-per-call synchronous driver
    — pipelined open-loop runs go through ``run_open_loop`` (which scans
    ``_make_open_pipeline_step``).

    Returns ``open_wave(keys, groups, kinds, prio, n_arrive, tables,
    qstate, wave_idx) -> (commit, tables', qstate', stats)``:

    - keys/groups/kinds [ns*T, K]: the wave's FRESH arrival candidates
      (the front-end materializes at most T per shard per wave); the first
      ``n_arrive[shard]`` lanes of each shard's slice actually arrive —
      the driver draws the counts host-side
      (workloads/arrivals.PoissonArrivals.shard_counts).
    - prio [ns*T]: per-lane wave priorities for the DEQUEUED lanes.
    - qstate: the sharded queue tuple from ``init_open_queue``.
    - stats int32[ns, STATS_LEN] flattened: slots 6..9 carry
      admitted/arrival_drops/inc_drops (per-wave deltas) and the post-wave
      queue occupancy snapshot; slots 10..15 are the per-cause abort
      counts (terminal aborts reclassify as CAUSE_INC_CAP, so
      causes[CAUSE_INC_CAP] == inc_drops here at depth 1).

    Ring discipline per shard and wave — enqueue arrivals, dequeue up to T
    lanes FIFO, run the routed wave, re-enqueue aborted lanes with
    incarnation + 1 (drop + count past ``cfg.max_incarnations``), record
    committed lanes' time-to-commit (waves) in the shard's histogram.
    Arrivals land before the dequeue frees lanes, so the re-enqueue can
    never overflow (the core/admission.py invariant; the conservation
    oracle in tests/test_open_loop.py reconciles the counters exactly).
    """
    if not cfg.open_loop:
        raise ValueError("make_open_wave_fn needs queue_cap >= 1 "
                         "(the open-loop switch); use make_wave_fn for "
                         "closed-loop waves")
    ns = n_shards(mesh)
    if cfg.depth(ns) > 1:
        raise ValueError(
            f"make_open_wave_fn is the one-wave-per-call synchronous "
            f"driver: pipeline_depth={cfg.pipeline_depth} on a {ns}-shard "
            "mesh needs the scanned runner — use run_open_loop (1-shard "
            "meshes auto-fall back to depth 1)")
    body = _make_shard_body(cfg, mesh)
    mv = cfg.is_mv
    T, K = cfg.lanes_per_shard, cfg.slots
    C = cfg.queue_cap

    def local_wave(keys, groups, kinds, prio, n_arrive, tables, qstate,
                   wave_idx):
        (qk, qg, qi, qa, qc, qd, head, size, next_id, lat_hist) = qstate
        head, size, nid = head[0], size[0], next_id[0]
        w = wave_idx.astype(jnp.int32)

        def enq(head, size, mask, ek, eg, ei, ea, ec, ed):
            """Append masked lanes into the ring (ascending lane order);
            the cumsum-rank placement of admission.ring_enqueue."""
            tabs, size, n_acc, n_ovf = admission.ring_enqueue(
                C, head, size, mask, (qk, qg, qi, qa, qc, qd),
                (ek, eg, ei, ea, ec, ed))
            return tabs + (size, n_acc, n_ovf)

        # --- arrivals: first n_arrive fresh lanes enter the ring --------
        n_arr = jnp.minimum(n_arrive[0], T)
        arr = jnp.arange(T, dtype=jnp.int32) < n_arr
        ids = nid + jnp.arange(T, dtype=jnp.int32)
        qk, qg, qi, qa, qc, qd, size, n_adm, n_ovf = enq(
            head, size, arr, keys, groups, kinds,
            jnp.full((T,), w, jnp.int32), jnp.zeros((T,), jnp.int32), ids)

        # --- admit: fill the shard's T lanes FIFO -----------------------
        take = jnp.minimum(size, T)
        i = jnp.arange(T, dtype=jnp.int32)
        got = i < take
        pos = (head + i) % C
        dk = jnp.where(got[:, None], qk[pos, :], -1)
        dg = jnp.where(got[:, None], qg[pos, :], 0)
        di = jnp.where(got[:, None], qi[pos, :], t.NOP)
        admit_w = jnp.where(got, qa[pos], 0)
        incarn = jnp.where(got, qc[pos], 0)
        head, size = (head + take) % C, size - take

        # --- the routed wave on the admitted lanes ----------------------
        commit, tables, lane_dropped, has_write, dropped_op, cause = body(
            dk, dg, di, prio, tables, wave_idx)
        commit = commit & got
        aborted = got & ~commit

        # --- retry incarnations / latency -------------------------------
        retry = aborted & (incarn < cfg.max_incarnations)
        inc_drop = aborted & ~retry
        # A terminal abort leaves the system as an incarnation drop — that
        # outcome outranks whatever validation verdict killed the attempt
        # (CAUSE_INC_CAP is the lowest code), mirroring the local engine.
        cause = jnp.where(inc_drop, jnp.int32(t.CAUSE_INC_CAP), cause)
        # Arrivals enqueued before the dequeue freed these slots, so this
        # can never overflow (n_re_ovf stays 0; the oracle asserts it via
        # the exact counter reconciliation).
        qk, qg, qi, qa, qc, qd, size, _, n_re_ovf = enq(
            head, size, retry, dk, dg, di, admit_w, incarn + 1,
            jnp.where(got, qd[pos], -1))
        lat_hist = admission.record_ttc(lat_hist, w - admit_w + 1, commit)

        ro = ~has_write
        head_stats = jnp.stack([
            commit.sum(), aborted.sum(), lane_dropped.sum(),
            dropped_op.sum(),
            (commit & ro).sum(), (aborted & ro).sum(),
            n_adm, n_ovf + n_re_ovf,
            inc_drop.sum(), size]).astype(jnp.int32)
        stats = jnp.concatenate([head_stats, t.cause_counts(cause,
                                                            aborted)])
        qstate = (qk, qg, qi, qa, qc, qd, head[None], size[None],
                  (nid + n_arr)[None], lat_hist)
        return commit, tables, qstate, stats

    spec = _spec_ops(mesh)
    tab_spec = (spec,) * (4 if mv else 2)
    q_spec = (spec,) * 10
    wave = shard_map(
        local_wave, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, tab_spec, q_spec, P()),
        out_specs=(spec, tab_spec, q_spec, spec))
    return wave


def _make_open_pipeline_step(cfg: DistConfig, mesh):
    """One steady-state step of the software-pipelined OPEN-LOOP wave: the
    closed pipeline schedule (_make_pipeline_step) with the per-shard
    admission ring threaded through the carry.  Wave w-2's verdicts land
    this step, so its aborted lanes re-enqueue TWO waves after they ran —
    the retry latency the pipeline buys its overlap with.  Retries
    re-enter the ring before this step's fresh arrivals (oldest first);
    with two waves in flight the depth-1 "re-enqueue can never overflow"
    invariant no longer holds, so a retry the full ring rejects leaves the
    system as an incarnation drop (counted — the conservation identity
    ``admitted == commits + queued_final + inc_drops`` stays exact)."""
    route, owner_claim, sender_commit, owner_install = _make_phases(cfg,
                                                                    mesh)
    exchange = _make_exchange(cfg, mesh)
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    W = verdict_words(cap)
    T, K = cfg.lanes_per_shard, cfg.slots
    C = cfg.queue_cap

    def step(carry, x):
        (tables, rb1, rb2, rb3, v_in, c_in, st1, st2, os1, os2,
         qk, qg, qi, qa, qc, qd, head, size, nid, lat_hist) = carry
        keys, groups, kinds, prio, n_arrive, wave, live_w = x

        # --- owner phases: install wave w-3, claim wave w-1 -------------
        tables = owner_install(tables, rb3, c_in, wave - jnp.uint32(3))
        tables, v_words = owner_claim(tables, rb1, wave - jnp.uint32(1))

        # --- sender: commit wave w-2, ring bookkeeping -------------------
        commit, c_words, cause = sender_commit(st2, v_in)
        dk2, dg2, di2, admit2, inc2, got2, qid2, n_adm2, n_ovf2 = os2
        commit = commit & got2
        aborted = got2 & ~commit
        retry = aborted & (inc2 < cfg.max_incarnations)
        # Terminal aborts reclassify as CAUSE_INC_CAP like the synchronous
        # wave; a retry the full ring rejects (n_re_ovf) KEEPS its
        # validation cause — ring_enqueue exposes no per-lane overflow
        # mask — so causes[CAUSE_INC_CAP] <= inc_drops at depth >= 2
        # while the per-cause sum still equals aborts exactly.
        cause = jnp.where(aborted & ~retry, jnp.int32(t.CAUSE_INC_CAP),
                          cause)
        (qk, qg, qi, qa, qc, qd), size, _, n_re_ovf = admission.ring_enqueue(
            C, head, size, retry, (qk, qg, qi, qa, qc, qd),
            (dk2, dg2, di2, admit2, inc2 + 1, qid2))
        inc_drop = (aborted & ~retry).sum() + n_re_ovf
        w2 = (wave.astype(jnp.int32) - 2)
        lat_hist = admission.record_ttc(lat_hist, w2 - admit2 + 1, commit)

        # --- arrivals for wave w -----------------------------------------
        n_arr = jnp.where(live_w, jnp.minimum(n_arrive, T), 0)
        arr = jnp.arange(T, dtype=jnp.int32) < n_arr
        ids = nid + jnp.arange(T, dtype=jnp.int32)
        (qk, qg, qi, qa, qc, qd), size, n_adm, n_ovf = admission.ring_enqueue(
            C, head, size, arr, (qk, qg, qi, qa, qc, qd),
            (keys, groups, kinds,
             jnp.full((T,), wave.astype(jnp.int32), jnp.int32),
             jnp.zeros((T,), jnp.int32), ids))
        nid = nid + n_arr

        # --- dequeue wave w's lanes (never on drain steps) ---------------
        take = jnp.where(live_w, jnp.minimum(size, T), 0)
        i = jnp.arange(T, dtype=jnp.int32)
        got = i < take
        pos = (head + i) % C
        dk = jnp.where(got[:, None], qk[pos, :], -1)
        dg = jnp.where(got[:, None], qg[pos, :], 0)
        di = jnp.where(got[:, None], qi[pos, :], t.NOP)
        admit_w = jnp.where(got, qa[pos], 0)
        incarn = jnp.where(got, qc[pos], 0)
        qid = jnp.where(got, qd[pos], -1)
        head, size = (head + take) % C, size - take

        # --- route wave w, ONE fused exchange ----------------------------
        out, st0 = route(dk, dg, di, prio)
        arrived = exchange(jnp.concatenate([out, v_words, c_words],
                                           axis=-1))
        r_out = arrived[:, :2 * cap]
        v_nxt = arrived[:, 2 * cap:2 * cap + W]
        c_nxt = arrived[:, 2 * cap + W:]

        # Every counter in the emitted row belongs to wave w-2 (the wave
        # whose fate resolved this step): its admission counters rode the
        # os carry from the step that enqueued it, so the runner's
        # [2 : 2+n_waves] slice conserves exactly.  QUEUED stays a current
        # occupancy snapshot (informational; the driver's queued_final
        # reads the final qstate, not this column).
        ro = ~st2[5]
        head_stats = jnp.stack([
            commit.sum(), aborted.sum(), st2[4].sum(), st2[6].sum(),
            (commit & ro).sum(), (aborted & ro).sum(),
            n_adm2, n_ovf2, inc_drop, size]).astype(jnp.int32)
        stats = jnp.concatenate([head_stats, t.cause_counts(cause,
                                                            aborted)])
        os0 = (dk, dg, di, admit_w, incarn, got, qid, n_adm, n_ovf)
        carry = (tables, r_out, rb1, rb2, v_nxt, c_nxt, st0, st1, os0, os1,
                 qk, qg, qi, qa, qc, qd, head, size, nid, lat_hist)
        return carry, (commit, stats)

    return step


def make_open_run_fn(cfg: DistConfig, mesh, n_waves: int):
    """The scanned PIPELINED open-loop runner (cfg.depth(n_shards) >= 2):
    returns ``run(keys [n_waves, ns*T, K], groups, kinds, prio [n_waves,
    ns*T], n_arrive [n_waves, ns], tables, qstate, wave0) -> (commit
    [n_waves, ns*T], tables', qstate', stats [n_waves, ns*STATS_LEN])``.
    The scan runs ``n_waves + 3`` steps — the three drain steps admit no
    arrivals and dequeue no lanes, they only flush the in-flight waves —
    and drops the two warmup output rows, so row w is wave w's commit."""
    if not cfg.open_loop:
        raise ValueError("make_open_run_fn needs queue_cap >= 1 "
                         "(the open-loop switch)")
    ns = n_shards(mesh)
    if cfg.depth(ns) < 2:
        raise ValueError(
            "make_open_run_fn is the pipelined scanned runner: "
            f"effective depth {cfg.depth(ns)} on this mesh runs the "
            "synchronous make_open_wave_fn instead (run_open_loop picks)")
    pstep = _make_open_pipeline_step(cfg, mesh)
    mv = cfg.is_mv
    T, K = cfg.lanes_per_shard, cfg.slots

    def local_run(keys, groups, kinds, prio, n_arrive, tables, qstate,
                  wave0):
        (qk, qg, qi, qa, qc, qd, head, size, next_id, lat_hist) = qstate
        keys = jnp.concatenate([keys, jnp.full((3, T, K), -1, jnp.int32)])
        groups = jnp.concatenate([groups, jnp.zeros((3, T, K), jnp.int32)])
        kinds = jnp.concatenate(
            [kinds, jnp.full((3, T, K), t.NOP, jnp.int32)])
        prio = jnp.concatenate([prio, jnp.zeros((3, T), jnp.uint32)])
        n_arr = jnp.concatenate([n_arrive[:, 0],
                                 jnp.zeros((3,), n_arrive.dtype)])
        n_steps = n_waves + 3
        waves = wave0 + jnp.arange(n_steps, dtype=jnp.uint32)
        live = jnp.arange(n_steps) < n_waves
        open_slot = (jnp.full((T, K), -1, jnp.int32),
                     jnp.zeros((T, K), jnp.int32),
                     jnp.full((T, K), t.NOP, jnp.int32),
                     jnp.zeros((T,), jnp.int32),
                     jnp.zeros((T,), jnp.int32),
                     jnp.zeros((T,), jnp.bool_),
                     jnp.full((T,), -1, jnp.int32),
                     jnp.int32(0), jnp.int32(0))
        carry = _pipe_carry_init(cfg, ns, tables) + (
            open_slot, open_slot,
            qk, qg, qi, qa, qc, qd, head[0], size[0], next_id[0], lat_hist)
        carry, (commit, stats) = jax.lax.scan(
            pstep, carry, (keys, groups, kinds, prio, n_arr, waves, live))
        (tables, _, _, _, _, _, _, _, _, _,
         qk, qg, qi, qa, qc, qd, head, size, nid, lat_hist) = carry
        qstate = (qk, qg, qi, qa, qc, qd, head[None], size[None],
                  nid[None], lat_hist)
        return (commit[2:2 + n_waves], tables, qstate,
                stats[2:2 + n_waves])

    spec = _spec_stack(mesh)
    spec1 = _spec_ops(mesh)
    tab_spec = (spec1,) * (4 if mv else 2)
    q_spec = (spec1,) * 10
    run = shard_map(
        local_run, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, tab_spec, q_spec, P()),
        out_specs=(spec, tab_spec, q_spec, spec))
    return run


def init_open_queue(cfg: DistConfig, mesh):
    """Fresh sharded open-loop queue state for ``make_open_wave_fn``:
    ``(q_key, q_grp, q_kind, q_admit, q_inc, q_id, head, size, next_id,
    lat_hist)`` — per-shard ring buffers (globally [ns*cap, ...]), ring
    cursors ([ns], local scalars inside shard_map), and the per-shard
    time-to-commit histogram ([ns*lat_bins]).  ``next_id`` starts at
    ``shard * 2^20`` so admission serials are globally unique without any
    cross-shard coordination (up to 2^20 admissions per shard)."""
    if not cfg.open_loop:
        raise ValueError("init_open_queue needs queue_cap >= 1")
    ns = n_shards(mesh)
    C, K, L = cfg.queue_cap, cfg.slots, cfg.lat_bins
    zi1 = jnp.zeros((ns * C,), jnp.int32)
    return (jnp.full((ns * C, K), -1, jnp.int32),          # q_key
            jnp.zeros((ns * C, K), jnp.int32),             # q_grp
            jnp.full((ns * C, K), t.NOP, jnp.int32),       # q_kind
            zi1,                                           # q_admit
            zi1,                                           # q_inc
            zi1,                                           # q_id
            jnp.zeros((ns,), jnp.int32),                   # head
            jnp.zeros((ns,), jnp.int32),                   # size
            jnp.arange(ns, dtype=jnp.int32) * (1 << 20),   # next_id
            jnp.zeros((ns * L,), jnp.int32))               # lat_hist


def run_open_loop(cfg: DistConfig, mesh, arrive_counts, gen_fn,
                  n_waves: int):
    """Host-side open-loop driver: run ``n_waves`` open waves and
    reconcile the per-shard stats into one summary dict.  The effective
    pipeline depth picks the engine — a host loop of jitted synchronous
    waves at depth 1, the one-XLA-program pipelined scan
    (``make_open_run_fn``) at depth >= 2.

    ``arrive_counts`` is int[n_waves, n_shards] (PoissonArrivals
    .shard_counts); ``gen_fn(wave) -> (keys, groups, kinds, prio)``
    supplies the wave's globally-shaped fresh-arrival candidates and lane
    priorities (seeded host-side, so reruns and backends see identical
    traffic).  The summary carries the conservation identities the oracle
    test asserts: admitted == commits + queued_final + inc_drops and
    offered == admitted + arrival_drops, both exact — at EVERY pipeline
    depth (a pipelined retry re-enqueues two waves later and may find the
    ring full, in which case it drops into inc_drops).
    """
    import numpy as np
    ns = n_shards(mesh)
    acc = np.zeros((ns, STATS_LEN), np.int64)
    tables = init_tables(cfg, mesh)
    qstate = init_open_queue(cfg, mesh)
    offered = 0
    if cfg.depth(ns) >= 2:
        run = jax.jit(make_open_run_fn(cfg, mesh, n_waves))
        per_wave = [gen_fn(w) for w in range(n_waves)]
        keys, groups, kinds, prio = (jnp.stack(col)
                                     for col in zip(*per_wave))
        n_arr = jnp.asarray(arrive_counts, jnp.int32)
        offered = int(jnp.minimum(n_arr, cfg.lanes_per_shard).sum())
        commit, tables, qstate, stats = run(
            keys, groups, kinds, prio, n_arr, tables, qstate,
            jnp.uint32(0))
        acc += np.asarray(stats).reshape(n_waves, ns, STATS_LEN)\
            .sum(axis=0)
    else:
        wave = jax.jit(make_open_wave_fn(cfg, mesh))
        for w in range(n_waves):
            keys, groups, kinds, prio = gen_fn(w)
            n_arr = jnp.asarray(arrive_counts[w], jnp.int32)
            offered += int(jnp.minimum(n_arr, cfg.lanes_per_shard).sum())
            commit, tables, qstate, stats = wave(
                keys, groups, kinds, prio, n_arr, tables, qstate,
                jnp.uint32(w))
            acc += np.asarray(stats).reshape(ns, STATS_LEN)
    lat_hist = np.asarray(qstate[-1]).reshape(ns, cfg.lat_bins)
    queued = int(np.asarray(qstate[7]).sum())
    return {
        "commits": int(acc[:, STAT_COMMITS].sum()),
        "aborts": int(acc[:, STAT_ABORTS].sum()),
        "ro_commits": int(acc[:, STAT_RO_COMMITS].sum()),
        "ro_aborts": int(acc[:, STAT_RO_ABORTS].sum()),
        "offered": offered,
        "admitted": int(acc[:, STAT_ADMITTED].sum()),
        "arrival_drops": int(acc[:, STAT_ARRIVAL_DROPS].sum()),
        "inc_drops": int(acc[:, STAT_INC_DROPS].sum()),
        "queued_final": queued,
        "abort_causes": [int(x) for x in acc[:, STAT_CAUSES].sum(axis=0)],
        "lat_hist": lat_hist,
        "per_shard_stats": acc,
    }


def init_tables(cfg: DistConfig, mesh):
    """Fresh sharded state for ``cfg.cc``:

    - occ:         ``(wts, claim_w)``
    - mvcc/mvocc:  ``(claim_w, claim_r, mv_begin, mv_head)`` — the version
      ring of core/mvstore.py (slot 0 live at begin 0, head 0) plus the two
      claim channels, all range-sharded over the padded record space.
    """
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    N, G = ns * rec_per, cfg.n_groups
    claim_w = jnp.full((N, G), t.NO_CLAIM, jnp.uint32)
    if cfg.is_mv:
        mv_begin, mv_head, _ = mvstore.mv_init(N, cfg.mv_depth, G)
        claim_r = jnp.full((N, G), t.NO_CLAIM, jnp.uint32)
        return (claim_w, claim_r, mv_begin, mv_head)
    return (jnp.zeros((N, G), jnp.uint32), claim_w)


def abstract_args(cfg: DistConfig, mesh):
    """ShapeDtypeStructs (with shardings) for the dry-run cell."""
    from jax.sharding import NamedSharding
    ax = _axes(mesh)
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    sh2 = NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]))

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh2)

    N = ns * rec_per
    if cfg.is_mv:
        tables = (sds((N, G), jnp.uint32),              # claim_w
                  sds((N, G), jnp.uint32),              # claim_r
                  sds((N, cfg.mv_depth, G), jnp.uint32),  # mv_begin
                  sds((N,), jnp.int32))                 # mv_head
    else:
        tables = (sds((N, G), jnp.uint32),              # wts
                  sds((N, G), jnp.uint32))              # claim_w
    return (sds((ns * T, K), jnp.int32),    # keys
            sds((ns * T, K), jnp.int32),    # groups
            sds((ns * T, K), jnp.int32),    # kinds
            sds((ns * T,), jnp.uint32),     # prio
            tables,
            jax.ShapeDtypeStruct((), jnp.uint32))
