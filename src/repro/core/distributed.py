"""Distributed OCC: the paper's section-5 future work ("evaluate in a
distributed setting"), mapped onto a TPU mesh with shard_map + all_to_all.

Layout
------
The record space is range-sharded over every mesh axis combined (an
``n_shards``-way partition); each device owns its slice of the version /
claim tables.  Lanes (transactions) are sharded the same way.  One wave is:

  1. route    every op is routed to its key's owner shard.  Per-destination
              fixed-capacity buffers [n_shards, cap, words] are exchanged
              with one ``all_to_all``; ops beyond a pair's capacity abort
              their lane (counted; capacity is sized for the workload).
  2. claim    owners scatter-min writer claims into their table shard and
              probe — the same reset-free wave-tag tables as the local
              engine (core/claims.py), reused verbatim on the local shard.
  3. verdict  per-op conflict flags return through the inverse all_to_all;
              a lane commits iff none of its routed ops conflicted and none
              were capacity-dropped.
  4. install  committed write ops advance their (record, group) version —
              the commit bit rides the return trip, so installation reuses
              the routed buffer (no second exchange).

Granularity (the paper's mechanism) is carried per op exactly as in the
local engine: coarse probes the whole row, fine probes the op's group.

In-wave conflict semantics match the local engine (DESIGN.md section 2):
a read aborts iff a *higher-priority* lane claimed its cell this wave,
regardless of that lane's own fate — STO's non-waiting prevention — which is
what makes one round trip sufficient.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import claims
from repro.core import types as t

NO_OP = jnp.int32(0x7FFFFFFF)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    n_records: int
    n_groups: int = 2
    lanes_per_shard: int = 64      # T_loc
    slots: int = 16                # K ops per txn
    route_cap: int = 0             # 0 = auto: 4x fair share
    granularity: int = 1           # 0 coarse / 1 fine (probe width)

    def cap(self, n_shards: int) -> int:
        if self.route_cap:
            return self.route_cap
        fair = self.lanes_per_shard * self.slots / max(n_shards, 1)
        return max(8, int(4 * fair))


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def n_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in mesh.axis_names)


def make_wave_fn(cfg: DistConfig, mesh):
    """Returns wave(keys, groups, kinds, prio, wts, claim_w, wave_idx) ->
    (commit [T], new_wts, new_claim_w, stats) — all arguments globally
    shaped, sharded over the combined mesh axes.
    """
    ax = _axes(mesh)
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    fine = cfg.granularity == 1

    def local_wave(keys, groups, kinds, prio, wts, claim_w, wave_idx):
        # keys/groups/kinds: [T, K] local lanes; prio: [T]
        # wts/claim_w: [rec_per, G] local shard.
        live = (kinds != t.NOP) & (keys >= 0)
        owner = jnp.where(live, keys // rec_per, ns)         # dest shard
        lkey = jnp.where(live, keys % rec_per, NO_OP)

        # --- build per-destination buffers -----------------------------
        flat_owner = owner.reshape(-1)
        order = jnp.argsort(flat_owner)                       # group by dest
        sorted_owner = flat_owner[order]
        counts = jnp.bincount(sorted_owner, length=ns + 1)[:ns]
        offs = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * K) - offs[jnp.clip(sorted_owner, 0, ns - 1)]
        ok = (sorted_owner < ns) & (pos < cap)
        slot = jnp.where(ok, sorted_owner * cap + pos, ns * cap)

        def pack(v, fill):
            buf = jnp.full((ns * cap + 1,), fill, jnp.int32)
            return buf.at[slot].set(v.reshape(-1)[order], mode="drop")[:-1]

        # Perf iteration (txn-engine): pack (group | kind | prio16) into ONE
        # int32 rider word — 2 words per op on the wire instead of 4; the
        # lane id never travels (the sender keeps the slot->lane map).
        meta = (groups | (kinds << 1)
                | (jnp.broadcast_to(prio[:, None], (T, K)).astype(jnp.int32)
                   << 3))
        b_key = pack(lkey, NO_OP).reshape(ns, cap)
        b_meta = pack(meta, 0x7FFF8).reshape(ns, cap)
        b_lane = pack(jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[:, None], (T, K)), -1
        ).reshape(ns, cap)          # local only: slot -> lane

        # capacity-dropped ops abort their lane
        drop_lane = jnp.where(~ok & (sorted_owner < ns), order // K, T)
        lane_dropped = jnp.zeros((T + 1,), jnp.bool_).at[drop_lane].set(
            True)[:T]

        # --- exchange: rows -> owners ----------------------------------
        a2a = partial(jax.lax.all_to_all, axis_name=ax, split_axis=0,
                      concat_axis=0, tiled=True)
        r_key = a2a(b_key)
        r_meta = a2a(b_meta)
        r_grp = r_meta & 1
        r_kind = (r_meta >> 1) & 3
        r_prio = (r_meta >> 3) & 0xFFFF

        # --- owner side: claim, probe ----------------------------------
        r_live = r_key != NO_OP
        is_w = r_live & ((r_kind == t.WRITE) | (r_kind == t.ADD))
        is_r = r_live & (r_kind == t.READ)
        words = claims.claim_word(wave_idx, r_prio.astype(jnp.uint32))
        claim_w = claims.scatter_claims(claim_w, r_key, r_grp, words, is_w)
        wprio = claims.effective_probe(claim_w, r_key, r_grp, wave_idx, fine)
        conflict = is_r & (wprio < r_prio.astype(jnp.uint32))

        # --- verdicts return to lane owners (1 byte per op) -------------
        v_conf = a2a(conflict.astype(jnp.int8))               # [ns, cap]
        lane_conf = jnp.zeros((T + 1,), jnp.int32).at[
            jnp.where(b_lane >= 0, b_lane, T).reshape(-1)].add(
            v_conf.reshape(-1).astype(jnp.int32))[:T]
        commit = (lane_conf == 0) & ~lane_dropped

        # --- install: commit bits ride back to owners (1 byte) ----------
        b_commit = jnp.where(
            b_lane >= 0,
            commit[jnp.clip(b_lane, 0, T - 1)].astype(jnp.int8),
            jnp.int8(0))
        r_commit = a2a(b_commit)
        bump = is_w & (r_commit > 0)
        kk = jnp.where(bump, r_key, t.OOB_KEY)
        wts = wts.at[kk.reshape(-1), r_grp.reshape(-1)].add(
            jnp.uint32(1), mode="drop")

        stats = jnp.stack([commit.sum(), (~commit).sum(),
                           lane_dropped.sum()]).astype(jnp.int32)
        return commit, wts, claim_w, stats

    spec_ops = P(ax if len(ax) > 1 else ax[0])
    wave = shard_map(
        local_wave, mesh=mesh,
        in_specs=(spec_ops, spec_ops, spec_ops, spec_ops, spec_ops,
                  spec_ops, P()),
        out_specs=(spec_ops, spec_ops, spec_ops, spec_ops))
    return wave


def init_tables(cfg: DistConfig, mesh):
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    return (jnp.zeros((ns * rec_per, cfg.n_groups), jnp.uint32),
            jnp.full((ns * rec_per, cfg.n_groups), t.NO_CLAIM, jnp.uint32))


def abstract_args(cfg: DistConfig, mesh):
    """ShapeDtypeStructs (with shardings) for the dry-run cell."""
    from jax.sharding import NamedSharding
    ax = _axes(mesh)
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    sh2 = NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]))

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh2)

    return (sds((ns * T, K), jnp.int32),    # keys
            sds((ns * T, K), jnp.int32),    # groups
            sds((ns * T, K), jnp.int32),    # kinds
            sds((ns * T,), jnp.uint32),     # prio
            sds((ns * rec_per, G), jnp.uint32),
            sds((ns * rec_per, G), jnp.uint32),
            jax.ShapeDtypeStruct((), jnp.uint32))
