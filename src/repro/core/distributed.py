"""Distributed OCC: the paper's section-5 future work ("evaluate in a
distributed setting"), mapped onto a TPU mesh with shard_map + all_to_all.

Layout
------
The record space is range-sharded over every mesh axis combined (an
``n_shards``-way partition); each device owns its slice of the version /
claim tables.  Lanes (transactions) are sharded the same way.  One wave is:

  1. route    every op is routed to its key's owner shard.  Per-destination
              fixed-capacity buffers [n_shards, cap, words] are built by the
              backend's ``route_pack`` op — a counting/offset scan (the
              placement a stable argsort by owner would give, WITHOUT the
              sort; kernels/route_pack.py) — and exchanged with one
              ``all_to_all``.  Ops beyond a pair's capacity abort their
              lane (counted; capacity is sized for the workload).
  2. claim    owners run the backend's fused ``claim_probe`` op on their
              claim-table shard: ONE pass min-installs the routed write
              claims and answers every routed op's strongest-claimant
              probe — the same reset-free wave-tag tables as the local
              engine (core/claims.py), halved kernel launches and claim-row
              HBM round-trips (kernels/claim_probe.py).
  3. verdict  per-op conflict flags return through the inverse all_to_all;
              the sender *gathers* its verdicts back by each op's
              (owner, pos) routing coordinates from route_pack — no return
              scatter.  A lane commits iff none of its routed ops
              conflicted and none were capacity-dropped.
  4. install  committed write ops advance their (record, group) version
              through the backend's ``commit_install`` op — the commit bit
              rides the return trip, so installation reuses the routed
              buffer (no second exchange).

Every shard-local table touch goes through ``backend.resolve(cfg)``
(core/backend.py): ``DistConfig.backend`` selects XLA gather/scatter or the
Pallas kernels exactly like the local engine, bit-identically — the
sharded wave is the local wave's op pipeline behind one exchange
(DESIGN.md section 10).

Granularity (the paper's mechanism) is carried per op exactly as in the
local engine: coarse probes the whole row, fine probes the op's group.

In-wave conflict semantics match the local engine (DESIGN.md section 2):
a read aborts iff a *higher-priority* lane claimed its cell this wave,
regardless of that lane's own fate — STO's non-waiting prevention — which is
what makes one round trip sufficient.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import backend as kb
from repro.core import types as t

# Python ints (not jnp scalars): route_pack bakes the buffer fills into the
# Pallas kernel body, which may not capture traced constants.
NO_OP = 0x7FFFFFFF       # empty buffer cell in the key channel
META_FILL = 0x7FFF8      # empty meta: group 0, kind NOP, prio16 NO_PRIO
LANE_FILL = -1           # empty cell in the local slot -> lane map


@dataclasses.dataclass(frozen=True)
class DistConfig:
    n_records: int
    n_groups: int = 2
    lanes_per_shard: int = 64      # T_loc
    slots: int = 16                # K ops per txn
    route_cap: int = 0             # 0 = auto: 4x fair share, 8-aligned
    granularity: int = 1           # 0 coarse / 1 fine (probe width)
    backend: str = "jnp"           # kernel-backend surface substrate for
                                   # every shard-local table touch
                                   # (core/backend.py): "jnp" XLA, "pallas"
                                   # TPU kernels (interpret mode off-TPU)

    def __post_init__(self):
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'jnp' or 'pallas')")
        if self.route_cap < 0:
            raise ValueError(
                f"route_cap={self.route_cap} is negative (0 = auto, "
                "positive = explicit per-destination capacity)")
        if 0 < self.route_cap < self.slots:
            raise ValueError(
                f"route_cap={self.route_cap} < slots={self.slots}: one "
                "lane sending its whole transaction to a single shard "
                "could never fit, so every wave would drop it — set "
                "route_cap >= slots (or 0 for auto)")
        if self.route_cap % 8:
            raise ValueError(
                f"route_cap={self.route_cap} must be a multiple of 8: "
                "exchange buffers are the Pallas kernels' lane dimension "
                "and must never be ragged (auto capacity rounds itself)")
        if not 1 <= self.n_groups <= 2:
            raise ValueError(
                f"n_groups={self.n_groups}: the wire meta word packs the "
                "group id into one bit (group | kind << 1 | prio16 << 3)")

    def cap(self, n_shards: int) -> int:
        """Per-destination buffer capacity: explicit, or 4x the fair share
        — but never below ``slots``, so one lane routing its whole
        transaction to a single shard always fits (the invariant the
        explicit-cap validation enforces).  Always a multiple of 8 (auto
        rounds up, explicit is validated) so Pallas lane tiling never sees
        ragged exchange buffers."""
        if self.route_cap:
            return self.route_cap
        fair = self.lanes_per_shard * self.slots / max(n_shards, 1)
        return -(-max(8, int(4 * fair), self.slots) // 8) * 8


def _axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def n_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in mesh.axis_names)


def make_wave_fn(cfg: DistConfig, mesh):
    """Returns wave(keys, groups, kinds, prio, wts, claim_w, wave_idx) ->
    (commit [T], new_wts, new_claim_w, stats) — all arguments globally
    shaped, sharded over the combined mesh axes.  ``stats`` is int32[4]
    per shard: [commits, aborts, capacity-dropped lanes, dropped ops].

    The resolved backend (``cfg.backend``) is threaded into the
    shard-local wave; route/claim/probe/install all run through its
    surface ops on the shard's table slice.
    """
    ax = _axes(mesh)
    ns = n_shards(mesh)
    cap = cfg.cap(ns)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    fine = cfg.granularity == 1
    be = kb.resolve(cfg)

    def local_wave(keys, groups, kinds, prio, wts, claim_w, wave_idx):
        # keys/groups/kinds: [T, K] local lanes; prio: [T]
        # wts/claim_w: [rec_per, G] local shard.
        live = (kinds != t.NOP) & (keys >= 0)
        owner = jnp.where(live, keys // rec_per, ns)         # dest shard
        lkey = jnp.where(live, keys % rec_per, NO_OP)

        # --- build per-destination buffers (backend route_pack) ---------
        # Perf iteration (txn-engine): pack (group | kind | prio16) into ONE
        # int32 rider word — 2 words per op on the wire instead of 4; the
        # lane id never travels (the sender keeps the slot->lane map).
        meta = (groups | (kinds << 1)
                | (jnp.broadcast_to(prio[:, None], (T, K)).astype(jnp.int32)
                   << 3))
        lane = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                                (T, K))
        vals = jnp.stack([lkey.reshape(-1), meta.reshape(-1),
                          lane.reshape(-1)])
        buf, pos, took = be.route_pack(owner.reshape(-1), vals, ns, cap,
                                       (NO_OP, META_FILL, LANE_FILL))
        b_key, b_meta, b_lane = buf[0], buf[1], buf[2]

        # capacity-dropped ops abort their lane (no scatter: took is
        # flat-op aligned, so a reshape + any does the lane reduce)
        dropped_op = ~took & (owner.reshape(-1) < ns)
        lane_dropped = dropped_op.reshape(T, K).any(axis=1)

        # --- exchange: rows -> owners ----------------------------------
        a2a = partial(jax.lax.all_to_all, axis_name=ax, split_axis=0,
                      concat_axis=0, tiled=True)
        r_key = a2a(b_key)
        r_meta = a2a(b_meta)
        r_live = r_key != NO_OP
        rk = jnp.where(r_live, r_key, -1)     # masked-op convention of the
        r_grp = r_meta & 1                    # backend surface: key -1
        r_kind = (r_meta >> 1) & 3
        r_prio = ((r_meta >> 3) & 0xFFFF).astype(jnp.uint32)

        # --- owner side: fused claim install + probe (ONE table pass) ---
        is_w = r_live & ((r_kind == t.WRITE) | (r_kind == t.ADD))
        is_r = r_live & (r_kind == t.READ)
        claim_w, wprio = be.claim_probe(claim_w, rk, r_grp, r_prio,
                                        wave_idx, is_w, fine)
        conflict = is_r & (wprio < r_prio)

        # --- verdicts return to lane owners (1 byte per op) -------------
        # Gathered back by each op's routing coordinates — sort-free and
        # scatter-free, the inverse of route_pack's placement.
        v_conf = a2a(conflict.astype(jnp.int8))               # [ns, cap]
        oo = jnp.clip(owner.reshape(-1), 0, ns - 1)
        pp = jnp.clip(pos, 0, cap - 1)
        op_conf = (v_conf[oo, pp] > 0) & took
        commit = ~op_conf.reshape(T, K).any(axis=1) & ~lane_dropped

        # --- install: commit bits ride back to owners (1 byte) ----------
        b_commit = jnp.where(
            b_lane >= 0,
            commit[jnp.clip(b_lane, 0, T - 1)].astype(jnp.int8),
            jnp.int8(0))
        r_commit = a2a(b_commit)
        bump = is_w & (r_commit > 0)
        wts = be.commit_install(wts, rk, r_grp, bump)

        stats = jnp.stack([commit.sum(), (~commit).sum(),
                           lane_dropped.sum(),
                           dropped_op.sum()]).astype(jnp.int32)
        return commit, wts, claim_w, stats

    spec_ops = P(ax if len(ax) > 1 else ax[0])
    wave = shard_map(
        local_wave, mesh=mesh,
        in_specs=(spec_ops, spec_ops, spec_ops, spec_ops, spec_ops,
                  spec_ops, P()),
        out_specs=(spec_ops, spec_ops, spec_ops, spec_ops))
    return wave


def init_tables(cfg: DistConfig, mesh):
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    return (jnp.zeros((ns * rec_per, cfg.n_groups), jnp.uint32),
            jnp.full((ns * rec_per, cfg.n_groups), t.NO_CLAIM, jnp.uint32))


def abstract_args(cfg: DistConfig, mesh):
    """ShapeDtypeStructs (with shardings) for the dry-run cell."""
    from jax.sharding import NamedSharding
    ax = _axes(mesh)
    ns = n_shards(mesh)
    rec_per = -(-cfg.n_records // ns)
    T, K, G = cfg.lanes_per_shard, cfg.slots, cfg.n_groups
    sh2 = NamedSharding(mesh, P(ax if len(ax) > 1 else ax[0]))

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh2)

    return (sds((ns * T, K), jnp.int32),    # keys
            sds((ns * T, K), jnp.int32),    # groups
            sds((ns * T, K), jnp.int32),    # kinds
            sds((ns * T,), jnp.uint32),     # prio
            sds((ns * rec_per, G), jnp.uint32),
            sds((ns * rec_per, G), jnp.uint32),
            jax.ShapeDtypeStruct((), jnp.uint32))
