"""The kernel-backend layer: one dispatch surface for every CC mechanism.

Every concurrency-control mechanism in ``core/cc/`` — and the distributed
engine's shard-local wave (``core/distributed.py``) — touches shared state
through exactly ``N_OPS`` ops (``SURFACE_OPS`` below — doc strings
elsewhere cite the constant, pinned by tests/test_backend_surface.py), the
full surface a wave needs (DESIGN.md sections 5, 9 and 10):

    validate        read-set verdicts vs the writer-claim table (OCC rule;
                    mvcc/mvocc's first-committer-wins channels)
    validate_dual   fine AND coarse verdicts from one row fetch (AutoGran)
    probe           raw strongest-claimant prio16 (NO_PRIO if unclaimed)
    claim_probe     FUSED claim_scatter + probe: one pass installs the
                    wave's claim words and answers every op's post-install
                    strongest-claimant probe (the probe family — OCC,
                    TicToc, 2PL, SwissTM, Adaptive — and the distributed
                    owner step; half the launches and claim-row DMAs)
    wave_commit     the probe-family MEGAKERNEL (kernels/wave_commit.py):
                    one launch with aliased claim/version tables installs
                    the wave's write claims, answers every op's
                    strongest-claimant probe, reduces per-op conflicts to
                    lane verdicts in VMEM, and bumps versions for
                    committed writes — each touched row rides ONE DMA per
                    wave where the unfused claim_probe -> verdict ->
                    commit_install chain re-fetched it 2-3 times
                    (EngineConfig.fuse_wave routes the probe family here)
    iterate_validate  interval (scan) validation — phantom protection for
                    extent-carrying ops: conflict when any record of the
                    op's validated interval carries a live same-wave claim
                    stronger than the lane; fine = the exact interval at
                    the op's group column (per-gap timestamps), coarse =
                    the bucket-expanded interval with the whole-row
                    compare (bucket-interval claims, one word per
                    EngineConfig.bucket_size records) — DESIGN.md
                    section 13
    ts_gather       per-op (wts | rts) observation; coarse = row max (TicToc)
    claim_scatter   pack + scatter-min claim words (install-only callers:
                    AutoGran's verdict path, the MV claim channels)
    commit_install  +1 version bumps for committed writes (OCC-family +
                    the distributed install return-trip)
    ts_install_max  monotone scatter-max timestamp install (TicToc)
    segment_count   same-cell op counts within the wave (TicToc's extension
                    chains + the engine's install-contention cost model —
                    ops that are not simple row gathers)
    route_pack      sort-free per-destination exchange-buffer pack (the
                    distributed wave's send side; counting/offset scan in
                    place of the old argsort routing pass)
    mv_gather       snapshot version select on the multi-version ring
                    (mvcc/mvocc reads; core/mvstore.py)
    mv_install      ring-slot claim + version publish (mvcc/mvocc commits)
    verdict_pack    bit-pack per-op verdict bytes for the wire — 2 bits/op
                    (conflict + read-validation), 16 ops per int32 word, a
                    4x byte cut on the distributed verdict/commit return
                    channels (kernels/verdict_pack.py)
    verdict_unpack  the inverse: wire words back to per-op verdict bytes

``resolve(cfg)`` maps ``EngineConfig.backend`` (or ``DistConfig.backend`` —
any config with a ``backend`` field) to one of two stateless singleton
implementations:

- ``jnp``    — XLA gather/scatter (the oracles in ``kernels/ref.py`` and the
  helpers in ``core/claims.py`` are the same computations);
- ``pallas`` — the TPU-native kernels behind ``kernels/ops.py`` (interpret
  mode off-TPU), every op a scalar-prefetch row-DMA grid or an aliased-output
  sequential-grid scatter.

Both decode the one claim-word layout in ``core/claimword.py`` and are
bit-identical (tests/test_backend_parity.py, tests/test_kernels.py).  CC
mechanisms hold no ``cfg.backend`` branches: they call ``resolve(cfg)`` once
per wave and use only this surface, so a new mechanism gets TPU execution for
free and a new backend only has to implement these ``N_OPS`` ops.

``resolve`` honors ``cfg.lane_block`` on the pallas backend: the row-DMA
kernels tile the wave into LB-lane blocks (kernels/wave_commit.py
``pick_lane_block``; 0 = auto from table width) and the override threads
through every lane-block kernel call.
"""
from __future__ import annotations

from repro.core import claims
from repro.core import types as t
from repro.core.claimword import inv_wave

#: The canonical kernel-backend surface: every op both backends implement
#: as a method, in DESIGN.md section 5 table order.  ``N_OPS`` is THE
#: op count — README.md, DESIGN.md, core/engine.py and launch/txn_bench.py
#: cite it instead of a hard-coded number word, and
#: tests/test_backend_surface.py pins the docs, the backends' method
#: surfaces and the CC_OPS/DIST_OPS subsets to this tuple.
SURFACE_OPS = ("validate", "validate_dual", "probe", "claim_probe",
               "wave_commit", "iterate_validate", "ts_gather",
               "claim_scatter", "commit_install", "ts_install_max",
               "segment_count", "route_pack", "mv_gather", "mv_install",
               "verdict_pack", "verdict_unpack")

#: Op count of the backend surface (sixteen as of the iterate_validate PR).
N_OPS = len(SURFACE_OPS)


class JnpBackend:
    """XLA gather/scatter implementation (the reference substrate)."""
    name = "jnp"
    use_pallas = False

    def validate(self, claim_w, keys, groups, myprio, check, wave,
                 fine: bool):
        """Conflict bool[T, K]: live read cells claimed by a strictly
        stronger lane this wave."""
        wprio = (claims.probe(claim_w, keys, groups, wave) if fine
                 else claims.probe_any_group(claim_w, keys, wave))
        return check & (wprio < myprio)

    def validate_dual(self, claim_w, keys, groups, myprio, check, wave):
        """(fine, coarse) conflict bool[T, K] from one logical row fetch."""
        from repro.kernels import ref
        return ref.occ_validate_dual(claim_w, keys, groups, myprio, check,
                                     inv_wave(wave))

    def probe(self, table, keys, groups, wave, fine: bool):
        """Strongest live claimant prio16 per op (NO_PRIO if unclaimed)."""
        return (claims.probe(table, keys, groups, wave) if fine
                else claims.probe_any_group(table, keys, wave))

    def claim_probe(self, table, keys, groups, prio, wave, mask,
                    fine: bool):
        """Fused claim_scatter + probe: min-install claim words for masked
        ops, return every op's post-install strongest-claimant prio16."""
        from repro.kernels import ref
        return ref.claim_probe_fused(table, keys, groups, prio, mask, wave,
                                     fine)

    def wave_commit(self, claim_w, claim_r, wts, keys, groups, prio, do_w,
                    do_r, check_w, check_w2, check_r, extra, wave,
                    fine: bool, dual: bool, bump: bool):
        """The fused probe-family wave: claim install + probe + lane
        verdicts + version bumps in one pass.  Returns (claim_w', claim_r',
        wts', conflict bool[T, K], commit bool[T]); claim_r/wts ride only
        when dual/bump."""
        from repro.kernels import ref
        return ref.wave_commit(claim_w, claim_r, wts, keys, groups, prio,
                               do_w, do_r, check_w, check_w2, check_r,
                               extra, wave, fine, dual, bump)

    def iterate_validate(self, table, keys, extents, groups, myprio, check,
                         wave, fine: bool, bucket_size: int, ext_cap: int):
        """Interval (scan) validation: conflict bool[T, K] where any record
        of ``[key, key + extent)`` (bucket-expanded when coarse) carries a
        live same-wave claim stronger than the lane — the phantom check."""
        from repro.kernels import ref
        return ref.iterate_validate(table, keys, extents, groups, myprio,
                                    check, inv_wave(wave), fine,
                                    bucket_size, ext_cap)

    def route_pack(self, owner, vals, n_dest: int, cap: int, fills):
        """Sort-free per-destination fixed-capacity buffer pack."""
        from repro.kernels import ref
        return ref.route_pack(owner, vals, n_dest, cap, fills)

    def ts_gather(self, table, keys, groups, fine: bool):
        """Per-op timestamp observation; coarse reads the row max."""
        from repro.kernels import ref
        return ref.ts_gather(table, keys, groups, fine)

    def claim_scatter(self, table, keys, groups, prio, wave, mask):
        """Scatter-min packed claim words into table[record, group]."""
        from repro.kernels import ref
        return ref.claim_scatter(table, keys, groups, prio, mask, wave)

    def commit_install(self, wts, keys, groups, do):
        """+1 per committed write op (monotone version bump)."""
        from repro.kernels import ref
        return ref.occ_commit(wts, keys, groups, do)

    def ts_install_max(self, table, keys, groups, vals, mask,
                       whole_row: bool = False):
        """Monotone scatter-max timestamp install."""
        from repro.kernels import ref
        return ref.ts_install_max(table, keys, groups, vals, mask, whole_row)

    def segment_count(self, keys, groups, G: int, mask):
        """#same-(record, group) ops in the wave, per op (0 where masked)."""
        from repro.kernels import ref
        return ref.segment_count(keys, groups, G, mask)

    def mv_gather(self, begin, keys, groups, ts, fine: bool):
        """(slot, ok) of the newest ring version visible at snapshot ts."""
        from repro.kernels import ref
        return ref.mv_gather(begin, keys, groups, ts, fine)

    def mv_install(self, begin, head, keys, groups, do, ts):
        """Claim one ring slot per written record; publish begin stamps."""
        from repro.kernels import ref
        return ref.mv_install(begin, head, keys, groups, do, ts)

    def verdict_pack(self, v):
        """Bit-pack verdict bytes: 2 bits/op, 16 ops per int32 wire word."""
        from repro.kernels import ref
        return ref.verdict_pack(v)

    def verdict_unpack(self, words, n: int):
        """Inverse of verdict_pack: wire words -> int8[..., n] verdicts."""
        from repro.kernels import ref
        return ref.verdict_unpack(words, n)


class PallasBackend:
    """TPU-native kernels (compiled on TPU, interpret mode elsewhere).

    ``lane_block`` threads the lane-block tiling override (LB lanes per
    grid step; 0 = auto) into every row-DMA kernel — see
    kernels/wave_commit.pick_lane_block and ``resolve``."""
    name = "pallas"
    use_pallas = True

    def __init__(self, lane_block: int = 0):
        self.lane_block = lane_block

    def validate(self, claim_w, keys, groups, myprio, check, wave,
                 fine: bool):
        from repro.kernels import ops
        return ops.occ_validate(claim_w, keys, groups, myprio, check,
                                inv_wave(wave), fine,
                                lane_block=self.lane_block, use_pallas=True)

    def validate_dual(self, claim_w, keys, groups, myprio, check, wave):
        from repro.kernels import ops
        return ops.occ_validate_dual(claim_w, keys, groups, myprio, check,
                                     inv_wave(wave),
                                     lane_block=self.lane_block,
                                     use_pallas=True)

    def probe(self, table, keys, groups, wave, fine: bool):
        from repro.kernels import ops
        return ops.claim_probe(table, keys, groups, inv_wave(wave), fine,
                               lane_block=self.lane_block, use_pallas=True)

    def claim_probe(self, table, keys, groups, prio, wave, mask,
                    fine: bool):
        from repro.kernels import ops
        return ops.claim_probe_fused(table, keys, groups, prio, mask, wave,
                                     fine, lane_block=self.lane_block,
                                     use_pallas=True)

    def wave_commit(self, claim_w, claim_r, wts, keys, groups, prio, do_w,
                    do_r, check_w, check_w2, check_r, extra, wave,
                    fine: bool, dual: bool, bump: bool):
        from repro.kernels import ops
        return ops.wave_commit(claim_w, claim_r, wts, keys, groups, prio,
                               do_w, do_r, check_w, check_w2, check_r,
                               extra, wave, fine, dual, bump,
                               lane_block=self.lane_block, use_pallas=True)

    def iterate_validate(self, table, keys, extents, groups, myprio, check,
                         wave, fine: bool, bucket_size: int, ext_cap: int):
        from repro.kernels import ops
        return ops.iterate_validate(table, keys, extents, groups, myprio,
                                    check, inv_wave(wave), fine,
                                    bucket_size, ext_cap,
                                    lane_block=self.lane_block,
                                    use_pallas=True)

    def route_pack(self, owner, vals, n_dest: int, cap: int, fills):
        from repro.kernels import ops
        return ops.route_pack(owner, vals, n_dest, cap, fills,
                              use_pallas=True)

    def ts_gather(self, table, keys, groups, fine: bool):
        from repro.kernels import ops
        return ops.ts_gather(table, keys, groups, fine, use_pallas=True)

    def claim_scatter(self, table, keys, groups, prio, wave, mask):
        from repro.kernels import ops
        return ops.claim_scatter(table, keys, groups, prio, mask, wave,
                                 use_pallas=True)

    def commit_install(self, wts, keys, groups, do):
        from repro.kernels import ops
        return ops.occ_commit(wts, keys, groups, do, use_pallas=True)

    def ts_install_max(self, table, keys, groups, vals, mask,
                       whole_row: bool = False):
        from repro.kernels import ops
        return ops.ts_install_max(table, keys, groups, vals, mask, whole_row,
                                  use_pallas=True)

    def segment_count(self, keys, groups, G: int, mask):
        from repro.kernels import ops
        return ops.segment_count(keys, groups, G, mask, use_pallas=True)

    def mv_gather(self, begin, keys, groups, ts, fine: bool):
        from repro.kernels import ops
        return ops.mv_gather(begin, keys, groups, ts, fine,
                             lane_block=self.lane_block, use_pallas=True)

    def mv_install(self, begin, head, keys, groups, do, ts):
        from repro.kernels import ops
        return ops.mv_install(begin, head, keys, groups, do, ts,
                              use_pallas=True)

    def verdict_pack(self, v):
        from repro.kernels import ops
        return ops.verdict_pack(v, use_pallas=True)

    def verdict_unpack(self, words, n: int):
        from repro.kernels import ops
        return ops.verdict_unpack(words, n, use_pallas=True)


_BACKENDS = {"jnp": JnpBackend(), "pallas": PallasBackend()}

#: The surface ops each mechanism's wave routes through the backend —
#: consumed by benchmark JSON rows so BENCH_* trajectories record which ops
#: actually ran as Pallas kernels (see launch/txn_bench.py).  Every
#: mechanism includes ``segment_count``: the engine's install-contention
#: cost model counts same-row committers/readers through it each wave
#: (core/engine.py make_wave_step), on top of TicToc's extension chains.
#: The probe family (OCC's read validation included) runs on the fused
#: ``wave_commit`` megakernel — the claim_probe -> verdict ->
#: commit_install chain in ONE launch (EngineConfig.fuse_wave; the
#: unfused chain remains behind fuse_wave=False).  ``commit_install``
#: stays listed for the bumping mechanisms: its version-bump traffic
#: rides the fused launch but is still attributed to the op (the cost
#: model splits it out — analysis/txn_cost.py).  ``claim_scatter``
#: remains listed only where a mechanism still installs claims it never
#: probes as priorities (AutoGran's verdict path, the MV
#: first-committer-wins channels).  ``iterate_validate`` is listed for
#: every mechanism that phantom-protects scans (extent > 1 ops): the
#: probe family and AutoGran validate intervals against the post-install
#: write-claim table, mvocc against its wave claim channel; mvcc alone
#: omits it — snapshot-isolation scans read a stable snapshot and are
#: never re-validated (DESIGN.md section 13).
CC_OPS = {
    t.CC_OCC: ("wave_commit", "iterate_validate", "commit_install",
               "segment_count"),
    t.CC_TICTOC: ("wave_commit", "iterate_validate", "ts_gather",
                  "ts_install_max", "segment_count"),
    t.CC_2PL: ("wave_commit", "iterate_validate", "commit_install",
               "segment_count"),
    t.CC_SWISS: ("wave_commit", "iterate_validate", "commit_install",
                 "segment_count"),
    t.CC_ADAPTIVE: ("wave_commit", "iterate_validate", "commit_install",
                    "segment_count"),
    t.CC_AUTOGRAN: ("validate_dual", "iterate_validate", "claim_scatter",
                    "commit_install", "segment_count"),
    t.CC_MVCC: ("validate", "claim_scatter", "mv_gather", "mv_install",
                "segment_count"),
    t.CC_MVOCC: ("validate", "iterate_validate", "claim_scatter",
                 "mv_gather", "mv_install", "segment_count"),
}

#: The surface ops one shard-local distributed wave routes through the
#: backend (core/distributed.py), per mechanism: the sort-free exchange
#: pack, the verdict bit-pack/unpack pair riding every verdict and commit
#: return channel, and the owner-side claim step — occ's runs as the
#: fused ``wave_commit`` (DistConfig.fuse_wave; claim install + probe +
#: verdicts in one table pass), the multi-version pair keeps the
#: ``claim_probe`` primitive (two claim channels + the ring gather can't
#: share one launch) — plus the install return-trip: ``commit_install``
#: version bumps for occ, ``mv_gather`` snapshot reads + ``mv_install``
#: ring publishes for the multi-version pair.  Scan fragments validate on
#: their owner shard through ``iterate_validate`` (intervals split at
#: range-shard boundaries; verdicts AND-reduce back on the sender —
#: DESIGN.md section 13).  Recorded by benchmarks/txn_scaling.py rows.
DIST_OPS = ("route_pack", "verdict_pack", "verdict_unpack", "wave_commit",
            "iterate_validate", "commit_install")
DIST_MV_OPS = ("route_pack", "verdict_pack", "verdict_unpack",
               "claim_probe", "mv_gather", "mv_install")
#: mvocc adds the interval pass; mvcc does NOT — its scans read the
#: snapshot's consistent cut and never re-validate (cc/mvcc.py).
DIST_MVOCC_OPS = DIST_MV_OPS + ("iterate_validate",)


def resolve(cfg) -> JnpBackend | PallasBackend:
    """Config (EngineConfig / DistConfig — anything with a validated
    ``backend`` field) -> the backend singleton.  A nonzero
    ``cfg.lane_block`` override on the pallas backend gets a dedicated
    instance threading the tiling into the lane-block kernels (the
    backends are stateless otherwise — DESIGN.md section 5)."""
    if cfg.backend == "pallas":
        lb = getattr(cfg, "lane_block", 0)
        if lb:
            return PallasBackend(lane_block=lb)
    return _BACKENDS[cfg.backend]


def kernel_coverage(backend_name: str, cc: int) -> dict:
    """{op: "pallas" | "xla"} for the ops mechanism ``cc`` routes through
    backend ``backend_name`` — the attribution record for benchmark JSON."""
    engine = "pallas" if backend_name == "pallas" else "xla"
    return {op: engine for op in CC_OPS[cc]}


def dist_kernel_coverage(backend_name: str, cc: str = "occ") -> dict:
    """Kernel attribution for the distributed wave's shard-local ops
    (``cc`` is the DistConfig mechanism string: occ / mvcc / mvocc)."""
    engine = "pallas" if backend_name == "pallas" else "xla"
    ops = {"mvcc": DIST_MV_OPS, "mvocc": DIST_MVOCC_OPS}.get(cc, DIST_OPS)
    return {op: engine for op in ops}
