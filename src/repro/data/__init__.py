from repro.data.pipeline import make_batch

__all__ = ["make_batch"]
