"""Deterministic, stateless synthetic data pipeline.

``make_batch(cfg, shape, step)`` is a pure function of the step index: no
cursor state, no files.  Properties this buys at cluster scale:

  - exact restart: resuming from a checkpoint at step k replays batch k;
  - elastic resharding: batches are generated *globally* and sharded by the
    caller's NamedSharding, so a different device count sees identical data;
  - per-host sharding: a host materializes only its addressable slice when
    ``host_slice`` is passed (process_index, process_count).

Token streams mimic a skewed unigram distribution (Zipf-ish over the vocab)
so losses move like real text rather than uniform noise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _tokens(key, shape, vocab: int):
    # Zipf-flavored unigram draw: u^4 concentrates mass on low token ids.
    u = jax.random.uniform(key, shape, jnp.float32)
    return jnp.minimum((u ** 4 * vocab).astype(jnp.int32), vocab - 1)


def make_batch(cfg, shape, step: int, *, train: bool = True,
               host_slice=None, seed: int = 1234):
    """Batch pytree for (cfg, shape) at ``step`` (jnp arrays, unsharded)."""
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if host_slice is not None:
        idx, count = host_slice
        assert B % count == 0
        B = B // count
        key = jax.random.fold_in(key, idx)
    kt, kp, kf = jax.random.split(key, 3)
    extra = 1 if train else 0
    batch = {}
    s_text = S
    if cfg.n_patches:
        s_text = S - cfg.n_patches
        batch["patches"] = (jax.random.normal(
            kp, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    if cfg.n_frames:
        batch["frames"] = (jax.random.normal(
            kf, (B, cfg.n_frames, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    batch["tokens"] = _tokens(kt, (B, s_text + extra), cfg.vocab)
    return batch
