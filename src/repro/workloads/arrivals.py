"""Poisson arrival schedules for the open-loop traffic front-end.

An open-loop benchmark decouples transaction *arrival* from transaction
*service*: clients submit on their own clock (here, a Poisson process of
``rate`` expected transactions per wave) and the engine admits from the
queue (core/admission.py).  Two seeded streams serve the two engines:

- ``poisson_offered`` — the in-scan draw the local engine uses: a JAX
  Poisson sample per wave, capped at the lane-grid width (the front-end
  materializes at most T fresh transactions per wave; arrivals beyond
  that cap are deferred to the offered count of no wave — the cap is the
  generator's width, not a queue drop, so size rates accordingly).
- ``PoissonArrivals`` — a host-side pre-drawn schedule (NumPy
  ``default_rng``) for the distributed driver, whose wave loop runs in
  Python: ``counts(n_waves, max_per_wave)`` yields the same kind of
  capped per-wave arrival counts, reproducibly from ``seed``.

Both are deliberately tiny: the schedule is a seeded PRNG stream, nothing
more, so bit-identity across backends (jnp vs pallas) and across reruns
is inherited from the seeds.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def poisson_offered(rng: jax.Array, rate: float, max_n: int) -> jax.Array:
    """One wave's arrival count: min(Poisson(rate), max_n), int32."""
    draw = jax.random.poisson(rng, jnp.float32(rate))
    return jnp.minimum(draw, max_n).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """Seeded host-side arrival schedule (the distributed wave driver's
    stream; benchmarks/txn_scaling.py)."""
    rate: float          # expected arrivals per wave
    seed: int = 0

    def counts(self, n_waves: int, max_per_wave: int) -> np.ndarray:
        """int32[n_waves] per-wave arrival counts, capped at the
        front-end's per-wave generation width."""
        rng = np.random.default_rng(self.seed)
        return np.minimum(rng.poisson(self.rate, n_waves),
                          max_per_wave).astype(np.int32)

    def shard_counts(self, n_waves: int, n_shards: int,
                     max_per_shard: int) -> np.ndarray:
        """int32[n_waves, n_shards]: the distributed front-end's arrival
        counts — each shard's admission queue runs its own thinned
        Poisson stream (rate / n_shards), capped at the shard's lane
        width."""
        rng = np.random.default_rng(self.seed)
        return np.minimum(
            rng.poisson(self.rate / max(n_shards, 1),
                        (n_waves, n_shards)),
            max_per_shard).astype(np.int32)
