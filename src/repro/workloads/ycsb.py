"""YCSB-like workload, per the paper's section 3.3:

  - 10M keys, each value 10 columns of 10 bytes;
  - each transaction: 16 operations, ~50% reads / ~50% writes, each picking a
    key ~ scrambled-Zipfian(theta=0.9) and one uniformly random column;
  - fine granularity = one timestamp for even-numbered columns, one for odd
    (paper section 3.4) — i.e. group = column % 2.

Writes are blind single-column overwrites (no read-modify-write), matching the
YCSB "update one field" semantics.

``ro_frac`` mixes in read-only scan transactions (txn_type 1: every op a
READ) — the YCSB-B/C-style client class the multi-version mechanisms
protect: under mvcc/mvocc these lanes read their snapshot and never abort,
while single-version OCC aborts them on any conflicting concurrent write
(benchmarks/abort_rates.py).  ``ro_frac=0`` (the default) draws the exact
PRNG stream this workload always had.

``scan_frac`` mixes in short-range SCAN transactions (YCSB-E style): one
interval READ of ``scan_len`` consecutive keys (op_extent = scan_len,
start Zipfian like every other key, clamped to stay in-table) plus one
point WRITE, so scan lanes are update transactions and every serializable
mechanism must phantom-protect the interval (iterate_validate /
CAUSE_PHANTOM — DESIGN.md section 13).  The scan class is its own
txn_type (after the read-only class when both exist); ``scan_frac=0``
(the default) again draws the historical PRNG stream bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import types as t
from repro.core.types import StoreState, TxnBatch, store_init
from repro.workloads.zipf import ZipfSampler


@dataclasses.dataclass(frozen=True)
class YCSBWorkload:
    n_keys: int = 10_000_000
    n_cols_schema: int = 10        # YCSB schema: 10 columns
    ops_per_txn: int = 16
    write_frac: float = 0.5
    ro_frac: float = 0.0           # fraction of read-only transactions
    scan_frac: float = 0.0         # fraction of short-range-scan txns
    scan_len: int = 8              # interval width of a scan op (extent)
    theta: float = 0.9
    zipf: ZipfSampler = None  # type: ignore[assignment]

    # Engine-facing schema:
    n_groups: int = 2
    n_rings: int = 1
    n_txn_types: int = 1

    def __post_init__(self):
        # The read-only and scan classes are their own txn_types; derive
        # the count here so direct dataclass construction can't desync it
        # from gen()'s output (a txn_type beyond n_txn_types would
        # silently corrupt the engine's commits_by_type scatter).
        n_types = 1 + (self.ro_frac > 0) + (self.scan_frac > 0)
        if self.n_txn_types < n_types:
            object.__setattr__(self, "n_txn_types", n_types)
        if self.scan_frac > 0:
            if not 1 <= self.scan_len <= self.n_keys:
                raise ValueError(
                    f"scan_len must be in [1, n_keys], got {self.scan_len}")

    @staticmethod
    def make(n_keys: int = 10_000_000, theta: float = 0.9,
             ops_per_txn: int = 16, write_frac: float = 0.5,
             ro_frac: float = 0.0, scan_frac: float = 0.0,
             scan_len: int = 8) -> "YCSBWorkload":
        return YCSBWorkload(n_keys=n_keys, theta=theta,
                            ops_per_txn=ops_per_txn, write_frac=write_frac,
                            ro_frac=ro_frac, scan_frac=scan_frac,
                            scan_len=scan_len,
                            zipf=ZipfSampler.make(n_keys, theta))

    @property
    def n_records(self) -> int:
        return self.n_keys

    @property
    def n_cols(self) -> int:
        return self.n_cols_schema

    @property
    def slots(self) -> int:
        return self.ops_per_txn

    @property
    def max_extent(self) -> int:
        """Widest interval any generated op carries (EngineConfig.max_extent
        anchor): scan_len when the scan class exists, else 1 (all point)."""
        return self.scan_len if self.scan_frac > 0 else 1

    def init_store(self, track_values: bool = False,
                   mv_depth: int = 0) -> StoreState:
        return store_init(self.n_records, self.n_groups,
                          self.n_cols if track_values else 0,
                          n_rings=self.n_rings, mv_depth=mv_depth)

    def gen(self, rng: jax.Array, wave: jax.Array, lanes: int,
            ring_tails: jax.Array):
        K = self.ops_per_txn
        # Extra splits only when the optional classes exist, so the default
        # workload (and every pre-scan ro_frac mix) draws its historical
        # PRNG stream unchanged.
        n_split = 4 + (self.ro_frac > 0) + (self.scan_frac > 0)
        parts = list(jax.random.split(rng, n_split))
        rk, rc, rw, rv = parts[:4]
        if self.ro_frac > 0:
            is_ro = jax.random.uniform(parts[4], (lanes,)) < self.ro_frac
        else:
            is_ro = jnp.zeros((lanes,), jnp.bool_)
        if self.scan_frac > 0:
            is_sc = (jax.random.uniform(parts[-1], (lanes,))
                     < self.scan_frac) & ~is_ro
        else:
            is_sc = jnp.zeros((lanes,), jnp.bool_)
        keys = self.zipf.sample(rk, (lanes, K))
        cols = jax.random.randint(rc, (lanes, K), 0, self.n_cols_schema)
        is_w = jax.random.uniform(rw, (lanes, K)) < self.write_frac
        is_w = is_w & ~is_ro[:, None]
        op_key = keys
        op_kind = jnp.where(is_w, t.WRITE, t.READ).astype(jnp.int32)
        op_extent = jnp.ones((lanes, K), jnp.int32)
        n_ops = jnp.full((lanes,), K, jnp.int32)
        scan_type = jnp.int32(1 + (self.ro_frac > 0))
        txn_type = jnp.where(is_sc, scan_type, is_ro.astype(jnp.int32))
        if self.scan_frac > 0:
            # Scan txn: op 0 = one interval READ of scan_len consecutive
            # keys (Zipfian start, clamped in-table), op 1 = one point
            # WRITE (an update txn — serializable mechanisms must phantom-
            # protect it), the rest masked out.
            col = jnp.arange(K, dtype=jnp.int32)[None, :]
            sc = is_sc[:, None]
            start = jnp.minimum(keys[:, :1], self.n_keys - self.scan_len)
            op_key = jnp.where(
                sc, jnp.where(col == 0, start,
                              jnp.where(col == 1, keys[:, 1:2], -1)),
                op_key)
            op_kind = jnp.where(
                sc & (col == 1), t.WRITE,
                jnp.where(sc, t.READ, op_kind)).astype(jnp.int32)
            op_extent = jnp.where(sc & (col == 0),
                                  jnp.int32(self.scan_len), op_extent)
            n_ops = jnp.where(is_sc, 2, n_ops)
        batch = TxnBatch(
            op_key=op_key,
            op_group=(cols % 2).astype(jnp.int32),  # the paper's parity split
            op_col=cols.astype(jnp.int32),
            op_kind=op_kind,
            op_val=jax.random.uniform(rv, (lanes, K)),
            txn_type=txn_type,
            n_ops=n_ops,
            op_extent=op_extent,
        )
        return batch, ring_tails
