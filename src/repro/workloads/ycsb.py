"""YCSB-like workload, per the paper's section 3.3:

  - 10M keys, each value 10 columns of 10 bytes;
  - each transaction: 16 operations, ~50% reads / ~50% writes, each picking a
    key ~ scrambled-Zipfian(theta=0.9) and one uniformly random column;
  - fine granularity = one timestamp for even-numbered columns, one for odd
    (paper section 3.4) — i.e. group = column % 2.

Writes are blind single-column overwrites (no read-modify-write), matching the
YCSB "update one field" semantics.

``ro_frac`` mixes in read-only scan transactions (txn_type 1: every op a
READ) — the YCSB-B/C-style client class the multi-version mechanisms
protect: under mvcc/mvocc these lanes read their snapshot and never abort,
while single-version OCC aborts them on any conflicting concurrent write
(benchmarks/abort_rates.py).  ``ro_frac=0`` (the default) draws the exact
PRNG stream this workload always had.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import types as t
from repro.core.types import StoreState, TxnBatch, store_init
from repro.workloads.zipf import ZipfSampler


@dataclasses.dataclass(frozen=True)
class YCSBWorkload:
    n_keys: int = 10_000_000
    n_cols_schema: int = 10        # YCSB schema: 10 columns
    ops_per_txn: int = 16
    write_frac: float = 0.5
    ro_frac: float = 0.0           # fraction of read-only transactions
    theta: float = 0.9
    zipf: ZipfSampler = None  # type: ignore[assignment]

    # Engine-facing schema:
    n_groups: int = 2
    n_rings: int = 1
    n_txn_types: int = 1

    def __post_init__(self):
        # The read-only class is its own txn_type; derive the count here so
        # direct dataclass construction can't desync it from gen()'s output
        # (a txn_type beyond n_txn_types would silently corrupt the
        # engine's commits_by_type scatter).
        if self.ro_frac > 0 and self.n_txn_types < 2:
            object.__setattr__(self, "n_txn_types", 2)

    @staticmethod
    def make(n_keys: int = 10_000_000, theta: float = 0.9,
             ops_per_txn: int = 16, write_frac: float = 0.5,
             ro_frac: float = 0.0) -> "YCSBWorkload":
        return YCSBWorkload(n_keys=n_keys, theta=theta,
                            ops_per_txn=ops_per_txn, write_frac=write_frac,
                            ro_frac=ro_frac,
                            zipf=ZipfSampler.make(n_keys, theta))

    @property
    def n_records(self) -> int:
        return self.n_keys

    @property
    def n_cols(self) -> int:
        return self.n_cols_schema

    @property
    def slots(self) -> int:
        return self.ops_per_txn

    def init_store(self, track_values: bool = False,
                   mv_depth: int = 0) -> StoreState:
        return store_init(self.n_records, self.n_groups,
                          self.n_cols if track_values else 0,
                          n_rings=self.n_rings, mv_depth=mv_depth)

    def gen(self, rng: jax.Array, wave: jax.Array, lanes: int,
            ring_tails: jax.Array):
        K = self.ops_per_txn
        if self.ro_frac > 0:
            # Extra split only when the read-only class exists, so the
            # default workload draws its historical PRNG stream unchanged.
            rk, rc, rw, rv, rro = jax.random.split(rng, 5)
            is_ro = jax.random.uniform(rro, (lanes,)) < self.ro_frac
        else:
            rk, rc, rw, rv = jax.random.split(rng, 4)
            is_ro = jnp.zeros((lanes,), jnp.bool_)
        keys = self.zipf.sample(rk, (lanes, K))
        cols = jax.random.randint(rc, (lanes, K), 0, self.n_cols_schema)
        is_w = jax.random.uniform(rw, (lanes, K)) < self.write_frac
        is_w = is_w & ~is_ro[:, None]
        batch = TxnBatch(
            op_key=keys,
            op_group=(cols % 2).astype(jnp.int32),  # the paper's parity split
            op_col=cols.astype(jnp.int32),
            op_kind=jnp.where(is_w, t.WRITE, t.READ).astype(jnp.int32),
            op_val=jax.random.uniform(rv, (lanes, K)),
            txn_type=is_ro.astype(jnp.int32),
            n_ops=jnp.full((lanes,), K, jnp.int32),
        )
        return batch, ring_tails
