"""TPC-C (New-order, Payment, Order-status — 92% of the standard mix, the
three the paper implements; ``scan_len > 0`` adds a Stock-level-style
fourth type and turns Order-status's order-line reads into one interval
scan), laid out for vectorized wave execution.

Tables live in one flat record space (dense arithmetic keys replace the
Masstree index — see DESIGN.md section 2):

    Warehouse | District | Customer | Item | Stock | Order ring | OrderLine ring

Contention comes from the paper's analysis (section 3.4):
  - New-order READS the warehouse/district tax fields;
  - Payment UPDATES the warehouse/district YTD fields of the same rows;
  - with one timestamp per row these are FALSE conflicts — the paper's
    central observation.  Fine granularity gives W/D/C rows two timestamps:
    group 0 = rarely-updated fields (tax, customer identity/credit),
    group 1 = the rest (YTD, balance, counts).

YTD/balance updates are blind commutative increments (ADD) — STO-style
commutative updates; this matches the paper's implementation in which
New-order's District access is a *read-only* operation (order-id assignment
happens outside CC, modeled by the per-district append rings whose cursors
advance by wave prefix-sum).

The per-district order id / insert slots are assigned outside CC (ring
cursors), like the paper's platform assigns o_id via fetch-and-add.  Aborted
New-orders leave ring holes, as they do on the real system.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import types as t
from repro.core.types import StoreState, TxnBatch, store_init
from repro.workloads.zipf import nurand

# Transaction types.
NEW_ORDER, PAYMENT, ORDER_STATUS, STOCK_LEVEL = 0, 1, 2, 3
# Renormalized standard mix (45/43/4 out of the 92% the paper implements).
MIX = (45 / 92, 43 / 92, 4 / 92)
# With the scan classes on (scan_len > 0): Stock-level joins at its
# standard 4% weight — 45/43/4/4 renormalized.
MIX_SCAN = (45 / 96, 43 / 96, 4 / 96, 4 / 96)

MAX_ITEMS = 15
SLOTS = 64

# Column layout (n_cols = 4).
W_TAX, W_YTD = 0, 1
D_TAX, D_YTD = 0, 1
C_INFO, C_BAL, C_YTD, C_CNT = 0, 1, 2, 3
S_QTY = 0

# Fine-granularity groups for W/D/C rows (the paper's two timestamps).
G_RARE, G_HOT = 0, 1


@dataclasses.dataclass(frozen=True)
class TPCCWorkload:
    n_warehouses: int = 8          # the paper fixes 8 (= their NUMA nodes)
    n_districts: int = 10
    n_cust_per_d: int = 3000
    n_items: int = 100_000
    o_cap: int = 1024              # order-ring capacity per district
    #: 0 (default) = the historical three-type point-op mix, bit-for-bit.
    #: > 0 turns on the scan classes: Order-status reads its order lines as
    #: ONE interval scan (extent MAX_ITEMS — the OL keys are consecutive by
    #: construction), and a Stock-level-style type joins the mix scanning
    #: ``scan_len`` consecutive stock rows of the home warehouse.
    scan_len: int = 0

    n_groups: int = 2
    n_txn_types: int = 3

    def __post_init__(self):
        if self.scan_len > 0:
            if self.scan_len > self.n_items:
                raise ValueError(
                    f"scan_len {self.scan_len} exceeds n_items "
                    f"{self.n_items}")
            if self.n_txn_types < 4:
                object.__setattr__(self, "n_txn_types", 4)

    @staticmethod
    def make(n_warehouses: int = 8, scale: float = 1.0,
             scan_len: int = 0) -> "TPCCWorkload":
        """scale < 1 shrinks the per-warehouse tables (for tests)."""
        return TPCCWorkload(
            n_warehouses=n_warehouses,
            n_cust_per_d=max(int(3000 * scale), 8),
            n_items=max(int(100_000 * scale), 16),
            o_cap=max(int(1024 * scale), 16),
            scan_len=scan_len,
        )

    # ---- layout ----
    @property
    def n_dist_total(self) -> int:
        return self.n_warehouses * self.n_districts

    @property
    def w_base(self) -> int: return 0

    @property
    def d_base(self) -> int: return self.n_warehouses

    @property
    def c_base(self) -> int: return self.d_base + self.n_dist_total

    @property
    def i_base(self) -> int:
        return self.c_base + self.n_dist_total * self.n_cust_per_d

    @property
    def s_base(self) -> int: return self.i_base + self.n_items

    @property
    def o_base(self) -> int:
        return self.s_base + self.n_warehouses * self.n_items

    @property
    def ol_base(self) -> int:
        return self.o_base + self.n_dist_total * self.o_cap

    @property
    def n_records(self) -> int:
        return self.ol_base + self.n_dist_total * self.o_cap * MAX_ITEMS

    @property
    def n_cols(self) -> int: return 4

    @property
    def n_rings(self) -> int: return self.n_dist_total

    @property
    def slots(self) -> int: return SLOTS

    @property
    def max_extent(self) -> int:
        """Widest interval any generated op carries (EngineConfig.max_extent
        anchor): the Order-status OL scan is extent MAX_ITEMS, the
        Stock-level window is ``scan_len``; 1 when scans are off."""
        return max(MAX_ITEMS, self.scan_len) if self.scan_len > 0 else 1

    def init_store(self, track_values: bool = False,
                   mv_depth: int = 0) -> StoreState:
        return store_init(self.n_records, self.n_groups,
                          self.n_cols if track_values else 0,
                          n_rings=self.n_rings, mv_depth=mv_depth)

    # ---- key helpers ----
    def d_key(self, w, d): return self.d_base + w * self.n_districts + d

    def c_key(self, w, d, c):
        return (self.c_base
                + (w * self.n_districts + d) * self.n_cust_per_d + c)

    def s_key(self, w, i): return self.s_base + w * self.n_items + i

    def o_key(self, r, pos): return self.o_base + r * self.o_cap + pos

    def ol_key(self, r, pos, j):
        return self.ol_base + (r * self.o_cap + pos) * MAX_ITEMS + j

    # ---- generation ----
    def gen(self, rng: jax.Array, wave: jax.Array, lanes: int,
            ring_tails: jax.Array):
        T, K = lanes, SLOTS
        # The extra split only exists in scan mode, so scan_len=0 draws the
        # historical PRNG stream bit-for-bit.
        if self.scan_len > 0:
            (r_type, r_w, r_d, r_c, r_it, r_nit, r_q, r_rem, r_rw, r_rd,
             r_sl) = jax.random.split(rng, 11)
            txn_type = jax.random.choice(
                r_type, 4, (T,),
                p=jnp.array(MIX_SCAN, jnp.float32)).astype(jnp.int32)
        else:
            (r_type, r_w, r_d, r_c, r_it, r_nit, r_q, r_rem, r_rw, r_rd
             ) = jax.random.split(rng, 10)
            txn_type = jax.random.choice(
                r_type, 3, (T,),
                p=jnp.array(MIX, jnp.float32)).astype(jnp.int32)
        w = jax.random.randint(r_w, (T,), 0, self.n_warehouses)
        d = jax.random.randint(r_d, (T,), 0, self.n_districts)
        c = nurand(r_c, 1023, 0, self.n_cust_per_d - 1, 259, (T,))
        items = nurand(r_it, 8191, 0, self.n_items - 1, 7911, (T, MAX_ITEMS))
        items = items % self.n_items
        n_it = jax.random.randint(r_nit, (T,), 5, MAX_ITEMS + 1)
        qty = jax.random.randint(r_q, (T, MAX_ITEMS), 1, 11).astype(
            jnp.float32)

        # Payment: 15% remote customer (different warehouse + district).
        remote = jax.random.uniform(r_rem, (T,)) < 0.15
        rw_ = jax.random.randint(r_rw, (T,), 0, self.n_warehouses)
        rd_ = jax.random.randint(r_rd, (T,), 0, self.n_districts)
        c_w = jnp.where(remote, rw_, w)
        c_d = jnp.where(remote, rd_, d)

        # Ring slot assignment for New-order lanes: per-district prefix sums.
        ring = (w * self.n_districts + d).astype(jnp.int32)
        is_no = txn_type == NEW_ORDER
        onehot = (ring[:, None] == jnp.arange(self.n_dist_total)[None, :]
                  ) & is_no[:, None]
        rank = jnp.cumsum(onehot, axis=0) - 1
        my_rank = jnp.take_along_axis(rank, ring[:, None], axis=1)[:, 0]
        o_pos = (ring_tails[ring] + my_rank) % self.o_cap
        new_tails = ring_tails + onehot.sum(axis=0).astype(jnp.int32)

        no = self._gen_new_order(T, w, d, c, items, n_it, qty, ring, o_pos)
        pay = self._gen_payment(T, w, d, c_w, c_d, c)
        os_ = self._gen_order_status(T, w, d, c, ring, ring_tails)
        variants = [no, pay, os_]
        if self.scan_len > 0:
            i0 = jax.random.randint(r_sl, (T,), 0,
                                    self.n_items - self.scan_len + 1)
            variants.append(self._gen_stock_level(T, w, d, i0))

        batch = jax.tree.map(
            lambda *xs: jnp.take_along_axis(
                jnp.stack(xs),
                txn_type.reshape((1, T) + (1,) * (xs[0].ndim - 1)),
                axis=0)[0],
            *variants)
        batch = dataclasses.replace(batch, txn_type=txn_type)
        return batch, new_tails

    def _empty(self, T):
        return dict(
            op_key=jnp.full((T, SLOTS), -1, jnp.int32),
            op_group=jnp.zeros((T, SLOTS), jnp.int32),
            op_col=jnp.zeros((T, SLOTS), jnp.int32),
            op_kind=jnp.zeros((T, SLOTS), jnp.int32),
            op_val=jnp.zeros((T, SLOTS), jnp.float32),
            op_extent=jnp.ones((T, SLOTS), jnp.int32),
        )

    @staticmethod
    def _set(f, sl, key, col, kind, group, val=0.0, mask=None):
        key = jnp.asarray(key, jnp.int32)
        if mask is not None:
            key = jnp.where(mask, key, -1)
        f["op_key"] = f["op_key"].at[:, sl].set(key)
        f["op_col"] = f["op_col"].at[:, sl].set(col)
        f["op_kind"] = f["op_kind"].at[:, sl].set(kind)
        f["op_group"] = f["op_group"].at[:, sl].set(group)
        f["op_val"] = f["op_val"].at[:, sl].set(val)

    def _gen_new_order(self, T, w, d, c, items, n_it, qty, ring, o_pos):
        f = self._empty(T)
        jmask = jnp.arange(MAX_ITEMS)[None, :] < n_it[:, None]
        self._set(f, 0, w, W_TAX, t.READ, G_RARE)
        self._set(f, 1, self.d_key(w, d), D_TAX, t.READ, G_RARE)
        self._set(f, 2, self.c_key(w, d, c), C_INFO, t.READ, G_RARE)
        self._set(f, slice(3, 18), self.i_base + items, 0, t.READ, G_RARE,
                  mask=jmask)
        skeys = self.s_key(w[:, None], items)
        self._set(f, slice(18, 33), skeys, S_QTY, t.READ, G_RARE, mask=jmask)
        self._set(f, slice(33, 48), skeys, S_QTY, t.WRITE, G_RARE, val=qty,
                  mask=jmask)
        self._set(f, 48, self.o_key(ring, o_pos), 0, t.WRITE, G_RARE,
                  val=c.astype(jnp.float32))
        olk = self.ol_key(ring[:, None], o_pos[:, None],
                          jnp.arange(MAX_ITEMS)[None, :])
        self._set(f, slice(49, 64), olk, 0, t.WRITE, G_RARE,
                  val=items.astype(jnp.float32), mask=jmask)
        n_ops = 4 + 3 * n_it
        return TxnBatch(txn_type=jnp.zeros((T,), jnp.int32),
                        n_ops=n_ops.astype(jnp.int32), **f)

    def _gen_payment(self, T, w, d, c_w, c_d, c):
        f = self._empty(T)
        ck = self.c_key(c_w, c_d, c)
        one = jnp.ones((T,), jnp.float32)
        self._set(f, 0, w, W_YTD, t.ADD, G_HOT, val=one)
        self._set(f, 1, self.d_key(w, d), D_YTD, t.ADD, G_HOT, val=one)
        self._set(f, 2, ck, C_INFO, t.READ, G_RARE)
        self._set(f, 3, ck, C_BAL, t.ADD, G_HOT, val=-one)
        self._set(f, 4, ck, C_YTD, t.ADD, G_HOT, val=one)
        self._set(f, 5, ck, C_CNT, t.ADD, G_HOT, val=one)
        return TxnBatch(txn_type=jnp.ones((T,), jnp.int32),
                        n_ops=jnp.full((T,), 6, jnp.int32), **f)

    def _gen_order_status(self, T, w, d, c, ring, ring_tails):
        f = self._empty(T)
        ck = self.c_key(w, d, c)
        last = (ring_tails[ring] - 1) % self.o_cap
        self._set(f, 0, ck, C_INFO, t.READ, G_RARE)
        self._set(f, 1, ck, C_BAL, t.READ, G_HOT)
        self._set(f, 2, self.o_key(ring, last), 0, t.READ, G_RARE)
        if self.scan_len > 0:
            # The order's MAX_ITEMS order-line keys are consecutive by
            # construction (ol_key is j-major), so the per-slot point reads
            # collapse into ONE interval scan — the iterator a real
            # Order-status runs, phantom-protected via iterate_validate.
            self._set(f, 3, self.ol_key(ring, last, 0), 0, t.READ, G_RARE)
            f["op_extent"] = f["op_extent"].at[:, 3].set(MAX_ITEMS)
            n_ops = 4
        else:
            olk = self.ol_key(ring[:, None], last[:, None],
                              jnp.arange(MAX_ITEMS)[None, :])
            self._set(f, slice(3, 18), olk, 0, t.READ, G_RARE,
                      mask=jnp.ones((T, MAX_ITEMS), jnp.bool_))
            n_ops = 18
        return TxnBatch(txn_type=jnp.full((T,), 2, jnp.int32),
                        n_ops=jnp.full((T,), n_ops, jnp.int32), **f)

    def _gen_stock_level(self, T, w, d, i0):
        """Stock-level style: read the district, then scan ``scan_len``
        consecutive stock rows of the home warehouse (the standard
        transaction's recent-order stock check, flattened to one window
        over the dense stock keys).  Read-only — under MV it serializes at
        its snapshot; single-version mechanisms phantom-protect the scan."""
        f = self._empty(T)
        self._set(f, 0, self.d_key(w, d), D_TAX, t.READ, G_RARE)
        self._set(f, 1, self.s_key(w, i0), S_QTY, t.READ, G_RARE)
        f["op_extent"] = f["op_extent"].at[:, 1].set(self.scan_len)
        return TxnBatch(txn_type=jnp.full((T,), STOCK_LEVEL, jnp.int32),
                        n_ops=jnp.full((T,), 2, jnp.int32), **f)
