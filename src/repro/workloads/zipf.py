"""Vectorized scrambled-Zipfian key sampler (the YCSB generator, in JAX).

Implements the Gray et al. "Quickly generating billion-record synthetic
databases" inverse-CDF construction used verbatim by YCSB's
ZipfianGenerator/ScrambledZipfianGenerator: ranks follow P(i) ~ 1/i^theta and
are then hash-scrambled so the hot set is spread across the keyspace (hot keys
are not neighbors).  zeta(n, theta) is precomputed once on the host in
float64; sampling is pure jnp and jit/vmap-friendly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ZipfSampler:
    n: int
    theta: float
    zetan: float
    eta: float
    alpha: float

    @staticmethod
    def make(n: int, theta: float = 0.9) -> "ZipfSampler":
        i = np.arange(1, n + 1, dtype=np.float64)
        zetan = float(np.sum(1.0 / i ** theta))
        zeta2 = 1.0 + 0.5 ** theta
        eta = (1.0 - (2.0 / n) ** (1.0 - theta)) / (1.0 - zeta2 / zetan)
        return ZipfSampler(n=n, theta=theta, zetan=zetan, eta=eta,
                           alpha=1.0 / (1.0 - theta))

    def ranks(self, rng: jax.Array, shape) -> jax.Array:
        """Zipfian ranks in [0, n): rank 0 is the hottest."""
        u = jax.random.uniform(rng, shape, jnp.float32, 1e-7, 1.0)
        uz = u * self.zetan
        tail = (self.n * jnp.power(self.eta * u - self.eta + 1.0,
                                   self.alpha)).astype(jnp.int32)
        r = jnp.where(uz < 1.0, 0,
                      jnp.where(uz < 1.0 + 0.5 ** self.theta, 1, tail))
        return jnp.clip(r, 0, self.n - 1)

    def sample(self, rng: jax.Array, shape) -> jax.Array:
        """Scrambled-Zipfian keys in [0, n)."""
        return scramble(self.ranks(rng, shape), self.n)


def scramble(x: jax.Array, n: int) -> jax.Array:
    """Murmur3-finalizer integer hash, mod n (YCSB uses FNV64 — any
    well-mixing integer hash serves; collisions are part of the generator's
    contract)."""
    h = x.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(n)).astype(jnp.int32)


def nurand(rng: jax.Array, A: int, x: int, y: int, C: int, shape):
    """TPC-C NURand(A, x, y): non-uniform customer/item id selection."""
    r1, r2 = jax.random.split(rng)
    a = jax.random.randint(r1, shape, 0, A + 1)
    b = jax.random.randint(r2, shape, x, y + 1)
    return (((a | b) + C) % (y - x + 1)) + x
