from repro.workloads.arrivals import PoissonArrivals, poisson_offered
from repro.workloads.tpcc import TPCCWorkload
from repro.workloads.ycsb import YCSBWorkload

__all__ = ["YCSBWorkload", "TPCCWorkload", "PoissonArrivals",
           "poisson_offered"]
