from repro.workloads.ycsb import YCSBWorkload
from repro.workloads.tpcc import TPCCWorkload

__all__ = ["YCSBWorkload", "TPCCWorkload"]
