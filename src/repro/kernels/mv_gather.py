"""Snapshot version-select kernel (the MV store's read path).

A multi-version read walks the record's version chain for the newest version
visible at its snapshot timestamp.  On the paper's CPU platform that is a
pointer chase per read; here the chain is a fixed-depth ring
(core/mvstore.py), so the TPU-native formulation is the same lane-block
row-DMA grid as the claim-table gathers (kernels/occ_validate.py): op keys
are prefetched into SMEM, each ``(T // LB,)`` grid step DMAs its block's
LB*K whole begin-timestamp rings [D, G] HBM->VMEM (the whole read stream in
flight at once — kernels/wave_commit.py), and the VPU does the visibility
scan vectorized over the block — all D slots of all block ops compared at
once instead of a serial chain walk.

Granularity is the visibility width (DESIGN.md section 9): fine checks the
op's own group's begin timestamp per slot, coarse reduces each slot over the
whole row (one timestamp per record: max over groups, so a group-1-only
update hides the slot from coarse group-0 readers — the false-conflict
structure of the paper's section 3.4 at the version-chain level).  Empty
slots carry MV_EMPTY begins and are never visible.  When NO retained slot is
visible the snapshot has been reclaimed by the ring's epoch advance: ok is
False and the caller aborts the reader — it can never read a recycled slot.

Masked ops (key < 0) clamp their DMA to row 0 and are forced to
(slot 0, ok False), matching the jnp gather's fill path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.wave_commit import (_row_dmas, _start, _wait,
                                       pick_lane_block)


def _kernel(fine, D, G, LB, K, keys_ref, ts_ref, kv_b, grp_b, tbl, slot_b,
            ok_b, rows_s, sem):
    LBK = LB * K
    t0 = pl.program_id(0) * LB
    _row_dmas(_start, keys_ref, tbl, rows_s, sem, t0, LB, K)
    _row_dmas(_wait, keys_ref, tbl, rows_s, sem, t0, LB, K)

    rows = rows_s[...]                                   # uint32[LBK, D, G]
    ts = ts_ref[0]
    if fine:
        gb = grp_b[...].reshape(LBK)
        sel = (jnp.arange(G, dtype=jnp.int32)[None, None, :]
               == gb[:, None, None])
        eff = jnp.where(sel, rows, jnp.uint32(0)).max(axis=2)
    else:
        eff = rows.max(axis=2)                           # uint32[LBK, D]
    score = jnp.where(eff <= ts, eff + jnp.uint32(1), jnp.uint32(0))
    best = score.max(axis=1)                             # (LBK,)
    slot = jnp.where(score == best[:, None],
                     jnp.arange(D, dtype=jnp.int32)[None, :], D).min(axis=1)
    live = kv_b[...].reshape(LBK) >= 0
    slot_b[...] = jnp.where(live, slot, 0).reshape(LB, K)
    ok_b[...] = (live & (best > 0)).reshape(LB, K)


def mv_gather_pallas(begin: jax.Array, keys: jax.Array, groups: jax.Array,
                     ts: jax.Array, fine: bool, lane_block: int = 0,
                     interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """(slot int32[T, K], ok bool[T, K]) — see ref.mv_gather."""
    T, K = keys.shape
    D, G = begin.shape[1], begin.shape[2]
    LB = pick_lane_block(T, K, G * D, lane_block)
    LBK = LB * K
    tsa = jnp.reshape(ts.astype(jnp.uint32), (1,))
    blk = pl.BlockSpec((LB, K), lambda i, keys, ts: (i, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, ts
        grid=(T // LB,),
        in_specs=[blk, blk,
                  pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=(blk, blk),
        scratch_shapes=[pltpu.VMEM((LBK, D, G), jnp.uint32),
                        pltpu.SemaphoreType.DMA((LBK,))],
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, D, G, LB, K),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((T, K), jnp.int32),
                   jax.ShapeDtypeStruct((T, K), jnp.bool_)),
        interpret=interpret,
    )(keys, tsa, keys, groups, begin)
