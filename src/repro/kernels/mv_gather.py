"""Snapshot version-select kernel (the MV store's read path).

A multi-version read walks the record's version chain for the newest version
visible at its snapshot timestamp.  On the paper's CPU platform that is a
pointer chase per read; here the chain is a fixed-depth ring
(core/mvstore.py), so the TPU-native formulation is the same scalar-prefetch
DMA as the claim-table gathers (kernels/occ_validate.py): op keys are
prefetched into SMEM, each grid step DMAs one record's whole begin-timestamp
ring [D, G] HBM->VMEM, and the VPU does the visibility scan — all D slots
compared at once instead of a serial chain walk.

Granularity is the visibility width (DESIGN.md section 9): fine checks the
op's own group's begin timestamp per slot, coarse reduces each slot over the
whole row (one timestamp per record: max over groups, so a group-1-only
update hides the slot from coarse group-0 readers — the false-conflict
structure of the paper's section 3.4 at the version-chain level).  Empty
slots carry MV_EMPTY begins and are never visible.  When NO retained slot is
visible the snapshot has been reclaimed by the ring's epoch advance: ok is
False and the caller aborts the reader — it can never read a recycled slot.

Masked ops (key < 0) clamp their DMA to row 0 and are forced to
(slot 0, ok False), matching the jnp gather's fill path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(fine: bool, D: int, G: int, keys_ref, ts_ref, grp_ref, row_ref,
            slot_ref, ok_ref):
    row = row_ref[0]                                      # uint32[D, G]
    ts = ts_ref[0]
    if fine:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32)[None, :] == g
        eff = jnp.where(sel, row, jnp.uint32(0)).max(axis=1)
    else:
        eff = row.max(axis=1)                             # uint32[D]
    score = jnp.where(eff <= ts, eff + jnp.uint32(1), jnp.uint32(0))
    best = score.max()
    slot = jnp.where(score == best, jnp.arange(D, dtype=jnp.int32), D).min()
    t, k = pl.program_id(0), pl.program_id(1)
    live = keys_ref[t, k] >= 0
    slot_ref[0, 0] = jnp.where(live, slot, 0)
    ok_ref[0, 0] = live & (best > 0)


def mv_gather_pallas(begin: jax.Array, keys: jax.Array, groups: jax.Array,
                     ts: jax.Array, fine: bool,
                     interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """(slot int32[T, K], ok bool[T, K]) — see ref.mv_gather."""
    T, K = keys.shape
    D, G = begin.shape[1], begin.shape[2]
    tsa = jnp.reshape(ts.astype(jnp.uint32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, ts drive the index_maps
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ts: (t, k)),   # groups
            # One record's whole begin ring per op, DMA'd by prefetched key.
            pl.BlockSpec((1, D, G),
                         lambda t, k, keys, ts: (jnp.maximum(keys[t, k], 0),
                                                 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda t, k, keys, ts: (t, k)),
            pl.BlockSpec((1, 1), lambda t, k, keys, ts: (t, k)),
        ),
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, D, G),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((T, K), jnp.int32),
                   jax.ShapeDtypeStruct((T, K), jnp.bool_)),
        interpret=interpret,
    )(keys, tsa, groups, begin)
