"""Op sixteen: interval (scan) validation — phantom protection.

Hekaton-style iterator validation for extent-carrying ops: every scan op
covers ``[key, key + extent)`` and must abort if any record of its
validated interval carries a live same-wave claim stronger than the
scanning lane (DESIGN.md section 13).  Run against the POST-install claim
table, the monotone wave tags make this exactly the phantom check — the
only claims visible are this wave's writers, i.e. precisely the installs
the scan's wave-start snapshot could have missed.

The grid reuses the lane-block row-DMA idiom of occ_validate.py, scaled
by the interval width: ``(T // LB,)`` steps, and each step issues
``LB*K*span`` row fetches back-to-back (span = the STATIC per-op row
bound from ``ref.scan_span``) before one wait and a fully vectorized
compare.  Granularity is the interval-claim layout, not just the compare
width:

- fine (per-gap timestamps): rows ``key .. key+extent-1`` probed at the
  op's own group column — only a writer of the scanned column group
  inside the exact interval kills the scan;
- coarse (bucket-interval claims, one claim word per ``bucket_size``
  records): the bucket-EXPANDED interval is probed with the whole-row
  compare; a bucket's claim word is the min over its member rows, so the
  kernel fetches the bucket's rows and min-reduces — writers anywhere in
  a touched bucket abort the scan (false phantoms at the bucket edges).

Masked ops (check False or key < 0) and rows past the table edge clamp
their DMA to row 0 and are masked out of the compare.  ``LB`` has its own
chooser (``pick_scan_block``): the row scratch scales by span, so scan
blocks are narrower than the point-op kernels' for the same table width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import NO_PRIO, live_prio
from repro.kernels.ref import scan_span
from repro.kernels.wave_commit import _start, _wait

#: VMEM budget for the (LB*K*span, G) row scratch the auto chooser fits.
_SCAN_TILE_BYTES = 1 << 19


def pick_scan_block(T: int, K: int, G: int, span: int,
                    override: int = 0) -> int:
    """Lanes per grid step for the interval kernel.  Auto mode fits the
    row scratch (LB*K*span rows of G words) under ``_SCAN_TILE_BYTES``;
    an explicit override (EngineConfig.lane_block) wins.  Either way the
    result snaps DOWN to a divisor of T."""
    if override:
        lb = max(1, min(int(override), T))
    else:
        lb = max(1, _SCAN_TILE_BYTES // max(4 * K * span * G, 1))
        lb = min(lb, T)
    while T % lb:
        lb -= 1
    return lb


def _interval_dmas(action, keys_ref, tbl_ref, buf_ref, sem_ref, t0, LB, K,
                   span, B, fine, N):
    """Issue (or wait) the span row copies of every block op: scratch row
    ``op*span + j`` holds interval row j of block op ``op``.  All
    LB*K*span copies of a stream are in flight together."""

    def body(i, _):
        op = i // span
        t = t0 + op // K
        key = keys_ref[t, op % K]
        start = key if fine else (key // B) * B
        row = start + i % span
        ok = (key >= 0) & (row >= 0) & (row < N)
        row = jnp.where(ok, row, 0)
        copy = pltpu.make_async_copy(tbl_ref.at[row], buf_ref.at[i],
                                     sem_ref.at[i])
        action(copy)
        return 0

    jax.lax.fori_loop(0, LB * K * span, body, 0)


def _kernel(fine, G, LB, K, span, B, N, keys_ref, ivw_ref, kv_b, ext_b,
            grp_b, prio_b, chk_b, tbl, out_b, rows_s, sem):
    LBK = LB * K
    t0 = pl.program_id(0) * LB
    _interval_dmas(_start, keys_ref, tbl, rows_s, sem, t0, LB, K, span, B,
                   fine, N)
    _interval_dmas(_wait, keys_ref, tbl, rows_s, sem, t0, LB, K, span, B,
                   fine, N)
    kv = kv_b[...].reshape(LBK)
    ext = jnp.maximum(ext_b[...].reshape(LBK), 1)
    if fine:
        start = kv
        width = ext
    else:
        start = (kv // B) * B
        width = ((kv + ext + B - 1) // B) * B - start
    pr = live_prio(rows_s[...], ivw_ref[0])            # (LBK*span, G)
    if fine:
        gb = grp_b[...].reshape(LBK)
        gbf = jnp.broadcast_to(gb[:, None], (LBK, span)).reshape(LBK * span)
        sel = jnp.arange(G, dtype=jnp.int32)[None, :] == gbf[:, None]
        wprio = jnp.where(sel, pr, jnp.uint32(NO_PRIO)).min(axis=1)
    else:
        wprio = pr.min(axis=1)
    wprio = wprio.reshape(LBK, span)
    jidx = jnp.broadcast_to(jnp.arange(span, dtype=jnp.int32)[None, :],
                            (LBK, span))
    row = start[:, None] + jidx
    act = ((jidx < width[:, None]) & (kv[:, None] >= 0)
           & (row >= 0) & (row < N))
    conf = (chk_b[...].reshape(LBK)[:, None] & act
            & (wprio < prio_b[...].reshape(LBK)[:, None])).any(axis=1)
    out_b[...] = conf.reshape(LB, K)


def iterate_validate_pallas(table: jax.Array, keys: jax.Array,
                            extents: jax.Array, groups: jax.Array,
                            myprio: jax.Array, check: jax.Array,
                            inv_wave: jax.Array, fine: bool,
                            bucket_size: int, ext_cap: int,
                            lane_block: int = 0,
                            interpret: bool = False) -> jax.Array:
    """conflict bool[T, K] — see ref.iterate_validate for the oracle."""
    T, K = keys.shape
    N, G = table.shape
    span = scan_span(ext_cap, fine, bucket_size)
    LB = pick_scan_block(T, K, G, span, lane_block)
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    LBK = LB * K
    blk = pl.BlockSpec((LB, K), lambda i, keys, ivw: (i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T // LB,),
        in_specs=[blk] * 5
        + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=blk,
        scratch_shapes=[pltpu.VMEM((LBK * span, G), jnp.uint32),
                        pltpu.SemaphoreType.DMA((LBK * span,))],
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, G, LB, K, span, bucket_size, N),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.bool_),
        interpret=interpret,
    )(keys, ivw, keys, extents, groups, myprio.astype(jnp.uint32), check,
      table)
