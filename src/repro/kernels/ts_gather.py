"""Timestamp row-gather kernel (TicToc's (wts, rts) observation).

TicToc reads two timestamps per op — the cell's write timestamp and read
timestamp — before computing its commit_ts.  On the paper's CPU platform this
is the same pointer chase as OCC validation; the TPU-native formulation is the
same scalar-prefetch DMA as kernels/occ_validate.py: op keys are prefetched
into SMEM, each grid step DMAs one timestamp-table row HBM->VMEM (the
BlockSpec index_map reads the key), and the VPU selects the observation width.

Granularity is the observation width (DESIGN.md sections 2 and 5): fine reads
the op's own group column, coarse reads the row *max* — one timestamp per
record means any group's modification constrains the whole row.  The row is
already in VMEM either way, so the coarse reduce is free: the DMA cost is
identical for both granularities.

Masked ops (key < 0) clamp their DMA to row 0 and are forced to 0 in the
output — the same fill value the jnp gather path uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(fine: bool, G: int, keys_ref, grp_ref, row_ref, out_ref):
    row = row_ref[0, :]                                   # uint32[G]
    if fine:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32) == g
        ts = jnp.where(sel, row, jnp.uint32(0)).max()
    else:
        ts = row.max()
    t, k = pl.program_id(0), pl.program_id(1)
    live = keys_ref[t, k] >= 0
    out_ref[0, 0] = jnp.where(live, ts, jnp.uint32(0))


def ts_gather_pallas(table: jax.Array, keys: jax.Array, groups: jax.Array,
                     fine: bool, interpret: bool = False) -> jax.Array:
    """Per-op timestamp observation uint32[T, K] — see ref.ts_gather."""
    T, K = keys.shape
    G = table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # keys drive the index_maps
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # groups
            # One timestamp-table row per op, DMA'd by prefetched key.
            pl.BlockSpec((1, G),
                         lambda t, k, keys: (jnp.maximum(keys[t, k], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.uint32),
        interpret=interpret,
    )(keys, groups, table)
