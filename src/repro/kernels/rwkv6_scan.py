"""RWKV-6 ("Finch") wkv kernel — data-dependent-decay linear attention.

Per head, the state is a [Dk, Dv] matrix updated per token:

    out_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

Grid (B*H,): each step keeps the whole [S, Dk] r/k/w tiles, the [S, Dv] v
tile, and the [Dk, Dv] state in VMEM and walks time on the VPU (rank-1 update
+ matvec per token).  Head dims are small (64) so the state is 16 KB — the
VMEM working set is dominated by the sequence tiles, which is why ops.py
chunks long sequences and carries the state between chunks (this is also the
decode path: chunk length 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(S: int, H: int, r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            out_ref, slast_ref):
    u = u_ref[0, :].astype(jnp.float32)                    # [Dk]

    def step(t, state):
        rt = r_ref[0, t, :].astype(jnp.float32)            # [Dk]
        kt = k_ref[0, t, :].astype(jnp.float32)
        vt = v_ref[0, t, :].astype(jnp.float32)            # [Dv]
        wt = w_ref[0, t, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                     # [Dk, Dv]
        out = ((state + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        out_ref[0, t, :] = out.astype(out_ref.dtype)
        return wt[:, None] * state + kv

    s = jax.lax.fori_loop(0, S, step, s0_ref[0].astype(jnp.float32))
    slast_ref[0] = s


def rwkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, s0: jax.Array, interpret: bool = False):
    """r,k,w: [B,H,S,Dk]; v: [B,H,S,Dv]; u: [H,Dk]; s0: [B,H,Dk,Dv] f32.
    Returns (out [B,H,S,Dv], s_last [B,H,Dk,Dv])."""
    B, H, S, Dk = r.shape
    Dv = v.shape[-1]
    rr = r.reshape(B * H, S, Dk)
    kk = k.reshape(B * H, S, Dk)
    vv = v.reshape(B * H, S, Dv)
    ww = w.reshape(B * H, S, Dk)
    ss = s0.reshape(B * H, Dk, Dv)

    def head_index(bh):
        return (bh % H, 0)

    out, s_last = pl.pallas_call(
        functools.partial(_kernel, S, H),
        grid=(B * H,),
        in_specs=[
            pl.BlockSpec((1, S, Dk), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, S, Dk), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, S, Dv), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, S, Dk), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, Dk), head_index),
            pl.BlockSpec((1, Dk, Dv), lambda bh: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, Dv), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda bh: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, Dv), r.dtype),
            jax.ShapeDtypeStruct((B * H, Dk, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(rr, kk, vv, ww, u, ss)
    return out.reshape(B, H, S, Dv), s_last.reshape(B, H, Dk, Dv)
