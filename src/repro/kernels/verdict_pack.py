"""Verdict bit-packing kernels (the distributed wave's wire shrink).

The routed wave used to return one int8 per op on the verdict and commit
exchanges.  Only 2 bits of that byte ever carry information (bit 0 =
unconditional conflict, bit 1 = read-validation — DESIGN.md section 10),
so these kernels interleave 16 ops per int32 wire word: op j's fields land
at bits ``2*(j % 16)`` and ``2*(j % 16) + 1`` of word ``j // 16`` — a 4x
byte reduction for the 16-aligned exchange caps the benchmarks run.

Like route_pack, each destination's row sits whole in VMEM and the grid
walks destinations.  Packing is a masked shift-and-reduce over a
word-vs-op 2-D iota (no reshape, no gather: word w sums the shifted
fields of ops ``16w .. 16w+15``); unpacking is the transposed select.
Both are bit-identical to the ``ref.verdict_pack``/``ref.verdict_unpack``
oracles (tests/test_pipeline.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(v_ref, out_ref):
    v = v_ref[0, :].astype(jnp.uint32) & 3                  # [M]
    M = v.shape[0]
    W = out_ref.shape[1]
    w_idx = jax.lax.broadcasted_iota(jnp.int32, (W, M), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (W, M), 1)
    shift = (2 * (j_idx % 16)).astype(jnp.uint32)
    contrib = jnp.where(j_idx // 16 == w_idx, v[None, :] << shift,
                        jnp.uint32(0))
    # Disjoint bit fields: the sum is a bitwise OR of the shifted lanes.
    out_ref[0, :] = contrib.sum(axis=1, dtype=jnp.uint32).astype(jnp.int32)


def _unpack_kernel(n: int, words_ref, out_ref):
    w = words_ref[0, :].astype(jnp.uint32)                  # [W]
    W = w.shape[0]
    w_idx = jax.lax.broadcasted_iota(jnp.int32, (W, n), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (W, n), 1)
    shift = (2 * (j_idx % 16)).astype(jnp.uint32)
    vals = jnp.where(j_idx // 16 == w_idx, (w[:, None] >> shift) & 3,
                     jnp.uint32(0))
    out_ref[0, :] = vals.sum(axis=0, dtype=jnp.uint32).astype(jnp.int8)


def verdict_pack_pallas(v: jax.Array, interpret: bool = False) -> jax.Array:
    """int8[D, M] verdict bytes -> int32[D, ceil(M/16)] wire words (see
    ref.verdict_pack)."""
    D, M = v.shape
    W = -(-M // 16)
    return pl.pallas_call(
        _pack_kernel,
        grid=(D,),
        in_specs=[pl.BlockSpec((1, M), lambda d: (d, 0))],
        out_specs=pl.BlockSpec((1, W), lambda d: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((D, W), jnp.int32),
        interpret=interpret,
    )(v)


def verdict_unpack_pallas(words: jax.Array, n: int,
                          interpret: bool = False) -> jax.Array:
    """int32[D, ceil(n/16)] wire words -> int8[D, n] verdict bytes (see
    ref.verdict_unpack)."""
    D, W = words.shape
    return pl.pallas_call(
        functools.partial(_unpack_kernel, n),
        grid=(D,),
        in_specs=[pl.BlockSpec((1, W), lambda d: (d, 0))],
        out_specs=pl.BlockSpec((1, n), lambda d: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((D, n), jnp.int8),
        interpret=interpret,
    )(words)
