"""Sort-free routing-pack kernel (the distributed wave's send side).

The sharded engine used to build its per-destination exchange buffers with
an ``argsort`` over op owners plus ``bincount``/``cumsum`` offsets — the one
per-wave sort left in the repo after the local wave went sort-free.  This
kernel replaces it with a counting/offset scan: the grid walks destinations,
each step matches the wave's owner vector against its destination id, a
cumulative count gives every matching op its in-destination rank (the exact
placement a *stable* argsort by owner would produce), and a rank-vs-slot
one-hot select materializes the destination's fixed-capacity buffer row for
every payload channel at once.  The whole wave ([M] int32 owners + [W, M]
payloads) sits in VMEM, so like segment_count this is an all-pairs-style
compare with no sort, no O(n_records) table, and an order-free result.

Ops whose rank reaches the capacity are dropped (``took`` False — their
lane aborts, counted by the caller); masked ops carry an out-of-range owner
and match no destination.  Per-destination ``pos``/``took`` rows are
reduced to per-op vectors by the wrapper (sum/any over destinations — each
op matches at most one), bit-identical to ``ref.route_pack``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cap: int, fills, owner_ref, vals_ref, buf_ref, pos_ref,
            took_ref):
    d = pl.program_id(0)
    own = owner_ref[0, :]                             # int32[M]
    match = own == d
    prefix = jnp.cumsum(match) - match                # rank within dest d
    fit = match & (prefix < cap)
    pos_ref[0, :] = jnp.where(match, prefix, 0).astype(jnp.int32)
    took_ref[0, :] = fit
    # One-hot (rank == slot) select: at most one op per buffer cell.
    sel = fit[None, :] & (prefix[None, :]
                          == jnp.arange(cap, dtype=jnp.int32)[:, None])
    have = sel.any(axis=1)                            # bool[cap]
    for w, fill in enumerate(fills):                  # W static channels
        v = jnp.where(sel, vals_ref[w, :][None, :], 0).sum(axis=1)
        buf_ref[w, 0, :] = jnp.where(have, v.astype(jnp.int32),
                                     jnp.int32(fill))


def route_pack_pallas(owner: jax.Array, vals: jax.Array, n_dest: int,
                      cap: int, fills, interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(buf [W, n_dest, cap], pos [M], took [M]) — see ref.route_pack."""
    W, M = vals.shape
    out = pl.pallas_call(
        functools.partial(_kernel, cap, tuple(fills)),
        grid=(n_dest,),
        in_specs=[
            pl.BlockSpec((1, M), lambda d: (0, 0)),       # owner (whole wave)
            pl.BlockSpec((W, M), lambda d: (0, 0)),       # payload channels
        ],
        out_specs=(
            pl.BlockSpec((W, 1, cap), lambda d: (0, d, 0)),
            pl.BlockSpec((1, M), lambda d: (d, 0)),
            pl.BlockSpec((1, M), lambda d: (d, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((W, n_dest, cap), jnp.int32),
                   jax.ShapeDtypeStruct((n_dest, M), jnp.int32),
                   jax.ShapeDtypeStruct((n_dest, M), jnp.bool_)),
        interpret=interpret,
    )(owner.reshape(1, M), vals)
    buf, pos_rows, took_rows = out
    return buf, pos_rows.sum(axis=0).astype(jnp.int32), took_rows.any(axis=0)
