"""OCC read-set validation kernel.

The hot loop of optimistic commit: for every read op, fetch the claimed-writer
word of its (record, group) cell and compare priorities.  On the paper's CPU
platform this is a pointer chase per read; the TPU-native formulation is a
scalar-prefetch-driven DMA: op keys are prefetched into SMEM and claim rows
move HBM->VMEM by explicit ``make_async_copy`` row DMAs, then the VPU does
the tag/priority compare.

The grid is LANE BLOCKS (kernels/wave_commit.py): ``(T // LB,)`` with an
LB-lane x K-slot block per step instead of the old one-op-per-step
``(T, K)`` grid.  A step issues the row fetches for all LB*K ops of its
block back-to-back (the whole read stream in flight at once), waits once,
and runs the compares fully vectorized over the block — amortizing the
per-step grid overhead that dominated at one row DMA per step.  ``LB`` is
auto-chosen from the table width (``pick_lane_block``) with an
``EngineConfig.lane_block`` override; LB=1 recovers the per-op tiling.

Granularity is the compare width (DESIGN.md section 2): fine compares the
op's own group column, coarse reduces over the whole row (G is small — one
8/16-byte row per op — so the coarse reduce is free; the DMA is the cost, and
it is identical for both granularities, matching the paper's "fine-grained
timestamps have no measurable overhead").

Three kernels share the one lane-block row-DMA grid:

- ``occ_validate_pallas`` — conflict bool at one granularity (OCC's hot loop);
- ``occ_validate_dual_pallas`` — fine AND coarse verdicts from the same row
  fetch, so AutoGran's double probe costs one DMA per op, not two;
- ``claim_probe_pallas`` — the raw strongest-claimant prio16 (NO_PRIO when
  the cell is unclaimed this wave), for mechanisms that need the priority
  itself rather than a verdict (TicToc's extension rule, SwissTM, 2PL,
  Adaptive; DESIGN.md section 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import NO_PRIO, live_prio
from repro.kernels.wave_commit import (_row_dmas, _start, _wait,
                                       pick_lane_block)


def _table_prio(rows, ivw, gb, fine, G):
    """Strongest live claimant per block op from its fetched row."""
    pr = live_prio(rows, ivw)                            # (LBK, G)
    if fine:
        sel = jnp.arange(G, dtype=jnp.int32)[None, :] == gb[:, None]
        return jnp.where(sel, pr, jnp.uint32(NO_PRIO)).min(axis=1)
    return pr.min(axis=1)


def _kernel(fine, G, LB, K, keys_ref, ivw_ref, grp_b, prio_b, chk_b, tbl,
            out_b, rows_s, sem):
    LBK = LB * K
    t0 = pl.program_id(0) * LB
    _row_dmas(_start, keys_ref, tbl, rows_s, sem, t0, LB, K)
    _row_dmas(_wait, keys_ref, tbl, rows_s, sem, t0, LB, K)
    gb = grp_b[...].reshape(LBK)
    wprio = _table_prio(rows_s[...], ivw_ref[0], gb, fine, G)
    conf = chk_b[...].reshape(LBK) & (wprio < prio_b[...].reshape(LBK))
    out_b[...] = conf.reshape(LB, K)


def _val_specs(T, K, G, LB, n_scalar_ins, n_outs):
    """Shared lane-block grid spec: blocked per-op scalars, ANY table,
    blocked outputs, row scratch + DMA semaphores."""
    LBK = LB * K
    blk = pl.BlockSpec((LB, K), lambda i, keys, ivw: (i, 0))
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T // LB,),
        in_specs=[blk] * n_scalar_ins
        + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=blk if n_outs == 1 else (blk,) * n_outs,
        scratch_shapes=[pltpu.VMEM((LBK, G), jnp.uint32),
                        pltpu.SemaphoreType.DMA((LBK,))],
    )


def occ_validate_pallas(claim_w: jax.Array, keys: jax.Array,
                        groups: jax.Array, myprio: jax.Array,
                        check: jax.Array, inv_wave: jax.Array, fine: bool,
                        lane_block: int = 0,
                        interpret: bool = False) -> jax.Array:
    """conflict bool[T, K] — see ref.occ_validate for the oracle.  Masked
    ops (key < 0) clamp their DMA to row 0; ``check`` zeroes their result."""
    T, K = keys.shape
    G = claim_w.shape[1]
    LB = pick_lane_block(T, K, G, lane_block)
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    return pl.pallas_call(
        functools.partial(_kernel, fine, G, LB, K),
        grid_spec=_val_specs(T, K, G, LB, 3, 1),
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.bool_),
        interpret=interpret,
    )(keys, ivw, groups, myprio.astype(jnp.uint32), check, claim_w)


def _dual_kernel(G, LB, K, keys_ref, ivw_ref, grp_b, prio_b, chk_b, tbl,
                 fine_b, coarse_b, rows_s, sem):
    LBK = LB * K
    t0 = pl.program_id(0) * LB
    _row_dmas(_start, keys_ref, tbl, rows_s, sem, t0, LB, K)
    _row_dmas(_wait, keys_ref, tbl, rows_s, sem, t0, LB, K)
    pr = live_prio(rows_s[...], ivw_ref[0])              # (LBK, G)
    gb = grp_b[...].reshape(LBK)
    sel = jnp.arange(G, dtype=jnp.int32)[None, :] == gb[:, None]
    fprio = jnp.where(sel, pr, jnp.uint32(NO_PRIO)).min(axis=1)
    cprio = pr.min(axis=1)
    chk = chk_b[...].reshape(LBK)
    myp = prio_b[...].reshape(LBK)
    fine_b[...] = (chk & (fprio < myp)).reshape(LB, K)
    coarse_b[...] = (chk & (cprio < myp)).reshape(LB, K)


def occ_validate_dual_pallas(claim_w: jax.Array, keys: jax.Array,
                             groups: jax.Array, myprio: jax.Array,
                             check: jax.Array, inv_wave: jax.Array,
                             lane_block: int = 0, interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """(fine, coarse) conflict bool[T, K] from ONE row DMA per op — the
    AutoGran double probe without the double fetch."""
    T, K = keys.shape
    G = claim_w.shape[1]
    LB = pick_lane_block(T, K, G, lane_block)
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    return pl.pallas_call(
        functools.partial(_dual_kernel, G, LB, K),
        grid_spec=_val_specs(T, K, G, LB, 3, 2),
        out_shape=(jax.ShapeDtypeStruct((T, K), jnp.bool_),
                   jax.ShapeDtypeStruct((T, K), jnp.bool_)),
        interpret=interpret,
    )(keys, ivw, groups, myprio.astype(jnp.uint32), check, claim_w)


def _probe_kernel(fine, G, LB, K, keys_ref, ivw_ref, kv_b, grp_b, tbl,
                  out_b, rows_s, sem):
    LBK = LB * K
    t0 = pl.program_id(0) * LB
    _row_dmas(_start, keys_ref, tbl, rows_s, sem, t0, LB, K)
    _row_dmas(_wait, keys_ref, tbl, rows_s, sem, t0, LB, K)
    gb = grp_b[...].reshape(LBK)
    wprio = _table_prio(rows_s[...], ivw_ref[0], gb, fine, G)
    live = kv_b[...].reshape(LBK) >= 0
    out_b[...] = jnp.where(live, wprio,
                           jnp.uint32(NO_PRIO)).reshape(LB, K)


def claim_probe_pallas(table: jax.Array, keys: jax.Array, groups: jax.Array,
                       inv_wave: jax.Array, fine: bool, lane_block: int = 0,
                       interpret: bool = False) -> jax.Array:
    """Strongest live claimant prio16 per op (uint32[T, K]; NO_PRIO when the
    cell is unclaimed this wave or the op is masked) — see ref.claim_probe."""
    T, K = keys.shape
    G = table.shape[1]
    LB = pick_lane_block(T, K, G, lane_block)
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    return pl.pallas_call(
        functools.partial(_probe_kernel, fine, G, LB, K),
        grid_spec=_val_specs(T, K, G, LB, 2, 1),
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.uint32),
        interpret=interpret,
    )(keys, ivw, keys, groups, table)
