"""OCC read-set validation kernel.

The hot loop of optimistic commit: for every read op, fetch the claimed-writer
word of its (record, group) cell and compare priorities.  On the paper's CPU
platform this is a pointer chase per read; the TPU-native formulation is a
scalar-prefetch-driven DMA: op keys are prefetched into SMEM, each grid step
DMAs one version-table row HBM->VMEM (BlockSpec index_map reads the key), and
the VPU does the tag/priority compare.

Granularity is the compare width (DESIGN.md section 2): fine compares the
op's own group column, coarse reduces over the whole row (G is small — one
8/16-byte row per op — so the coarse reduce is free; the DMA is the cost, and
it is identical for both granularities, matching the paper's "fine-grained
timestamps have no measurable overhead").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import NO_PRIO, live_prio


def _kernel(fine: bool, G: int,
            keys_ref, ivw_ref, grp_ref, prio_ref, chk_ref, row_ref, out_ref):
    row = row_ref[0, :]                                   # uint32[G]
    pr = live_prio(row, ivw_ref[0])
    if fine:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32) == g
        wprio = jnp.where(sel, pr, NO_PRIO).min()
    else:
        wprio = pr.min()
    out_ref[0, 0] = chk_ref[0, 0] & (wprio < prio_ref[0, 0])


def occ_validate_pallas(claim_w: jax.Array, keys: jax.Array,
                        groups: jax.Array, myprio: jax.Array,
                        check: jax.Array, inv_wave: jax.Array, fine: bool,
                        interpret: bool = False) -> jax.Array:
    """conflict bool[T, K] — see ref.occ_validate for the oracle."""
    T, K = keys.shape
    G = claim_w.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave drive the index_maps
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # myprio
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # check
            # One version-table row per op, DMA'd by prefetched key.  Masked
            # ops (key < 0) clamp to row 0; `check` zeroes their result.
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.bool_),
        interpret=interpret,
    )(keys, ivw, groups, myprio.astype(jnp.uint32), check, claim_w)
