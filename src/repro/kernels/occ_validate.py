"""OCC read-set validation kernel.

The hot loop of optimistic commit: for every read op, fetch the claimed-writer
word of its (record, group) cell and compare priorities.  On the paper's CPU
platform this is a pointer chase per read; the TPU-native formulation is a
scalar-prefetch-driven DMA: op keys are prefetched into SMEM, each grid step
DMAs one version-table row HBM->VMEM (BlockSpec index_map reads the key), and
the VPU does the tag/priority compare.

Granularity is the compare width (DESIGN.md section 2): fine compares the
op's own group column, coarse reduces over the whole row (G is small — one
8/16-byte row per op — so the coarse reduce is free; the DMA is the cost, and
it is identical for both granularities, matching the paper's "fine-grained
timestamps have no measurable overhead").

Three kernels share the one row-DMA grid:

- ``occ_validate_pallas`` — conflict bool at one granularity (OCC's hot loop);
- ``occ_validate_dual_pallas`` — fine AND coarse verdicts from the same row
  fetch, so AutoGran's double probe costs one DMA per op, not two;
- ``claim_probe_pallas`` — the raw strongest-claimant prio16 (NO_PRIO when
  the cell is unclaimed this wave), for mechanisms that need the priority
  itself rather than a verdict (TicToc's extension rule, SwissTM, 2PL,
  Adaptive; DESIGN.md section 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import NO_PRIO, live_prio


def _kernel(fine: bool, G: int,
            keys_ref, ivw_ref, grp_ref, prio_ref, chk_ref, row_ref, out_ref):
    row = row_ref[0, :]                                   # uint32[G]
    pr = live_prio(row, ivw_ref[0])
    if fine:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32) == g
        wprio = jnp.where(sel, pr, NO_PRIO).min()
    else:
        wprio = pr.min()
    out_ref[0, 0] = chk_ref[0, 0] & (wprio < prio_ref[0, 0])


def occ_validate_pallas(claim_w: jax.Array, keys: jax.Array,
                        groups: jax.Array, myprio: jax.Array,
                        check: jax.Array, inv_wave: jax.Array, fine: bool,
                        interpret: bool = False) -> jax.Array:
    """conflict bool[T, K] — see ref.occ_validate for the oracle."""
    T, K = keys.shape
    G = claim_w.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave drive the index_maps
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # myprio
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # check
            # One version-table row per op, DMA'd by prefetched key.  Masked
            # ops (key < 0) clamp to row 0; `check` zeroes their result.
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.bool_),
        interpret=interpret,
    )(keys, ivw, groups, myprio.astype(jnp.uint32), check, claim_w)


def _dual_kernel(G: int, keys_ref, ivw_ref, grp_ref, prio_ref, chk_ref,
                 row_ref, fine_ref, coarse_ref):
    row = row_ref[0, :]                                   # uint32[G]
    pr = live_prio(row, ivw_ref[0])
    g = grp_ref[0, 0]
    sel = jnp.arange(G, dtype=jnp.int32) == g
    fprio = jnp.where(sel, pr, NO_PRIO).min()
    cprio = pr.min()
    chk = chk_ref[0, 0]
    myp = prio_ref[0, 0]
    fine_ref[0, 0] = chk & (fprio < myp)
    coarse_ref[0, 0] = chk & (cprio < myp)


def occ_validate_dual_pallas(claim_w: jax.Array, keys: jax.Array,
                             groups: jax.Array, myprio: jax.Array,
                             check: jax.Array, inv_wave: jax.Array,
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """(fine, coarse) conflict bool[T, K] from ONE row DMA per op — the
    AutoGran double probe without the double fetch."""
    T, K = keys.shape
    G = claim_w.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # myprio
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # check
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
        ),
    )
    return pl.pallas_call(
        functools.partial(_dual_kernel, G),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((T, K), jnp.bool_),
                   jax.ShapeDtypeStruct((T, K), jnp.bool_)),
        interpret=interpret,
    )(keys, ivw, groups, myprio.astype(jnp.uint32), check, claim_w)


def _probe_kernel(fine: bool, G: int, keys_ref, ivw_ref, grp_ref, row_ref,
                  out_ref):
    row = row_ref[0, :]                                   # uint32[G]
    pr = live_prio(row, ivw_ref[0])
    if fine:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32) == g
        wprio = jnp.where(sel, pr, NO_PRIO).min()
    else:
        wprio = pr.min()
    t, k = pl.program_id(0), pl.program_id(1)
    live = keys_ref[t, k] >= 0
    out_ref[0, 0] = jnp.where(live, wprio, jnp.uint32(NO_PRIO))


def claim_probe_pallas(table: jax.Array, keys: jax.Array, groups: jax.Array,
                       inv_wave: jax.Array, fine: bool,
                       interpret: bool = False) -> jax.Array:
    """Strongest live claimant prio16 per op (uint32[T, K]; NO_PRIO when the
    cell is unclaimed this wave or the op is masked) — see ref.claim_probe."""
    T, K = keys.shape
    G = table.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
    )
    return pl.pallas_call(
        functools.partial(_probe_kernel, fine, G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.uint32),
        interpret=interpret,
    )(keys, ivw, groups, table)
