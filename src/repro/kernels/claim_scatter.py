"""Fused claim-scatter kernel: pack + scatter-min claim words on-chip.

The jnp backend claims in two steps — pack `(inv_wave << 16) | prio16` words
(core/claimword.py), then an XLA scatter-min into the claim table
(claims.scatter_claims).  This kernel fuses both: the claim word is packed in
registers from the prefetched inv_wave and the op's prio16, and min-installed
into the aliased claim-table row the grid step just DMA'd.  The packed word
never exists in HBM, and the pallas backend stops silently falling back to
XLA for claims (ROADMAP open item; DESIGN.md section 5).

Why min: claim words are arranged so *lower = stronger* — the current wave's
tag is numerically below every stale wave's and in-wave priority breaks ties
— so min over duplicate cells picks the strongest claimant, the vectorized
replacement for the paper's CAS races (core/claims.py).  Min is commutative
and idempotent, so the sequential-grid visit order cannot be observed:
bit-identical to the XLA scatter-min.

Masked ops clamp their DMA to row 0 and install EMPTY_WORD (the identity of
min), leaving the row unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import EMPTY_WORD, PRIO16_MASK, WAVE_SHIFT


def _kernel(keys_ref, ivw_ref, grp_ref, prio_ref, do_ref, row_ref, out_ref):
    # Accumulate through the *output* ref (see occ_commit.py): sequential
    # grid steps revisiting a row must read back their predecessors' claims.
    del row_ref
    G = out_ref.shape[-1]
    word = ((ivw_ref[0] << WAVE_SHIFT)
            | (prio_ref[0, 0] & jnp.uint32(PRIO16_MASK)))
    g = grp_ref[0, 0]
    sel = (jnp.arange(G, dtype=jnp.int32) == g) & do_ref[0, 0]
    cand = jnp.where(sel, word, jnp.uint32(EMPTY_WORD))
    out_ref[0, :] = jnp.minimum(out_ref[0, :], cand)


def claim_scatter_pallas(table: jax.Array, keys: jax.Array,
                         groups: jax.Array, prio: jax.Array, do: jax.Array,
                         inv_wave: jax.Array,
                         interpret: bool = False) -> jax.Array:
    """table' with the wave's claim words min-installed — see
    ref.claim_scatter."""
    T, K = keys.shape
    G = table.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # prio
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # do
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=pl.BlockSpec(
            (1, G), lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0), 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={5: 0},  # table is operand 5 counting prefetch
        interpret=interpret,
    )(keys, ivw, groups, prio.astype(jnp.uint32), do & (keys >= 0), table)
