"""OCC commit kernel: install version bumps for committed write ops.

Aliased-output scatter: the version table is both input and output
(input_output_aliases), the grid walks the wave's write ops in serialization
order, and each step DMAs the op's row, adds a one-hot increment, and writes
it back.  The TPU grid is *sequential*, which is what makes read-modify-write
on revisited rows well-defined — the same property the engine's claim tables
get from XLA scatter combiners.

Hardware note: on real TPUs, revisiting an output block at non-consecutive
grid steps forces a writeback+refetch of that row between visits; correctness
relies on the alias (validated exhaustively in interpret mode against
ref.occ_commit, including duplicate-row cases).  Multiple bumps of the same
cell are semantically idempotent for OCC (any bump invalidates readers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(keys_ref, grp_ref, do_ref, row_ref, out_ref):
    # Accumulate through the *output* ref: the aliased out buffer holds the
    # original table, and sequential grid steps revisiting a row read back
    # their predecessors' writes.  (Reading the input ref instead would see
    # the pristine pre-kernel row and lose duplicate bumps.)
    del row_ref
    G = out_ref.shape[-1]
    g = grp_ref[0, 0]
    bump = ((jnp.arange(G, dtype=jnp.int32) == g)
            & do_ref[0, 0]).astype(jnp.uint32)
    out_ref[0, :] = out_ref[0, :] + bump


def occ_commit_pallas(wts: jax.Array, keys: jax.Array, groups: jax.Array,
                      do: jax.Array, interpret: bool = False) -> jax.Array:
    """wts' with +1 at each (key[t,k], group[t,k]) where do[t,k]."""
    T, K = keys.shape
    G = wts.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # groups
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # do
            pl.BlockSpec((1, G),
                         lambda t, k, keys: (jnp.maximum(keys[t, k], 0), 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, G), lambda t, k, keys: (jnp.maximum(keys[t, k], 0), 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(wts.shape, wts.dtype),
        input_output_aliases={3: 0},  # wts is operand 3 counting the prefetch
        interpret=interpret,
    )(keys, groups, do & (keys >= 0), wts)
