"""Same-cell segment-count kernel (TicToc's extension-pass contention).

TicToc's cost model needs, per op, how many ops of the SAME WAVE hit the
same (record, group) cell — the rts-extension CAS chain length and the
commit-ts install chain (cc/tictoc.py).  The jnp path counts segments with
an XLA sort + two searchsorted passes; this kernel closes that last XLA hop
on the pallas TicToc path (ROADMAP item) with a direct all-pairs compare:
the wave's op set is tiny ([T, K] int32s fit in VMEM whole), so each grid
step loads one lane's ops plus the full wave and the VPU reduces the
[T*K, K] equality matrix — no sort, no O(n_records) table, and the count is
an order-free sum, bit-identical to the sorted formulation.

Masked ops take a sentinel cell id and masked columns are zeroed, matching
ref.segment_count exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(G: int, keys_ref, grp_ref, msk_ref, mykeys_ref, mygrp_ref,
            mymsk_ref, out_ref):
    sent = jnp.int32(0x7FFFFFFF)
    all_cell = jnp.where(msk_ref[...], keys_ref[...] * G + grp_ref[...],
                         sent).reshape(-1)                # int32[T*K]
    my_cell = jnp.where(mymsk_ref[0, :], mykeys_ref[0, :] * G
                        + mygrp_ref[0, :], sent)          # int32[K]
    eq = (all_cell[:, None] == my_cell[None, :]) & msk_ref[...].reshape(-1)[
        :, None]                                          # [T*K, K]
    cnt = eq.sum(axis=0)
    out_ref[0, :] = jnp.where(mymsk_ref[0, :], cnt.astype(jnp.float32), 0.0)


def segment_count_pallas(keys: jax.Array, groups: jax.Array, G: int,
                         mask: jax.Array,
                         interpret: bool = False) -> jax.Array:
    """float32[T, K] same-cell op counts — see ref.segment_count."""
    T, K = keys.shape
    full = pl.BlockSpec((T, K), lambda t: (0, 0))
    mine = pl.BlockSpec((1, K), lambda t: (t, 0))
    return pl.pallas_call(
        functools.partial(_kernel, G),
        grid=(T,),
        in_specs=[full, full, full, mine, mine, mine],
        out_specs=pl.BlockSpec((1, K), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, K), jnp.float32),
        interpret=interpret,
    )(keys, groups, mask, keys, groups, mask)
