"""Blocked (flash) attention kernel: causal, GQA, optional sliding window.

Grid (B*Hq, Sq/bq, Sk/bk) with the key dimension innermost ("arbitrary"
semantics); running max/denominator live in VMEM scratch and the output block
is finalized on the last key step.  K/V BlockSpec index maps fold the GQA
head mapping (kv_head = q_head // (Hq/Hkv)) so grouped heads share K/V DMAs.
Block shapes are MXU-aligned (q/k blocks 128x128 by default, head_dim padded
to a lane multiple by the wrapper in ops.py).

Sliding-window support masks per-element and skips key blocks that fall
entirely outside [q - window + 1, q] — with window << Sk (mixtral-style SWA)
the skipped blocks make long-context prefill linear in Sk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(causal: bool, window: int | None, scale: float, sk_valid: int,
            delta: int, bq: int, bk: int, nk: int,
            q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + delta
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Whole-block skip tests (static grid; dynamic predicate).
    oob = jnp.bool_(False)
    if causal:
        oob |= ki * bk > qi * bq + (bq - 1) + delta          # strictly above
    if window is not None:
        oob |= (ki + 1) * bk - 1 <= qi * bq + delta - window  # all expired

    @pl.when(~oob)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # [bq, D]
        k = k_ref[0].astype(jnp.float32)                     # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < sk_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_s[:, 0] * alpha + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)                     # [bk, D]
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new
        l_s[:, 0] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_s[:, 0], 1e-30)
        o_ref[0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, sq_valid: int,
                           sk_valid: int, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, D] (Sq, Sk already padded to block multiples);
    k, v: [B, Hkv, Sk, D].  sq_valid/sk_valid = unpadded lengths; query row i
    (i < sq_valid) sits at absolute position i + (sk_valid - sq_valid),
    end-aligned with the keys (prefill and decode conventions agree)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    delta = sk_valid - sq_valid  # end-aligned absolute positions
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)

    def kv_index(bh, qi, ki, *_):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // rep, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, causal, window, scale, sk_valid, delta,
                          bq, bk, nk),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
