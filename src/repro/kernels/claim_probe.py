"""Fused claim-install + probe kernel (one pass over the claim table).

The probe-family mechanisms (TicToc/2PL/SwissTM/Adaptive, the OCC read
validation, and the distributed owner step) all ran the same two-kernel
sequence on the hottest table each wave: ``claim_scatter`` (RMW every write
op's claim row) followed by ``probe`` (DMA every op's claim row again).
This kernel does both in ONE sequential grid pass — half the kernel
launches and half the claim-table HBM row round-trips.

Like ``mv_install`` it is dual-purpose per grid step: the claim table is
aliased input/output, each step DMAs its op's row once, min-installs the
packed claim word (write ops), and answers the op's strongest-claimant
probe.  The subtlety is that the probe must see claims installed by *later*
grid steps too (the jnp semantics probe the fully-installed table).  The
sequential grid only shows a step its predecessors' installs — so the
kernel completes the picture from VMEM: the whole wave's (key, group, prio,
mask) vectors ride along as full blocks (they are tiny, segment_count
style), and an all-pairs same-cell min over them yields the strongest
*same-wave* claimant of the op's cell.  min(row probe, wave min) then
equals the post-install probe, because under the claim-word monotonicity
precondition (no table word tagged newer than this wave — see
ref.claim_probe_fused) every claim that could change the row's probe this
wave is in the VMEM wave vectors.  Min is commutative and idempotent, so
grid order is unobservable: bit-identical to the two-phase jnp path.

Granularity is the probe width as everywhere (DESIGN.md section 2): fine
matches the op's (record, group) cell, coarse matches any group of the
record — on both the row probe and the all-pairs wave term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import (EMPTY_WORD, NO_PRIO, PRIO16_MASK,
                                  WAVE_SHIFT, live_prio)

_SENT = 0x7FFFFFFF  # cell id of masked ops in the all-pairs compare


def _kernel(fine: bool, G: int, keys_ref, ivw_ref, grp_ref, prio_ref,
            do_ref, allk_ref, allg_ref, allp_ref, alldo_ref, row_ref,
            tbl_ref, out_ref):
    # Accumulate through the aliased *output* ref (see occ_commit.py).
    del row_ref
    ivw = ivw_ref[0]
    t, k = pl.program_id(0), pl.program_id(1)
    key = keys_ref[t, k]
    g = grp_ref[0, 0]
    row = tbl_ref[0, :]                               # uint32[G]
    pr = live_prio(row, ivw)

    # Same-wave claimants of my cell, from the in-VMEM wave vectors.
    allp = (allp_ref[...] & jnp.uint32(PRIO16_MASK)).reshape(-1)
    if fine:
        table_prio = jnp.where(jnp.arange(G, dtype=jnp.int32) == g, pr,
                               NO_PRIO).min()
        all_cell = jnp.where(alldo_ref[...],
                             allk_ref[...] * G + allg_ref[...],
                             jnp.int32(_SENT)).reshape(-1)
        hit = all_cell == key * G + g
    else:
        table_prio = pr.min()
        all_key = jnp.where(alldo_ref[...], allk_ref[...],
                            jnp.int32(_SENT)).reshape(-1)
        hit = all_key == key
    wave_prio = jnp.where(hit, allp, jnp.uint32(NO_PRIO)).min()
    wprio = jnp.minimum(table_prio, wave_prio)
    out_ref[0, 0] = jnp.where(key >= 0, wprio, jnp.uint32(NO_PRIO))

    # Install this op's claim word (packed in registers, claim_scatter.py).
    word = ((ivw << WAVE_SHIFT)
            | (prio_ref[0, 0] & jnp.uint32(PRIO16_MASK)))
    sel = (jnp.arange(G, dtype=jnp.int32) == g) & do_ref[0, 0]
    tbl_ref[0, :] = jnp.minimum(row, jnp.where(sel, word,
                                               jnp.uint32(EMPTY_WORD)))


def claim_probe_fused_pallas(table: jax.Array, keys: jax.Array,
                             groups: jax.Array, prio: jax.Array,
                             do: jax.Array, inv_wave: jax.Array, fine: bool,
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """(table', wprio uint32[T, K]) — see ref.claim_probe_fused."""
    T, K = keys.shape
    G = table.shape[1]
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    do = do & (keys >= 0)
    p16 = prio.astype(jnp.uint32)
    full = pl.BlockSpec((T, K), lambda t, k, keys, ivw: (0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # prio
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),   # do
            full,                                                   # wave keys
            full,                                                   # wave grps
            full,                                                   # wave prio
            full,                                                   # wave mask
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
        ],
        out_specs=(
            pl.BlockSpec((1, G),
                         lambda t, k, keys, ivw: (jnp.maximum(keys[t, k], 0),
                                                  0)),
            pl.BlockSpec((1, 1), lambda t, k, keys, ivw: (t, k)),
        ),
    )
    return pl.pallas_call(
        functools.partial(_kernel, fine, G),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct((T, K), jnp.uint32)),
        input_output_aliases={9: 0},  # table is operand 9 counting prefetch
        interpret=interpret,
    )(keys, ivw, groups, p16, do, keys, groups, p16, do, table)
