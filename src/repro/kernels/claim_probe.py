"""Fused claim-install + probe kernel (one pass over the claim table).

The probe-family mechanisms (TicToc/2PL/SwissTM/Adaptive, the OCC read
validation, and the distributed owner step) all ran the same two-kernel
sequence on the hottest table each wave: ``claim_scatter`` (RMW every write
op's claim row) followed by ``probe`` (DMA every op's claim row again).
This kernel does both in ONE sequential grid pass — half the kernel
launches and half the claim-table HBM row round-trips.  (The probe family
itself now rides the full ``wave_commit`` megakernel; this op remains the
fused install+probe primitive for callers that need the raw priorities —
the distributed owner step on the unfused path, `fuse_wave=False`.)

The grid is LANE BLOCKS (kernels/wave_commit.py): ``(T // LB,)`` with an
LB-lane x K-slot block per step.  The claim table sits in ANY memory space
and rows move by explicit ``make_async_copy`` DMAs into VMEM scratch — the
whole block's row stream in flight at once — then the probe and install
math runs vectorized over the block.  The probe must see claims installed
by *later* grid steps too (the jnp semantics probe the fully-installed
table), so the kernel completes the picture from VMEM: the whole wave's
(key, group, prio, mask) vectors ride along as full blocks, and an
all-pairs same-cell min over them yields the strongest *same-wave*
claimant of each op's cell.  min(row probe, wave min) then equals the
post-install probe, because under the claim-word monotonicity precondition
(no table word tagged newer than this wave — see ref.claim_probe_fused)
every claim that could change the row's probe this wave is in the VMEM
wave vectors.  The same wave min makes the block's writebacks FINAL rows
(min(fetched row, strongest same-wave word per cell)) — idempotent, so
same-row ops within a block write identical bytes and writeback order is
unobservable; bit-identical to the two-phase jnp path.

Granularity is the probe width as everywhere (DESIGN.md section 2): fine
matches the op's (record, group) cell, coarse matches any group of the
record — on both the row probe and the all-pairs wave term.  Installs are
always fine (claims scatter to the op's own cell).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import PRIO16_MASK
from repro.kernels.wave_commit import (_install_rows, _probe, _row_dmas,
                                       _start, _wait, pick_lane_block)


def _claim_probe_fused_kernel(fine, G, LB, K, keys_ref, ivw_ref, kv, grp,
                              prio, do, tbl_in, tbl_out, out_b, rows_s,
                              new_s, sem_r, sem_w):
    # RMW through the aliased *output* ref (see occ_commit.py).
    del tbl_in
    LBK = LB * K
    ivw = ivw_ref[0]
    t0 = pl.program_id(0) * LB

    _row_dmas(_start, keys_ref, tbl_out, rows_s, sem_r, t0, LB, K)
    _row_dmas(_wait, keys_ref, tbl_out, rows_s, sem_r, t0, LB, K)

    kraw = jax.lax.dynamic_slice(kv[...], (t0, 0), (LB, K)).reshape(LBK)
    kcl = jnp.maximum(kraw, 0)
    gb = jax.lax.dynamic_slice(grp[...], (t0, 0), (LB, K)).reshape(LBK)
    allk = kv[...].reshape(-1)
    allg = grp[...].reshape(-1)
    allp16 = (prio[...] & jnp.uint32(PRIO16_MASK)).reshape(-1)
    alldo = do[...].reshape(-1)

    rows = rows_s[...]
    wprio = _probe(rows, ivw, kcl, kraw, gb, allk, allg, allp16, alldo,
                   fine, G)
    out_b[...] = wprio.reshape(LB, K)

    new_s[...] = _install_rows(rows, ivw, kcl, allk, allg, allp16, alldo, G)
    _row_dmas(_start, keys_ref, tbl_out, new_s, sem_w, t0, LB, K,
              to_table=True)
    _row_dmas(_wait, keys_ref, tbl_out, new_s, sem_w, t0, LB, K,
              to_table=True)


def claim_probe_fused_pallas(table: jax.Array, keys: jax.Array,
                             groups: jax.Array, prio: jax.Array,
                             do: jax.Array, inv_wave: jax.Array, fine: bool,
                             lane_block: int = 0, interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """(table', wprio uint32[T, K]) — see ref.claim_probe_fused."""
    T, K = keys.shape
    G = table.shape[1]
    LB = pick_lane_block(T, K, G, lane_block)
    LBK = LB * K
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    do = do & (keys >= 0)
    p16 = prio.astype(jnp.uint32)
    full = pl.BlockSpec((T, K), lambda i, keys, ivw: (0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, inv_wave
        grid=(T // LB,),
        in_specs=[full, full, full, full, any_spec],
        out_specs=(
            any_spec,
            pl.BlockSpec((LB, K), lambda i, keys, ivw: (i, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((LBK, G), jnp.uint32),
            pltpu.VMEM((LBK, G), jnp.uint32),
            pltpu.SemaphoreType.DMA((LBK,)),
            pltpu.SemaphoreType.DMA((LBK,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_claim_probe_fused_kernel, fine, G, LB, K),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct((T, K), jnp.uint32)),
        input_output_aliases={6: 0},  # table is operand 6 counting prefetch
        interpret=interpret,
    )(keys, ivw, keys, groups, p16, do, table)
