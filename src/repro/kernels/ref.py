"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.claimword import (EMPTY_WORD, NO_PRIO, WAVE_SHIFT,
                                  claim_word, inv_wave, live_prio)
from repro.core.mvstore import MV_EMPTY
from repro.core.types import OOB_KEY  # negative indices wrap, OOB drops


# -------------------------------------------------- precondition validation
# claim_probe_fused and mv_install only answer from ONE row pass because the
# engine maintains monotone tags: claim cells hold waves <= the current one,
# begin cells hold timestamps < the install ts.  A caller that violates this
# gets silently wrong answers — so the documented preconditions are checked
# here whenever the check is free: on *eager* (concrete, non-traced) calls,
# i.e. the kernel-oracle tests and interactive/interpret use.  Inside jit
# (every engine wave) the inputs are tracers and the check compiles to
# nothing.  Disable with REPRO_PRECONDITION_CHECKS=0 (resolved per call).
def _checks_enabled(*arrays) -> bool:
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return False
    return os.environ.get("REPRO_PRECONDITION_CHECKS", "1") != "0"


def check_claim_tag_monotone(table, keys, wave) -> None:
    """Raise if any cell the wave touches carries a wave tag NEWER than
    ``wave`` — the monotone-wave-tag precondition of claim_probe_fused
    (claim tables are claimed once per wave; tags only age)."""
    if not _checks_enabled(table, keys, wave):
        return
    k = np.where(np.asarray(keys) >= 0, np.asarray(keys), 0).reshape(-1)
    rows = np.asarray(table)[np.minimum(k, table.shape[0] - 1)]
    tags = rows >> WAVE_SHIFT     # inv_wave: smaller = newer
    bad = (tags < int(inv_wave(jnp.asarray(wave)))) \
        & (np.asarray(keys).reshape(-1) >= 0)[:, None]
    if bad.any():
        raise ValueError(
            f"claim_probe precondition violated: {int(bad.sum())} touched "
            "claim cell(s) carry a wave tag newer than the current wave "
            f"({int(np.asarray(wave))}) — claim tables must only hold "
            "claims from waves <= the current one (core/claimword.py "
            "monotone tags); the fused one-pass probe would silently "
            "return wrong answers.  Set REPRO_PRECONDITION_CHECKS=0 to "
            "bypass.")


def check_mv_begin_monotone(begin, keys, do, ts) -> None:
    """Raise if any installed-into ring row already holds a begin >= ``ts``
    — the monotone install-timestamp precondition of mv_install (same-wave
    revisit detection reads begin == ts as 'claimed this wave')."""
    if not _checks_enabled(begin, keys, do, ts):
        return
    m = (np.asarray(do) & (np.asarray(keys) >= 0)).reshape(-1)
    if not m.any():
        return
    k = np.where(m, np.asarray(keys).reshape(-1), 0)
    rows = np.asarray(begin)[np.minimum(k, begin.shape[0] - 1)]
    bad = (rows != MV_EMPTY) & (rows >= int(np.asarray(ts))) & m[:, None,
                                                                 None]
    if bad.any():
        raise ValueError(
            f"mv_install precondition violated: {int(bad.sum())} begin "
            f"cell(s) in installed-into rows already hold >= ts="
            f"{int(np.asarray(ts))} — install timestamps must advance "
            "strictly per wave (core/mvstore.install_ts), else the kernel's "
            "same-wave revisit detection silently merges distinct waves.  "
            "Set REPRO_PRECONDITION_CHECKS=0 to bypass.")


# ---------------------------------------------------------------- OCC kernels
def occ_validate(claim_w: jax.Array, keys: jax.Array, groups: jax.Array,
                 myprio: jax.Array, check: jax.Array,
                 inv_wave: jax.Array, fine: bool) -> jax.Array:
    """Conflict flags for read-set validation (see core/claims.py probe)."""
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    rows = claim_w.at[k, :].get(mode="fill", fill_value=EMPTY_WORD)
    pr = live_prio(rows, inv_wave)
    if fine:
        g1 = jnp.take_along_axis(pr, groups[..., None], axis=-1)[..., 0]
        wprio = g1
    else:
        wprio = pr.min(axis=-1)
    return check & (wprio < myprio)


def occ_validate_dual(claim_w: jax.Array, keys: jax.Array, groups: jax.Array,
                      myprio: jax.Array, check: jax.Array,
                      inv_wave: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(fine, coarse) conflict flags from one logical row fetch."""
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    rows = claim_w.at[k, :].get(mode="fill", fill_value=EMPTY_WORD)
    pr = live_prio(rows, inv_wave)
    fprio = jnp.take_along_axis(pr, groups[..., None], axis=-1)[..., 0]
    cprio = pr.min(axis=-1)
    return check & (fprio < myprio), check & (cprio < myprio)


def claim_probe(table: jax.Array, keys: jax.Array, groups: jax.Array,
                inv_wave: jax.Array, fine: bool) -> jax.Array:
    """Strongest live claimant prio16 per op; NO_PRIO when unclaimed/masked."""
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    rows = table.at[k, :].get(mode="fill", fill_value=EMPTY_WORD)
    pr = live_prio(rows, inv_wave)
    if fine:
        wprio = jnp.take_along_axis(pr, groups[..., None], axis=-1)[..., 0]
    else:
        wprio = pr.min(axis=-1)
    return jnp.where(keys >= 0, wprio, jnp.uint32(NO_PRIO))


def occ_commit(wts: jax.Array, keys: jax.Array, groups: jax.Array,
               do: jax.Array) -> jax.Array:
    """Bump version of each (key, group) once per committed write op."""
    k = jnp.where(do & (keys >= 0), keys, OOB_KEY)
    return wts.at[k.reshape(-1), groups.reshape(-1)].add(jnp.uint32(1),
                                                         mode="drop")


def ts_gather(table: jax.Array, keys: jax.Array, groups: jax.Array,
              fine: bool) -> jax.Array:
    """Per-op timestamp observation: own cell (fine) or row max (coarse —
    one timestamp per record); 0 for masked ops."""
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    if fine:
        return table.at[k, groups].get(mode="fill", fill_value=0)
    rows = table.at[k, :].get(mode="fill", fill_value=0)
    return rows.max(axis=-1)


def ts_install_max(table: jax.Array, keys: jax.Array, groups: jax.Array,
                   vals: jax.Array, do: jax.Array,
                   whole_row: bool = False) -> jax.Array:
    """Monotone scatter-max of vals into table[key, group] per masked op;
    whole_row installs across every group of the record."""
    k = jnp.where(do & (keys >= 0), keys, OOB_KEY).reshape(-1)
    v = vals.astype(jnp.uint32).reshape(-1)
    if whole_row:
        for g in range(table.shape[1]):
            table = table.at[k, g].max(v, mode="drop")
        return table
    return table.at[k, groups.reshape(-1)].max(v, mode="drop")


def claim_scatter(table: jax.Array, keys: jax.Array, groups: jax.Array,
                  prio: jax.Array, do: jax.Array,
                  wave: jax.Array) -> jax.Array:
    """Pack claim words and scatter-min them into table[key, group]."""
    words = claim_word(wave, prio)
    k = jnp.where(do & (keys >= 0), keys, OOB_KEY)
    return table.at[k.reshape(-1), groups.reshape(-1)].min(
        words.reshape(-1), mode="drop")


def claim_probe_fused(table: jax.Array, keys: jax.Array, groups: jax.Array,
                      prio: jax.Array, do: jax.Array, wave: jax.Array,
                      fine: bool) -> tuple[jax.Array, jax.Array]:
    """Fused claim install + probe (the backend's ``claim_probe`` op).

    Scatter-min the wave's packed claim words for the masked (write) ops,
    then return the *post-install* strongest-claimant prio16 for EVERY op —
    one op where the two-phase path ran ``claim_scatter`` followed by
    ``claim_probe``.  Returns ``(table', wprio uint32[T, K])``.

    Precondition (the engine invariant the Pallas kernel relies on): no
    pre-existing table word carries a wave tag *newer* than ``wave`` —
    cells hold claims from waves <= the current one (the monotone wave tag
    of core/claimword.py; claim tables are claimed once per wave).  Under
    it the probe of the final table equals min(probe of the pre-wave
    table, strongest same-wave claimant of the cell), which is what lets
    the kernel answer both from ONE row DMA per op.  Violations are caught
    on eager calls by ``check_claim_tag_monotone``.
    """
    check_claim_tag_monotone(table, keys, wave)
    table = claim_scatter(table, keys, groups, prio, do, wave)
    return table, claim_probe(table, keys, groups, inv_wave(wave), fine)


def wave_commit(claim_w: jax.Array, claim_r, wts, keys: jax.Array,
                groups: jax.Array, prio: jax.Array, do_w: jax.Array, do_r,
                check_w: jax.Array, check_w2, check_r, extra,
                wave: jax.Array, fine: bool, dual: bool, bump: bool):
    """Op fifteen: the fused probe-family wave — claim install + probe +
    lane verdicts + version bumps in one logical pass.

    Composes the existing primitives, so the fused engine path is
    bit-identical to the unfused one *by construction*:

      1. ``claim_probe_fused`` on the writer table (install ``do_w`` ops'
         claim words, probe every op) -> ``wprio``;
      2. ``dual``: the same on the reader table with ``do_r`` -> ``rprio``
         (2PL / Adaptive visible reads);
      3. per-op conflicts from the caller's pre-thinned check masks:
         ``check_w``    ->  stronger writer claim     (wprio  < prio)
         ``check_w2``   ->  ANY other writer claim    (wprio != NO_PRIO
                            and wprio != prio; TicToc's extension channel)
         ``check_r``    ->  stronger reader claim     (rprio  < prio)
         ``extra``      ->  caller-computed conflicts OR'd in verbatim;
      4. lane verdict ``commit = ~conflict.any(axis=1)``;
      5. ``bump``: +1 version per committed ``do_w`` op (``occ_commit``).

    ``check_w2``/``check_r``/``extra`` may be None (skipped); ``claim_r``/
    ``do_r`` are required iff ``dual``, ``wts`` iff ``bump``.  Returns
    ``(claim_w', claim_r', wts', conflict bool[T, K], commit bool[T])``
    with None passed through for unused tables.

    Precondition: the monotone wave tag of ``claim_probe_fused`` on every
    claim table touched (checked eagerly by ``check_claim_tag_monotone``;
    ``REPRO_PRECONDITION_CHECKS=0`` opts out).
    """
    claim_w, wprio = claim_probe_fused(claim_w, keys, groups, prio, do_w,
                                       wave, fine)
    conflict = check_w & (wprio < prio)
    if check_w2 is not None:
        conflict |= (check_w2 & (wprio != jnp.uint32(NO_PRIO))
                     & (wprio != prio))
    if dual:
        claim_r, rprio = claim_probe_fused(claim_r, keys, groups, prio,
                                           do_r, wave, fine)
        conflict |= check_r & (rprio < prio)
    if extra is not None:
        conflict |= extra
    commit = ~conflict.any(axis=1)
    if bump:
        wts = occ_commit(wts, keys, groups, do_w & commit[:, None])
    return claim_w, claim_r, wts, conflict, commit


def scan_span(ext_cap: int, fine: bool, bucket_size: int) -> int:
    """STATIC per-op row bound of iterate_validate: ext_cap rows for the
    fine (exact-interval) layout; for coarse the bucket expansion of a
    worst-aligned interval — a 1-row first bucket plus ceil((ext_cap-1)/B)
    further buckets of B rows each."""
    if fine or ext_cap <= 1:
        return ext_cap
    return (1 + -(-(ext_cap - 1) // bucket_size)) * bucket_size


def iterate_validate(table: jax.Array, keys: jax.Array, extents: jax.Array,
                     groups: jax.Array, myprio: jax.Array, check: jax.Array,
                     inv_wave: jax.Array, fine: bool, bucket_size: int,
                     ext_cap: int) -> jax.Array:
    """Op sixteen: interval (scan) validation against a claim table.

    Each masked op covers the record interval ``[key, key + extent)``
    (``TxnBatch.op_extent``; extent 1 = a point op) and conflicts when ANY
    record of its validated interval carries a live same-wave claim
    stronger than ``myprio`` — the phantom check of Hekaton-style iterator
    validation, run against the POST-install claim table so it sees
    exactly this wave's writers (monotone wave tags hide earlier waves,
    whose installs the scan's wave-start snapshot already observed).

    Granularity is the interval-claim layout (DESIGN.md section 13):

    - ``fine``: per-gap timestamps — every row of ``[key, key+extent)`` is
      probed at the op's own group column, so only a writer of the scanned
      column group inside the exact interval aborts the scan;
    - coarse: bucket-interval claims, one claim word per ``bucket_size``
      consecutive records — the scan validates the bucket-EXPANDED
      interval ``[floor(key/B)*B, ceil((key+extent)/B)*B)`` with the
      whole-row (any-group) compare; a bucket's claim word is the min over
      its member rows' words, so writers anywhere in a touched bucket
      abort the scan (false phantoms at the bucket edges — the
      granularity trade-off, now for intervals).

    ``ext_cap`` is the STATIC bound on any extent (EngineConfig.max_extent)
    — the row loop unrolls to it, so the op costs nothing at ext_cap == 1
    call sites (the engine compiles the pass out entirely there).  Rows
    past the table edge read EMPTY_WORD (no conflict); masked ops
    (``check`` False or key < 0) never conflict.  Returns bool[T, K].
    """
    ext = jnp.maximum(extents, 1)
    if fine:
        start = keys
        width = ext
    else:
        B = bucket_size
        start = (keys // B) * B
        width = ((keys + ext + B - 1) // B) * B - start
    span = scan_span(ext_cap, fine, bucket_size)
    conflict = jnp.zeros(keys.shape, jnp.bool_)
    for j in range(span):
        row = start + j
        active = check & (keys >= 0) & (j < width)
        k = jnp.where(active, row, OOB_KEY)
        rows = table.at[k, :].get(mode="fill", fill_value=EMPTY_WORD)
        pr = live_prio(rows, inv_wave)
        if fine:
            wprio = jnp.take_along_axis(pr, groups[..., None],
                                        axis=-1)[..., 0]
        else:
            wprio = pr.min(axis=-1)
        conflict |= active & (wprio < myprio)
    return conflict


def route_pack(owner: jax.Array, vals: jax.Array, n_dest: int, cap: int,
               fills) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-free routing pack: per-destination fixed-capacity buffers.

    ``owner`` int32[M] gives each op's destination (out-of-range = masked,
    never packed); ``vals`` int32[W, M] carries W payload channels and
    ``fills`` their W empty-cell fill values (static Python ints).  Ops are
    placed in flat-op order by a counting/offset scan — op i lands at
    ``buf[:, owner[i], pos[i]]`` where ``pos[i]`` counts earlier ops bound
    for the same destination (exactly the placement a stable argsort by
    owner would produce, without the sort).  Ops whose rank reaches ``cap``
    are capacity-dropped.

    Returns ``(buf int32[W, n_dest, cap], pos int32[M], took bool[M])``:
    ``took`` is False for masked and capacity-dropped ops; ``pos`` stays
    the in-destination rank even when dropped (0 for masked ops) so
    verdict buffers can be *gathered* back per op — no return scatter.
    """
    W, M = vals.shape
    d = jnp.arange(n_dest, dtype=jnp.int32)[:, None]
    match = owner[None, :] == d                        # [n_dest, M]
    prefix = jnp.cumsum(match, axis=1) - match         # rank within dest
    pos = jnp.where(match, prefix, 0).sum(axis=0).astype(jnp.int32)
    took = (match & (prefix < cap)).any(axis=0)
    # Materialize via a unique-slot scatter (at most one op per cell, so it
    # is order-free); dropped/masked ops land in the trimmed overflow cell.
    slot = jnp.where(took, owner * cap + pos, n_dest * cap)
    bufs = [jnp.full((n_dest * cap + 1,), fills[w], jnp.int32)
            .at[slot].set(vals[w], mode="drop")[:-1].reshape(n_dest, cap)
            for w in range(W)]
    return jnp.stack(bufs), pos, took


def verdict_pack(v: jax.Array) -> jax.Array:
    """Bit-pack per-op verdict bytes for the wire (the distributed wave's
    verdict/commit return channels).

    ``v`` int8[..., M] carries 2 meaningful bits per op (bit 0 =
    unconditional conflict, bit 1 = read-validation — the wire layout of
    core/distributed.py); the packed form interleaves them 16 ops per
    int32 word: op j's fields land at bits ``2*(j % 16)`` and
    ``2*(j % 16) + 1`` of word ``j // 16``.  Returns
    int32[..., ceil(M/16)] — a 4x byte reduction vs one int8 per op when
    M is a multiple of 16 (exchange caps are 8-aligned; benchmark caps are
    16-aligned).  Inverse: ``verdict_unpack``.
    """
    M = v.shape[-1]
    W = -(-M // 16)
    vv = v.astype(jnp.uint32) & 3
    pad = W * 16 - M
    if pad:
        vv = jnp.pad(vv, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    vv = vv.reshape(v.shape[:-1] + (W, 16))
    shifts = jnp.uint32(2) * jnp.arange(16, dtype=jnp.uint32)
    # Fields are disjoint, so the sum is a bitwise OR of the shifted lanes.
    return (vv << shifts).sum(axis=-1, dtype=jnp.uint32).astype(jnp.int32)


def verdict_unpack(words: jax.Array, n: int) -> jax.Array:
    """Inverse of ``verdict_pack``: int32[..., ceil(n/16)] wire words ->
    int8[..., n] verdict bytes (low 2 bits populated, upper bits zero)."""
    w = words.astype(jnp.uint32)
    j = jnp.arange(n)
    shift = jnp.uint32(2) * (j % 16).astype(jnp.uint32)
    return ((w[..., j // 16] >> shift) & 3).astype(jnp.int8)


def segment_count(keys: jax.Array, groups: jax.Array, G: int,
                  mask: jax.Array) -> jax.Array:
    """#masked ops in the wave hitting the same (record, group) cell, per op
    (0 where masked) — the same-cell contention counts of TicToc's extension
    pass.  Delegates to the engine's sort-based counter so exactly one
    implementation defines the semantics both backends must match."""
    from repro.core.claims import cell_counts
    return cell_counts(keys, groups, G, mask)


# ------------------------------------------------------- multi-version store
def mv_gather(begin: jax.Array, keys: jax.Array, groups: jax.Array,
              ts: jax.Array, fine: bool) -> tuple[jax.Array, jax.Array]:
    """Snapshot version select on the MV ring (core/mvstore.py layout).

    begin: uint32[N, D, G] per-slot per-group begin timestamps.  Returns
    (slot int32, ok bool) per op: the newest ring slot visible at snapshot
    ``ts`` — fine visibility checks the op's own group's begin, coarse
    treats the record as one unit (max over groups, one timestamp per
    record).  ``ok`` is False when every retained slot postdates the
    snapshot (version reclaimed — the reader must abort, never read
    garbage) or the op is masked.
    """
    D, G = begin.shape[1], begin.shape[2]
    k = jnp.where(keys >= 0, keys, OOB_KEY)
    rows = begin.at[k, :, :].get(mode="fill",
                                 fill_value=MV_EMPTY)     # [T, K, D, G]
    if fine:
        sel = jnp.arange(G, dtype=jnp.int32) == groups[..., None, None]
        eff = jnp.where(sel, rows, jnp.uint32(0)).max(axis=-1)
    else:
        eff = rows.max(axis=-1)                           # [T, K, D]
    # score = eff + 1 where visible, 0 where not: empty slots (MV_EMPTY) and
    # future versions drop out, argmax-by-min-index picks the newest.
    score = jnp.where(eff <= ts.astype(jnp.uint32), eff + jnp.uint32(1),
                      jnp.uint32(0))
    best = score.max(axis=-1)
    slot = jnp.where(score == best[..., None],
                     jnp.arange(D, dtype=jnp.int32), D).min(axis=-1)
    return slot.astype(jnp.int32), best > 0


def mv_install(begin: jax.Array, head: jax.Array, keys: jax.Array,
               groups: jax.Array, do: jax.Array, ts: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Ring-slot claim + version publish on the MV ring.

    Per record with >= 1 masked op: advance head to the next ring slot
    (reclaiming its previous occupant), copy the old newest slot's begin row
    into it (carry-forward of unwritten groups), then publish ``begin[g] =
    ts`` for every masked op's group.  At most one slot is claimed per
    record per wave — concurrent committers of different groups merge.

    Precondition (the engine invariant both backends rely on): every
    pre-existing begin value is < ``ts`` — install timestamps advance
    per wave (core/mvstore.install_ts), which is what lets the Pallas
    kernel detect same-wave revisits from the row alone.  Violations are
    caught on eager calls by ``check_mv_begin_monotone``.
    """
    check_mv_begin_monotone(begin, keys, do, ts)
    D = begin.shape[1]
    k = jnp.where(do & (keys >= 0), keys, OOB_KEY).reshape(-1)
    g = groups.reshape(-1)
    h_old = head.at[k].get(mode="fill", fill_value=0)
    h_new = (h_old + 1) % D
    # Carry-forward copy: duplicates write the same source row (head moves
    # once per record per wave), so the unordered scatter is deterministic.
    old_rows = begin.at[k, h_old, :].get(mode="fill", fill_value=0)
    begin = begin.at[k, h_new, :].set(old_rows, mode="drop")
    # Publish: every masked op stamps ts into its group of the new slot
    # (duplicates write the identical value).
    begin = begin.at[k, h_new, g].set(ts.astype(jnp.uint32), mode="drop")
    head = head.at[k].set(h_new, mode="drop")
    return begin, head


# ------------------------------------------------------------ flash attention
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None) -> jax.Array:
    """Reference attention.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] with Hq % Hkv == 0 (GQA).
    window: sliding-window size (keys within [i - window + 1, i]).
    For decode (Sq=1 with a cache of Sk), pass causal=False and window=None
    (the cache is already the visible set).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    Sk = k.shape[2]
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (prefill/decode)
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


# ----------------------------------------------------------------- RG-LRU
def rglru(log_a: jax.Array, x: jax.Array,
          h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """RG-LRU linear recurrence (Griffin/recurrentgemma):

        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t,   a_t = exp(log_a_t)

    log_a, x: [B, S, D] (log_a <= 0).  Returns (h [B,S,D], h_last [B,D]).
    """
    a = jnp.exp(log_a.astype(jnp.float32))
    gx = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, None)) * x.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros(x.shape[:1] + x.shape[2:], jnp.float32)

    def step(h, inp):
        at, gxt = inp
        h = at * h + gxt
        return h, h

    aT = jnp.moveaxis(a, 1, 0)
    gT = jnp.moveaxis(gx, 1, 0)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (aT, gT))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), h_last


# ----------------------------------------------------------------- RWKV-6
def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, s0: jax.Array | None = None
          ) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 ("Finch") wkv recurrence with data-dependent decay.

    r,k,w: [B, H, S, Dk]; v: [B, H, S, Dv]; u: [H, Dk] (bonus).
    State S_t [Dk, Dv]:  out_t = (S_{t-1} + (u*k_t) v_t^T)^T r_t
                         S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    w in (0,1).  Returns (out [B,H,S,Dv], S_last [B,H,Dk,Dv]).
    """
    B, H, S, Dk = r.shape
    Dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt, uu = inp
        kv = kt[:, :, :, None] * vt[:, :, None, :]          # [B,H,Dk,Dv]
        out = jnp.einsum("bhkv,bhk->bhv",
                         state + uu[None, :, :, None] * kv, rt)
        state = wt[:, :, :, None] * state + kv
        return state, out

    rs = jnp.moveaxis(r.astype(jnp.float32), 2, 0)
    ks = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    ws = jnp.moveaxis(w.astype(jnp.float32), 2, 0)
    us = jnp.broadcast_to(u.astype(jnp.float32), (S, H, Dk))
    s_last, outs = jax.lax.scan(step, s0.astype(jnp.float32),
                                (rs, ks, vs, ws, us))
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), s_last
