"""Ring-slot claim + version-publish kernel (the MV store's commit path).

Extends the aliased-output sequential-scatter pattern of occ_commit.py to a
read-modify-write with *two* aliased tables: the begin-timestamp ring
[N, D, G] and the head cursor [N, 1] are both input and output
(input_output_aliases), the sequential TPU grid walks the wave's committed
write ops, and each step DMAs its record's whole ring + cursor, edits them
in VMEM, and writes both back.

Unlike the min/+1/max scatters, a version install is NOT a per-cell
commutative combine — a record must claim exactly ONE new slot per wave no
matter how many committed ops hit it (concurrent group writers and
duplicate in-transaction writes merge into that slot).  The sequential grid
makes this well-defined: the FIRST op to visit a record advances the head,
copies the old newest slot's begin row into the new slot (carry-forward of
unwritten groups) and stamps its group; LATER visits detect the same-wave
install — some begin in the row already equals this wave's install
timestamp, which no earlier wave can have written because install
timestamps advance monotonically (core/mvstore.install_ts) — and only stamp
their group.  Under that monotonicity precondition the result is
order-independent across a wave, and bit-identical to the jnp oracle
(ref.mv_install), which resolves every op against the pre-wave head instead.

Masked ops clamp their DMA to row 0 and write the ring and cursor back
unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(D: int, G: int, keys_ref, ts_ref, grp_ref, do_ref, b_in, h_in,
            b_out, h_out):
    # Accumulate through the *output* refs (see occ_commit.py): the aliased
    # buffers hold the current tables and sequential grid steps revisiting a
    # record read back their predecessors' install.
    del b_in, h_in
    ts = ts_ref[0]
    do = do_ref[0, 0]
    row = b_out[0]                                        # uint32[D, G]
    h = h_out[0, 0]
    already = (row == ts).any()      # same-wave slot already claimed
    adv = do & ~already
    h_eff = jnp.where(adv, (h + 1) % D, h)
    dsel = jnp.arange(D, dtype=jnp.int32)[:, None] == h_eff
    old_row = jnp.where(jnp.arange(D, dtype=jnp.int32)[:, None] == h, row,
                        jnp.uint32(0)).max(axis=0)        # uint32[G]
    copied = jnp.where(dsel & adv, old_row[None, :], row)
    gsel = (jnp.arange(G, dtype=jnp.int32)[None, :] == grp_ref[0, 0]) \
        & dsel & do
    b_out[0] = jnp.where(gsel, ts, copied)
    h_out[0, 0] = jnp.where(do, h_eff, h)


def mv_install_pallas(begin: jax.Array, head: jax.Array, keys: jax.Array,
                      groups: jax.Array, do: jax.Array, ts: jax.Array,
                      interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """(begin', head') with one new ring slot per masked record — see
    ref.mv_install (incl. the begin < ts monotonicity precondition)."""
    T, K = keys.shape
    D, G = begin.shape[1], begin.shape[2]
    tsa = jnp.reshape(ts.astype(jnp.uint32), (1,))
    head2 = head.reshape(-1, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # keys, ts
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys, ts: (t, k)),   # groups
            pl.BlockSpec((1, 1), lambda t, k, keys, ts: (t, k)),   # do
            pl.BlockSpec((1, D, G),
                         lambda t, k, keys, ts: (jnp.maximum(keys[t, k], 0),
                                                 0, 0)),
            pl.BlockSpec((1, 1),
                         lambda t, k, keys, ts: (jnp.maximum(keys[t, k], 0),
                                                 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, D, G),
                         lambda t, k, keys, ts: (jnp.maximum(keys[t, k], 0),
                                                 0, 0)),
            pl.BlockSpec((1, 1),
                         lambda t, k, keys, ts: (jnp.maximum(keys[t, k], 0),
                                                 0)),
        ),
    )
    begin2, head3 = pl.pallas_call(
        functools.partial(_kernel, D, G),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct(begin.shape, begin.dtype),
                   jax.ShapeDtypeStruct(head2.shape, head2.dtype)),
        # begin is operand 4 and head operand 5, counting the two prefetches.
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(keys, tsa, groups, do & (keys >= 0), begin, head2)
    return begin2, head3.reshape(-1)
