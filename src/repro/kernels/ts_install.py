"""Scatter-max timestamp-install kernel (TicToc's wts/rts advance).

TicToc installs commit timestamps monotonically: wts/rts of each written
(record, group) cell only ever move up (`table.at[...].max` on the jnp
backend).  This kernel is the aliased-output formulation, extending
kernels/occ_commit.py's pattern: the timestamp table is both input and output
(input_output_aliases), the sequential TPU grid walks the wave's ops, and each
step DMAs the op's row, maxes in the candidate value, and writes it back.
Because max is commutative and idempotent, duplicate (record, group) cells in
one wave land on the same result in any visit order — which is what makes the
kernel bit-identical to the XLA scatter-max.

``whole_row=True`` installs the value across *every* group of the record —
coarse-granularity rts extension raises the whole row's read horizon (one
timestamp per record; see cc/tictoc.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(whole_row: bool, keys_ref, grp_ref, val_ref, do_ref, row_ref,
            out_ref):
    # Accumulate through the *output* ref (see occ_commit.py): the aliased
    # out buffer holds the current table and sequential grid steps revisiting
    # a row read back their predecessors' installs.
    del row_ref
    G = out_ref.shape[-1]
    if whole_row:
        sel = jnp.ones((G,), jnp.bool_)
    else:
        g = grp_ref[0, 0]
        sel = jnp.arange(G, dtype=jnp.int32) == g
    cand = jnp.where(sel & do_ref[0, 0], val_ref[0, 0], jnp.uint32(0))
    out_ref[0, :] = jnp.maximum(out_ref[0, :], cand)


def ts_install_max_pallas(table: jax.Array, keys: jax.Array,
                          groups: jax.Array, vals: jax.Array, do: jax.Array,
                          whole_row: bool = False,
                          interpret: bool = False) -> jax.Array:
    """table' with table[k, g] = max(table[k, g], vals) per masked op — see
    ref.ts_install_max."""
    T, K = keys.shape
    G = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, K),
        in_specs=[
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # groups
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # vals
            pl.BlockSpec((1, 1), lambda t, k, keys: (t, k)),      # do
            pl.BlockSpec((1, G),
                         lambda t, k, keys: (jnp.maximum(keys[t, k], 0), 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, G), lambda t, k, keys: (jnp.maximum(keys[t, k], 0), 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, whole_row),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={4: 0},  # table is operand 4 counting prefetch
        interpret=interpret,
    )(keys, groups, vals.astype(jnp.uint32), do & (keys >= 0), table)
