"""RG-LRU recurrence kernel (Griffin / recurrentgemma-9b recurrent blocks).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t,    a_t = exp(log_a_t) in (0,1]

The recurrence is elementwise over the channel dim, so the natural TPU tiling
is (batch, channel-block): grid (B, D/bd), each step holding a [S, bd] tile of
log_a and x in VMEM and walking time sequentially on the VPU while the next
tile's DMA overlaps.  The time loop is VMEM-resident — no HBM traffic inside —
so the kernel is bandwidth-bound at exactly 2 reads + 1 write per element,
the roofline optimum for a first-order recurrence.

Long sequences (S > chunk) are chunked by the ops.py wrapper, carrying h
between chunks; decode (S=1) takes the reference path (a single fma).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(S: int, loga_ref, x_ref, h0_ref, h_ref, hlast_ref):
    def step(t, h):
        a = jnp.exp(loga_ref[0, t, :].astype(jnp.float32))
        gx = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, None)) \
            * x_ref[0, t, :].astype(jnp.float32)
        h = a * h + gx
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, S, step, h0_ref[0, :].astype(jnp.float32))
    hlast_ref[0, :] = h


def rglru_pallas(log_a: jax.Array, x: jax.Array, h0: jax.Array,
                 block_d: int = 128, interpret: bool = False):
    """log_a, x: [B, S, D]; h0: [B, D] f32.  Returns (h [B,S,D], h_last)."""
    B, S, D = x.shape
    bd = min(block_d, D)
    grid = (B, D // bd)
    h, h_last = pl.pallas_call(
        functools.partial(_kernel, S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, bd), lambda b, di: (b, 0, di)),
            pl.BlockSpec((1, S, bd), lambda b, di: (b, 0, di)),
            pl.BlockSpec((1, bd), lambda b, di: (b, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, bd), lambda b, di: (b, 0, di)),
            pl.BlockSpec((1, bd), lambda b, di: (b, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(log_a, x, h0)
    return h, h_last
