"""Op fifteen: the lane-block megakernel for the probe-family wave.

One ``pallas_call`` replaces the whole claim -> verdict -> install chain
(``claim_probe`` launch, XLA verdict compare, ``commit_install`` launch)
that the probe family ran per wave: in a single launch with the claim and
version tables aliased in/out, the kernel installs the wave's write
claims, answers every op's strongest-claimant probe, reduces the per-op
conflicts to lane verdicts in VMEM, and bumps versions for the committed
writes — each touched row rides ONE DMA per wave instead of 2-3
(DESIGN.md section 5).

Tiling.  The grid is LANE BLOCKS — ``(T // LB,)`` with an ``LB``-lane x
K-slot block per step — instead of the one-op-per-step ``(T, K)`` grid of
the older kernels.  Tables sit in ANY/HBM memory space and rows move by
explicit ``make_async_copy`` DMAs into VMEM scratch: a step issues the
row fetches for all LB*K ops of its block back-to-back (the whole read
stream is in flight at once — double buffering generalized to depth
LB*K), waits once, runs the block's probe/verdict/install math fully
vectorized, and streams the writeback DMAs out.  ``LB`` is auto-chosen
from the table width (wider rows -> smaller blocks, bounded by the
all-pairs tile's VMEM footprint) with an ``EngineConfig.lane_block``
override; ``pick_lane_block`` snaps to a divisor of T, so LB=1
degenerates to the old per-op tiling.

Correctness under the block tiling.  A block's row fetches all happen
before any of its writebacks, so two same-row ops in one block read the
same pre-block row state — the kernel therefore writes back *final*
values, not increments applied to possibly-stale reads:

  - claim install: ``min(fetched row, strongest same-wave claim word per
    cell)`` with the wave term computed from the full in-VMEM wave
    vectors (the all-pairs trick of ``claim_probe.py``).  Every same-row
    op writes the identical final row (min is idempotent), so writeback
    order within a block is unobservable.
  - version bump: ``fetched row + same-block committed-write count per
    cell``.  Lane verdicts are block-local by construction (a block holds
    whole lanes), so the count is complete within the block; same-row ops
    again write identical bytes.  Cross-block accumulation is ordered by
    the sequential grid (a step's writebacks are waited before the step
    ends, so the next block's fetches see them).

Probes see later blocks' installs through the same all-pairs wave term as
``claim_probe.py`` — sound under the monotone-wave-tag precondition
checked by ``ref.check_claim_tag_monotone``.  Masked ops clamp to row 0
but compute the SAME final row 0 as any real row-0 op in the block
(matching on clamped keys), so their redundant writebacks are harmless.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.claimword import (EMPTY_WORD, NO_PRIO, PRIO16_MASK,
                                  WAVE_SHIFT, live_prio)

_SENT = 0x7FFFFFFF  # cell id of masked ops in the all-pairs compare

#: VMEM budget for the all-pairs tile ((T*K) x (LB*K) int32 compares) the
#: auto lane-block chooser fits under.
_PAIR_TILE_BYTES = 1 << 20


def pick_lane_block(T: int, K: int, G: int, override: int = 0) -> int:
    """Lanes per grid step.  Auto mode fits the (T*K) x (LB*K) all-pairs
    tile under ``_PAIR_TILE_BYTES`` and caps the row scratch by the table
    width G (wider rows -> smaller blocks); an explicit ``override``
    (EngineConfig.lane_block) wins.  Either way the result snaps DOWN to
    a divisor of T, so the grid tiles exactly and LB=1 recovers the
    per-op tiling."""
    if override:
        lb = max(1, min(int(override), T))
    else:
        lb = max(1, _PAIR_TILE_BYTES // max(4 * T * K * K, 1))
        lb = min(lb, T, max(256 // max(G, 1), 1))
    while T % lb:
        lb -= 1
    return lb


def _start(copy):
    copy.start()


def _wait(copy):
    copy.wait()


def _row_dmas(action, keys_ref, tbl_ref, buf_ref, sem_ref, t0, LB, K,
              to_table: bool = False):
    """Issue (or wait) one row copy per block op: table row <-> scratch
    row j.  All LB*K copies of a stream are in flight together."""

    def body(j, _):
        t = t0 + j // K
        key = jnp.maximum(keys_ref[t, j % K], 0)
        if to_table:
            copy = pltpu.make_async_copy(buf_ref.at[j], tbl_ref.at[key],
                                         sem_ref.at[j])
        else:
            copy = pltpu.make_async_copy(tbl_ref.at[key], buf_ref.at[j],
                                         sem_ref.at[j])
        action(copy)
        return 0

    jax.lax.fori_loop(0, LB * K, body, 0)


def _probe(rows, ivw, kcl, kraw, gb, allk, allg, allp16, alldo, fine, G):
    """Strongest-claimant prio16 per block op: min(fetched-row probe,
    same-wave all-pairs term) — claim_probe.py's math, vectorized over
    the lane block.  NO_PRIO for masked (kraw < 0) ops."""
    pr = live_prio(rows, ivw)                          # (LBK, G)
    garange = jnp.arange(G, dtype=jnp.int32)
    if fine:
        tprio = jnp.where(garange[None, :] == gb[:, None], pr,
                          jnp.uint32(NO_PRIO)).min(axis=1)
        all_cell = jnp.where(alldo, allk * G + allg, jnp.int32(_SENT))
        hit = all_cell[:, None] == (kcl * G + gb)[None, :]
    else:
        tprio = pr.min(axis=1)
        all_key = jnp.where(alldo, allk, jnp.int32(_SENT))
        hit = all_key[:, None] == kcl[None, :]
    wave_prio = jnp.where(hit, allp16[:, None],
                          jnp.uint32(NO_PRIO)).min(axis=0)
    return jnp.where(kraw >= 0, jnp.minimum(tprio, wave_prio),
                     jnp.uint32(NO_PRIO))


def _install_rows(rows, ivw, kcl, allk, allg, allp16, alldo, G):
    """Final claim rows for the block: min(fetched row, strongest
    same-wave claim word per cell) — always fine resolution (claims are
    scattered fine regardless of granularity).  Identical for every
    same-row op, so block writeback order is unobservable."""
    word_all = (ivw << WAVE_SHIFT) | allp16            # (TK,) uint32
    key_hit = (allk[:, None] == kcl[None, :]) & alldo[:, None]
    cols = []
    for g in range(G):
        gm = key_hit & (allg[:, None] == g)
        wmin = jnp.where(gm, word_all[:, None],
                         jnp.uint32(EMPTY_WORD)).min(axis=0)
        cols.append(jnp.minimum(rows[:, g], wmin))
    return jnp.stack(cols, axis=1)


def _bump_rows(rows, kcl, gb, bump_ops, G):
    """Final version rows: fetched row + same-block committed-write count
    per cell.  Complete within the block (lane verdicts are block-local);
    identical bytes for every same-row op."""
    key_eq = kcl[:, None] == kcl[None, :]              # (LBK, LBK)
    cols = []
    for g in range(G):
        cnt = (key_eq & bump_ops[None, :]
               & (gb[None, :] == g)).sum(axis=1).astype(jnp.uint32)
        cols.append(rows[:, g] + cnt)
    return jnp.stack(cols, axis=1)


def _wave_commit_kernel(fine, G, LB, K, T, dual, bump, *refs):
    LBK = LB * K
    it = iter(refs)
    keys_ref, ivw_ref = next(it), next(it)
    (kv, grp, prio, dow, dor, cw, c2, crm, ex) = (next(it)
                                                  for _ in range(9))
    cw_in = next(it)
    cr_in = next(it) if dual else None
    wts_in = next(it) if bump else None
    conf_out, commit_out, cwo = next(it), next(it), next(it)
    cro = next(it) if dual else None
    wtso = next(it) if bump else None
    rw, nw, sem_rw, sem_ww = (next(it) for _ in range(4))
    if dual:
        rr, nr, sem_rr, sem_wr = (next(it) for _ in range(4))
    if bump:
        rv, nv, sem_rv, sem_wv = (next(it) for _ in range(4))
    del cw_in, cr_in, wts_in  # RMW through the aliased OUTPUT refs

    ivw = ivw_ref[0]
    t0 = pl.program_id(0) * LB

    # ---- fetch: every block op's row(s), all copies in flight at once
    _row_dmas(_start, keys_ref, cwo, rw, sem_rw, t0, LB, K)
    if dual:
        _row_dmas(_start, keys_ref, cro, rr, sem_rr, t0, LB, K)
    if bump:
        _row_dmas(_start, keys_ref, wtso, rv, sem_rv, t0, LB, K)
    _row_dmas(_wait, keys_ref, cwo, rw, sem_rw, t0, LB, K)
    if dual:
        _row_dmas(_wait, keys_ref, cro, rr, sem_rr, t0, LB, K)
    if bump:
        _row_dmas(_wait, keys_ref, wtso, rv, sem_rv, t0, LB, K)

    # ---- block views (dynamic slice of the full in-VMEM wave vectors)
    def blk(ref, dtype=None):
        x = jax.lax.dynamic_slice(ref[...], (t0, 0), (LB, K)).reshape(LBK)
        return x if dtype is None else x.astype(dtype)

    kraw = blk(kv)
    kcl = jnp.maximum(kraw, 0)
    gb = blk(grp)
    pbu = blk(prio).astype(jnp.uint32)
    dwb = blk(dow)
    allk = kv[...].reshape(-1)
    allg = grp[...].reshape(-1)
    allp16 = (prio[...].astype(jnp.uint32)
              & jnp.uint32(PRIO16_MASK)).reshape(-1)
    alldow = dow[...].reshape(-1)

    # ---- probe + per-op conflicts + lane verdicts, fully vectorized
    wprio = _probe(rw[...], ivw, kcl, kraw, gb, allk, allg, allp16,
                   alldow, fine, G)
    conf = blk(cw) & (wprio < pbu)
    conf |= blk(c2) & (wprio != jnp.uint32(NO_PRIO)) & (wprio != pbu)
    if dual:
        rprio = _probe(rr[...], ivw, kcl, kraw, gb, allk, allg, allp16,
                       dor[...].reshape(-1), fine, G)
        conf |= blk(crm) & (rprio < pbu)
    conf |= blk(ex)
    confm = conf.reshape(LB, K)
    commit = ~confm.any(axis=1)                        # (LB,)
    conf_out[...] = confm
    commit_out[...] = commit[:, None]

    # ---- install writebacks: final rows, streamed back to the tables
    nw[...] = _install_rows(rw[...], ivw, kcl, allk, allg, allp16,
                            alldow, G)
    _row_dmas(_start, keys_ref, cwo, nw, sem_ww, t0, LB, K, to_table=True)
    if dual:
        alldor = dor[...].reshape(-1)
        nr[...] = _install_rows(rr[...], ivw, kcl, allk, allg, allp16,
                                alldor, G)
        _row_dmas(_start, keys_ref, cro, nr, sem_wr, t0, LB, K,
                  to_table=True)
    if bump:
        bump_ops = dwb & jnp.broadcast_to(commit[:, None],
                                          (LB, K)).reshape(LBK)
        nv[...] = _bump_rows(rv[...], kcl, gb, bump_ops, G)
        _row_dmas(_start, keys_ref, wtso, nv, sem_wv, t0, LB, K,
                  to_table=True)
    # Writebacks must land before the next block fetches (sequential
    # grid): wait them out before the step ends.
    _row_dmas(_wait, keys_ref, cwo, nw, sem_ww, t0, LB, K, to_table=True)
    if dual:
        _row_dmas(_wait, keys_ref, cro, nr, sem_wr, t0, LB, K,
                  to_table=True)
    if bump:
        _row_dmas(_wait, keys_ref, wtso, nv, sem_wv, t0, LB, K,
                  to_table=True)


def wave_commit_pallas(claim_w: jax.Array, claim_r, wts, keys: jax.Array,
                       groups: jax.Array, prio: jax.Array, do_w: jax.Array,
                       do_r, check_w: jax.Array, check_w2, check_r, extra,
                       inv_wave: jax.Array, fine: bool, dual: bool,
                       bump: bool, lane_block: int = 0,
                       interpret: bool = False):
    """(claim_w', claim_r', wts', conflict bool[T,K], commit bool[T]) —
    see ref.wave_commit (None passed through for absent tables)."""
    T, K = keys.shape
    G = claim_w.shape[1]
    LB = pick_lane_block(T, K, G, lane_block)
    LBK = LB * K
    ivw = jnp.reshape(inv_wave.astype(jnp.uint32), (1,))
    do_w = do_w & (keys >= 0)
    zeros = jnp.zeros((T, K), jnp.bool_)
    do_r = (do_r & (keys >= 0)) if dual else zeros
    check_w2 = zeros if check_w2 is None else check_w2
    check_r = zeros if check_r is None else check_r
    extra = zeros if extra is None else extra
    p16 = prio.astype(jnp.uint32)

    full = pl.BlockSpec((T, K), lambda i, keys, ivw: (0, 0))
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    n_tbl = 1 + int(dual) + int(bump)
    in_specs = [full] * 9 + [any_spec] * n_tbl
    out_specs = [pl.BlockSpec((LB, K), lambda i, keys, ivw: (i, 0)),
                 pl.BlockSpec((LB, 1), lambda i, keys, ivw: (i, 0))] \
        + [any_spec] * n_tbl
    out_shape = [jax.ShapeDtypeStruct((T, K), jnp.bool_),
                 jax.ShapeDtypeStruct((T, 1), jnp.bool_),
                 jax.ShapeDtypeStruct(claim_w.shape, claim_w.dtype)]
    tables = [claim_w]
    aliases = {11: 2}
    if dual:
        out_shape.append(jax.ShapeDtypeStruct(claim_r.shape, claim_r.dtype))
        tables.append(claim_r)
        aliases[12] = 3
    if bump:
        out_shape.append(jax.ShapeDtypeStruct(wts.shape, wts.dtype))
        tables.append(wts)
        aliases[11 + n_tbl - 1] = 2 + n_tbl - 1

    def tbl_scratch():
        return [pltpu.VMEM((LBK, G), jnp.uint32),
                pltpu.VMEM((LBK, G), jnp.uint32),
                pltpu.SemaphoreType.DMA((LBK,)),
                pltpu.SemaphoreType.DMA((LBK,))]

    scratch = tbl_scratch() * n_tbl

    outs = pl.pallas_call(
        functools.partial(_wave_commit_kernel, fine, G, LB, K, T, dual,
                          bump),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,   # keys, inv_wave
            grid=(T // LB,),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(keys, ivw, keys, groups, p16, do_w, do_r, check_w, check_w2,
      check_r, extra, *tables)

    conflict, commit = outs[0], outs[1][:, 0]
    claim_w = outs[2]
    claim_r = outs[3] if dual else None
    wts = outs[2 + n_tbl - 1] if bump else None
    return claim_w, claim_r, wts, conflict, commit
