"""Public kernel entry points.

Each op auto-selects the execution path:
  - on TPU: the Pallas kernel (compiled);
  - elsewhere (this CPU container, tests): either the jnp reference (fast,
    used inside jitted models) or the Pallas kernel in interpret mode
    (tests/test_kernels.py validates kernel == reference across shape/dtype
    sweeps).

Set ``REPRO_KERNELS`` ("pallas" | "ref") or pass use_pallas/interpret
explicitly to override; models route through these wrappers so the same model
code runs on both backends.  The env var is resolved *per call* (not at
import time), so tests and benchmarks can toggle it after this module loads.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.claimword import inv_wave as _inv_wave
from repro.kernels import ref
from repro.kernels.claim_probe import claim_probe_fused_pallas
from repro.kernels.claim_scatter import claim_scatter_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.iterate_validate import iterate_validate_pallas
from repro.kernels.occ_commit import occ_commit_pallas
from repro.kernels.mv_gather import mv_gather_pallas
from repro.kernels.mv_install import mv_install_pallas
from repro.kernels.occ_validate import (claim_probe_pallas,
                                        occ_validate_dual_pallas,
                                        occ_validate_pallas)
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.route_pack import route_pack_pallas
from repro.kernels.rwkv6_scan import rwkv6_pallas
from repro.kernels.segment_count import segment_count_pallas
from repro.kernels.ts_gather import ts_gather_pallas
from repro.kernels.ts_install import ts_install_max_pallas
from repro.kernels.verdict_pack import (verdict_pack_pallas,
                                        verdict_unpack_pallas)
from repro.kernels.wave_commit import wave_commit_pallas


def _force() -> str:
    return os.environ.get("REPRO_KERNELS", "")  # "", "pallas", "ref"


def _use_pallas(use_pallas) -> bool:
    if use_pallas is not None:
        return use_pallas
    force = _force()
    if force == "pallas":
        return True
    if force == "ref":
        return False
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ------------------------------------------------------------------ OCC
def occ_validate(claim_w, keys, groups, myprio, check, inv_wave, fine: bool,
                 lane_block: int = 0, use_pallas=None):
    if _use_pallas(use_pallas):
        return occ_validate_pallas(claim_w, keys, groups,
                                   myprio.astype(jnp.uint32), check,
                                   inv_wave, fine, lane_block=lane_block,
                                   interpret=_interp())
    return ref.occ_validate(claim_w, keys, groups, myprio, check,
                            inv_wave, fine)


def occ_validate_dual(claim_w, keys, groups, myprio, check, inv_wave,
                      lane_block: int = 0, use_pallas=None):
    if _use_pallas(use_pallas):
        return occ_validate_dual_pallas(claim_w, keys, groups,
                                        myprio.astype(jnp.uint32), check,
                                        inv_wave, lane_block=lane_block,
                                        interpret=_interp())
    return ref.occ_validate_dual(claim_w, keys, groups, myprio, check,
                                 inv_wave)


def claim_probe(table, keys, groups, inv_wave, fine: bool,
                lane_block: int = 0, use_pallas=None):
    if _use_pallas(use_pallas):
        return claim_probe_pallas(table, keys, groups, inv_wave, fine,
                                  lane_block=lane_block,
                                  interpret=_interp())
    return ref.claim_probe(table, keys, groups, inv_wave, fine)


def occ_commit(wts, keys, groups, do, use_pallas=None):
    if _use_pallas(use_pallas):
        return occ_commit_pallas(wts, keys, groups, do, interpret=_interp())
    return ref.occ_commit(wts, keys, groups, do)


# --------------------------------------------------------- TicToc timestamps
def ts_gather(table, keys, groups, fine: bool, use_pallas=None):
    if _use_pallas(use_pallas):
        return ts_gather_pallas(table, keys, groups, fine,
                                interpret=_interp())
    return ref.ts_gather(table, keys, groups, fine)


def ts_install_max(table, keys, groups, vals, do, whole_row: bool = False,
                   use_pallas=None):
    if _use_pallas(use_pallas):
        return ts_install_max_pallas(table, keys, groups, vals, do,
                                     whole_row, interpret=_interp())
    return ref.ts_install_max(table, keys, groups, vals, do, whole_row)


# -------------------------------------------------------------- claim tables
def claim_scatter(table, keys, groups, prio, do, wave, use_pallas=None):
    if _use_pallas(use_pallas):
        return claim_scatter_pallas(table, keys, groups, prio, do,
                                    _inv_wave(wave), interpret=_interp())
    return ref.claim_scatter(table, keys, groups, prio, do, wave)


def claim_probe_fused(table, keys, groups, prio, do, wave, fine: bool,
                      lane_block: int = 0, use_pallas=None):
    if _use_pallas(use_pallas):
        # Same debug-mode precondition check as the jnp oracle path (eager
        # calls only; free under jit — see ref.check_claim_tag_monotone).
        ref.check_claim_tag_monotone(table, keys, wave)
        return claim_probe_fused_pallas(table, keys, groups, prio, do,
                                        _inv_wave(wave), fine,
                                        lane_block=lane_block,
                                        interpret=_interp())
    return ref.claim_probe_fused(table, keys, groups, prio, do, wave, fine)


def wave_commit(claim_w, claim_r, wts, keys, groups, prio, do_w, do_r,
                check_w, check_w2, check_r, extra, wave, fine: bool,
                dual: bool, bump: bool, lane_block: int = 0,
                use_pallas=None):
    """Op fifteen: the fused probe-family wave (claim install + probe +
    lane verdicts + version bumps, one launch) — see ref.wave_commit."""
    if _use_pallas(use_pallas):
        ref.check_claim_tag_monotone(claim_w, keys, wave)
        if dual:
            ref.check_claim_tag_monotone(claim_r, keys, wave)
        return wave_commit_pallas(claim_w, claim_r, wts, keys, groups,
                                  prio.astype(jnp.uint32), do_w, do_r,
                                  check_w, check_w2, check_r, extra,
                                  _inv_wave(wave), fine, dual, bump,
                                  lane_block=lane_block,
                                  interpret=_interp())
    return ref.wave_commit(claim_w, claim_r, wts, keys, groups, prio, do_w,
                           do_r, check_w, check_w2, check_r, extra, wave,
                           fine, dual, bump)


def iterate_validate(table, keys, extents, groups, myprio, check, inv_wave,
                     fine: bool, bucket_size: int, ext_cap: int,
                     lane_block: int = 0, use_pallas=None):
    """Op sixteen: interval (scan) validation — conflict bool[T, K] for
    every masked op whose ``[key, key + extent)`` interval carries a live
    same-wave claim stronger than the lane.  See ref.iterate_validate."""
    if _use_pallas(use_pallas):
        return iterate_validate_pallas(table, keys, extents, groups,
                                       myprio.astype(jnp.uint32), check,
                                       inv_wave, fine, bucket_size, ext_cap,
                                       lane_block=lane_block,
                                       interpret=_interp())
    return ref.iterate_validate(table, keys, extents, groups, myprio, check,
                                inv_wave, fine, bucket_size, ext_cap)


def route_pack(owner, vals, n_dest: int, cap: int, fills, use_pallas=None):
    if _use_pallas(use_pallas):
        return route_pack_pallas(owner, vals, n_dest, cap, fills,
                                 interpret=_interp())
    return ref.route_pack(owner, vals, n_dest, cap, fills)


def verdict_pack(v, use_pallas=None):
    if _use_pallas(use_pallas):
        return verdict_pack_pallas(v, interpret=_interp())
    return ref.verdict_pack(v)


def verdict_unpack(words, n: int, use_pallas=None):
    if _use_pallas(use_pallas):
        return verdict_unpack_pallas(words, n, interpret=_interp())
    return ref.verdict_unpack(words, n)


def segment_count(keys, groups, G: int, mask, use_pallas=None):
    if _use_pallas(use_pallas):
        return segment_count_pallas(keys, groups, G, mask,
                                    interpret=_interp())
    return ref.segment_count(keys, groups, G, mask)


# ------------------------------------------------------- multi-version store
def mv_gather(begin, keys, groups, ts, fine: bool, lane_block: int = 0,
              use_pallas=None):
    if _use_pallas(use_pallas):
        return mv_gather_pallas(begin, keys, groups, ts, fine,
                                lane_block=lane_block,
                                interpret=_interp())
    return ref.mv_gather(begin, keys, groups, ts, fine)


def mv_install(begin, head, keys, groups, do, ts, use_pallas=None):
    if _use_pallas(use_pallas):
        ref.check_mv_begin_monotone(begin, keys, do, ts)
        return mv_install_pallas(begin, head, keys, groups, do, ts,
                                 interpret=_interp())
    return ref.mv_install(begin, head, keys, groups, do, ts)


# ------------------------------------------------------- flash attention
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128, use_pallas=None):
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D].  See ref.attention."""
    if not _use_pallas(use_pallas):
        return ref.attention(q, k, v, causal=causal, window=window,
                             scale=scale)
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    bq, bk = min(block_q, max(Sq, 8)), min(block_k, max(Sk, 8))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, sq_valid=Sq, sk_valid=Sk,
                                 block_q=bq, block_k=bk,
                                 interpret=_interp())
    return out[:, :, :Sq, :]


# ------------------------------------------------------------- RG-LRU
def rglru(log_a, x, h0=None, chunk: int = 2048, use_pallas=None):
    """See ref.rglru.  Chunks long sequences, carrying h between chunks."""
    B, S, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    if not _use_pallas(use_pallas):
        return ref.rglru(log_a, x, h0)
    if S <= chunk:
        return rglru_pallas(log_a, x, h0, interpret=_interp())
    n = -(-S // chunk)
    la = _pad_to(log_a, 1, chunk).reshape(B, n, chunk, D)
    xx = _pad_to(x, 1, chunk).reshape(B, n, chunk, D)

    def step(h, inp):
        la_c, x_c = inp
        hs, h = rglru_pallas(la_c, x_c, h, interpret=_interp())
        return h, hs

    h_last, hs = jax.lax.scan(
        step, h0, (jnp.moveaxis(la, 1, 0), jnp.moveaxis(xx, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, n * chunk, D)[:, :S]
    return hs, h_last


# ------------------------------------------------------------- RWKV-6
def rwkv6(r, k, v, w, u, s0=None, chunk: int = 2048, use_pallas=None):
    """See ref.rwkv6.  Chunks long sequences, carrying the state."""
    B, H, S, Dk = r.shape
    Dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
    if not _use_pallas(use_pallas):
        return ref.rwkv6(r, k, v, w, u, s0)
    if S <= chunk:
        return rwkv6_pallas(r, k, v, w, u, s0, interpret=_interp())
    n = -(-S // chunk)

    def pad(x, const=0.0):
        p = (-S) % chunk
        if p:
            widths = [(0, 0)] * x.ndim
            widths[2] = (0, p)
            x = jnp.pad(x, widths, constant_values=const)
        return x.reshape(B, H, n, chunk, x.shape[-1])

    # Padded steps must be identity on the state: w=1 (keep), k=0 (no add).
    rr, kk, vv, ww = pad(r), pad(k), pad(v), pad(w, const=1.0)

    def step(s, inp):
        r_c, k_c, v_c, w_c = inp
        out, s = rwkv6_pallas(r_c, k_c, v_c, w_c, u, s, interpret=_interp())
        return s, out

    s_last, outs = jax.lax.scan(
        step, s0, tuple(jnp.moveaxis(t, 2, 0) for t in (rr, kk, vv, ww)))
    outs = jnp.moveaxis(outs, 0, 2).reshape(B, H, n * chunk, Dv)[:, :, :S]
    return outs, s_last
