"""Pallas TPU kernels for the system's compute hot spots.

Layout per the repo convention: one ``<name>.py`` per kernel containing the
``pl.pallas_call`` + BlockSpec tiling, ``ops.py`` with the jit'd public
wrappers (auto-selecting kernel vs reference by backend), and ``ref.py`` with
the pure-jnp oracles every kernel is validated against (interpret mode on CPU,
shape/dtype sweeps in tests/test_kernels.py).

Kernels (the CC set implements the backend surface of core/backend.py —
DESIGN.md section 5):
  occ_validate    read-set validation: scalar-prefetch row DMA + compare;
                  also the dual-granularity variant (one DMA, fine+coarse
                  verdicts) and the raw strongest-claimant probe
  claim_probe     FUSED claim install + post-install probe: one aliased
                  row DMA per op serves both the scatter-min claim and the
                  strongest-claimant answer (wave-local all-pairs min
                  completes the later-grid-step claims) — the probe
                  family's two hottest passes in one kernel
  occ_commit      version-bump scatter with aliased output
  ts_gather       TicToc (wts, rts) row gather; coarse = row max
  ts_install      monotone scatter-max timestamp install (whole-row option)
  claim_scatter   fused pack+scatter-min of claim words
  segment_count   same-cell op counts in a wave (all-pairs compare — TicToc
                  extension chains without the XLA sort)
  route_pack      sort-free per-destination exchange-buffer pack for the
                  distributed wave (counting/offset scan over the in-VMEM
                  wave replaces the argsort routing pass)
  mv_gather       multi-version snapshot select: one DMA fetches a record's
                  whole begin ring, the VPU scans all D slots at once
  mv_install      ring-slot claim + version publish: aliased-output RMW over
                  the begin ring AND head cursor (DESIGN.md section 9)
  flash_attention blocked causal attention (GQA, optional sliding window)
  rglru_scan      RG-LRU linear recurrence (recurrentgemma)
  rwkv6_scan      RWKV-6 wkv state recurrence (data-dependent decay)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
