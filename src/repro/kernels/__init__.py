"""Pallas TPU kernels for the system's compute hot spots.

Layout per the repo convention: one ``<name>.py`` per kernel containing the
``pl.pallas_call`` + BlockSpec tiling, ``ops.py`` with the jit'd public
wrappers (auto-selecting kernel vs reference by backend), and ``ref.py`` with
the pure-jnp oracles every kernel is validated against (interpret mode on CPU,
shape/dtype sweeps in tests/test_kernels.py).

Kernels:
  occ_validate    OCC read-set validation: scalar-prefetch row gather + compare
  occ_commit      version-bump scatter with aliased output
  flash_attention blocked causal attention (GQA, optional sliding window)
  rglru_scan      RG-LRU linear recurrence (recurrentgemma)
  rwkv6_scan      RWKV-6 wkv state recurrence (data-dependent decay)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
