"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Structure (the 1000-node story, exercised at CPU scale):
  - supervisor loop: any step failure (injected or real) rolls back to the
    last durable checkpoint and resumes — `run_supervised` is the API the
    fault-tolerance tests drive;
  - checkpointing: interval + async + atomic (repro.checkpoint), config
    fingerprint guards against restoring the wrong architecture;
  - data: stateless `make_batch(step)` — restart/elastic-resume replays the
    exact stream;
  - preemption: SIGTERM flushes a checkpoint before exit.
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TrainRun:
    """Everything the supervisor needs to (re)build step state."""
    cfg: object
    mesh: object
    optimizer: object
    shape: object
    ckpt: object                    # CheckpointManager
    injector: object = None
    log_every: int = 10

    def build(self):
        from repro.models import model as model_mod
        from repro.models import steps
        ts = steps.build_train_step(self.cfg, self.mesh, self.optimizer)
        return jax.jit(ts, donate_argnums=(0, 1))

    def fresh_state(self, seed: int = 0):
        from repro.models import model as model_mod
        params = model_mod.init_params(self.cfg, jax.random.PRNGKey(seed))
        opt_state = self.optimizer.init(params)
        return params, opt_state


def run_supervised(run: TrainRun, total_steps: int, *, seed: int = 0,
                   max_restarts: int = 20):
    """Supervisor loop: train to total_steps surviving failures."""
    from repro.data import make_batch
    from repro.ft.failures import SimulatedFailure

    step_fn = run.build()
    params, opt_state = run.fresh_state(seed)
    start = 0
    restored, manifest = run.ckpt.restore_latest(
        {"params": params, "opt": opt_state})
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start = manifest["step"]
        print(f"[train] resumed from step {start}")

    restarts = 0
    metrics = {}
    step = start
    losses = []
    while step < total_steps:
        try:
            batch = make_batch(run.cfg, run.shape, step)
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.int32(step))
            if run.injector is not None:
                run.injector.maybe_fail(step)
            step += 1
            run.ckpt.maybe_save(step, {"params": params, "opt": opt_state})
            if step % run.log_every == 0 or step == total_steps:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f}")
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            print(f"[train] {e} -> restart {restarts}")
            # tear down and restore from the last durable checkpoint
            run.ckpt.wait()
            params, opt_state = run.fresh_state(seed)
            restored, manifest = run.ckpt.restore_latest(
                {"params": params, "opt": opt_state})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                step = manifest["step"]
            else:
                step = 0
    run.ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                        force=True)
    run.ckpt.wait()
    return params, opt_state, losses, restarts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1,
                    help="data mesh axis (local devices)")
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ShapeSpec
    from repro.ft import FailureInjector
    from repro.launch.mesh import make_host_mesh
    from repro.optim import AdamW

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    mesh = make_host_mesh(args.data, args.model)
    seq = args.seq + (cfg.n_patches or 0)
    shape = ShapeSpec("cli", "train", seq, args.batch)
    opt = AdamW.from_config(cfg, peak_lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 1))
    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_every,
                             fingerprint=f"{cfg.name}-smoke={args.smoke}")
    run = TrainRun(cfg=cfg, mesh=mesh, optimizer=opt, shape=shape,
                   ckpt=ckpt,
                   injector=FailureInjector(at_steps=tuple(args.fail_at)))

    def flush(sig, frame):
        print("[train] SIGTERM: flushing checkpoint")
        ckpt.wait()
        sys.exit(0)

    signal.signal(signal.SIGTERM, flush)

    t0 = time.time()
    _, _, losses, restarts = run_supervised(run, args.steps)
    dt = time.time() - t0
    print(f"[train] done: {args.steps} steps in {dt:.1f}s, "
          f"{restarts} restarts, final loss {losses[-1][1]:.4f}")


if __name__ == "__main__":
    main()
