"""Serving driver: batched prefill + decode with a versioned session store.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --requests 8 --prompt-len 24 --gen 16

The session directory uses the paper's mechanism in the serving control
plane: each session row's metadata columns (static identity vs. hot decode
cursor) sit in different timestamp groups, so concurrent admission batches
(writers of the cursor) never falsely conflict with routing reads of the
identity columns — OCC with fine-grained timestamps (see core/, and
examples/serve_lm.py for the end-to-end demo).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def serve(cfg, mesh, *, n_requests: int, prompt_len: int, gen: int,
          seed: int = 0):
    from repro.data.pipeline import _tokens
    from repro.models import steps

    s_cache = prompt_len + gen + (cfg.n_patches or 0)
    prefill = jax.jit(steps.build_prefill_step(cfg, mesh, s_cache))
    decode = jax.jit(steps.build_decode_step(cfg, mesh),
                     donate_argnums=(1,))
    from repro.models import model as model_mod
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))

    key = jax.random.PRNGKey(seed + 1)
    batch = {"tokens": _tokens(key, (n_requests, prompt_len), cfg.vocab)}
    if cfg.n_patches:
        batch["patches"] = jnp.zeros(
            (n_requests, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.n_frames:
        batch["frames"] = jnp.zeros(
            (n_requests, cfg.n_frames, cfg.d_model), jnp.float32)

    t0 = time.time()
    cache, logits = prefill(params, batch)
    tok = greedy(logits)[:, None]
    t_prefill = time.time() - t0

    out = [tok]
    pos0 = prompt_len + (cfg.n_patches or 0)
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(pos0 + i))
        tok = greedy(logits)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = np.concatenate([np.asarray(t) for t in out], axis=1)
    return tokens, t_prefill, t_decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.launch.mesh import make_host_mesh

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    mesh = make_host_mesh()
    tokens, tp, td = serve(cfg, mesh, n_requests=args.requests,
                           prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] {args.requests} requests: prefill {tp*1e3:.1f}ms, "
          f"{args.gen} tokens in {td*1e3:.1f}ms "
          f"({args.requests*args.gen/max(td,1e-9):.0f} tok/s)")
    print("[serve] first request:", tokens[0][:16])


if __name__ == "__main__":
    main()
