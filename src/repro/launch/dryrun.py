import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on 512 placeholder devices, and record the evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/

Per cell this prints/records:
  - compiled.memory_analysis()   bytes per device (does it fit 16G v5e HBM?)
  - compiled.cost_analysis()     HLO flops/bytes (scan bodies counted once —
                                 see analysis/roofline.py for the corrected
                                 accounting)
  - collective bytes parsed from the optimized HLO (trip-count aware)

The txn-engine distributed cell (the paper's system) runs under
``--arch txn-engine``.
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if arch == "txn-engine":
        from repro.core.distributed import (DistConfig, abstract_args,
                                            make_wave_fn)
        cfg = DistConfig(n_records=10_000_000, n_groups=2,
                         lanes_per_shard=64, slots=16)
        fn = make_wave_fn(cfg, mesh)
        args = abstract_args(cfg, mesh)
        lowered = jax.jit(fn).lower(*args)
    else:
        from repro import configs
        from repro.models import steps
        cfg = configs.get(arch)
        if shape_name not in cfg.shapes:
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "skip", "note": cfg.skip_notes}
        fn, args = steps.build_cell(cfg, shape_name, mesh)
        lowered = jax.jit(fn).lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            k: getattr(mem, k, None)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
        },
    }
    try:
        from repro.analysis.roofline import collective_bytes_from_hlo
        hlo = compiled.as_text()
        rec["collective_bytes"] = collective_bytes_from_hlo(hlo)
        rec["collective_bytes_raw"] = collective_bytes_from_hlo(
            hlo, dtype_correct=False)
    except Exception as e:  # HLO text may be huge / parse edge cases
        rec["collective_bytes_error"] = repr(e)
    print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"mem/dev={mem.temp_size_in_bytes/2**30:.2f}GiB temp "
          f"+ {mem.argument_size_in_bytes/2**30:.2f}GiB args")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args(argv)

    from repro import configs

    cells = []
    if args.all:
        for name, cfg in configs.ARCHS.items():
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                cells.append((name, shape))
        cells.append(("txn-engine", "wave"))
    else:
        cells.append((args.arch, args.shape or "train_4k"))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            if args.all:
                # one subprocess per cell: isolates failures and keeps the
                # 80-cell sweep's memory bounded
                import subprocess
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape,
                     "--mesh", "multi" if mp else "single",
                     "--out", args.out],
                    env={**os.environ},
                )
                if r.returncode:
                    failures += 1
                    print(f"[dryrun] FAIL {tag}", file=sys.stderr)
                continue
            try:
                rec = run_cell(arch, shape, mp)
            except Exception:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "status": "fail",
                       "error": traceback.format_exc(limit=20)}
                failures += 1
                print(f"[dryrun] FAIL {tag}", file=sys.stderr)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
