"""Production meshes.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally visible devices (tests, examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
