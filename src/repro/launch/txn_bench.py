"""Transaction-engine benchmark CLI (the paper's experiments).

    PYTHONPATH=src python -m repro.launch.txn_bench --workload tpcc \
        --cc occ tictoc --granularity both --lanes 16 64 128 --waves 300

The whole cc x granularity x lanes grid compiles to ONE XLA program
(core/engine.py sweep, vmapped in lane buckets); ``--backend pallas`` routes
every CC shared-state op (the wave_commit megakernel, validate/gather,
commit/timestamp scatters) through the TPU-native kernels via the
``backend.N_OPS``-op backend surface of core/backend.py (interpret mode on CPU — see
DESIGN.md section 5).  Each JSON row records the resolved backend and
per-op kernel coverage (CC_OPS), which benchmarks/perf_dashboard.py
aggregates into reports/perf_dashboard.md.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time


@functools.lru_cache(maxsize=32)
def _make_workload(workload: str, *, scale: float = 1.0,
                   n_keys: int = 1_000_000, write_frac: float = 0.5,
                   ro_frac: float = 0.0, theta: float = 0.9,
                   scan_frac: float = 0.0, scan_len: int = 0):
    """Workloads are deterministic in their parameters and read-only once
    built, so identical grid points share ONE object — which also keys the
    compiled-sweep memo (core/engine.py), letting a re-run of the same
    grid (benchmarks/common.py warm_then_time) skip tracing entirely."""
    from repro.workloads import TPCCWorkload, YCSBWorkload
    if workload == "tpcc":
        return TPCCWorkload.make(n_warehouses=8, scale=scale,
                                 scan_len=scan_len)
    return YCSBWorkload.make(n_keys=n_keys, write_frac=write_frac,
                             ro_frac=ro_frac, theta=theta,
                             scan_frac=scan_frac, scan_len=scan_len or 8)


def _cost_fields(cc_name: str, lanes: int, granularity: int, slots: int,
                 n_groups: int, mv_depth: int, max_extent: int = 1,
                 bucket_size: int = 8) -> dict:
    """Per-op roofline cost-model columns (analysis/txn_cost.py): analytic
    bytes/flops per transaction attempt and the mechanism's fraction of
    the default chip's roofline.  Closed-form in the wave shape, so the
    fields are backend-INDEPENDENT (CI's jnp-vs-pallas CLI parity diff
    relies on that)."""
    from repro.analysis import txn_cost as tc
    shape = tc.WaveShape(lanes=lanes, slots=slots, n_groups=n_groups,
                         granularity=granularity, mv_depth=mv_depth,
                         max_extent=max_extent, bucket_size=bucket_size)
    cost = tc.txn_cost(cc_name, shape)
    fields = {
        "bytes_per_txn": round(cost["bytes_per_txn"], 1),
        "flops_per_txn": round(cost["flops_per_txn"], 1),
        "roofline_frac": round(cost["roofline_frac"], 6),
        "roofline_bound": cost["bound"],
        "roofline_chip": cost["chip"],
    }
    if cc_name in tc.PROBE_CHAIN_LAUNCHES:
        # ISSUE 9 fused-wave accounting: launches and touched-row DMA
        # visits of the probe chain per wave, fused (the shipped default)
        # next to the unfused baseline — the dashboard's row-traffic-cut
        # columns.
        chain = tc.probe_chain(cc_name, shape, fused=True)
        unfused = tc.probe_chain(cc_name, shape, fused=False)
        fields.update({
            "launches_per_wave": chain["launches_per_wave"],
            "dma_rows_per_wave": chain["dma_rows_per_wave"],
            "dma_rows_per_wave_unfused": unfused["dma_rows_per_wave"],
        })
    return fields


def _row(workload: str, cc_name: str, p, wall_s: float,
         backend: str, *, slots: int = 0, n_groups: int = 2,
         mv_depth: int = 0, max_extent: int = 1,
         bucket_size: int = 8) -> dict:
    from repro.core import types as t
    from repro.core.backend import kernel_coverage
    row = {
        "workload": workload, "cc": cc_name, "granularity": p.granularity,
        "lanes": p.lanes, "waves": p.waves,
        "commits": p.commits, "aborts": p.aborts,
        "abort_rate": round(p.abort_rate, 4),
        "ro_commits": p.ro_commits, "ro_aborts": p.ro_aborts,
        "ro_abort_rate": round(p.ro_abort_rate, 4),
        "throughput": round(p.throughput, 4),
        "ext_events": p.ext_events,
        "wall_s": round(wall_s, 2),
        "backend": backend,
        # Which backend-surface ops this mechanism actually routed through
        # Pallas kernels vs XLA — makes BENCH_*.json trajectories
        # attributable to an execution engine (DESIGN.md section 5).
        "kernel_ops": kernel_coverage(backend, t.CC_IDS[cc_name]),
        # Interval-read shape of the run; extent-1 rows are pure point
        # workloads (perf_dashboard.py defaults missing values to 1 for
        # pre-scan JSON rows).
        "max_extent": max_extent,
    }
    if getattr(p, "abort_causes", None) is not None:
        # Per-cause abort breakdown (types.CAUSE_*), name-keyed in code
        # order; the values sum to `aborts` exactly (the conservation
        # invariant tests/test_abort_causes.py asserts).
        row["abort_causes"] = {t.CAUSE_NAMES[i]: int(n)
                               for i, n in enumerate(p.abort_causes)}
    if slots:
        row.update(_cost_fields(cc_name, p.lanes, p.granularity, slots,
                                n_groups, mv_depth, max_extent,
                                bucket_size))
    if getattr(p, "open_loop", False):
        # Goodput (unique committed txns per simulated us) and the
        # per-txn-class time-to-commit percentiles (waves) the dashboard's
        # latency section reads (DESIGN.md section 11).
        row.update({
            "open_loop": True,
            "goodput": round(p.goodput, 4),
            "offered": p.offered, "admitted": p.admitted,
            "arrival_drops": p.arrival_drops, "inc_drops": p.inc_drops,
            "queued_final": p.queued_final,
            "p50_ttc_waves": p.p50_ttc, "p99_ttc_waves": p.p99_ttc,
        })
    return row


def run_grid(workload: str, ccs: list, grans, lanes: list, waves: int, *,
             scale: float = 1.0, n_keys: int = 1_000_000, seed: int = 0,
             backend: str = "jnp", mv_depth: int = 4, snapshot_age: int = 0,
             write_frac: float = 0.5, ro_frac: float = 0.0,
             theta: float = 0.9, scan_frac: float = 0.0, scan_len: int = 0,
             arrival_rate: float = 0.0,
             queue_cap: int = 0, max_incarnations: int = 0,
             per_wave: bool = False, return_points: bool = False):
    """Run the whole benchmark grid in one jitted sweep; returns row dicts.

    ``wall_s`` in each row is the grid's wall time amortized over its rows
    (the grid runs as one XLA program, so per-point timing does not exist).
    The multi-version ring (``mv_depth``) is only allocated when the grid
    contains an MV mechanism; ``snapshot_age`` (aged reader snapshots —
    mvstore.snapshot_ts) requires an all-MV grid, since only snapshot
    readers have a snapshot to age.  ``arrival_rate > 0`` switches every
    grid point to the open-loop front-end (core/admission.py) — rows then
    carry goodput, the admission counters, and the per-class
    time-to-commit percentiles; queue_cap defaults to 4x the widest lane
    count and max_incarnations to 8 when left at 0.
    """
    from repro.core import types as t
    from repro.core.engine import sweep

    wl = _make_workload(workload, scale=scale, n_keys=n_keys,
                        write_frac=write_frac, ro_frac=ro_frac, theta=theta,
                        scan_frac=scan_frac, scan_len=scan_len)
    need_mv = any(t.CC_IDS[c] in t.MV_CCS for c in ccs)
    if snapshot_age and not all(t.CC_IDS[c] in t.MV_CCS for c in ccs):
        raise ValueError("snapshot_age > 0 needs an all-MV cc grid "
                         "(mvcc/mvocc): single-version mechanisms have no "
                         "snapshots to age")
    if arrival_rate > 0:
        queue_cap = queue_cap or 4 * max(lanes)
        max_incarnations = max_incarnations or 8
    # The base cfg must itself validate: an aged-snapshot grid is all-MV,
    # so anchor it on the first requested mechanism instead of CC_OCC.
    cfg = t.EngineConfig(
        cc=t.CC_IDS[ccs[0]] if snapshot_age else t.CC_OCC,
        lanes=max(lanes), slots=wl.slots,
        n_records=wl.n_records, n_groups=wl.n_groups, n_cols=wl.n_cols,
        n_txn_types=wl.n_txn_types, n_rings=wl.n_rings, backend=backend,
        mv_depth=mv_depth if need_mv else 0, snapshot_age=snapshot_age,
        max_extent=wl.max_extent,
        arrival_rate=arrival_rate, queue_cap=queue_cap,
        max_incarnations=max_incarnations)
    t0 = time.time()
    points = sweep(cfg, wl, waves, ccs=[t.CC_IDS[c] for c in ccs],
                   grans=tuple(grans), lane_counts=tuple(lanes),
                   seeds=(seed,), per_wave=per_wave)
    wall = (time.time() - t0) / max(len(points), 1)
    rows = [_row(workload, t.CC_NAMES[p.cc], p, wall, backend,
                 slots=wl.slots, n_groups=wl.n_groups,
                 mv_depth=cfg.mv_depth, max_extent=cfg.max_extent,
                 bucket_size=cfg.bucket_size)
            for p in points]
    if return_points:
        # (rows, SweepPoints) — the points carry the per-wave timeline the
        # Chrome-trace exporter consumes (analysis/trace.py).
        return rows, points
    return rows


def run_one(workload: str, cc_name: str, gran: int, lanes: int, waves: int,
            *, scale: float = 1.0, n_keys: int = 1_000_000, seed: int = 0,
            backend: str = "jnp", mv_depth: int = 4, snapshot_age: int = 0,
            scan_frac: float = 0.0, scan_len: int = 0,
            arrival_rate: float = 0.0, queue_cap: int = 0,
            max_incarnations: int = 0):
    """Single grid point (one compiled run; prefer run_grid for grids)."""
    from repro.core import types as t
    from repro.core.engine import run

    wl = _make_workload(workload, scale=scale, n_keys=n_keys,
                        scan_frac=scan_frac, scan_len=scan_len)
    if arrival_rate > 0:
        queue_cap = queue_cap or 4 * lanes
        max_incarnations = max_incarnations or 8
    cfg = t.EngineConfig(
        cc=t.CC_IDS[cc_name], lanes=lanes, slots=wl.slots,
        n_records=wl.n_records, n_groups=wl.n_groups, n_cols=wl.n_cols,
        n_txn_types=wl.n_txn_types, granularity=gran, n_rings=wl.n_rings,
        backend=backend,
        mv_depth=mv_depth if t.CC_IDS[cc_name] in t.MV_CCS else 0,
        snapshot_age=snapshot_age, max_extent=wl.max_extent,
        arrival_rate=arrival_rate,
        queue_cap=queue_cap, max_incarnations=max_incarnations)
    from repro.core.backend import kernel_coverage
    t0 = time.time()
    res = run(cfg, wl, n_waves=waves, seed=seed)
    wall = time.time() - t0
    row = {
        "workload": workload, "cc": cc_name, "granularity": gran,
        "lanes": lanes, "waves": waves,
        "commits": res.commits, "aborts": res.aborts,
        "abort_rate": round(res.abort_rate, 4),
        "ro_commits": res.ro_commits, "ro_aborts": res.ro_aborts,
        "ro_abort_rate": round(res.ro_abort_rate, 4),
        "throughput": round(res.throughput, 4),
        "ext_events": res.ext_events,
        "wall_s": round(wall, 2),
        "backend": backend,
        "kernel_ops": kernel_coverage(backend, t.CC_IDS[cc_name]),
        "max_extent": cfg.max_extent,
    }
    if res.abort_causes is not None:
        row["abort_causes"] = {t.CAUSE_NAMES[i]: int(n)
                               for i, n in enumerate(res.abort_causes)}
    row.update(_cost_fields(cc_name, lanes, gran, wl.slots, wl.n_groups,
                            cfg.mv_depth, cfg.max_extent, cfg.bucket_size))
    if res.open_loop:
        row.update({
            "open_loop": True, "goodput": round(res.goodput, 4),
            "offered": res.offered, "admitted": res.admitted,
            "arrival_drops": res.arrival_drops,
            "inc_drops": res.inc_drops,
            "queued_final": res.queued_final,
            "p50_ttc_waves": res.p50_ttc, "p99_ttc_waves": res.p99_ttc,
        })
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("tpcc", "ycsb"), default="tpcc")
    ap.add_argument("--cc", nargs="+",
                    default=["occ", "tictoc", "2pl", "swisstm", "adaptive",
                             "mvcc", "mvocc"])
    ap.add_argument("--granularity", choices=("coarse", "fine", "both"),
                    default="both")
    ap.add_argument("--lanes", type=int, nargs="+", default=[16, 64, 128])
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--n-keys", type=int, default=1_000_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="probe/commit substrate (pallas = TPU kernels, "
                         "interpret mode on CPU)")
    ap.add_argument("--mv-depth", type=int, default=4,
                    help="version-ring depth for mvcc/mvocc grids "
                         "(core/mvstore.py; ignored without an MV cc)")
    ap.add_argument("--snapshot-age", type=int, default=0,
                    help="pin MV reader snapshots this many waves in the "
                         "past (aged readers; ring reclamation aborts fire "
                         "once writers outrun the ring — requires an "
                         "all-mvcc/mvocc --cc list)")
    # None sentinels so the guards below detect flag *presence*, not just
    # non-default values (the --snapshot-age validation pattern).
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop traffic: expected Poisson arrivals per "
                         "wave (capped at the lane width); switches every "
                         "grid point from the closed-loop retry buffer to "
                         "the admission queue (DESIGN.md section 11)")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="admission-queue ring capacity (open loop only; "
                         "default 4x the widest --lanes)")
    ap.add_argument("--max-incarnations", type=int, default=None,
                    help="re-executions allowed per transaction before it "
                         "is dropped and counted (open loop only; "
                         "default 8)")
    ap.add_argument("--write-frac", type=float, default=None,
                    help="YCSB per-op write probability (default 0.5)")
    ap.add_argument("--ro-frac", type=float, default=None,
                    help="YCSB fraction of read-only transactions "
                         "(default 0)")
    ap.add_argument("--theta", type=float, default=None,
                    help="YCSB Zipf skew (default 0.9)")
    ap.add_argument("--scan-frac", type=float, default=None,
                    help="YCSB fraction of short-range-scan transactions "
                         "(YCSB-E style; adds the interval-read txn class "
                         "and switches the engine to extent-carrying ops)")
    ap.add_argument("--scan-len", type=int, default=None,
                    help="interval width of a scan op in records: the YCSB "
                         "scan class's range (default 8; needs "
                         "--scan-frac > 0) or, for TPC-C, switches on the "
                         "Order-status/Stock-level scan classes at this "
                         "stock window")
    ap.add_argument("--json", default=None)
    ap.add_argument("--trace", nargs="?", const="reports/txn_trace.json",
                    default=None, metavar="PATH",
                    help="export the wave-level timeline as Chrome-trace "
                         "JSON (analysis/trace.py; open in chrome://"
                         "tracing or ui.perfetto.dev) — one process row "
                         "per grid point, one slice per wave with commit/"
                         "abort-cause deltas on the simulated-time axis; "
                         "REPRO_TRACE=1 (or =<path>) enables the same "
                         "without a flag")
    args = ap.parse_args(argv)

    ycsb_flags = (args.write_frac, args.ro_frac, args.theta)
    if args.workload == "tpcc" and any(v is not None for v in ycsb_flags):
        ap.error("--write-frac/--ro-frac/--theta shape the ycsb workload "
                 "only; TPC-C's mix is fixed by the standard")
    # Presence validation: each scan flag must name a scan class the
    # chosen workload actually has.  YCSB's class is switched by
    # --scan-frac (with --scan-len as its width); TPC-C's mix is fixed by
    # the standard, so only --scan-len (the Stock-level window) applies.
    if args.scan_frac is not None:
        if args.workload == "tpcc":
            ap.error("--scan-frac shapes the ycsb scan class only; TPC-C's "
                     "mix is fixed by the standard (--scan-len switches on "
                     "its Order-status/Stock-level scans)")
        if not 0 < args.scan_frac <= 1:
            ap.error(f"--scan-frac must be in (0, 1], got {args.scan_frac}")
    if args.scan_len is not None:
        if args.scan_len < 1:
            ap.error(f"--scan-len must be >= 1, got {args.scan_len}")
        if args.workload == "ycsb" and args.scan_frac is None:
            ap.error("--scan-len sizes the ycsb scan class: set "
                     "--scan-frac > 0 to add scan transactions to the mix")
    if args.snapshot_age:
        from repro.core import types as t
        if not all(t.CC_IDS[c] in t.MV_CCS for c in args.cc):
            ap.error("--snapshot-age only ages multi-version snapshots: "
                     "use it with an all-mvcc/mvocc --cc list")
    if args.arrival_rate is None:
        if args.queue_cap is not None or args.max_incarnations is not None:
            ap.error("--queue-cap/--max-incarnations shape the open-loop "
                     "admission queue only: set --arrival-rate > 0 (the "
                     "open-loop switch) to use them")
    elif args.arrival_rate <= 0:
        ap.error(f"--arrival-rate must be > 0 (got {args.arrival_rate}); "
                 "omit the flag for the closed-loop retry buffer")
    trace_path = args.trace
    if trace_path is None:
        env = os.environ.get("REPRO_TRACE", "")
        if env and env != "0":
            trace_path = (env if env not in ("1", "true")
                          else "reports/txn_trace.json")
    grans = {"coarse": (0,), "fine": (1,), "both": (0, 1)}[args.granularity]
    rows, points = run_grid(
        args.workload, args.cc, grans, args.lanes, args.waves,
        scale=args.scale, n_keys=args.n_keys, seed=args.seed,
        backend=args.backend, mv_depth=args.mv_depth,
        snapshot_age=args.snapshot_age,
        write_frac=(0.5 if args.write_frac is None
                    else args.write_frac),
        ro_frac=0.0 if args.ro_frac is None else args.ro_frac,
        theta=0.9 if args.theta is None else args.theta,
        scan_frac=args.scan_frac or 0.0,
        scan_len=args.scan_len or 0,
        arrival_rate=args.arrival_rate or 0.0,
        queue_cap=args.queue_cap or 0,
        max_incarnations=args.max_incarnations or 0,
        per_wave=bool(trace_path), return_points=True)
    for r in rows:
        line = (f"{r['workload']} {r['cc']:9s} "
                f"{'fine' if r['granularity'] else 'coarse'} "
                f"T={r['lanes']:4d}: "
                f"thpt={r['throughput']:8.3f} txn/us  "
                f"abort={100*r['abort_rate']:6.2f}%")
        if r.get("open_loop"):
            line += (f"  goodput={r['goodput']:8.3f} txn/us  "
                     f"p50/p99 ttc={max(r['p50_ttc_waves']):g}/"
                     f"{max(r['p99_ttc_waves']):g} waves")
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    if trace_path:
        from repro.analysis.trace import sweep_trace, write_trace
        d = os.path.dirname(trace_path)
        if d:
            os.makedirs(d, exist_ok=True)
        write_trace(trace_path, sweep_trace(points))
        print(f"wrote Chrome trace -> {trace_path} ({len(points)} grid "
              "points; load in chrome://tracing or ui.perfetto.dev)")


if __name__ == "__main__":
    main()
