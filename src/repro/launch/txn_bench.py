"""Transaction-engine benchmark CLI (the paper's experiments).

    PYTHONPATH=src python -m repro.launch.txn_bench --workload tpcc \
        --cc occ tictoc --granularity both --lanes 16 64 128 --waves 300
"""
from __future__ import annotations

import argparse
import json
import time


def run_one(workload: str, cc_name: str, gran: int, lanes: int, waves: int,
            *, scale: float = 1.0, n_keys: int = 1_000_000, seed: int = 0):
    from repro.core import types as t
    from repro.core.engine import run
    from repro.workloads import TPCCWorkload, YCSBWorkload

    if workload == "tpcc":
        wl = TPCCWorkload.make(n_warehouses=8, scale=scale)
    else:
        wl = YCSBWorkload.make(n_keys=n_keys)
    cfg = t.EngineConfig(
        cc=t.CC_IDS[cc_name], lanes=lanes, slots=wl.slots,
        n_records=wl.n_records, n_groups=wl.n_groups, n_cols=wl.n_cols,
        n_txn_types=wl.n_txn_types, granularity=gran, n_rings=wl.n_rings)
    t0 = time.time()
    res = run(cfg, wl, n_waves=waves, seed=seed)
    wall = time.time() - t0
    return {
        "workload": workload, "cc": cc_name, "granularity": gran,
        "lanes": lanes, "waves": waves,
        "commits": res.commits, "aborts": res.aborts,
        "abort_rate": round(res.abort_rate, 4),
        "throughput": round(res.throughput, 4),
        "ext_events": res.ext_events,
        "wall_s": round(wall, 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("tpcc", "ycsb"), default="tpcc")
    ap.add_argument("--cc", nargs="+",
                    default=["occ", "tictoc", "2pl", "swisstm", "adaptive"])
    ap.add_argument("--granularity", choices=("coarse", "fine", "both"),
                    default="both")
    ap.add_argument("--lanes", type=int, nargs="+", default=[16, 64, 128])
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--n-keys", type=int, default=1_000_000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    grans = {"coarse": [0], "fine": [1], "both": [0, 1]}[args.granularity]
    rows = []
    for gran in grans:
        for cc in args.cc:
            for lanes in args.lanes:
                r = run_one(args.workload, cc, gran, lanes, args.waves,
                            scale=args.scale, n_keys=args.n_keys)
                rows.append(r)
                print(f"{r['workload']} {r['cc']:9s} "
                      f"{'fine' if gran else 'coarse'} T={lanes:4d}: "
                      f"thpt={r['throughput']:8.3f} txn/us  "
                      f"abort={100*r['abort_rate']:6.2f}%")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
