"""End-to-end training driver: a ~100M-parameter dense LM trained for a few
hundred steps on whatever devices are visible (CPU in this container), with
checkpointing and fault-tolerant resume — the full production path at toy
scale.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data import make_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainRun, run_supervised
from repro.optim import AdamW

# ~100M params: 12 x (d=640, H=10, kv=5, F=2560) + 48k vocab
CFG_100M = ModelConfig(
    name="demo-100m", family="dense",
    n_layers=12, d_model=640, n_heads_raw=10, n_kv=5, d_head=64,
    d_ff=2560, vocab_raw=48_000,
    rope_theta=10_000.0, head_pad=1,
    param_dtype="float32", adam_master_f32=False,
    n_micro=1, remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--out", default="reports/train_lm_loss.json")
    args = ap.parse_args()

    cfg = CFG_100M
    n = cfg.param_count(padded=True)
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    mesh = make_host_mesh()
    shape = ShapeSpec("demo", "train", args.seq, args.batch)
    opt = AdamW.from_config(cfg, peak_lr=6e-4, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 5))
    run = TrainRun(
        cfg=cfg, mesh=mesh, optimizer=opt, shape=shape,
        ckpt=CheckpointManager(args.ckpt_dir, interval=100,
                               fingerprint=cfg.name),
        log_every=10)

    t0 = time.time()
    _, _, losses, restarts = run_supervised(run, args.steps)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[train_lm] {dt:.0f}s wall ({tok_s:.0f} tok/s), "
          f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"losses": losses, "wall_s": dt, "params": n}, f)
    assert losses[-1][1] < losses[0][1], "loss must decrease"


if __name__ == "__main__":
    main()
