"""Quickstart: the paper's central result in one minute.

    PYTHONPATH=src python examples/quickstart.py

Runs TPC-C (8 warehouses, 96 simulated threads) under OCC with coarse
(one timestamp per row) vs fine (the paper's two-timestamp split) version
timestamps, and under TicToc with coarse timestamps — showing that plain OCC
with fine-grained timestamps beats the fancier mechanism.
"""
import sys

sys.path.insert(0, "src")

from repro.core import types as t
from repro.core.engine import run
from repro.workloads import TPCCWorkload


def main():
    wl = TPCCWorkload.make(n_warehouses=8, scale=0.5)
    T, waves = 96, 200

    def go(cc, gran):
        cfg = t.EngineConfig(
            cc=cc, lanes=T, slots=wl.slots, n_records=wl.n_records,
            n_groups=wl.n_groups, n_cols=wl.n_cols,
            n_txn_types=wl.n_txn_types, granularity=gran,
            n_rings=wl.n_rings)
        return run(cfg, wl, n_waves=waves, seed=0)

    print(f"TPC-C, 8 warehouses, {T} simulated threads, {waves} waves\n")
    occ_c = go(t.CC_OCC, 0)
    occ_f = go(t.CC_OCC, 1)
    tic_c = go(t.CC_TICTOC, 0)
    rows = [("OCC, coarse timestamps", occ_c),
            ("OCC, fine timestamps  ", occ_f),
            ("TicToc, coarse        ", tic_c)]
    for name, r in rows:
        print(f"  {name}: {r.throughput:7.2f} txn/us   "
              f"abort rate {100*r.abort_rate:5.2f}%")
    print(f"\nfine-grained timestamps cut OCC's abort rate "
          f"{occ_c.abort_rate/max(occ_f.abort_rate,1e-9):.0f}x and "
          f"outperform TicToc by {occ_f.throughput/tic_c.throughput:.2f}x "
          f"(the paper's headline: 1.37x at 96 threads).")


if __name__ == "__main__":
    main()
