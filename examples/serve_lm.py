"""Serving with a versioned session store — the paper's mechanism in the
serving control plane.

    PYTHONPATH=src python examples/serve_lm.py

The server keeps a *session directory*: one row per session with columns
split exactly like the paper's District rows:

  group 0 (rarely updated): model id, adapter id, priority class — read by
          every routing/admission decision;
  group 1 (hot):            decode cursor, kv-page head, token count —
          written by every decode batch.

Admission control runs as optimistic transactions against this table while
decode batches bump the hot columns.  With one timestamp per row, every
admission read conflicts falsely with concurrent cursor bumps; with the
paper's two-group timestamps the conflicts vanish.  The demo measures both,
then serves real tokens through the prefill/decode path of a smoke-size LM.
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import types as t
from repro.core.engine import run as engine_run
from repro.core.types import StoreState, TxnBatch, store_init
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import serve

G_IDENTITY, G_CURSOR = 0, 1


@dataclasses.dataclass(frozen=True)
class SessionStoreWorkload:
    """Admission reads identity columns; decode batches ADD to cursors."""
    n_sessions: int = 4096
    ops_per_txn: int = 8
    n_groups: int = 2
    n_rings: int = 1
    n_txn_types: int = 2          # 0 = admission/routing, 1 = decode bump

    @property
    def n_records(self):
        return self.n_sessions

    @property
    def n_cols(self):
        return 4

    @property
    def slots(self):
        return self.ops_per_txn

    def init_store(self, track_values=False) -> StoreState:
        return store_init(self.n_records, self.n_groups,
                          self.n_cols if track_values else 0)

    def gen(self, rng, wave, lanes, ring_tails):
        K = self.ops_per_txn
        r1, r2, r3 = jax.random.split(rng, 3)
        # hot sessions: decode batches hammer a small active set
        active = 64
        sess = jax.random.randint(r1, (lanes, K), 0, active)
        is_decode = (jax.random.uniform(r2, (lanes,)) < 0.5)
        kind = jnp.where(is_decode[:, None], t.ADD, t.READ)
        group = jnp.where(is_decode[:, None], G_CURSOR, G_IDENTITY)
        batch = TxnBatch(
            op_key=sess.astype(jnp.int32),
            op_group=group.astype(jnp.int32),
            op_col=jnp.zeros((lanes, K), jnp.int32),
            op_kind=kind.astype(jnp.int32),
            op_val=jnp.ones((lanes, K), jnp.float32),
            txn_type=is_decode.astype(jnp.int32),
            n_ops=jnp.full((lanes,), K, jnp.int32))
        return batch, ring_tails


def main():
    wl = SessionStoreWorkload()
    print("== session directory: OCC coarse vs fine timestamps ==")
    for gran, name in ((0, "coarse (1 ts/row) "), (1, "fine (2 ts/row)  ")):
        cfg = t.EngineConfig(
            cc=t.CC_OCC, lanes=64, slots=wl.slots, n_records=wl.n_records,
            n_groups=wl.n_groups, n_cols=wl.n_cols,
            n_txn_types=wl.n_txn_types, granularity=gran)
        r = engine_run(cfg, wl, n_waves=150, seed=0)
        print(f"  {name}: {r.throughput:7.2f} txn/us, "
              f"abort {100*r.abort_rate:5.2f}%  "
              f"(admission commits: {r.commits_by_type[0]})")
    print("  -> identity reads never truly conflict with cursor bumps; "
          "fine timestamps remove the false aborts.\n")

    print("== serving tokens (smoke-size qwen3 backbone) ==")
    cfg = configs.get_smoke("qwen3-32b")
    mesh = make_host_mesh()
    tokens, tp, td = serve(cfg, mesh, n_requests=4, prompt_len=24, gen=12)
    print(f"  prefill {tp*1e3:.0f}ms, 12 tokens/req in {td*1e3:.0f}ms")
    print(f"  request 0 continuation: {tokens[0].tolist()}")


if __name__ == "__main__":
    main()
