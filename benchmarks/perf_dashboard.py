"""Perf dashboard: aggregate benchmark JSON rows into one markdown table
(ROADMAP item: "wire BENCH_*.json kernel_ops attribution into a perf
dashboard").

Sources: every ``BENCH_*.json`` in the repo root (the ad-hoc bench
trajectory files, .gitignored) plus ``reports/*.json`` (the curated figure
sweeps).  Two row shapes are understood:

- mechanism rows (txn_bench / figure sweeps: ``cc`` key) — summarized per
  (workload, cc, granularity, backend) at their peak-throughput lane
  count, with abort rate and per-op pallas/xla kernel attribution;
- distributed rows (txn_scaling: ``shards`` key) — waves/s, collective
  bytes per wave, and the shard-local op attribution.

    PYTHONPATH=src python -m benchmarks.perf_dashboard \
        [paths-or-globs ...] [--out reports/perf_dashboard.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_GLOBS = ("BENCH_*.json", "reports/*.json")


def load_rows(patterns=DEFAULT_GLOBS) -> tuple[list, list]:
    """Expand globs, read every JSON list, split (mechanism, distributed)
    rows; anything else (unknown schema) is skipped."""
    mech, dist = [], []
    for pat in patterns:
        for path in sorted(glob.glob(pat)):
            try:
                with open(path) as f:
                    rows = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if not isinstance(rows, list):
                continue
            for r in rows:
                if not isinstance(r, dict):
                    continue
                r = dict(r, _src=os.path.basename(path))
                if "cc" in r and "throughput" in r:
                    mech.append(r)
                elif "shards" in r:
                    dist.append(r)
    return mech, dist


def _ops_cell(kernel_ops: dict) -> str:
    """Compress a {op: "pallas"|"xla"} map: '4/4 pallas' or 'xla' or a
    mixed listing (mixed should not happen — it would mean a partial
    fallback, worth seeing loudly)."""
    if not kernel_ops:
        return "—"
    engines = set(kernel_ops.values())
    if engines == {"pallas"}:
        return f"{len(kernel_ops)}/{len(kernel_ops)} pallas"
    if engines == {"xla"}:
        return "xla"
    return ", ".join(f"{op}:{eng}" for op, eng in sorted(kernel_ops.items()))


def _gran(g) -> str:
    return "fine" if g else "coarse"


def render_markdown(mech: list, dist: list) -> str:
    out = ["# Perf dashboard", "",
           "Aggregated from benchmark JSON rows (BENCH_*.json + "
           "reports/*.json); regenerate with "
           "`PYTHONPATH=src python -m benchmarks.perf_dashboard`.", ""]

    if mech:
        groups: dict = {}
        for r in mech:
            key = (r.get("workload", "?"), r["cc"], r.get("granularity", 1),
                   r.get("backend", "?"))
            best = groups.get(key)
            if best is None or r["throughput"] > best["throughput"]:
                groups[key] = r
        out += ["## Mechanisms (peak-throughput point per "
                "workload × cc × granularity × backend)", "",
                "| workload | cc | granularity | backend | peak thpt "
                "(txn/us) | @lanes | abort rate | kernel ops | source |",
                "|---|---|---|---|---|---|---|---|---|"]
        for key in sorted(groups):
            r = groups[key]
            out.append(
                f"| {key[0]} | {key[1]} | {_gran(key[2])} | {key[3]} "
                f"| {r['throughput']:.3f} | {r.get('lanes', '?')} "
                f"| {100 * r.get('abort_rate', 0):.2f}% "
                f"| {_ops_cell(r.get('kernel_ops', {}))} "
                f"| {r['_src']} |")
        out.append("")

    if dist:
        out += ["## Distributed engine (txn_scaling; shards=0 = local "
                "sweep() anchor)", "",
                "| shards | waves/s | commits | coll KiB/wave | backend "
                "| kernel ops | source |",
                "|---|---|---|---|---|---|---|"]
        for r in sorted(dist, key=lambda r: (r["_src"], r["shards"])):
            out.append(
                f"| {r['shards']} | {r.get('waves_per_s', 0):.1f} "
                f"| {r.get('commits', '?')} "
                f"| {r.get('coll_bytes_per_wave', 0) / 1024:.1f} "
                f"| {r.get('backend', '?')} "
                f"| {_ops_cell(r.get('kernel_ops', {}))} | {r['_src']} |")
        out.append("")

    if not mech and not dist:
        out += ["No benchmark rows found — run `python -m "
                "repro.launch.txn_bench --json BENCH_x.json` or any "
                "`benchmarks/` figure script first.", ""]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("patterns", nargs="*", default=list(DEFAULT_GLOBS),
                    help="JSON files or globs (default: BENCH_*.json "
                         "reports/*.json)")
    ap.add_argument("--out", default="reports/perf_dashboard.md")
    args = ap.parse_args(argv)
    mech, dist = load_rows(tuple(args.patterns))
    md = render_markdown(mech, dist)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    print(f"[saved] {args.out}  ({len(mech)} mechanism rows, "
          f"{len(dist)} distributed rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
