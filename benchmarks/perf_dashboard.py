"""Perf dashboard: aggregate benchmark JSON rows into one markdown table
(ROADMAP item: "wire BENCH_*.json kernel_ops attribution into a perf
dashboard").

Sources: every ``BENCH_*.json`` in the repo root (the ad-hoc bench
trajectory files, .gitignored) plus ``reports/*.json`` (the curated figure
sweeps).  Two row shapes are understood:

- mechanism rows (txn_bench / figure sweeps: ``cc`` key) — summarized per
  (workload, cc, granularity, backend) at their peak-throughput lane
  count, with abort rate, the per-cause abort breakdown, the analytic
  bytes/flops-per-txn + fraction-of-roofline cost model
  (analysis/txn_cost.py), and per-op pallas/xla kernel attribution;
- distributed rows (txn_scaling: ``shards`` key) — waves/s, pipeline
  depth, commit and read-only splits, abort causes, collective bytes per
  wave (HLO-parsed) plus the modeled wire split (route / bit-packed
  verdict bytes, with the retired 1-byte-per-op verdict baseline), and
  the shard-local op attribution.  Distributed rows are DEDUPED by
  (cc, shards, depth, backend): txn_scaling appends on every run, so
  only the latest row per configuration renders.

Partial/truncated rows of a known shape (a killed bench run, a hand-edited
file) are never fatal: they are skipped with a warning line in the report
instead of aborting the whole dashboard.

    PYTHONPATH=src python -m benchmarks.perf_dashboard \
        [paths-or-globs ...] [--out reports/perf_dashboard.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_GLOBS = ("BENCH_*.json", "reports/*.json")


def _num(x) -> bool:
    """True for real JSON numbers (bool is an int in Python — excluded)."""
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _coerce(x):
    """Numeric value as float, or None.  Numeric STRINGS coerce too:
    hand-edited or CSV-converted bench files store "12.3", and comparing
    such strings lexically would rank "0.9" above "12.3" — every
    comparison in this module must go through here, never compare raw
    field values."""
    if _num(x):
        return float(x)
    if isinstance(x, str):
        try:
            return float(x)
        except ValueError:
            return None
    return None


def _fnum(r: dict, key: str, default=0):
    """Numeric field (coerced) or ``default`` — malformed values never
    crash a cell."""
    v = _coerce(r.get(key))
    return default if v is None else v


def _mech_problem(r) -> str | None:
    """Why a mechanism-shaped row can't be summarized (None = fine)."""
    if not isinstance(r, dict):
        return "not a JSON object"
    if _coerce(r.get("throughput")) is None:
        return "missing/non-numeric 'throughput'"
    return None


def _dist_problem(r) -> str | None:
    """Why a distributed-shaped row can't be summarized (None = fine)."""
    if not isinstance(r, dict):
        return "not a JSON object"
    if _coerce(r.get("shards")) is None:
        return "missing/non-numeric 'shards'"
    return None


def load_rows(patterns=DEFAULT_GLOBS) -> tuple[list, list]:
    """Expand globs, read every JSON list, split (mechanism, distributed)
    rows by shape (``cc`` vs ``shards`` key); rows of neither shape
    (unknown schema) are skipped.  Shape-matched rows are NOT validated
    here — render_markdown skips malformed ones with a report warning."""
    mech, dist = [], []
    for pat in patterns:
        for path in sorted(glob.glob(pat)):
            try:
                with open(path) as f:
                    rows = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if not isinstance(rows, list):
                continue
            for r in rows:
                if not isinstance(r, dict):
                    continue
                r = dict(r, _src=os.path.basename(path))
                # shards discriminates first: distributed rows also carry
                # a cc field since the MV wave went sharded
                if "shards" in r:
                    dist.append(r)
                elif "cc" in r:
                    mech.append(r)
    return mech, dist


def _ops_cell(kernel_ops: dict) -> str:
    """Compress a {op: "pallas"|"xla"} map: '4/4 pallas' or 'xla' or a
    mixed listing (mixed should not happen — it would mean a partial
    fallback, worth seeing loudly)."""
    if not kernel_ops:
        return "—"
    engines = set(kernel_ops.values())
    if engines == {"pallas"}:
        return f"{len(kernel_ops)}/{len(kernel_ops)} pallas"
    if engines == {"xla"}:
        return "xla"
    return ", ".join(f"{op}:{eng}" for op, eng in sorted(kernel_ops.items()))


def _gran(g) -> str:
    return "fine" if g else "coarse"


def _ttc_cell(v) -> str:
    """Per-txn-class time-to-commit list -> 'a/b/c' cell ('—' when
    absent/malformed)."""
    if isinstance(v, (list, tuple)) and v:
        nums = [_coerce(x) for x in v]
        if all(n is not None for n in nums):
            return "/".join(f"{n:g}" for n in nums)
    n = _coerce(v)
    return f"{n:g}" if n is not None else "—"


def _src_of(r) -> str:
    return r.get("_src", "?") if isinstance(r, dict) else "?"


#: types.CAUSE_NAMES order, duplicated here so the dashboard stays
#: import-free of jax-loading modules (it renders list-shaped cause rows
#: from txn_scaling too).
_CAUSE_ORDER = ("inc_cap", "capacity", "stale_snapshot", "lock_wound",
                "ww", "read_val", "phantom")


def _causes_cell(v) -> str:
    """Abort-cause breakdown cell: nonzero '<cause>:<n>' entries in code
    order.  Accepts the bench rows' name-keyed dict or txn_scaling's
    code-ordered list; '—' when absent/malformed, 'none' when all zero.
    Pre-scan rows (before the phantom cause existed) simply lack the
    trailing entry — both shapes tolerate that without warning."""
    if isinstance(v, dict):
        pairs = [(k, _coerce(v.get(k))) for k in _CAUSE_ORDER if k in v]
    elif isinstance(v, (list, tuple)):
        pairs = list(zip(_CAUSE_ORDER, (_coerce(x) for x in v)))
    else:
        return "—"
    if not pairs or any(n is None for _, n in pairs):
        return "—"
    nz = [f"{k}:{n:g}" for k, n in pairs if n]
    return " ".join(nz) if nz else "none"


def _scan_cell(r: dict) -> str:
    """Interval-read shape of the row: 'ext=N' (plus the workload's
    scan_frac x scan_len when the row carries them, e.g. scan_mix.py
    rows).  Pre-scan JSON rows have none of these fields and extent-1
    rows are pure point workloads — both render '—' (the default), never
    a warning."""
    ext = _coerce(r.get("max_extent"))
    if ext is None or ext <= 1:
        return "—"
    cell = f"ext={ext:g}"
    sf, sl = _coerce(r.get("scan_frac")), _coerce(r.get("scan_len"))
    if sf is not None and sl is not None:
        cell += f" ({sf:g}×{sl:g})"
    return cell


def _roofline_cell(r: dict) -> str:
    """'0.10% (memory)' — the mechanism's fraction of the modeled chip
    roofline and which roof binds (analysis/txn_cost.py)."""
    frac = _coerce(r.get("roofline_frac"))
    if frac is None:
        return "—"
    bound = r.get("roofline_bound", "?")
    return f"{100 * frac:.2f}% ({bound})"


def _per_txn_cell(r: dict, key: str) -> str:
    v = _coerce(r.get(key))
    return "—" if v is None else f"{v:g}"


def _dma_rows_cell(r: dict) -> str:
    """'1024 (/3 vs unfused)' — the fused probe chain's modeled touched-row
    DMA visits per wave next to the unfused-chain cut
    (analysis/txn_cost.py probe_chain); '—' outside the probe family."""
    rows = _coerce(r.get("dma_rows_per_wave"))
    if rows is None:
        return "—"
    unf = _coerce(r.get("dma_rows_per_wave_unfused"))
    if unf and rows:
        return f"{rows:g} (/{unf / rows:g} vs unfused)"
    return f"{rows:g}"


def render_markdown(mech: list, dist: list) -> str:
    out = ["# Perf dashboard", "",
           "Aggregated from benchmark JSON rows (BENCH_*.json + "
           "reports/*.json); regenerate with "
           "`PYTHONPATH=src python -m benchmarks.perf_dashboard`.", ""]

    # Partial/malformed rows (truncated bench files, killed runs) are
    # skipped and reported, never fatal.
    skipped: list[tuple[str, str]] = []
    mech_ok, dist_ok = [], []
    for r in mech:
        p = _mech_problem(r)
        if p:
            skipped.append((_src_of(r), f"mechanism row: {p}"))
        else:
            mech_ok.append(r)
    for r in dist:
        p = _dist_problem(r)
        if p:
            skipped.append((_src_of(r), f"distributed row: {p}"))
        else:
            dist_ok.append(r)

    if mech_ok:
        groups: dict = {}
        for r in mech_ok:
            # max_extent separates scan mixes from point mixes (they are
            # different workloads, not competing lane counts); pre-scan
            # rows default to the point shape, extent 1.
            key = (r.get("workload", "?"), r.get("cc", "?"),
                   r.get("granularity", 1), r.get("backend", "?"),
                   _fnum(r, "max_extent", 1))
            best = groups.get(key)
            # Coerced comparison: string throughputs ("0.9" vs "12.3")
            # must rank numerically, never lexically.
            if best is None or (_fnum(r, "throughput")
                                > _fnum(best, "throughput")):
                groups[key] = r
        out += ["## Mechanisms (peak-throughput point per "
                "workload × cc × granularity × backend × scan shape)", "",
                "B/txn and flop/txn are the analytic per-transaction "
                "roofline cost model (analysis/txn_cost.py) at the peak "
                "point's wave shape; roofline = fraction of the modeled "
                "chip's binding roof; abort causes sum exactly to the "
                "abort count (core/types.py ABORT_CAUSE taxonomy); "
                "launches/wave and DMA rows/wave are the fused probe "
                "chain's modeled launch count and touched-row visits, "
                "with the cut vs the unfused chain (probe-family "
                "mechanisms only).", "",
                "| workload | cc | granularity | backend | peak thpt "
                "(txn/us) | @lanes | abort rate | abort causes | scan "
                "| B/txn "
                "| flop/txn | roofline | launches/wave | DMA rows/wave "
                "| kernel ops | source |",
                "|---|---|---|---|---|---|---|---|---|---|---|---|---|"
                "---|---|---|"]
        for key in sorted(groups, key=str):
            r = groups[key]
            out.append(
                f"| {key[0]} | {key[1]} | {_gran(key[2])} | {key[3]} "
                f"| {_fnum(r, 'throughput'):.3f} | {r.get('lanes', '?')} "
                f"| {100 * _fnum(r, 'abort_rate'):.2f}% "
                f"| {_causes_cell(r.get('abort_causes'))} "
                f"| {_scan_cell(r)} "
                f"| {_per_txn_cell(r, 'bytes_per_txn')} "
                f"| {_per_txn_cell(r, 'flops_per_txn')} "
                f"| {_roofline_cell(r)} "
                f"| {_per_txn_cell(r, 'launches_per_wave')} "
                f"| {_dma_rows_cell(r)} "
                f"| {_ops_cell(r.get('kernel_ops', {}))} "
                f"| {_src_of(r)} |")
        out.append("")

    open_rows = [r for r in mech_ok if r.get("open_loop")]
    if open_rows:
        groups = {}
        for r in open_rows:
            key = (r.get("workload", "?"), r.get("cc", "?"),
                   r.get("granularity", 1), r.get("backend", "?"),
                   _fnum(r, "max_extent", 1))
            best = groups.get(key)
            if best is None or (_fnum(r, "goodput")
                                > _fnum(best, "goodput")):
                groups[key] = r
        out += ["## Open-loop latency (peak-goodput point per "
                "workload × cc × granularity × backend)", "",
                "Goodput = unique committed txns per simulated us; "
                "time-to-commit percentiles are per txn class, in waves "
                "from first admission to commit (DESIGN.md section 11).",
                "",
                "| workload | cc | granularity | backend | goodput "
                "(txn/us) | p50 ttc (waves) | p99 ttc (waves) "
                "| inc drops | arrival drops | source |",
                "|---|---|---|---|---|---|---|---|---|---|"]
        for key in sorted(groups, key=str):
            r = groups[key]
            out.append(
                f"| {key[0]} | {key[1]} | {_gran(key[2])} | {key[3]} "
                f"| {_fnum(r, 'goodput'):.3f} "
                f"| {_ttc_cell(r.get('p50_ttc_waves'))} "
                f"| {_ttc_cell(r.get('p99_ttc_waves'))} "
                f"| {r.get('inc_drops', '?')} "
                f"| {r.get('arrival_drops', '?')} "
                f"| {_src_of(r)} |")
        out.append("")

    if dist_ok:
        # Dedupe: one row per CONFIG, last in file order wins (= the most
        # recent run's numbers).  The config key is everything that makes
        # a txn_scaling grid point distinct — mechanism, shard count,
        # pipeline depth, backend, plus the open-loop family's mode and
        # granularity; without mode/granularity in the key (and in the cc
        # cell below) the closed-loop row and both open-loop rows of one
        # (cc, shards, depth) rendered as three identical-looking stacked
        # rows.
        latest: dict = {}
        for r in dist_ok:
            key = (r.get("mode", ""), r.get("granularity"),
                   r.get("cc", "occ"), _fnum(r, "shards"),
                   _fnum(r, "pipeline_depth", 0), r.get("backend", "?"))
            latest[key] = r
        dist_rows = list(latest.values())
        out += ["## Distributed engine (txn_scaling; shards=0 = local "
                "sweep() anchor)", "",
                "depth = software-pipeline depth of the scanned runner "
                "(1 = synchronous three-exchange wave, >= 2 = ONE fused "
                "all_to_all per wave); wire KiB/wave = modeled exchange "
                "payload per shard; verdict B/wave shows the bit-packed "
                "wire next to the retired 1-byte-per-op baseline; one row "
                "per config — cc × shards × depth × backend (× mode × "
                "granularity for the open-loop family, marked in the cc "
                "column) — latest run wins.", "",
                "| shards | cc | depth | waves/s | commits | ro commits "
                "| ro aborts | coll KiB/wave | wire KiB/wave | verdict "
                "B/wave (packed/legacy) | abort causes | backend "
                "| kernel ops | source |",
                "|---|---|---|---|---|---|---|---|---|---|---|---|---|"
                "---|"]
        for r in sorted(dist_rows,
                        key=lambda r: (_src_of(r), r.get("cc", "occ"),
                                       r["shards"],
                                       _fnum(r, "pipeline_depth", 0))):
            depth = _coerce(r.get("pipeline_depth"))
            wire = _coerce(r.get("wire_bytes_per_wave"))
            vp = _coerce(r.get("verdict_bytes_per_wave"))
            vl = _coerce(r.get("verdict_bytes_per_wave_legacy"))
            cc_cell = r.get("cc", "occ")
            if r.get("mode") == "open_loop":
                cc_cell += f" open/{_gran(r.get('granularity', 1))}"
            out.append(
                f"| {r['shards']} | {cc_cell} "
                f"| {'—' if depth is None else f'{depth:g}'} "
                f"| {_fnum(r, 'waves_per_s'):.1f} "
                f"| {r.get('commits', '?')} "
                f"| {r.get('ro_commits', '?')} "
                f"| {r.get('ro_aborts', '?')} "
                f"| {_fnum(r, 'coll_bytes_per_wave') / 1024:.1f} "
                f"| {'—' if wire is None else f'{wire / 1024:.1f}'} "
                f"| {'—' if vp is None or vl is None else f'{vp:g} / {vl:g}'} "
                f"| {_causes_cell(r.get('abort_causes'))} "
                f"| {r.get('backend', '?')} "
                f"| {_ops_cell(r.get('kernel_ops', {}))} | {_src_of(r)} |")
        out.append("")

    if skipped:
        out += [f"## Skipped rows ({len(skipped)})", "",
                "Malformed/partial rows found while aggregating — "
                "regenerate their source files:", ""]
        out += [f"- ⚠ `{src}`: {why}" for src, why in skipped]
        out.append("")

    if not mech_ok and not dist_ok and not skipped:
        out += ["No benchmark rows found — run `python -m "
                "repro.launch.txn_bench --json BENCH_x.json` or any "
                "`benchmarks/` figure script first.", ""]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("patterns", nargs="*", default=list(DEFAULT_GLOBS),
                    help="JSON files or globs (default: BENCH_*.json "
                         "reports/*.json)")
    ap.add_argument("--out", default="reports/perf_dashboard.md")
    args = ap.parse_args(argv)
    mech, dist = load_rows(tuple(args.patterns))
    md = render_markdown(mech, dist)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    print(f"[saved] {args.out}  ({len(mech)} mechanism rows, "
          f"{len(dist)} distributed rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
