"""Paper section 4.3 abort-rate numbers, plus the multi-version extension.

    TPC-C coarse @64:  TicToc 9.79%  vs OCC 17.57%
    TPC-C @128:        OCC coarse 30.91% -> fine 1.75% (largest drop)

Beyond-paper row set (DESIGN.md section 9): a write-heavy, high-contention
YCSB mix with a read-only client class.  Multi-version snapshot reads never
abort a read-only transaction (mvcc/mvocc ro_abort_rate = 0, any
granularity), while single-version coarse OCC aborts them on any
conflicting concurrent write — so the table answers "what do the fancier
readers-never-block schemes buy, and does timestamp granularity still
matter once they do?" (it does: the update side keeps the fine-vs-coarse
gap).
"""
from __future__ import annotations

import argparse

from benchmarks.common import one, save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--waves", type=int, default=400)
    ap.add_argument("--json", default="reports/abort_rates.json")
    args = ap.parse_args(argv)

    scale = 1.0
    rows = sweep("tpcc", lanes=[64, 128], waves=args.waves, scale=scale,
                 quiet=True, warm=True)
    save_rows(rows, args.json)

    print("lanes  cc        gran    abort%")
    for T in (64, 128):
        for cc in ("occ", "tictoc", "2pl", "swisstm", "adaptive",
                   "mvcc", "mvocc"):
            for g in (0, 1):
                r = one(rows, cc=cc, granularity=g, lanes=T)
                print(f"{T:5d}  {cc:9s} {'fine' if g else 'coarse':6s} "
                      f"{100*r['abort_rate']:7.2f}")
    o64c = one(rows, cc="occ", granularity=0, lanes=64)["abort_rate"]
    t64c = one(rows, cc="tictoc", granularity=0, lanes=64)["abort_rate"]
    o128c = one(rows, cc="occ", granularity=0, lanes=128)["abort_rate"]
    o128f = one(rows, cc="occ", granularity=1, lanes=128)["abort_rate"]
    print(f"\ncoarse @64: TicToc {100*t64c:.2f}% < OCC {100*o64c:.2f}% "
          f"(paper: 9.79% vs 17.57%)")
    print(f"OCC @128: coarse {100*o128c:.2f}% -> fine {100*o128f:.2f}% "
          f"(paper: 30.91% -> 1.75%)")

    # ---- multi-version row set: read-only abort rates under write-heavy,
    # high-contention YCSB (Zipf 0.9, 80% writes, 20% read-only scans) ----
    n_keys = 1_000_000 if args.full else 100_000
    mv_rows = sweep("ycsb", ccs=["occ", "mvcc", "mvocc"], lanes=[64, 128],
                    waves=args.waves, n_keys=n_keys, write_frac=0.8,
                    ro_frac=0.2, theta=0.9, quiet=True, warm=True)
    for r in mv_rows:
        r["variant"] = "ycsb_writeheavy_ro"
    save_rows(rows + mv_rows, args.json)

    print("\nread-only clients, YCSB write-heavy (80% writes, Zipf 0.9):")
    print("lanes  cc        gran    abort%  ro_abort%")
    for T in (64, 128):
        for cc in ("occ", "mvcc", "mvocc"):
            for g in (0, 1):
                r = one(mv_rows, cc=cc, granularity=g, lanes=T)
                print(f"{T:5d}  {cc:9s} {'fine' if g else 'coarse':6s} "
                      f"{100*r['abort_rate']:7.2f} {100*r['ro_abort_rate']:9.2f}")
    occ_ro = one(mv_rows, cc="occ", granularity=0, lanes=128)["ro_abort_rate"]
    mv_ro = one(mv_rows, cc="mvcc", granularity=0, lanes=128)["ro_abort_rate"]
    print(f"\nread-only abort @128 coarse: OCC {100*occ_ro:.2f}% vs "
          f"MVCC {100*mv_ro:.2f}% (snapshot readers never abort)")
    return rows + mv_rows


if __name__ == "__main__":
    main()
