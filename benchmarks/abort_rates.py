"""Paper section 4.3 abort-rate numbers.

    TPC-C coarse @64:  TicToc 9.79%  vs OCC 17.57%
    TPC-C @128:        OCC coarse 30.91% -> fine 1.75% (largest drop)
"""
from __future__ import annotations

import argparse

from benchmarks.common import one, save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--waves", type=int, default=400)
    ap.add_argument("--json", default="reports/abort_rates.json")
    args = ap.parse_args(argv)

    scale = 1.0
    rows = sweep("tpcc", lanes=[64, 128], waves=args.waves, scale=scale,
                 quiet=True)
    save_rows(rows, args.json)

    print("lanes  cc        gran    abort%")
    for T in (64, 128):
        for cc in ("occ", "tictoc", "2pl", "swisstm", "adaptive"):
            for g in (0, 1):
                r = one(rows, cc=cc, granularity=g, lanes=T)
                print(f"{T:5d}  {cc:9s} {'fine' if g else 'coarse':6s} "
                      f"{100*r['abort_rate']:7.2f}")
    o64c = one(rows, cc="occ", granularity=0, lanes=64)["abort_rate"]
    t64c = one(rows, cc="tictoc", granularity=0, lanes=64)["abort_rate"]
    o128c = one(rows, cc="occ", granularity=0, lanes=128)["abort_rate"]
    o128f = one(rows, cc="occ", granularity=1, lanes=128)["abort_rate"]
    print(f"\ncoarse @64: TicToc {100*t64c:.2f}% < OCC {100*o64c:.2f}% "
          f"(paper: 9.79% vs 17.57%)")
    print(f"OCC @128: coarse {100*o128c:.2f}% -> fine {100*o128f:.2f}% "
          f"(paper: 30.91% -> 1.75%)")
    return rows


if __name__ == "__main__":
    main()
