"""Beyond-paper: distributed txn-engine scaling (the paper's section 5:
"perform similar evaluations on distributed CC mechanisms").

Runs the shard_map wave on 1/2/4/8 host devices (same *global* lane and
record counts), measuring committed txns per second of wall time and the
per-wave collective bytes — the weak-scaling story of the routed engine —
for BOTH the single-version mechanism (occ) and the sharded multi-version
ring (mvcc: snapshot reads + first-committer-wins over the distributed
version ring of core/mvstore.py).  A ``shards=0`` anchor row first runs
the single-device engine through the vmapped ``sweep()`` grid runner at
the same global lane count, so the table reads "local engine vs N-shard
routed engine".  ``REPRO_TXN_BACKEND`` ("jnp" | "pallas") selects the
kernel-backend surface for BOTH engines — the distributed wave routes its
shard-local route/claim/probe/gather/install through core/backend.py like
the local one — and every row records the resolved backend, the per-op
kernel attribution, and the read-only commit/abort split the distributed
stats vector carries (core/distributed.py STATS_LEN layout).

    PYTHONPATH=src python -m benchmarks.txn_scaling
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

PROG = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, "src")
    from repro.core import distributed as D, types as t
    from repro.analysis.roofline import collective_bytes_from_hlo

    GLOBAL_LANES, K, N, WAVES = 256, 16, 1_000_000, 30
    BACKEND = os.environ.get("REPRO_TXN_BACKEND", "jnp")
    rows = []

    # shards=0 anchor: the local (single-device) engine at the same global
    # lane count, via the one-XLA-program sweep() grid runner.
    from repro.core import types as t
    from repro.core.backend import kernel_coverage
    from repro.core.engine import sweep as engine_sweep
    from repro.workloads import YCSBWorkload
    wl = YCSBWorkload.make(n_keys=N)
    cfg = t.EngineConfig(cc=t.CC_OCC, lanes=GLOBAL_LANES, slots=wl.slots,
                         n_records=wl.n_records, n_groups=wl.n_groups,
                         n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                         n_rings=wl.n_rings, backend=BACKEND)
    # Warm call first: the timed call then hits the XLA executable cache and
    # measures (re-trace +) waves rather than a full compile.
    engine_sweep(cfg, wl, WAVES, ccs=[t.CC_OCC], grans=(1,),
                 lane_counts=(GLOBAL_LANES,))
    t0 = time.time()
    (pt,) = engine_sweep(cfg, wl, WAVES, ccs=[t.CC_OCC], grans=(1,),
                         lane_counts=(GLOBAL_LANES,))
    rows.append({"shards": 0, "cc": "occ", "commits": pt.commits,
                 "waves_per_s": WAVES / (time.time() - t0),
                 "coll_bytes_per_wave": 0,
                 # The local engine's read-only split (SweepPoint) rides
                 # the row like the distributed stats split does.
                 "ro_commits": pt.ro_commits, "ro_aborts": pt.ro_aborts,
                 # Attribution: which engine the anchor actually ran on.
                 "backend": BACKEND,
                 "kernel_ops": kernel_coverage(BACKEND, t.CC_OCC)})
    print(f"local  : {rows[0]['waves_per_s']:6.1f} waves/s  "
          f"{pt.commits} commits  (sweep() anchor, no collectives)")

    from repro.core.backend import dist_kernel_coverage
    for cc in ("occ", "mvcc"):
        for ns in (1, 2, 4, 8):
            mesh = jax.make_mesh((ns,), ("data",))
            cfg = D.DistConfig(n_records=N, n_groups=2,
                               lanes_per_shard=GLOBAL_LANES // ns, slots=K,
                               backend=BACKEND, cc=cc,
                               mv_depth=4 if cc != "occ" else 0)
            rng = np.random.default_rng(0)
            keys = jnp.asarray(rng.integers(0, N, (GLOBAL_LANES, K),
                                            dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (GLOBAL_LANES, K),
                                              dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE],
                (GLOBAL_LANES, K)).astype(np.int32))
            tables = D.init_tables(cfg, mesh)
            # ONE compile per grid point: the executable answers the HLO
            # collective-bytes parse AND runs the timed loop (shapes are
            # fixed across waves), so waves/s never includes compile time.
            wave = jax.jit(D.make_wave_fn(cfg, mesh)).lower(
                keys, groups, kinds,
                jnp.zeros((GLOBAL_LANES,), jnp.uint32), tables,
                jnp.uint32(0)).compile()
            coll = collective_bytes_from_hlo(wave.as_text())
            # timed waves (fresh priorities per wave)
            commits = ro_c = ro_a = 0
            t0 = time.time()
            for w in range(WAVES):
                prio = jnp.asarray(
                    np.random.default_rng(w).permutation(GLOBAL_LANES)
                    .astype(np.uint32))
                c, tables, stats = wave(keys, groups, kinds, prio, tables,
                                        jnp.uint32(w))
                commits += int(c.sum())
                s = np.asarray(stats).reshape(ns, D.STATS_LEN)
                ro_c += int(s[:, D.STAT_RO_COMMITS].sum())
                ro_a += int(s[:, D.STAT_RO_ABORTS].sum())
            jax.block_until_ready(tables)
            dt = time.time() - t0
            rows.append({"shards": ns, "cc": cc, "commits": commits,
                         "waves_per_s": WAVES / dt,
                         "coll_bytes_per_wave": coll,
                         "ro_commits": ro_c, "ro_aborts": ro_a,
                         # The routed engine claims/probes/gathers/installs
                         # through the same backend surface as the local
                         # one; only the exchange itself stays shard_map +
                         # XLA collectives.
                         "backend": BACKEND,
                         "kernel_ops": dist_kernel_coverage(BACKEND, cc)})
            print(f"{cc:4s} shards={ns}: {WAVES/dt:6.1f} waves/s  "
                  f"{commits} commits  ro={ro_c}/{ro_a}  "
                  f"coll/wave={coll/1024:.1f} KiB")

    # Open-loop row family (DESIGN.md section 11): the same routed wave
    # behind per-shard admission queues — Poisson arrivals, bounded retry
    # incarnations, goodput (unique committed txns/s of wall time) and
    # p50/p99 time-to-commit in waves from the summed shard histograms.
    from repro.core.admission import ttc_percentiles
    from repro.workloads.arrivals import PoissonArrivals

    def gen_fn_for(seed_base, n_total):
        def gen(w):
            rng = np.random.default_rng(seed_base + w)
            keys = jnp.asarray(rng.integers(0, N, (n_total, K),
                                            dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (n_total, K),
                                              dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE], (n_total, K)).astype(np.int32))
            prio = jnp.asarray(rng.permutation(n_total).astype(np.uint32))
            return keys, groups, kinds, prio
        return gen

    for cc in ("occ", "mvcc"):
        for gran in (0, 1):
            for ns in (1, 8):
                mesh = jax.make_mesh((ns,), ("data",))
                T_loc = GLOBAL_LANES // ns
                cfg = D.DistConfig(n_records=N, n_groups=2,
                                   lanes_per_shard=T_loc, slots=K,
                                   granularity=gran, backend=BACKEND,
                                   cc=cc,
                                   mv_depth=4 if cc != "occ" else 0,
                                   queue_cap=4 * T_loc,
                                   max_incarnations=8, lat_bins=32)
                arr = PoissonArrivals(
                    rate=0.75 * GLOBAL_LANES,
                    seed=7).shard_counts(WAVES, ns, T_loc)
                t0 = time.time()
                s = D.run_open_loop(cfg, mesh, arr, gen_fn_for(5000, GLOBAL_LANES),
                                    WAVES)
                dt = time.time() - t0
                (p50,), (p99,) = ttc_percentiles(
                    s["lat_hist"].sum(axis=0)[None, :])
                rows.append({
                    "shards": ns, "cc": cc, "mode": "open_loop",
                    "granularity": gran,
                    "commits": s["commits"],
                    "waves_per_s": WAVES / dt,
                    "coll_bytes_per_wave": 0,
                    "goodput_txn_per_s": s["commits"] / dt,
                    "p50_ttc_waves": p50, "p99_ttc_waves": p99,
                    "offered": s["offered"], "admitted": s["admitted"],
                    "arrival_drops": s["arrival_drops"],
                    "inc_drops": s["inc_drops"],
                    "queued_final": s["queued_final"],
                    "ro_commits": s["ro_commits"],
                    "ro_aborts": s["ro_aborts"],
                    "backend": BACKEND,
                    "kernel_ops": dist_kernel_coverage(BACKEND, cc)})
                print(f"open {cc:4s} g={gran} shards={ns}: "
                      f"goodput={s['commits']/dt:8.1f} txn/s  "
                      f"p50/p99 ttc={p50:g}/{p99:g} waves  "
                      f"dropped={s['inc_drops']}")
    print("JSON:" + json.dumps(rows))
""")


def main(argv=None):
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, cwd=".", timeout=2400)
    print(r.stdout)
    if r.returncode:
        print(r.stderr[-2000:], file=sys.stderr)
        return 1
    for line in r.stdout.splitlines():
        if line.startswith("JSON:"):
            rows = json.loads(line[5:])
            os.makedirs("reports", exist_ok=True)
            with open("reports/txn_scaling.json", "w") as f:
                json.dump(rows, f, indent=1)
            print("[saved] reports/txn_scaling.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
