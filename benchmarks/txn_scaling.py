"""Beyond-paper: distributed txn-engine scaling (the paper's section 5:
"perform similar evaluations on distributed CC mechanisms").

Runs the shard_map wave on 1/2/4/8 host devices (same *global* lane and
record counts), measuring committed txns per second of wall time and the
per-wave collective bytes — the weak-scaling story of the routed engine —
for BOTH the single-version mechanism (occ) and the sharded multi-version
ring (mvcc: snapshot reads + first-committer-wins over the distributed
version ring of core/mvstore.py).  A ``shards=0`` anchor row first runs
the single-device engine through the vmapped ``sweep()`` grid runner at
the same global lane count, so the table reads "local engine vs N-shard
routed engine".  ``REPRO_TXN_BACKEND`` ("jnp" | "pallas") selects the
kernel-backend surface for BOTH engines — the distributed wave routes its
shard-local route/claim/probe/gather/install through core/backend.py like
the local one — and every row records the resolved backend, the per-op
kernel attribution, the read-only commit/abort split, and the per-cause
abort breakdown the distributed stats vector carries (core/distributed.py
STATS_LEN layout; the six cause slots sum exactly to total aborts).

Every multi-shard grid point runs at TWO pipeline depths through the
scanned ``make_run_fn`` runner (one XLA program per run, so waves/s
measures the wave, not host dispatch): depth 1 — the synchronous
three-exchange wave — and the software-pipelined depth (default 2, ONE
fused all_to_all per steady-state wave; ``--pipeline-depth``).  Rows
carry both the HLO-parsed collective bytes per wave and the modeled wire
split (``route_bytes_per_wave`` / ``verdict_bytes_per_wave`` / the
retired 1-byte-per-op ``verdict_bytes_per_wave_legacy`` baseline the
bit-packed wire beats >= 4x) from ``distributed.wire_bytes_per_wave``.

    PYTHONPATH=src python -m benchmarks.txn_scaling \\
        [--waves N] [--pipeline-depth D] [--shards 1 8] [--json out.json]

``--shards`` (or ``REPRO_TXN_SHARDS=1,8``) subsets the shard sweep — the
CI pallas-interpret smoke runs the 1/8 endpoints only, since every grid
point pays an interpret-mode compile there.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

PROG = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, "src")
    from repro.core import distributed as D, types as t
    from repro.analysis.roofline import collective_bytes_from_hlo

    K, N = 16, 1_000_000
    # Global lane count (kept at the default for real sweeps; the CI
    # pallas-interpret smoke shrinks it — interpret mode validates the
    # kernel semantics inside the pipelined wave, not speed, and its
    # route_pack cost grows superlinearly in the wave size).
    GLOBAL_LANES = int(os.environ.get("REPRO_TXN_LANES", "256"))
    WAVES = int(os.environ.get("REPRO_TXN_WAVES", "30"))
    DEPTH = int(os.environ.get("REPRO_TXN_DEPTH", "2"))
    BACKEND = os.environ.get("REPRO_TXN_BACKEND", "jnp")
    # Shard-count subset (e.g. "1,8" for the CI interpret-mode smoke,
    # where each grid point pays a pallas interpret compile).
    SHARDS = tuple(int(s) for s in os.environ.get(
        "REPRO_TXN_SHARDS", "1,2,4,8").split(","))
    rows = []

    # shards=0 anchor: the local (single-device) engine at the same global
    # lane count, via the one-XLA-program sweep() grid runner.
    from repro.core import types as t
    from repro.core.backend import kernel_coverage
    from repro.core.engine import sweep as engine_sweep
    from repro.workloads import YCSBWorkload
    wl = YCSBWorkload.make(n_keys=N)
    cfg = t.EngineConfig(cc=t.CC_OCC, lanes=GLOBAL_LANES, slots=wl.slots,
                         n_records=wl.n_records, n_groups=wl.n_groups,
                         n_cols=wl.n_cols, n_txn_types=wl.n_txn_types,
                         n_rings=wl.n_rings, backend=BACKEND)
    # Warm call first: the timed call then hits the XLA executable cache and
    # measures (re-trace +) waves rather than a full compile.
    engine_sweep(cfg, wl, WAVES, ccs=[t.CC_OCC], grans=(1,),
                 lane_counts=(GLOBAL_LANES,))
    t0 = time.time()
    (pt,) = engine_sweep(cfg, wl, WAVES, ccs=[t.CC_OCC], grans=(1,),
                         lane_counts=(GLOBAL_LANES,))
    rows.append({"shards": 0, "cc": "occ", "commits": pt.commits,
                 "waves_per_s": WAVES / (time.time() - t0),
                 "coll_bytes_per_wave": 0,
                 # The local engine's read-only split (SweepPoint) rides
                 # the row like the distributed stats split does.
                 "ro_commits": pt.ro_commits, "ro_aborts": pt.ro_aborts,
                 "abort_causes": pt.abort_causes,
                 # Attribution: which engine the anchor actually ran on.
                 "backend": BACKEND,
                 "kernel_ops": kernel_coverage(BACKEND, t.CC_OCC)})
    print(f"local  : {rows[0]['waves_per_s']:6.1f} waves/s  "
          f"{pt.commits} commits  (sweep() anchor, no collectives)")

    from repro.core.backend import dist_kernel_coverage
    for cc in ("occ", "mvcc"):
        for ns in SHARDS:
            mesh = jax.make_mesh((ns,), ("data",))
            # Effective depths at this shard count, deduplicated (1-shard
            # meshes auto-fall back to depth 1 — one row, not two).
            depths = sorted({D.DistConfig(
                n_records=N, lanes_per_shard=GLOBAL_LANES // ns, slots=K,
                cc=cc, mv_depth=4 if cc != "occ" else 0,
                pipeline_depth=d).depth(ns) for d in (1, DEPTH)})
            rng = np.random.default_rng(0)
            keys = jnp.asarray(rng.integers(0, N, (GLOBAL_LANES, K),
                                            dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (GLOBAL_LANES, K),
                                              dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE],
                (GLOBAL_LANES, K)).astype(np.int32))
            Ks = jnp.broadcast_to(keys, (WAVES,) + keys.shape)
            Gs = jnp.broadcast_to(groups, (WAVES,) + groups.shape)
            Is = jnp.broadcast_to(kinds, (WAVES,) + kinds.shape)
            Ps = jnp.asarray(np.stack(
                [np.random.default_rng(w).permutation(GLOBAL_LANES)
                 for w in range(WAVES)]).astype(np.uint32))
            for depth in depths:
                cfg = D.DistConfig(n_records=N, n_groups=2,
                                   lanes_per_shard=GLOBAL_LANES // ns,
                                   slots=K, backend=BACKEND, cc=cc,
                                   mv_depth=4 if cc != "occ" else 0,
                                   pipeline_depth=depth)
                tables = D.init_tables(cfg, mesh)
                # ONE compile per grid point: the scanned runner is one
                # XLA program for all WAVES waves; the executable answers
                # the HLO collective-bytes parse (trip-count aware, so
                # dividing by WAVES yields per-wave bytes) AND runs the
                # timed call — waves/s never includes compile time.
                run = jax.jit(D.make_run_fn(cfg, mesh, WAVES)).lower(
                    Ks, Gs, Is, Ps, tables, jnp.uint32(0)).compile()
                # Per-wave = per-scan-step: the pipelined scan runs three
                # extra drain steps beyond WAVES (each with the same one
                # fused exchange), so divide by the real trip count.
                steps = WAVES + (3 if depth >= 2 else 0)
                coll = collective_bytes_from_hlo(run.as_text()) / steps
                c, tb, stats = run(Ks, Gs, Is, Ps, tables, jnp.uint32(0))
                jax.block_until_ready(tb)          # warm (cached) call
                t0 = time.time()
                c, tb, stats = run(Ks, Gs, Is, Ps, tables, jnp.uint32(0))
                jax.block_until_ready(tb)
                dt = time.time() - t0
                commits = int(np.asarray(c).sum())
                s = np.asarray(stats).reshape(WAVES, ns, D.STATS_LEN)
                ro_c = int(s[:, :, D.STAT_RO_COMMITS].sum())
                ro_a = int(s[:, :, D.STAT_RO_ABORTS].sum())
                # Per-cause abort breakdown summed over waves x shards;
                # conserves exactly: sum == total aborts at every depth.
                causes = [int(x) for x
                          in s[:, :, D.STAT_CAUSES].sum(axis=(0, 1))]
                wire = D.wire_bytes_per_wave(cfg, mesh)
                rows.append({"shards": ns, "cc": cc, "commits": commits,
                             "waves_per_s": WAVES / dt,
                             "pipeline_depth": depth,
                             "coll_bytes_per_wave": coll,
                             "ro_commits": ro_c, "ro_aborts": ro_a,
                             "abort_causes": causes,
                             # The routed engine claims/probes/gathers/
                             # installs through the same backend surface
                             # as the local one; only the exchange itself
                             # stays shard_map + XLA collectives.
                             "backend": BACKEND,
                             "kernel_ops": dist_kernel_coverage(BACKEND,
                                                                cc),
                             **wire})
                print(f"{cc:4s} shards={ns} depth={depth}: "
                      f"{WAVES/dt:6.1f} waves/s  {commits} commits  "
                      f"ro={ro_c}/{ro_a}  coll/wave={coll/1024:.1f} KiB  "
                      f"wire/wave={wire['wire_bytes_per_wave']/1024:.1f} "
                      f"KiB")

    # Open-loop row family (DESIGN.md section 11): the same routed wave
    # behind per-shard admission queues — Poisson arrivals, bounded retry
    # incarnations, goodput (unique committed txns/s of wall time) and
    # p50/p99 time-to-commit in waves from the summed shard histograms.
    # Multi-shard points run at the pipelined depth (run_open_loop scans
    # ONE fused-exchange program); retries land two waves later there, the
    # conservation identities stay exact at every depth.
    from repro.core.admission import ttc_percentiles
    from repro.workloads.arrivals import PoissonArrivals

    def gen_fn_for(seed_base, n_total):
        def gen(w):
            rng = np.random.default_rng(seed_base + w)
            keys = jnp.asarray(rng.integers(0, N, (n_total, K),
                                            dtype=np.int32))
            groups = jnp.asarray(rng.integers(0, 2, (n_total, K),
                                              dtype=np.int32))
            kinds = jnp.asarray(rng.choice(
                [t.READ, t.WRITE], (n_total, K)).astype(np.int32))
            prio = jnp.asarray(rng.permutation(n_total).astype(np.uint32))
            return keys, groups, kinds, prio
        return gen

    for cc in ("occ", "mvcc"):
        for gran in (0, 1):
            for ns in [n for n in (1, 8) if n in SHARDS]:
                mesh = jax.make_mesh((ns,), ("data",))
                T_loc = GLOBAL_LANES // ns
                cfg = D.DistConfig(n_records=N, n_groups=2,
                                   lanes_per_shard=T_loc, slots=K,
                                   granularity=gran, backend=BACKEND,
                                   cc=cc,
                                   mv_depth=4 if cc != "occ" else 0,
                                   pipeline_depth=DEPTH,
                                   queue_cap=4 * T_loc,
                                   max_incarnations=8, lat_bins=32)
                arr = PoissonArrivals(
                    rate=0.75 * GLOBAL_LANES,
                    seed=7).shard_counts(WAVES, ns, T_loc)
                t0 = time.time()
                s = D.run_open_loop(cfg, mesh, arr, gen_fn_for(5000, GLOBAL_LANES),
                                    WAVES)
                dt = time.time() - t0
                (p50,), (p99,) = ttc_percentiles(
                    s["lat_hist"].sum(axis=0)[None, :])
                rows.append({
                    "shards": ns, "cc": cc, "mode": "open_loop",
                    "granularity": gran,
                    "pipeline_depth": cfg.depth(ns),
                    "commits": s["commits"],
                    "waves_per_s": WAVES / dt,
                    "coll_bytes_per_wave": 0,
                    "goodput_txn_per_s": s["commits"] / dt,
                    "p50_ttc_waves": p50, "p99_ttc_waves": p99,
                    "offered": s["offered"], "admitted": s["admitted"],
                    "arrival_drops": s["arrival_drops"],
                    "inc_drops": s["inc_drops"],
                    "queued_final": s["queued_final"],
                    "ro_commits": s["ro_commits"],
                    "ro_aborts": s["ro_aborts"],
                    "abort_causes": s["abort_causes"],
                    "backend": BACKEND,
                    "kernel_ops": dist_kernel_coverage(BACKEND, cc),
                    **D.wire_bytes_per_wave(cfg, mesh)})
                print(f"open {cc:4s} g={gran} shards={ns} "
                      f"depth={cfg.depth(ns)}: "
                      f"goodput={s['commits']/dt:8.1f} txn/s  "
                      f"p50/p99 ttc={p50:g}/{p99:g} waves  "
                      f"dropped={s['inc_drops']}")
    print("JSON:" + json.dumps(rows))
""")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=None,
                    help="waves per grid point (default 30)")
    ap.add_argument("--pipeline-depth", type=int, default=None,
                    help="software-pipeline depth of the second depth "
                         "sweep (default 2; 1 collapses the sweep to the "
                         "synchronous wave only)")
    ap.add_argument("--shards", type=int, nargs="+", default=None,
                    help="shard counts to sweep (default 1 2 4 8; the "
                         "open-loop family keeps its 1/8 endpoints "
                         "intersected with this set)")
    ap.add_argument("--json", default="reports/txn_scaling.json")
    args = ap.parse_args(argv)
    # Presence-validated: the flags are optional, but a PROVIDED value
    # must be sane (argparse type=int already rejects non-integers).
    if args.waves is not None and args.waves < 1:
        ap.error(f"--waves must be >= 1, got {args.waves}")
    if args.pipeline_depth is not None and args.pipeline_depth < 1:
        ap.error(f"--pipeline-depth must be >= 1 (1 = synchronous wave), "
                 f"got {args.pipeline_depth}")
    if args.shards is not None and any(
            s < 1 or s > 8 or s & (s - 1) for s in args.shards):
        ap.error(f"--shards must be powers of two in [1, 8] (the forced "
                 f"host-device count), got {args.shards}")
    env = dict(os.environ)
    if args.waves is not None:
        env["REPRO_TXN_WAVES"] = str(args.waves)
    if args.pipeline_depth is not None:
        env["REPRO_TXN_DEPTH"] = str(args.pipeline_depth)
    if args.shards is not None:
        env["REPRO_TXN_SHARDS"] = ",".join(str(s) for s in args.shards)
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, cwd=".", timeout=2400, env=env)
    print(r.stdout)
    if r.returncode:
        print(r.stderr[-2000:], file=sys.stderr)
        return 1
    for line in r.stdout.splitlines():
        if line.startswith("JSON:"):
            rows = json.loads(line[5:])
            out_dir = os.path.dirname(args.json)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"[saved] {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
