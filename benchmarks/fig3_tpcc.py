"""Paper Figure 3: TPC-C, 8 warehouses fixed (contention grows with thread
count), coarse (3a) vs fine (3b) timestamps.

    PYTHONPATH=src python -m benchmarks.fig3_tpcc [--ratios] [--full]

Validated claims (paper section 4.3):
  3a: TicToc gains over OCC as contention increases (through T=96);
      TicToc degrades at 128 threads, losing to 2PL.
  3b: OCC fastest at almost all core counts; fine granularity lifts all.
  ratios: OCC+fine >= 1.37x TicToc+coarse @ 96;
          OCC+fine >= 1.14x TicToc+fine  @ 128.
"""
from __future__ import annotations

import argparse

from benchmarks.common import LANES, one, save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale TPC-C tables")
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--ratios", action="store_true")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--json", default="reports/fig3_tpcc.json")
    args = ap.parse_args(argv)

    scale = 1.0
    print(f"# Fig 3a (coarse) + 3b (fine), 8 warehouses, scale={scale} "
          f"[{args.backend} backend, one jitted grid]")
    rows = sweep("tpcc", waves=args.waves, scale=scale,
                 backend=args.backend, warm=True)
    save_rows(rows, args.json)

    occ96f = one(rows, cc="occ", granularity=1, lanes=96)["throughput"]
    tic96c = one(rows, cc="tictoc", granularity=0, lanes=96)["throughput"]
    occ128f = one(rows, cc="occ", granularity=1, lanes=128)["throughput"]
    tic128f = one(rows, cc="tictoc", granularity=1, lanes=128)["throughput"]
    occ64c = one(rows, cc="occ", granularity=0, lanes=64)["throughput"]
    tic64c = one(rows, cc="tictoc", granularity=0, lanes=64)["throughput"]
    tic128c = one(rows, cc="tictoc", granularity=0, lanes=128)["throughput"]
    tpl128c = one(rows, cc="2pl", granularity=0, lanes=128)["throughput"]

    print(f"3a: TicToc/OCC coarse @64: {tic64c/occ64c:.2f}x (paper: >1)")
    print(f"3a: 2PL/TicToc coarse @128: {tpl128c/tic128c:.2f}x (paper: >1)")
    print(f"ratio: OCC-fine@96 / TicToc-coarse@96 = "
          f"{occ96f/tic96c:.2f}x (paper: 1.37x)")
    print(f"ratio: OCC-fine@128 / TicToc-fine@128 = "
          f"{occ128f/tic128f:.2f}x (paper: 1.14x)")
    # Beyond-paper: the multi-version pair on the same grid.  TPC-C's
    # write-write conflicts are same-group (stock), so pure-SI mvcc is
    # granularity-flat here — but serializable MV-OCC validates reads and
    # inherits the New-order/Payment false-conflict structure: its
    # fine/coarse gap mirrors OCC's, i.e. granularity still matters in the
    # multi-version world.
    mvc = one(rows, cc="mvocc", granularity=0, lanes=128)["throughput"]
    mvf = one(rows, cc="mvocc", granularity=1, lanes=128)["throughput"]
    print(f"mv: mvocc fine/coarse @128 = {mvf/mvc:.2f}x "
          "(granularity still matters without read-only aborts)")
    return rows


if __name__ == "__main__":
    main()
