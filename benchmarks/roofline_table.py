"""Roofline table: one row per (arch x shape x mesh) from the dry-run
reports + the analytic model (EXPERIMENTS.md section Roofline).

    PYTHONPATH=src python -m benchmarks.roofline_table \
        --reports reports/dryrun --out reports/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:6.2f}ms"
    return f"{x*1e6:6.1f}us"


def build_table(report_dir: str):
    from repro import configs
    from repro.analysis.roofline import analytic_cell
    from repro.configs.base import SHAPES

    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skip":
            rows.append({**rec, "skip": True})
            continue
        if rec.get("status") != "ok" or rec["arch"] == "txn-engine":
            if rec.get("arch") == "txn-engine" and rec.get("status") == "ok":
                rows.append({**rec, "engine": True})
            continue
        cfg = configs.get(rec["arch"])
        shape = SHAPES[rec["shape"]]
        chips = 512 if rec["mesh"] == "multi" else 256
        cell = analytic_cell(cfg, shape, chips, tp=16,
                             coll_bytes=rec.get("collective_bytes", 0.0),
                             arch=rec["arch"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "chips": chips,
            "compute_s": cell.compute_s, "memory_s": cell.memory_s,
            "collective_s": cell.collective_s,
            "bottleneck": cell.bottleneck,
            "usefulness": cell.usefulness,
            "roofline_frac": cell.roofline_frac,
            "flops": cell.flops, "model_flops": cell.model_flops,
            "hlo_flops": rec.get("flops", 0.0),
            "coll_bytes": rec.get("collective_bytes", 0.0),
            "mem_gib": (rec.get("memory", {}).get("temp_size_in_bytes", 0)
                        or 0) / 2 ** 30,
        })
    return rows


def render(rows, out_path=None):
    hdr = (f"| {'arch':26s} | {'shape':11s} | mesh   | compute | memory  "
           f"| collect | bottleneck | useful | roofline% | temp GiB |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in rows:
        if r.get("skip"):
            lines.append(f"| {r['arch']:26s} | {r['shape']:11s} | "
                         f"{r['mesh']:6s} | skip (see DESIGN.md "
                         f"Arch-applicability) |")
            continue
        if r.get("engine"):
            lines.append(f"| {'txn-engine':26s} | {'wave':11s} | "
                         f"{r['mesh']:6s} | collective bytes "
                         f"{r.get('collective_bytes', 0)/2**20:.1f} MiB/dev |")
            continue
        lines.append(
            f"| {r['arch']:26s} | {r['shape']:11s} | {r['mesh']:6s} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['bottleneck']:10s} "
            f"| {r['usefulness']:5.2f}  | {100*r['roofline_frac']:6.1f}%   "
            f"| {r['mem_gib']:7.2f}  |")
    text = "\n".join(lines)
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            f.write(text + "\n")
        print(f"[saved] {out_path}")
    return text


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.md")
    args = ap.parse_args(argv)
    rows = build_table(args.reports)
    print(render(rows, args.out))
    return rows


if __name__ == "__main__":
    main()
