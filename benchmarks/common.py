"""Shared sweep machinery for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time

CCS = ["occ", "tictoc", "2pl", "swisstm", "adaptive", "mvcc", "mvocc"]
LANES = [8, 16, 32, 64, 96, 128]


def warm_then_time(fn, *args, **kw):
    """The warm-then-time pattern of benchmarks/txn_scaling.py, shared:
    call ``fn`` once to compile and fill every cache (blocking until the
    result is ready), then time a second, fully-warm call.  Returns
    ``(result, seconds)``; the seconds never include compile time — for
    grid sweeps the second call re-executes the compiled-sweep memo
    (core/engine.py _SWEEP_PROGRAMS) instead of re-tracing."""
    import jax
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, time.time() - t0


def sweep(workload: str, *, ccs=None, lanes=None, grans=(0, 1), waves=300,
          scale=1.0, n_keys=1_000_000, seed=1, quiet=False, backend="jnp",
          warm=False, **wl_kw):
    """One jitted sweep over the whole grid (core/engine.py sweep).
    Extra keywords (write_frac, ro_frac, theta, mv_depth) pass through to
    ``run_grid``.  ``warm=True`` runs the grid twice through
    ``warm_then_time`` and rewrites each row's ``wall_s`` from the warm
    second pass, so no emitted row includes compile time."""
    from repro.launch.txn_bench import run_grid
    grid_args = (workload, list(ccs or CCS), tuple(grans),
                 list(lanes or LANES), waves)
    grid_kw = dict(scale=scale, n_keys=n_keys, seed=seed, backend=backend,
                   **wl_kw)
    if warm:
        ret, dt = warm_then_time(run_grid, *grid_args, **grid_kw)
        rows = ret[0] if isinstance(ret, tuple) else ret
        wall = round(dt / max(len(rows), 1), 4)
        for r in rows:
            r["wall_s"] = wall
    else:
        ret = run_grid(*grid_args, **grid_kw)
    # return_points=True (the trace exporters) makes run_grid return
    # (rows, SweepPoints); plain callers get the row list as before.
    rows = ret[0] if isinstance(ret, tuple) else ret
    if not quiet:
        for r in rows:
            line = (f"  {workload} {r['cc']:9s} "
                    f"{'fine' if r['granularity'] else 'coarse'} "
                    f"T={r['lanes']:4d}  "
                    f"thpt={r['throughput']:8.3f}  "
                    f"abort={100*r['abort_rate']:6.2f}%")
            if r.get("open_loop"):
                line += (f"  goodput={r['goodput']:8.3f}  "
                         f"p99ttc={max(r['p99_ttc_waves']):g}w")
            print(line)
    return ret


def save_rows(rows, path):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[saved] {path}")


def by(rows, **kv):
    out = [r for r in rows
           if all(r.get(k) == v for k, v in kv.items())]
    return out


def one(rows, **kv):
    m = by(rows, **kv)
    assert len(m) == 1, (kv, len(m))
    return m[0]
